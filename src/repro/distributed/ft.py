"""Fault-tolerance runtime: heartbeats, straggler detection, elastic
re-meshing, and a supervised train loop with checkpoint/restart.

On a real multi-host deployment these hooks sit on the coordinator; the
logic (detection thresholds, re-mesh planning, restart protocol) is
host-count-agnostic and is what the tests exercise.  The restart path is
the same ``restore_checkpoint(..., shardings=new_mesh_shardings)`` used
in production: a checkpoint written under one mesh restores onto a
differently-shaped mesh (elastic shrink/grow) because leaves are stored
unsharded per-leaf.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.distributed.checkpoint import (CheckpointManager, latest_step,
                                          restore_checkpoint)

__all__ = ["HeartbeatMonitor", "plan_elastic_mesh", "TrainSupervisor",
           "WorkerFailure"]


class WorkerFailure(RuntimeError):
    """Raised by the training step when a (simulated) worker dies."""


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-rank heartbeats; flags dead ranks and stragglers.

    * dead: no heartbeat within ``timeout_s``
    * straggler: step-time > ``straggler_factor`` × median of the fleet
      (the standard mitigation at scale: flag, drain, re-mesh around it)
    """
    n_ranks: int
    timeout_s: float = 10.0
    straggler_factor: float = 2.0

    def __post_init__(self):
        now = time.monotonic()
        self._last: List[float] = [now] * self.n_ranks
        self._step_times: Dict[int, List[float]] = {
            r: [] for r in range(self.n_ranks)}

    def beat(self, rank: int, *, step_time_s: Optional[float] = None,
             now: Optional[float] = None) -> None:
        self._last[rank] = time.monotonic() if now is None else now
        if step_time_s is not None:
            ts = self._step_times[rank]
            ts.append(step_time_s)
            if len(ts) > 32:
                ts.pop(0)

    def dead_ranks(self, *, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [r for r, t in enumerate(self._last)
                if now - t > self.timeout_s]

    def stragglers(self) -> List[int]:
        means = {r: np.mean(ts) for r, ts in self._step_times.items() if ts}
        if len(means) < 2:
            return []
        med = float(np.median(list(means.values())))
        return [r for r, m in means.items()
                if m > self.straggler_factor * med]

    def healthy_ranks(self) -> List[int]:
        bad = set(self.dead_ranks()) | set(self.stragglers())
        return [r for r in range(self.n_ranks) if r not in bad]


def plan_elastic_mesh(n_healthy_chips: int, *, model_parallel: int = 16,
                      min_data: int = 1) -> Tuple[int, int]:
    """Largest (data, model) mesh that fits the surviving chips.

    Keeps the model axis intact (TP degree is baked into layouts) and
    shrinks the data axis — the standard elastic-DP policy.  Returns
    (data, model)."""
    model = model_parallel
    while model > 1 and n_healthy_chips < model:
        model //= 2
    data = max(n_healthy_chips // model, min_data)
    return data, model


@dataclasses.dataclass
class TrainSupervisor:
    """Run a step function under checkpoint/restart supervision.

    ``step_fn(state, step) -> (state, metrics)`` may raise
    ``WorkerFailure`` (node loss).  The supervisor restores the latest
    checkpoint and resumes — deterministically, because the data pipeline
    is keyed by step.  ``on_restart`` lets the caller rebuild meshes /
    re-jit against a shrunk device set before resuming.
    """
    checkpoint_dir: str
    ckpt_every: int = 10
    max_restarts: int = 8

    def run(self, state: Any, step_fn: Callable[[Any, int], Tuple[Any, Dict]],
            n_steps: int, *, start_step: int = 0,
            on_restart: Optional[Callable[[Any, int], Any]] = None,
            ) -> Tuple[Any, List[Dict]]:
        mgr = CheckpointManager(self.checkpoint_dir, every=self.ckpt_every,
                                async_save=False)
        history: List[Dict] = []
        step = start_step
        restarts = 0
        # step-0 checkpoint so the first failure can restart
        mgr.maybe_save(step, state)
        while step < n_steps:
            try:
                state, metrics = step_fn(state, step)
                step += 1
                history.append({"step": step, **metrics})
                mgr.maybe_save(step, state)
            except WorkerFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                restored = latest_step(self.checkpoint_dir)
                state, step, _ = restore_checkpoint(
                    self.checkpoint_dir, state, step=restored)
                if on_restart is not None:
                    state = on_restart(state, step)
        mgr.wait()
        return state, history
