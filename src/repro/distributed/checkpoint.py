"""Sharding-aware checkpointing: save/restore arbitrary pytrees.

Layout (one directory per step, atomic rename commit):

    <dir>/step_000042/
        manifest.json        # treedef, per-leaf dtype/shape, user metadata
        leaf_00000.npy ...   # one .npy per leaf

Design points for the 1000-node target:
  * per-leaf files — each host writes only the leaves it owns (here one
    process owns all, but the layout is host-parallel by construction);
  * restore takes an optional sharding tree and ``device_put``s each leaf
    directly to its (possibly different!) target sharding — this is what
    makes elastic re-mesh restarts work (repro.distributed.ft);
  * atomic: written to ``.tmp-<step>`` then renamed, so a crash mid-save
    never corrupts the latest checkpoint;
  * ``keep`` bounds disk usage.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]


def _tree_paths(tree) -> Tuple[List[str], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        names.append(jax.tree_util.keystr(path))
    return names, treedef


def save_checkpoint(directory: str | Path, step: int, tree: Any, *,
                    metadata: Optional[Dict] = None, keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f".tmp-{step:09d}"
    final = directory / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"step": step, "metadata": metadata or {},
                "treedef": str(treedef), "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "index": i, "file": fname, "path": jax.tree_util.keystr(path),
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)

    # retention
    steps = sorted(p for p in directory.iterdir()
                   if p.name.startswith("step_"))
    for old in steps[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in directory.iterdir()
                   if p.name.startswith("step_"))
    return steps[-1] if steps else None


def restore_checkpoint(directory: str | Path, tree_like: Any, *,
                       step: Optional[int] = None,
                       shardings: Optional[Any] = None
                       ) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like``.  ``shardings`` (same
    structure) lays leaves out on the CURRENT mesh — pass a different
    mesh's shardings to reshard on restore (elastic restart)."""
    directory = Path(directory)
    step = step if step is not None else latest_step(directory)
    assert step is not None, f"no checkpoints under {directory}"
    d = directory / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())

    flat, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(flat) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"target tree has {len(flat)}")
    sh_flat = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(flat))
    leaves = []
    for rec, target, sh in zip(manifest["leaves"], flat, sh_flat):
        arr = np.load(d / rec["file"])
        want_dtype = getattr(target, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return (jax.tree_util.tree_unflatten(treedef, leaves), step,
            manifest["metadata"])


class CheckpointManager:
    """Checkpoint-every-N with optional async (background-thread) saves."""

    def __init__(self, directory: str | Path, *, every: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.directory = Path(directory)
        self.every = every
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree: Any, *,
                   metadata: Optional[Dict] = None) -> bool:
        if step % self.every != 0:
            return False
        self.wait()
        # device_get NOW so training can mutate donated buffers after
        host_tree = jax.tree_util.tree_map(
            lambda l: np.asarray(jax.device_get(l)), tree)
        if self.async_save:
            self._pending = threading.Thread(
                target=save_checkpoint,
                args=(self.directory, step, host_tree),
                kwargs={"metadata": metadata, "keep": self.keep},
                daemon=True)
            self._pending.start()
        else:
            save_checkpoint(self.directory, step, host_tree,
                            metadata=metadata, keep=self.keep)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
