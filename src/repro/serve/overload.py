"""Overload-robustness layer: the scheduler hooks that make an engine
safe to oversubscribe.

``_OverloadMixin`` implements the ``_SlotEngine`` hooks behind demand
paging, preemptive page reclamation, and deadline-aware admission for
any engine that owns a ``_PagedPool`` (``self._pool``) and a channel
with a simulated clock:

* **demand paging** — ``_admit_reserve`` shrinks the admission-time
  page claim from worst-case ``prompt + max_new`` to the padded prompt
  plus one round of speculative headroom, and ``_ensure_slot`` grows a
  live slot's claim just before each round writes new positions.  A
  growth that raises ``kvcache.PoolExhausted`` makes the scheduler
  preempt a victim (scheduler policy: lowest priority, then
  most-remaining-budget) instead of crashing;
* **simulated time** — ``_now``/``_wait`` mirror the channel's
  ``clock_s``, charging explicit waits to ``ServeStats.stall_wait_s``
  so the clock decomposes exactly into transfers + charged waits;
* **resource faults** — ``_tick_resources`` applies a
  ``faults.PressureSchedule`` (scripted page-pool squeezes) at the top
  of every scheduler turn, and ``_on_stall`` waits a drained-but-stuck
  engine out to the schedule's next window edge;
* **deadline admission** — ``_admission_policy`` asks
  ``policy.DeadlineAdmission`` to predict the request's finish time
  from live telemetry and sheds it when the prediction already misses
  its deadline.

The mixin is pure hook overrides + one ``_init_overload`` call from
the engine constructor; the preemption/resume machinery itself lives
in ``serve.scheduler`` (parking committed tokens, replay-based
re-admission) and the page accounting in ``serve.kvcache``.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

from repro.models import transformer as TF
from repro.serve.faults import PressureSchedule
from repro.serve.policy import DeadlineAdmission

__all__ = ["_OverloadMixin"]


class _OverloadMixin:
    """Scheduler-hook implementations for overload-robust serving (see
    the module docstring); mixed into ``CollaborativeServingEngine``
    ahead of ``_SlotEngine`` so these override the scheduler's no-op
    defaults."""

    def _init_overload(self, cfg: TF.LMConfig, *, demand_paged: bool,
                       pressure: Optional[PressureSchedule],
                       admission: Union[DeadlineAdmission, str, None],
                       max_batch: int, initial_ch,
                       spec_acceptance: float,
                       a_bits: Optional[int]) -> None:
        # demand paging: admission reserves only the padded prompt plus
        # one round of speculative headroom; claims grow page-by-page as
        # the sequence crosses boundaries (_ensure_slot) and a mid-round
        # PoolExhausted preempts a victim instead of crashing
        self.demand_paged = bool(demand_paged)
        if self.demand_paged:
            assert self._pool is not None, \
                "demand_paged requires a paged KV layout " \
                "(edge_paged or cloud_paged)"
        self.pressure = pressure
        if admission == "deadline":
            admission = DeadlineAdmission(
                cfg, batch=max_batch, fallback_channel=initial_ch,
                acceptance_prior=spec_acceptance,
                blob_itemsize=(1 if a_bits is not None else 4))
        self.admission: Optional[DeadlineAdmission] = admission or None

    # -- demand paging -------------------------------------------------------
    def _admit_reserve(self, max_news: np.ndarray) -> np.ndarray:
        """Positions past the prompt that admission reserves pages for.
        Worst-case engines reserve the full budget plus speculative
        overshoot (a round's rejected tail can never spill into another
        request's pages); a demand-paged engine reserves only one round
        of speculative headroom — exactly what the first round after
        admission may write — and grows the claim via ``_ensure_slot``,
        which is what makes oversubscribing the pool safe."""
        head = self._round_headroom()
        if self.demand_paged:
            return np.minimum(max_news + head, self._spec_max)
        return max_news + head

    def _round_width(self):
        return self.spec_k

    def _ensure_slot(self, slot, horizon):
        if self._pool is not None and self.demand_paged:
            self._pool.ensure(slot, horizon)

    # -- simulated time + resource faults ------------------------------------
    def _tick_resources(self):
        if self.pressure is not None and self._pool is not None:
            self.pressure.apply(self._pool.allocator, self._now())
        if self._pool is not None:
            # pool-pressure snapshot: benchmarks and the fairness policy
            # read free pages / utilization off stats, not pool privates
            self.stats.observe_pool(self._pool)

    def _now(self):
        return float(getattr(self.channel, "clock_s", 0.0))

    def _wait(self, seconds):
        s = float(seconds)
        if s <= 0:
            return True
        w = getattr(self.channel, "wait", None)
        if w is None:
            return False         # clockless channel: nothing to advance
        w(s)
        self.stats.stall_wait_s += s
        return True

    def _on_stall(self):
        # a drained engine that can't admit is only worth retrying if a
        # pressure window is due to release pages; wait to its next edge
        if self.pressure is None:
            return False
        now = self._now()
        nxt = self.pressure.next_change(now)
        if nxt is None:
            return False
        return self._wait(nxt - now + 1e-9)

    # -- deadline-aware admission --------------------------------------------
    def _admission_policy(self, req, *, now, queue_tokens):
        if self.admission is None or req.deadline_s is None:
            return True
        t = self.admission.predict_finish(
            self.telemetry, now=now, cut=self.cut, spec_k=self.spec_k,
            plen=len(req.prompt), max_new=req.max_new_tokens,
            slots=self.max_batch, queue_tokens=queue_tokens)
        return t <= req.deadline_s
