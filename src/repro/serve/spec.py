"""Speculative draft/verify machinery of the collaborative engine.

With ``spec_k = k > 1`` the serial decode loop restructures into
draft/verify rounds that amortize the channel RTT and per-message
framing over up to k tokens:

1. **Draft (edge, local).**  Starting from the last committed token,
   the edge runs the *full* split model k times at low precision — its
   INT8 prefix over the paged INT8 edge cache, then a lightweight INT8
   copy of the cloud-suffix weights (the same fake-quant lattice the
   prefix uses) over a local *draft* KV cache that shares the edge
   block table.  Each step emits the Eq.(1)-quantized boundary delta
   and greedily drafts the next token from the local suffix.
2. **Uplink (one transfer).**  The edge ships the concatenated
   ``[B, k, D]`` quantized boundary blob — each of the k rows framed
   with its own per-row scale/zero-point so the cloud dequantizes
   exactly what a serial step would have seen — plus the k-1 draft
   tokens the cloud must grade (4 B each).  One channel traversal.
3. **Verify (cloud, one batched step).**  The cloud suffix runs all k
   positions in a single multi-token cached step (the paged kernel's
   q-block form, intra-block causal mask) and takes the longest prefix
   of drafts matching its own greedy tokens: a round commits between 1
   and k tokens and ``k = 1`` degenerates to the non-speculative step.
4. **Rollback (both sides, O(1)).**  Rejected positions are *not*
   erased: both sides keep their per-slot committed length — stale page
   entries are masked by causality and overwritten in place.
5. **Downlink (one transfer).**  The cloud returns the accept mask
   (``ceil(k/8)`` B/row) and the corrected token (4 B/row).

``_SpecDraftMixin`` hosts the jitted implementations; the draft length
k is a trace constant (scan length / verify q-block width), so each k a
policy may pick gets its own jitted pair, built on first use and cached
— an online ``spec_k`` switch after warm-up never recompiles.

Rounds carrying a temperature>0 slot run the ``*_sample`` twins (their
own per-k jit cache): the draft proposes seeded categorical draws from
its filtered distribution ``q`` and ships the graded positions' ``q``
rows alongside the blob (priced as extra uplink), and the verify grades
by **rejection sampling** (``serve.sampling.grade_and_correct``) instead
of argmax match — keeping the cloud's sampling distribution exact while
greedy rows in the same batch still commit bit-identical argmax tokens.

The mixin also hosts the **degradation** phases of the resilient engine
(``serve.resilience``), which reuse the same draft machinery with the
verify removed: when the cloud is unreachable, the edge's INT8 suffix
copy stops *drafting* and starts *serving* — ``_edge_only_step_impl``
is one full local step (prefix → boundary → suffix → token, zero wire
bytes), ``_edge_only_prefill_impl`` admits a request entirely on the
edge, and the two ``_resync_*`` phases replay buffered boundary rows
through the cloud suffix in one multi-token cached step per slot group
(the verify's q-block form with the grading removed) to rebuild its
paged KV on reconnect.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import dequantize
from repro.models import layers as ML
from repro.models import transformer as TF
from repro.serve import sampling as S
from repro.serve.kvcache import _paged_prefill_merge, _paged_prefill_view
from repro.serve.scheduler import _bucket_len, _jit_phase


class _SpecDraftMixin:
    """Draft/verify phase implementations, mixed into
    ``CollaborativeServingEngine`` (which provides cfg, caches, the
    boundary lattice ``_quant_boundary``, and the scheduler hooks) and
    into the per-cut runtimes of ``serve.fleet``.  Every impl operates
    over the *full* slot axis with a block table picking which slots'
    pages are written — which is exactly what lets the fleet engine
    verify many tenants' rounds in ONE batched call: tenants at the
    same (cut, k) share the call, everyone else's rows ride along
    masked to the dump page (``_PagedPool.table_for``)."""

    def _spec_fns(self, k: int):
        if k not in self._spec_jits:
            draft = _jit_phase(partial(self._spec_draft_impl, k),
                               donate=(5, 6))
            verify = _jit_phase(partial(self._verify_impl, k), donate=(6,),
                                mesh=getattr(self, "mesh", None))
            self._spec_jits[k] = (draft, verify)
        return self._spec_jits[k]

    def _spec_sample_fns(self, k: int):
        """Sampled twin of ``_spec_fns``: per-k cached jitted
        (draft, rejection-sampling verify) pair for rounds carrying at
        least one temperature>0 slot.  Greedy rows ride along on the
        argmax branch inside the same call (``serve.sampling``)."""
        if not hasattr(self, "_spec_sample_jits"):
            self._spec_sample_jits: Dict[int, Tuple[Any, Any]] = {}
        if k not in self._spec_sample_jits:
            draft = _jit_phase(partial(self._spec_draft_sample_impl, k),
                               donate=(5, 6))
            verify = _jit_phase(partial(self._verify_sample_impl, k),
                                donate=(7,),
                                mesh=getattr(self, "mesh", None))
            self._spec_sample_jits[k] = (draft, verify)
        return self._spec_sample_jits[k]

    def _draft_prefill_impl(self, blocks, blob, qp, cache, slots, bt_rows,
                            plens):
        """Fill the edge's local draft cache: the INT8 suffix copy runs
        the same dequantized boundary blob the cloud saw, so the draft
        model starts every round from the committed prefix state."""
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2), locally
        n = h.shape[0]
        if self.edge_paged:
            group = _paged_prefill_view(cache, self.n_cloud, n, cfg.n_kv)
            _, group = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                     cache=group, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx,
                                     block_tables=bt_rows,
                                     calibrate_kv=self.edge_int8,
                                     kv_lengths=plens)
            cache = _paged_prefill_merge(cache, group, slots)
        else:
            small = TF.init_cache(cfg, n, self.max_len, layers=self.n_cloud,
                                  quantized=self.edge_int8)
            _, small = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                     cache=small, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx)
            cache = dict(cache, **{k: cache[k].at[:, slots].set(small[k])
                                   for k in ("k", "v")})
        return cache

    def _spec_draft_impl(self, k, edge_blocks, draft_blocks, embed, tail,
                         cur, e_cache, d_cache, pos, bt):
        """k sequential local steps on the edge: INT8 prefix → Eq.(1)
        delta → local INT8 suffix copy → greedy draft token.  One jit'd
        ``lax.scan``, so a whole round costs one dispatch.  Emits the
        stacked ``[k, B, D]`` boundary blob with per-(row, position)
        quant params — bitwise the frames k serial steps would have
        shipped — and the k draft tokens."""
        self.trace_counts["spec_draft"] += 1
        cfg = self.cfg
        rope = self._rope()

        def step(carry, _):
            tok, p, ec, dc = carry
            x = ML.embed(embed, tok[:, None]).astype(cfg.dtype)
            h, ec = TF.run_blocks(edge_blocks, x, cfg, rope=rope, cache=ec,
                                  cache_index=p, qctx=self._edge_qctx,
                                  block_tables=bt)
            blob, qp = self._quant_boundary(h)              # per row
            hq = dequantize(blob, qp).astype(cfg.dtype)  # what the cloud sees
            y, dc = TF.run_blocks(draft_blocks, hq, cfg, rope=rope, cache=dc,
                                  cache_index=p, qctx=self._edge_qctx,
                                  block_tables=bt)
            logits = TF.lm_head(tail, y)[:, 0]
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            p = jnp.minimum(p + 1, self.max_len - 1)
            return (nxt, p, ec, dc), (blob[:, 0], qp.scale, qp.zero_point,
                                      nxt)

        (_, _, e_cache, d_cache), (blobs, scales, zps, drafts) = \
            jax.lax.scan(step, (cur, pos, e_cache, d_cache), None,
                         length=k)
        return blobs, scales, zps, drafts, e_cache, d_cache

    def _spec_draft_sample_impl(self, k, edge_blocks, draft_blocks, embed,
                                tail, cur, e_cache, d_cache, pos, bt, temps,
                                top_ps, seeds, offsets):
        """Sampled draft scan: step i proposes a ``DRAFT``-stream draw
        from the local suffix's filtered distribution ``q`` at absolute
        output index ``offsets + i`` (greedy rows keep the argmax, on
        the same raw logits tensor so their tokens stay bit-identical).
        Also emits the stacked ``[k, B, V]`` f32 ``q`` rows the verify
        grades against — an extra uplink the engine prices per graded
        position (``costmodel.speculative_round_time(draft_q_bytes)``).
        """
        self.trace_counts["spec_draft"] += 1
        cfg = self.cfg
        rope = self._rope()

        def step(carry, i):
            tok, p, ec, dc = carry
            x = ML.embed(embed, tok[:, None]).astype(cfg.dtype)
            h, ec = TF.run_blocks(edge_blocks, x, cfg, rope=rope, cache=ec,
                                  cache_index=p, qctx=self._edge_qctx,
                                  block_tables=bt)
            blob, qp = self._quant_boundary(h)              # per row
            hq = dequantize(blob, qp).astype(cfg.dtype)  # what the cloud sees
            y, dc = TF.run_blocks(draft_blocks, hq, cfg, rope=rope, cache=dc,
                                  cache_index=p, qctx=self._edge_qctx,
                                  block_tables=bt)
            logits = TF.lm_head(tail, y)[:, 0]
            greedy = jnp.argmax(logits, -1).astype(jnp.int32)
            q = S.filtered_probs(logits.astype(jnp.float32), temps, top_ps)
            draw = S.sample_rows(q, S.token_keys(seeds, offsets + i,
                                                 S.DRAFT))
            nxt = jnp.where(temps > 0.0, draw, greedy)
            p = jnp.minimum(p + 1, self.max_len - 1)
            return (nxt, p, ec, dc), (blob[:, 0], qp.scale, qp.zero_point,
                                      nxt, q)

        (_, _, e_cache, d_cache), (blobs, scales, zps, drafts, qs) = \
            jax.lax.scan(step, (cur, pos, e_cache, d_cache), jnp.arange(k))
        return blobs, scales, zps, drafts, qs, e_cache, d_cache

    def _draft_rebuild_impl(self, edge_blocks, draft_blocks, embed, toks,
                            d_cache, slots, bt_rows, plens):
        """Recompute the draft suffix K/V for live slots from committed
        prefix state: re-run the committed rows (prompt + committed
        tokens) through the edge prefix over a *throwaway* dense scratch
        cache — the real edge cache already holds these positions and
        must not be touched — then replay the boundary blob through the
        draft suffix exactly like a draft prefill.  Draft contents only
        steer the acceptance rate, never the committed stream, so the
        dense-scratch attention path is safe here."""
        self.trace_counts["draft_rebuild"] += 1
        cfg = self.cfg
        n, s = toks.shape
        x = ML.embed(embed, toks).astype(cfg.dtype)
        scratch = TF.init_cache(cfg, n, self.max_len, layers=self.n_edge,
                                quantized=self.edge_int8)
        h, _ = TF.run_blocks(edge_blocks, x, cfg, rope=self._rope(),
                             cache=scratch, cache_index=jnp.int32(0),
                             qctx=self._edge_qctx)
        ranged = jnp.where(jnp.arange(s)[None, :, None] <
                           plens[:, None, None], h, h[:, :1])
        blob, qp = self._quant_boundary(h, ranged)
        return self._draft_prefill_impl(draft_blocks, blob, qp, d_cache,
                                        slots, bt_rows, plens)

    def _rebuild_draft_caches(self) -> None:
        """Host driver for a warm k raise (satellite of the mesh PR):
        instead of draining the live slots — whose draft caches were
        never filled during k=1 rounds — rebuild each slot's draft K/V
        from its committed prefix (prompt + committed tokens minus the
        not-yet-processed last one), bucketing rows like admission so
        trace shapes stay bounded."""
        live = self._sched_active
        if not live:
            return
        if not hasattr(self, "_draft_rebuild"):
            self._draft_rebuild = _jit_phase(self._draft_rebuild_impl,
                                             donate=(4,))
        slots = sorted(live)
        rows = []
        for s in slots:
            r, _c = live[s]
            committed = self._sched_committed(r)
            rows.append(np.concatenate([np.asarray(r.prompt, np.int32),
                                        committed[:-1].astype(np.int32)]))
        order = sorted(range(len(slots)), key=lambda i: len(rows[i]))
        i = 0
        while i < len(order):
            bucket = _bucket_len(len(rows[order[i]]), self.max_len)
            grp = [order[i]]
            i += 1
            while i < len(order) and _bucket_len(
                    len(rows[order[i]]), self.max_len) == bucket:
                grp.append(order[i])
                i += 1
            toks = np.zeros((len(grp), bucket), np.int32)
            for j, g in enumerate(grp):
                toks[j, :len(rows[g])] = rows[g]
            plens = np.asarray([len(rows[g]) for g in grp], np.int32)
            gslots = np.asarray([slots[g] for g in grp], np.int32)
            bt_rows = None
            if self._pool is not None:
                bt_rows = self._pool.rows(gslots, bucket)
            self._draft_cache = self._draft_rebuild(
                self.edge_blocks, self.draft_blocks, self.embed,
                jnp.asarray(toks), self._draft_cache, jnp.asarray(gslots),
                bt_rows, jnp.asarray(plens))
        self.stats.draft_rebuilds += 1

    # -- degradation phases (serve.resilience) ------------------------------
    def _edge_only_step_impl(self, edge_blocks, draft_blocks, embed, tail,
                             cur, e_cache, d_cache, pos, bt):
        """One full local step: INT8 prefix → Eq.(1) boundary → INT8
        suffix copy → greedy token.  Identical math to one unrolled
        ``_spec_draft_impl`` iteration — which is what makes edge-only
        tokens bit-identical to cloud tokens in the lossless mode — but
        also emits the dequantized f32 boundary row, which the resilient
        engine buffers for the resync replay, and the quantized
        ``(blob, qp)`` frame so a round that loses its uplink mid-flight
        can commit the already-computed step without re-running it."""
        self.trace_counts["edge_only"] += 1
        cfg = self.cfg
        rope = self._rope()
        x = ML.embed(embed, cur[:, None]).astype(cfg.dtype)
        h, e_cache = TF.run_blocks(edge_blocks, x, cfg, rope=rope,
                                   cache=e_cache, cache_index=pos,
                                   qctx=self._edge_qctx, block_tables=bt)
        blob, qp = self._quant_boundary(h)
        hq = dequantize(blob, qp)                 # Eq.(2): the cloud's view
        y, d_cache = TF.run_blocks(draft_blocks, hq.astype(cfg.dtype), cfg,
                                   rope=rope, cache=d_cache, cache_index=pos,
                                   qctx=self._edge_qctx, block_tables=bt)
        logits = TF.lm_head(tail, y)[:, 0]
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        new_pos = jnp.minimum(pos + 1, self.max_len - 1)
        return blob, qp, hq[:, 0].astype(jnp.float32), nxt, e_cache, \
            d_cache, new_pos

    def _edge_only_prefill_impl(self, blocks, tail, blob, qp, cache, slots,
                                bt_rows, plens, cur, pos):
        """Admit a request with the cloud down: the draft suffix plays
        the cloud's role — same boundary blob, local lm_head — so the
        slot starts generating immediately with zero wire bytes."""
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)
        n = h.shape[0]
        group = _paged_prefill_view(cache, self.n_cloud, n, cfg.n_kv)
        y, group = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=group, cache_index=jnp.int32(0),
                                 qctx=self._edge_qctx, block_tables=bt_rows,
                                 calibrate_kv=self.edge_int8,
                                 kv_lengths=plens)
        cache = _paged_prefill_merge(cache, group, slots)
        logits = TF.lm_head(tail, y[jnp.arange(n), plens - 1][:, None])[:, 0]
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _edge_only_step_sample_impl(self, edge_blocks, draft_blocks, embed,
                                    tail, cur, e_cache, d_cache, pos, bt,
                                    temps, top_ps, seeds, offsets):
        """Sampled edge-only step: the committed token is a ``CLOUD``-
        stream draw from the draft suffix's filtered distribution — the
        *same* key the cloud's serial step would consume at this output
        index, so in the lossless mode (identical suffix logits) the
        degraded stream reproduces the cloud's sampled stream bitwise,
        and a post-resync replay can never fork it."""
        self.trace_counts["edge_only"] += 1
        cfg = self.cfg
        rope = self._rope()
        x = ML.embed(embed, cur[:, None]).astype(cfg.dtype)
        h, e_cache = TF.run_blocks(edge_blocks, x, cfg, rope=rope,
                                   cache=e_cache, cache_index=pos,
                                   qctx=self._edge_qctx, block_tables=bt)
        blob, qp = self._quant_boundary(h)
        hq = dequantize(blob, qp)                 # Eq.(2): the cloud's view
        y, d_cache = TF.run_blocks(draft_blocks, hq.astype(cfg.dtype), cfg,
                                   rope=rope, cache=d_cache, cache_index=pos,
                                   qctx=self._edge_qctx, block_tables=bt)
        logits = TF.lm_head(tail, y)[:, 0]
        greedy = jnp.argmax(logits, -1).astype(jnp.int32)
        p = S.filtered_probs(logits.astype(jnp.float32), temps, top_ps)
        draw = S.sample_rows(p, S.token_keys(seeds, offsets, S.CLOUD))
        nxt = jnp.where(temps > 0.0, draw, greedy)
        new_pos = jnp.minimum(pos + 1, self.max_len - 1)
        return blob, qp, hq[:, 0].astype(jnp.float32), nxt, e_cache, \
            d_cache, new_pos

    def _edge_only_prefill_sample_impl(self, blocks, tail, blob, qp, cache,
                                       slots, bt_rows, plens, cur, pos,
                                       temps, top_ps, seeds):
        """Sampled twin of ``_edge_only_prefill_impl``: the first token
        (absolute output index 0) is the same ``CLOUD``-stream draw the
        cloud's own sampled prefill would commit."""
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)
        n = h.shape[0]
        group = _paged_prefill_view(cache, self.n_cloud, n, cfg.n_kv)
        y, group = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=group, cache_index=jnp.int32(0),
                                 qctx=self._edge_qctx, block_tables=bt_rows,
                                 calibrate_kv=self.edge_int8,
                                 kv_lengths=plens)
        cache = _paged_prefill_merge(cache, group, slots)
        logits = TF.lm_head(tail, y[jnp.arange(n), plens - 1][:, None])[:, 0]
        greedy = jnp.argmax(logits, -1).astype(jnp.int32)
        p = S.filtered_probs(logits.astype(jnp.float32), temps, top_ps)
        draw = S.sample_rows(p, S.token_keys(seeds, jnp.zeros_like(seeds),
                                             S.CLOUD))
        cur = cur.at[slots].set(jnp.where(temps > 0.0, draw, greedy))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _resync_replay_impl(self, blocks, h, cache, pos, bt):
        """Rebuild the cloud suffix KV for slots that were live before
        the outage: one multi-token cached step over the ``[B, R, D]``
        buffered boundary rows at each slot's own resume position
        (vector ``cache_index`` — the verify's q-block form).  Slots not
        in the replay group ride along with a zeroed block-table row, so
        their (masked) writes land in the allocator's dump page."""
        self.trace_counts["resync"] += 1
        cfg = self.cfg
        _, cache = TF.run_blocks(blocks, h.astype(cfg.dtype), cfg,
                                 rope=self._rope(), cache=cache,
                                 cache_index=pos, block_tables=bt)
        return cache

    def _resync_prefill_impl(self, blocks, h, cache, slots, bt_rows, lens):
        """Rebuild the cloud suffix KV for slots *admitted during* the
        outage: prefill-style from position 0, calibrating the per-slot
        INT8 scales the cloud never got to compute (every buffered row
        is a real token — no bucket padding — so ``lens`` spans them
        all)."""
        self.trace_counts["resync"] += 1
        cfg = self.cfg
        n = h.shape[0]
        group = _paged_prefill_view(cache, self.n_cloud, n, cfg.n_kv)
        _, group = TF.run_blocks(blocks, h.astype(cfg.dtype), cfg,
                                 rope=self._rope(), cache=group,
                                 cache_index=jnp.int32(0),
                                 block_tables=bt_rows,
                                 calibrate_kv=self.cloud_int8,
                                 kv_lengths=lens)
        return _paged_prefill_merge(cache, group, slots)

    def _verify_impl(self, k, blocks, tail, blobs, scales, zps, drafts,
                     cache, pos, bt):
        """One batched multi-token cloud step over all k drafted
        positions, with longest-prefix acceptance: position i's greedy
        token ``t_i`` is compared against draft ``d_i``; the round
        commits ``t_1..t_{j+1}`` where j is the number of leading
        matches — the token at the first divergence is the *corrected*
        token, so every round commits at least one exact greedy token.
        Rejected cache positions are rolled back by the returned
        per-slot position (a length decrement; stale page entries stay
        masked by causality until overwritten)."""
        self.trace_counts["verify"] += 1
        cfg = self.cfg
        # Eq.(2) per (row, position): same lattice the serial path ships
        h = (blobs.astype(jnp.float32) - zps[..., None]) * scales[..., None]
        h = h.transpose(1, 0, 2).astype(cfg.dtype)              # [B, k, D]
        x, cache = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 block_tables=bt)
        logits = TF.lm_head(tail, x)                            # [B, k, V]
        t = jnp.argmax(logits, -1).astype(jnp.int32)            # [B, k]
        d = drafts.T                                            # [B, k]
        ok = (d[:, :k - 1] == t[:, :k - 1]).astype(jnp.int32)
        n_commit = 1 + jnp.sum(jnp.cumprod(ok, axis=1), axis=1)  # [B]
        new_cur = jnp.take_along_axis(t, (n_commit - 1)[:, None],
                                      axis=1)[:, 0]
        new_pos = jnp.minimum(pos + n_commit, self.max_len - 1)
        return t, n_commit, new_cur, cache, new_pos

    def _verify_sample_impl(self, k, blocks, tail, blobs, scales, zps,
                            drafts, qs, cache, pos, bt, temps, top_ps, seeds,
                            offsets):
        """Rejection-sampling verify: the same batched multi-token cloud
        step as ``_verify_impl``, graded by ``sampling.grade_and_correct``
        — sampled rows accept draft i with prob ``min(1, p_i(d)/q_i(d))``
        and correct from the normalized residual (bonus draw from ``p``
        if all graded drafts survive), greedy rows grade by argmax match
        and commit the identical tokens the greedy verify would.  The
        committed stream is distributionally exact vs serial cloud
        sampling (see ``serve.sampling``)."""
        self.trace_counts["verify"] += 1
        cfg = self.cfg
        h = (blobs.astype(jnp.float32) - zps[..., None]) * scales[..., None]
        h = h.transpose(1, 0, 2).astype(cfg.dtype)              # [B, k, D]
        x, cache = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 block_tables=bt)
        logits = TF.lm_head(tail, x)                            # [B, k, V]
        t = jnp.argmax(logits, -1).astype(jnp.int32)            # [B, k]
        d = drafts.T                                            # [B, k]
        B, _, V = logits.shape
        p = S.filtered_probs(logits.astype(jnp.float32).reshape(B * k, V),
                             jnp.repeat(temps, k),
                             jnp.repeat(top_ps, k)).reshape(B, k, V)
        q = qs.transpose(1, 0, 2)                               # [B, k, V]
        toks, n_commit = S.grade_and_correct(p, q, d, temps > 0.0, t,
                                             seeds, offsets)
        new_cur = jnp.take_along_axis(toks, (n_commit - 1)[:, None],
                                      axis=1)[:, 0]
        new_pos = jnp.minimum(pos + n_commit, self.max_len - 1)
        return toks, n_commit, new_cur, cache, new_pos
