"""Cloud-only batched serving engine (the non-collaborative baseline).

One KV cache over the full stack: dense fp by default, ``paged=True``
for the block-table page pool, ``int8_kv=True`` for 1 B/elem storage
with per-slot scales calibrated at prefill.  Rides the same
``_SlotEngine`` continuous-batching scheduler as the collaborative
engine."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as TF
from repro.serve.kvcache import (_PagedPool, _paged_prefill_merge,
                                 _paged_prefill_view)
from repro.serve.scheduler import _jit_phase, _SlotEngine
from repro.serve.sharding import place_cloud_engine

Params = Any


class ServingEngine(_SlotEngine):
    """Cloud-only batched engine (greedy decode, continuous batching).

    ``paged=True`` swaps the dense per-slot cache for the block-table
    page pool (+ ``int8_kv=True`` for 1 B/elem pages with per-slot
    scales); ``cache_dtype`` overrides the dense cache's storage dtype
    (e.g. bf16 for the fp16-cache baseline in the benchmarks);
    ``mesh`` TP-shards the params and KV pool over its ``model`` axis
    (see ``serve.sharding``) and runs every phase under the mesh."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *,
                 max_batch: int = 4, max_len: int = 128,
                 paged: bool = False, page_size: int = 16,
                 int8_kv: bool = False, num_pages: Optional[int] = None,
                 cache_dtype=None, timed: bool = False,
                 mesh: Optional[jax.sharding.Mesh] = None):
        super().__init__(cfg, max_batch=max_batch, max_len=max_len,
                         timed=timed)
        self.mesh = mesh
        self.params = params
        self.paged = paged
        self.page_size = page_size
        self.int8_kv = int8_kv
        if paged:
            self._pool = _PagedPool.build(max_batch, max_len, page_size,
                                          num_pages)
            self._cache = TF.init_cache(
                self.cfg, max_batch, max_len, paged=True,
                page_size=page_size, quantized=int8_kv,
                num_pages=self._pool.allocator.num_pages, dtype=cache_dtype)
            self._prefill = _jit_phase(self._paged_prefill_impl, donate=(2,),
                                       mesh=mesh)
        else:
            self._cache = TF.init_cache(self.cfg, max_batch, max_len=max_len,
                                        dtype=cache_dtype,
                                        quantized=int8_kv)
            self._prefill = _jit_phase(self._prefill_impl, donate=(2,),
                                       mesh=mesh)
        self._decode = _jit_phase(self._decode_impl, donate=(2,), mesh=mesh)
        if mesh is not None:
            place_cloud_engine(self)

    def _prefill_impl(self, params, toks, cache, slots, cur, pos, plens):
        self.trace_counts["prefill"] += 1
        n, _ = toks.shape
        small = TF.init_cache(self.cfg, n, max_len=self.max_len,
                              quantized=self.int8_kv,
                              dtype=cache["k"].dtype)
        logits, small = TF.prefill(params, toks, self.cfg, cache=small,
                                   last_pos=plens - 1)
        cache = dict(cache, **{k: cache[k].at[:, slots].set(small[k])
                               for k in ("k", "v")})
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _paged_prefill_impl(self, params, toks, cache, bt_rows, slots, cur,
                            pos, plens):
        self.trace_counts["prefill"] += 1
        group = _paged_prefill_view(cache, self.cfg.n_layers, toks.shape[0],
                                    self.cfg.n_kv)
        logits, group = TF.prefill(params, toks, self.cfg, cache=group,
                                   block_tables=bt_rows, last_pos=plens - 1)
        cache = _paged_prefill_merge(cache, group, slots)
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _decode_impl(self, params, cur, cache, pos, bt):
        self.trace_counts["decode"] += 1
        logits, cache = TF.decode_step(params, cur, cache, pos, self.cfg,
                                       block_tables=bt)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return nxt, cache, jnp.minimum(pos + 1, self.max_len - 1)

    def _admit(self, toks, plens, max_news, slots, cur, pos, samplings=None):
        assert not any(s is not None and s.sampled
                       for s in (samplings or [])), \
            "cloud-only baseline is greedy; sampled serving lives in " \
            "CollaborativeServingEngine (serve.sampling)"
        if self.paged:
            bt_rows = self._pool.admit(slots, plens, max_news, toks.shape[1])
            self._cache, cur, pos = self._prefill(
                self.params, toks, self._cache, bt_rows, jnp.asarray(slots),
                cur, pos, jnp.asarray(plens))
        else:
            self._cache, cur, pos = self._prefill(
                self.params, toks, self._cache, jnp.asarray(slots), cur, pos,
                jnp.asarray(plens))
        return cur, pos

    def _decode_all(self, cur, pos, n_active):
        bt = self._pool.table_dev() if self.paged else None
        cur, self._cache, pos = self._decode(self.params, cur,
                                             self._cache, pos, bt)
        return cur, pos

    def _retire(self, slot):
        if self.paged:
            self._pool.retire(slot)

    def _can_admit(self, group_shapes, plen, max_new, bucket):
        if not self.paged:
            return True
        return self._pool.can_admit(group_shapes + [(plen, max_new)], bucket)

    def cache_bytes(self, *, live_only: bool = False) -> int:
        """Cache footprint in bytes.  ``live_only`` counts just the
        pages currently allocated to requests (the demand-paging win)."""
        if self.paged and live_only:
            return self._pool.live_cache_bytes(self._cache)
        return sum(v.size * v.dtype.itemsize for v in self._cache.values())
