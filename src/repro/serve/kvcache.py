"""Paged-KV bookkeeping (host side) for the serving engines.

KV cache layouts (see ``transformer.init_cache`` for shapes):

* **dense** — every slot owns ``max_len`` positions up front; the
  decode einsum streams the whole ``[B, max_len]`` cache each step.
* **paged** — slots own a block-table row into a shared page pool
  (``PageAllocator``); HBM is claimed page-by-page at admission and
  returned at retirement, and reads run the paged flash kernel
  (``kernels.paged_attention``) whose cost scales with *allocated*
  pages, not ``max_len``.
* **paged + INT8** — pages store 1 B/elem with per-slot symmetric
  scales calibrated from each prompt at prefill (paper Eq.1 applied to
  serving state); dequantization happens inside the kernel's QK/AV
  loops so the cache never materializes above 1 B/elem.

The pool's geometry depends only on ``(max_batch, max_len, page_size)``
— never on the collaborative cut — so a live re-partition
(``policy.AdaptivePolicy``) keeps the allocator, the block table, and
every slot's page claim; only the per-layer cache arrays are rebuilt.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


class PoolExhausted(RuntimeError):
    """Typed "no free pages" failure of ``PageAllocator.alloc``.

    Subclasses ``RuntimeError`` so pre-existing callers that catch the
    bare exhaustion keep working; the overload-robust scheduler catches
    it specifically — a mid-round exhaustion triggers victim preemption
    (``scheduler._SlotEngine``), never a crash."""


class PageAllocator:
    """LIFO free-list allocator over a fixed pool of KV-cache pages.

    Page 0 is never handed out: retired/idle slots keep a zeroed block
    table row, so their (masked, harmless) decode writes land in page 0
    instead of corrupting a page that has been re-allocated to a live
    request.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least one allocatable page"
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))
        self._live: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def live(self) -> frozenset:
        return frozenset(self._live)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(
                f"KV page pool exhausted: need {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._live.update(pages)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p not in self._live:
                raise ValueError(
                    f"free of page {p} which is not live (double free, or "
                    f"a page this allocator never handed out)")
            self._live.remove(p)
            self._free.append(p)


class _PagedPool:
    """Block table + allocator for one engine-side page pool.

    Pages for a request are claimed at admission — by default enough to
    cover its padded prompt plus its (known) generation budget, plus any
    speculative-round headroom; a demand-paged engine reserves only the
    padded prompt plus one round of headroom and grows the claim with
    ``ensure`` as the sequence crosses page boundaries — and returned
    the moment the scheduler retires (or preempts) the slot.  The
    collaborative engine shares one pool (one block table) across its
    edge-prefix, cloud-suffix, and draft caches: all three see identical
    page geometry, so a verify-round rollback is the same length
    decrement on every cache.
    """

    def __init__(self, max_batch: int, pages_per_slot: int, num_pages: int,
                 page_size: int):
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        self.allocator = PageAllocator(num_pages)
        self.bt = np.zeros((max_batch, pages_per_slot), np.int32)
        self._slot_pages: Dict[int, List[int]] = {}
        self._dev: Optional[jax.Array] = None
        # per-owner (tenant) page accounting for the fleet engine's
        # weighted-fair sharing: admission tags each slot with an owner,
        # ensure()-growth and retirement keep the count current
        self._slot_owner: Dict[int, str] = {}
        self._owner_pages: Dict[str, int] = {}
        self._masked: Dict[Tuple[int, ...], jax.Array] = {}

    @classmethod
    def build(cls, max_batch: int, max_len: int, page_size: int,
              num_pages: Optional[int] = None) -> "_PagedPool":
        """Standard sizing: worst case ``max_batch`` full-length slots
        plus the reserved dump page.

        **Intentional-undersizing contract**: an explicit ``num_pages``
        below the standard sizing bounds *concurrency*, never
        feasibility — admission backpressures until retirements return
        pages (``scheduler._SlotEngine._can_admit``), and a demand-paged
        engine additionally oversubscribes the pool against worst-case
        budgets and preempts on ``PoolExhausted``.  A pool that cannot
        hold even one max-length slot (``pages_per_slot``) plus the
        reserved dump page can never serve anything and is rejected
        here, at construction, instead of stalling the first request."""
        pages_per_slot = _cdiv(max_len, page_size)
        if num_pages is None:
            num_pages = max_batch * pages_per_slot + 1
        elif num_pages < pages_per_slot + 1:
            raise ValueError(
                f"KV page pool num_pages={num_pages} can never admit a "
                f"single max-length slot: max_len={max_len} at "
                f"page_size={page_size} needs pages_per_slot="
                f"{pages_per_slot} plus the reserved dump page "
                f"(>= {pages_per_slot + 1}); undersizing below "
                f"{max_batch * pages_per_slot + 1} only bounds concurrency")
        return cls(max_batch, pages_per_slot, num_pages, page_size)

    def pages_needed(self, plen: int, max_new: int, padded_len: int) -> int:
        return _cdiv(max(int(plen) + int(max_new), int(padded_len)),
                     self.page_size)

    def can_admit(self, shapes: Sequence[Tuple[int, int]],
                  padded_len: int) -> bool:
        """Would a prefill group of (plen, max_new) shapes fit the free
        list right now?"""
        return sum(self.pages_needed(p, m, padded_len)
                   for p, m in shapes) <= self.allocator.num_free

    def live_cache_bytes(self, cache: Dict[str, jax.Array]) -> int:
        """Bytes resident in currently-allocated pages (+ scales) of the
        paged ``cache`` this pool indexes — the demand-paging footprint,
        as opposed to the pool's capacity."""
        per_page = int(np.prod(cache["k_pages"].shape[2:])) \
            * cache["k_pages"].dtype.itemsize
        n_layers = cache["k_pages"].shape[0]
        scales = sum(v.size * v.dtype.itemsize
                     for k, v in cache.items() if "scale" in k)
        return 2 * n_layers * len(self.allocator.live) * per_page + scales

    def admit(self, slots: Sequence[int], plens: Sequence[int],
              max_news: Sequence[int], padded_len: int,
              owner: Optional[str] = None) -> jax.Array:
        """Allocate pages for a prefill group; returns the group's block
        table rows [n, pages_per_slot].  ``owner`` tags the slots for
        per-tenant page accounting (``owner_pages``)."""
        for s, pl_, mn in zip(slots, plens, max_news):
            pages = self.allocator.alloc(
                self.pages_needed(pl_, mn, padded_len))
            self._slot_pages[int(s)] = pages
            if owner is not None:
                self._slot_owner[int(s)] = owner
                self._owner_pages[owner] = \
                    self._owner_pages.get(owner, 0) + len(pages)
            self.bt[s, :] = 0
            self.bt[s, :len(pages)] = pages
        self._dev = None
        self._masked.clear()
        # trim to the pages the padded prompt can touch: the prefill's
        # q-block read costs O(table width), so handing it the full
        # pages_per_slot row would make prefill scale with max_len
        # instead of the bucket (the generation's later pages are only
        # reachable by decode, which re-reads through table_dev)
        width = max(1, _cdiv(padded_len, self.page_size))
        # explicit copy: jax on CPU may zero-copy-alias numpy buffers, and
        # ``bt`` is mutated on the host while async decode steps are still
        # in flight — sharing it would race
        return jnp.array(self.bt[np.asarray(slots)][:, :width], copy=True)

    def rows(self, slots: Sequence[int], padded_len: int) -> jax.Array:
        """Current block-table rows for ``slots``, trimmed like ``admit``
        to the pages a ``padded_len``-position replay can touch — for
        rebuilding a cache over positions the slots already own (the
        draft-cache rebuild on a warm k raise).  Copied, never aliased,
        for the same async-mutation reason as ``admit``."""
        width = max(1, _cdiv(int(padded_len), self.page_size))
        return jnp.array(self.bt[np.asarray(slots)][:, :width], copy=True)

    def pages_held(self, slot: int) -> int:
        return len(self._slot_pages.get(int(slot), ()))

    def ensure(self, slot: int, n_positions: int) -> bool:
        """Demand-grow ``slot``'s page claim to cover ``n_positions``
        cache positions; returns True iff new pages were allocated.
        Raises ``PoolExhausted`` — with the slot's existing claim and
        block-table row untouched — when the free list cannot cover the
        growth, which is the scheduler's cue to preempt a victim."""
        s = int(slot)
        pages = self._slot_pages.get(s)
        assert pages is not None, f"slot {s} holds no pages"
        need = _cdiv(int(n_positions), self.page_size)
        if need <= len(pages):
            return False
        grown = self.allocator.alloc(need - len(pages))
        self.bt[s, len(pages):need] = grown
        pages.extend(grown)
        owner = self._slot_owner.get(s)
        if owner is not None:
            self._owner_pages[owner] += len(grown)
        self._dev = None
        self._masked.clear()
        return True

    def retire(self, slot: int) -> None:
        pages = self._slot_pages.pop(int(slot), None)
        if pages is not None:
            self.allocator.free(pages)
            owner = self._slot_owner.pop(int(slot), None)
            if owner is not None:
                self._owner_pages[owner] -= len(pages)
            self.bt[slot, :] = 0
            self._dev = None
            self._masked.clear()

    # -- pool-pressure observability (public: no private poking) -------------
    def free_pages(self) -> int:
        """Allocatable pages on the free list right now — the quantity
        admission backpressure and the fairness policy key off."""
        return self.allocator.num_free

    def utilization(self) -> float:
        """Fraction of allocatable pages currently claimed by live slots
        (the reserved dump page is excluded from the denominator)."""
        cap = self.allocator.num_pages - 1
        return (cap - self.allocator.num_free) / max(cap, 1)

    def owner_pages(self, owner: str) -> int:
        """Pages currently held by ``owner``-tagged slots (see ``admit``)."""
        return self._owner_pages.get(owner, 0)

    def slot_owner(self, slot: int) -> Optional[str]:
        return self._slot_owner.get(int(slot))

    def table_dev(self) -> jax.Array:
        """Block table on device, trimmed to the pages actually in use
        (rounded up to a power of two, so decode retraces are bounded by
        log2(pages_per_slot) widths, not every occupancy) — the decode
        read then costs O(allocated pages), not O(max_len).  Cached
        until the next admit/retire.  Copied, never aliased: the host
        mutates ``bt`` while earlier async decode steps may still be
        reading the device buffer."""
        if self._dev is None:
            used = max((len(p) for p in self._slot_pages.values()),
                       default=1)
            width = 1
            while width < used:
                width *= 2
            width = min(width, self.pages_per_slot)
            self._dev = jnp.array(self.bt[:, :width], copy=True)
        return self._dev

    def table_for(self, slots: Sequence[int]) -> jax.Array:
        """Like ``table_dev`` but with every row *outside* ``slots``
        zeroed, so slots riding along in somebody else's batched phase
        call write into the allocator's reserved dump page instead of
        their own pages — the convention the resync replay established,
        now the backbone of the fleet engine's cross-tenant batched
        rounds (a (cut, k) group's phase call spans the full slot axis
        but must only touch the group's pages).  Cached per group until
        the next admit/ensure/retire invalidates the table."""
        key = tuple(sorted(int(s) for s in slots))
        if key not in self._masked:
            full = np.asarray(self.table_dev())
            masked = np.zeros_like(full)
            masked[list(key)] = full[list(key)]
            self._masked[key] = jnp.array(masked, copy=True)
        return self._masked[key]


def _paged_prefill_view(cache: Dict[str, jax.Array], n_layers: int, n: int,
                        n_kv: int) -> Dict[str, jax.Array]:
    """Group-local view of a paged cache for one prefill call: the
    shared page pool plus fresh scale rows for the ``n``-row group (the
    prefill calibrates them; scatter back with _paged_prefill_merge)."""
    group = {"k_pages": cache["k_pages"], "v_pages": cache["v_pages"]}
    if "k_scale" in cache:
        group["k_scale"] = jnp.zeros((n_layers, n, n_kv), jnp.float32)
        group["v_scale"] = jnp.zeros_like(group["k_scale"])
    return group


def _paged_prefill_merge(cache: Dict[str, jax.Array],
                         group: Dict[str, jax.Array],
                         slots: jax.Array) -> Dict[str, jax.Array]:
    cache = dict(cache, k_pages=group["k_pages"], v_pages=group["v_pages"])
    if "k_scale" in cache:
        cache["k_scale"] = cache["k_scale"].at[:, slots].set(
            group["k_scale"])
        cache["v_scale"] = cache["v_scale"].at[:, slots].set(
            group["v_scale"])
    return cache
