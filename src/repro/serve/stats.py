"""``ServeStats`` — the per-phase serving counters every engine
populates (split out of ``serve.transport``, which re-exports it; the
accounting *semantics* — what counts as uplink/downlink/decode bytes —
are documented there, next to the code that does the charging)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence


@dataclasses.dataclass
class ServeStats:
    """Per-phase serving counters (see ``serve.transport`` for the
    accounting semantics).

    ``drafted_tokens`` / ``draft_hits`` grade the speculative drafts the
    verify step compared (k-1 per round per live slot), giving
    ``acceptance_rate`` — under rejection-sampling verify a "hit" is an
    *accepted* draft, so the same counters price stochastic acceptance.
    ``bytes_per_decode_token`` is uplink bytes per accepted token;
    ``wire_bytes_per_accepted_token`` adds the decode downlink.
    ``spec_k_switches``/``cut_switches`` count online retune events
    applied by a ``serve.policy`` controller.

    ``prefill_s``/``decode_s`` are wall-clock phase totals, populated
    when the engine runs with ``timed=True`` (timing blocks on device
    results, so it is off by default to keep the decode loop fully
    async).

    The fault counters are populated by ``ReliableTransport`` and the
    resilient engine (``serve.resilience``): ``retries`` counts
    retransmission attempts after a deadline miss or checksum failure,
    ``timeouts`` counts the deadline misses themselves, ``corrupt_msgs``
    counts messages whose checksum failed on arrival, ``outage_s`` is
    simulated time spent with the cloud declared down, and
    ``edge_only_tokens``/``resyncs`` count tokens committed with zero
    wire bytes during degradation and the cloud KV rebuilds on
    reconnect.  Retransmissions' bytes and waiting are charged to
    ``transmitted_bytes``/``channel_latency_s`` like any other traffic —
    a lossy link is priced, not hidden."""
    prefill_calls: int = 0
    decode_steps: int = 0
    transmitted_bytes: int = 0
    channel_latency_s: float = 0.0
    # per-phase splits
    prefill_bytes: int = 0
    decode_bytes: int = 0
    decode_bytes_log: List[int] = dataclasses.field(default_factory=list)
    downlink_bytes: int = 0
    decode_downlink_bytes: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    # speculative draft/verify rounds
    spec_rounds: int = 0
    drafted_tokens: int = 0
    draft_hits: int = 0
    # online re-tuning events (serve.policy)
    spec_k_switches: int = 0
    cut_switches: int = 0
    # warm k-raise path: ``draft_rebuilds`` counts draft-cache rebuilds
    # from committed prefix state (raising out of k=1 with live slots no
    # longer drains); ``policy_holds`` counts scheduler turns admission
    # actually paused on a policy barrier (now only cut re-partitions)
    draft_rebuilds: int = 0
    policy_holds: int = 0
    # reliability layer (serve.faults / ReliableTransport / resilience)
    retries: int = 0
    timeouts: int = 0
    corrupt_msgs: int = 0
    outage_s: float = 0.0
    edge_only_tokens: int = 0
    resyncs: int = 0
    # overload robustness (serve.scheduler / serve.policy / faults):
    # ``preemptions`` counts live slots suspended to reclaim their pages,
    # ``shed`` counts requests refused at admission because their
    # predicted finish already missed their deadline, ``deadline_misses``
    # counts served requests that finished late anyway, ``queue_wait_s``
    # is total simulated time requests spent between (re-)enqueue and
    # admission, and ``stall_wait_s`` is simulated time the scheduler
    # itself idled — waiting out page-pool pressure or a gap until the
    # next request arrival.  The simulated clock decomposes exactly:
    # every advance is either a charged transfer (``channel_latency_s``)
    # or a charged scheduler wait (``stall_wait_s``) — property-tested
    # in ``tests/test_overload_serve.py``.
    preemptions: int = 0
    shed: int = 0
    deadline_misses: int = 0
    queue_wait_s: float = 0.0
    stall_wait_s: float = 0.0
    # pool-pressure snapshot (multi-tenant fleet serving): engines that
    # own a ``kvcache._PagedPool`` refresh these each scheduler turn via
    # ``observe_pool`` so benchmarks and the fairness policy read pool
    # pressure off a stats snapshot instead of poking pool privates
    pool_free_pages: int = -1          # -1 = engine has no paged pool
    pool_utilization: float = 0.0
    pool_utilization_peak: float = 0.0

    def observe_pool(self, pool) -> None:
        """Snapshot a ``_PagedPool``'s pressure (free pages, utilization,
        peak utilization) onto this stats object."""
        self.pool_free_pages = pool.free_pages()
        self.pool_utilization = pool.utilization()
        self.pool_utilization_peak = max(self.pool_utilization_peak,
                                         self.pool_utilization)

    @classmethod
    def aggregate(cls, parts: Sequence["ServeStats"]) -> "ServeStats":
        """Fleet-wide rollup of per-tenant stats: counters sum, the pool
        snapshot (shared pool — identical on every tenant) carries the
        worst case.  ``decode_bytes_log`` concatenates in input order."""
        total = cls()
        for p in parts:
            for f in dataclasses.fields(cls):
                if f.name == "decode_bytes_log":
                    total.decode_bytes_log.extend(p.decode_bytes_log)
                elif f.name == "pool_free_pages":
                    total.pool_free_pages = (
                        p.pool_free_pages if total.pool_free_pages < 0
                        else min(total.pool_free_pages,
                                 max(p.pool_free_pages, 0)))
                elif f.name.startswith("pool_utilization"):
                    setattr(total, f.name,
                            max(getattr(total, f.name), getattr(p, f.name)))
                else:
                    setattr(total, f.name,
                            getattr(total, f.name) + getattr(p, f.name))
        return total

    def bytes_per_decode_token(self) -> float:
        """Decode *uplink* bytes per accepted token (PR 1/PR 2 metric)."""
        return self.decode_bytes / max(self.decode_tokens, 1)

    def wire_bytes_per_accepted_token(self) -> float:
        """Both directions per accepted token: uplink deltas + drafts
        and the downlink accept-mask + corrected token."""
        return (self.decode_bytes + self.decode_downlink_bytes) \
            / max(self.decode_tokens, 1)

    def acceptance_rate(self) -> float:
        """Fraction of graded speculative drafts the verify accepted."""
        return self.draft_hits / max(self.drafted_tokens, 1)

    def report(self) -> Dict[str, float]:
        return {
            "prefill_calls": self.prefill_calls,
            "decode_steps": self.decode_steps,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "accepted_tokens": self.decode_tokens,
            "transmitted_bytes": self.transmitted_bytes,
            "prefill_bytes": self.prefill_bytes,
            "decode_bytes": self.decode_bytes,
            "downlink_bytes": self.downlink_bytes,
            "bytes_per_decode_token": self.bytes_per_decode_token(),
            "wire_bytes_per_accepted_token":
                self.wire_bytes_per_accepted_token(),
            "spec_rounds": self.spec_rounds,
            "drafted_tokens": self.drafted_tokens,
            "acceptance_rate": self.acceptance_rate(),
            "spec_k_switches": self.spec_k_switches,
            "cut_switches": self.cut_switches,
            "draft_rebuilds": self.draft_rebuilds,
            "policy_holds": self.policy_holds,
            "channel_latency_s": self.channel_latency_s,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "retries": self.retries,
            "timeouts": self.timeouts,
            "corrupt_msgs": self.corrupt_msgs,
            "outage_s": self.outage_s,
            "edge_only_tokens": self.edge_only_tokens,
            "resyncs": self.resyncs,
            "preemptions": self.preemptions,
            "shed": self.shed,
            "deadline_misses": self.deadline_misses,
            "queue_wait_s": self.queue_wait_s,
            "stall_wait_s": self.stall_wait_s,
            "pool_free_pages": self.pool_free_pages,
            "pool_utilization": self.pool_utilization,
            "pool_utilization_peak": self.pool_utilization_peak,
        }
