"""Channel framing, wire accounting, and link telemetry for serving.

Everything that touches the simulated wireless channel lives here:

* the framing constants (``_QP_BYTES``/``_TOK_BYTES``/``_MSG_BYTES``) —
  canonical values come from ``core.costmodel`` so the engine's
  accounting and the cost model's round predictions can never drift
  apart;
* ``ServeStats`` — the per-phase byte/token/latency counters both
  engines populate (class body in ``serve.stats``, re-exported here);
* ``Transport`` — the charge/account methods the collaborative engine
  calls for every uplink blob and downlink return;
* ``LinkTelemetry`` — online EWMA estimates of the observed bandwidth,
  RTT, and draft acceptance, the measurement half of the
  telemetry → policy → engine control loop (``serve.policy``);
* ``DriftingChannel`` — a channel whose (bandwidth, rtt) follow a
  schedule over simulated time, for exercising that loop.

Accounting semantics (shared by every engine):

``transmitted_bytes`` is the total over the wire — prefill and decode
uplinks plus every cloud→edge downlink, each *message* carrying its
``_MSG_BYTES`` protocol header on top of the payload (headers, like the
RTT, are paid per traversal — the quantity a draft/verify round
amortizes k-fold).  ``decode_bytes`` is the decode-phase *uplink*:
per-row-quantized boundary deltas plus, in speculative rounds, the 4 B
draft-token ids the cloud grades.  ``downlink_bytes`` counts the return
direction — the sampled/corrected token (4 B/row) plus, in speculative
rounds, the byte-packed accept mask.  Prefill uplinks are charged by
each request's *true* prompt length — bucket padding is a compile-shape
artifact and never crosses the wire.  ``decode_tokens`` counts
**accepted (committed) tokens**.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.costmodel import Channel, MSG_BYTES, QP_BYTES, TOK_BYTES
# counters live in serve.stats; re-exported here because transport is
# their historical home and every engine/test imports them from here
from repro.serve.stats import ServeStats

# wire framing overhead for one quantized blob: f32 scale + f32 zero-point
_QP_BYTES = int(QP_BYTES)
# wire bytes for one token id (cloud→edge return / edge→cloud draft)
_TOK_BYTES = int(TOK_BYTES)
# per-*message* protocol framing (TCP/IP-class headers + slot ids/round
# counter): every channel traversal pays it once, which is exactly what a
# draft/verify round amortizes k-fold alongside the RTT
_MSG_BYTES = int(MSG_BYTES)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


class LinkTelemetry:
    """Online estimates of the link and the draft quality, from the
    traffic the engine sends anyway.

    Every charged message is an ``(nbytes, seconds)`` sample of
    ``seconds = nbytes / bandwidth + rtt`` — a line in ``nbytes`` — so
    an exponentially-weighted least-squares fit over the message stream
    recovers ``1/bandwidth`` (slope) and ``rtt`` (intercept).  Message
    sizes naturally span two orders of magnitude (prefill blobs vs
    per-round deltas vs 4 B token returns), which is what makes the
    regression well-conditioned; when recent traffic degenerates to one
    size the last well-conditioned estimate is held.  EWMA weighting
    makes the estimate track channel drift with a ~``1/alpha``-message
    memory.

    Draft/verify rounds contribute ``(graded, hits)`` samples giving an
    EWMA draft acceptance rate for ``autotune.tune_spec_k``, and every
    reliable-transport attempt contributes a delivered/lost sample
    giving an EWMA ``loss_rate`` — the expected-retransmit multiplier
    ``costmodel`` prices lossy links with.
    """

    # no physical last hop beats ~1 TB/s: a degenerate sample pair can
    # otherwise drive the fitted slope to ~0 and the bandwidth estimate
    # to absurdity (see observe_transfer's guard)
    BW_CEILING_BYTES_PER_S = 1e12

    def __init__(self, alpha: float = 0.25, min_samples: int = 4):
        self.alpha = alpha
        self.min_samples = min_samples
        self.n_samples = 0
        self.n_rounds = 0
        self._mx = self._my = self._mxx = self._mxy = 0.0
        self._bw: Optional[float] = None
        self._rtt: Optional[float] = None
        self._acc: Optional[float] = None
        self._loss: Optional[float] = None

    # -- observations -------------------------------------------------------
    def observe_transfer(self, nbytes: float, seconds: float) -> None:
        x, y = float(nbytes), float(seconds)
        # zero-duration samples carry no line information (the idealized
        # infinite channel) and, mixed with real samples, can drag the
        # fitted slope through zero — absurd bandwidth estimates
        if x <= 0 or y <= 0:
            return
        if self.n_samples == 0:
            self._mx, self._my = x, y
            self._mxx, self._mxy = x * x, x * y
        else:
            a = self.alpha
            self._mx += a * (x - self._mx)
            self._my += a * (y - self._my)
            self._mxx += a * (x * x - self._mxx)
            self._mxy += a * (x * y - self._mxy)
        self.n_samples += 1
        var = self._mxx - self._mx * self._mx
        cov = self._mxy - self._mx * self._my
        # refresh the held estimate only while the fit is well-conditioned
        if self.n_samples >= self.min_samples \
                and var > 1e-9 * max(self._mx * self._mx, 1.0) and cov > 0:
            slope = cov / var                       # seconds per byte
            self._bw = min(1.0 / slope, self.BW_CEILING_BYTES_PER_S)
            self._rtt = max(0.0, self._my - slope * self._mx)

    def observe_round(self, graded: int, hits: int) -> None:
        """One verify round's ``(graded drafts, accepted drafts)``.

        A round that graded drafts and accepted **none** of them is a
        first-class ``r = 0.0`` sample — it moves the EWMA toward zero
        (and *sets* the estimate when it is the very first sample), it
        is never conflated with a no-sample round.  Only ``graded <= 0``
        — a k=1 serial round, which grades nothing — is skipped: there
        is no draft evidence to learn from.  Rejection-sampling verify
        makes all-rejected rounds routine at high temperature, so this
        distinction is pinned by a unit test
        (``tests/test_sampled_spec.py``)."""
        if graded <= 0:
            return
        r = min(max(hits, 0), graded) / graded   # clamp defensively
        self._acc = r if self._acc is None \
            else self._acc + self.alpha * (r - self._acc)
        self.n_rounds += 1

    def observe_delivery(self, delivered: bool) -> None:
        """One reliable-transport attempt: EWMA of the loss indicator."""
        x = 0.0 if delivered else 1.0
        self._loss = x if self._loss is None \
            else self._loss + self.alpha * (x - self._loss)

    # -- estimates ----------------------------------------------------------
    @property
    def bandwidth_bytes_per_s(self) -> Optional[float]:
        return self._bw

    @property
    def rtt_s(self) -> Optional[float]:
        return self._rtt

    @property
    def loss_rate(self) -> float:
        return 0.0 if self._loss is None else self._loss

    def acceptance(self, prior: float = 0.8) -> float:
        return prior if self._acc is None else self._acc

    def channel(self, fallback: Channel) -> Channel:
        """The estimated channel, or ``fallback`` until the regression
        has locked on.  Carries the measured ``loss_rate`` either way,
        so the policy prices retransmissions even before the bandwidth
        fit converges."""
        if self._bw is None:
            return fallback if self._loss is None else dataclasses.replace(
                fallback, loss_rate=self.loss_rate)
        return Channel(bandwidth_bytes_per_s=self._bw, rtt_s=self._rtt or 0.0,
                       loss_rate=self.loss_rate, name="telemetry")


class DriftingChannel:
    """A channel whose conditions follow a schedule over *simulated*
    time (the cumulative transfer time it has charged), e.g. ::

        DriftingChannel([(0.0, Channel.from_kbps(2000, rtt_ms=20)),
                         (5.0, Channel.from_kbps(200, rtt_ms=150)),
                         (15.0, Channel.from_kbps(2000, rtt_ms=20))])

    Duck-types ``costmodel.Channel`` (``transfer_time``), so engines and
    telemetry are oblivious; the benchmark uses it to drive the online
    re-tuning loop through a bandwidth/RTT swing.
    """

    def __init__(self, schedule: Sequence[Tuple[float, Channel]]):
        assert schedule and schedule[0][0] == 0.0, \
            "schedule must start at simulated time 0"
        self.schedule = list(schedule)
        self.clock_s = 0.0

    @property
    def phase(self) -> Channel:
        cur = self.schedule[0][1]
        for t0, ch in self.schedule:
            if self.clock_s >= t0:
                cur = ch
        return cur

    @property
    def name(self) -> str:
        return f"drift[{self.phase.name}]"

    def transfer_time(self, nbytes: float) -> float:
        t = self.phase.transfer_time(nbytes)
        self.clock_s += t
        return t

    def wait(self, seconds: float) -> None:
        """Sender-side time passing (scheduler stalls, arrival gaps) —
        advances the schedule clock, the same convention as
        ``faults.FaultyChannel.wait``."""
        self.clock_s += max(0.0, float(seconds))


class Transport:
    """The collaborative engine's side of the wire: owns the channel and
    the telemetry, charges every message to a ``ServeStats``.

    ``stats`` is passed per call (not owned) so callers can swap in a
    fresh ``ServeStats`` between measurement windows without severing
    the telemetry, which deliberately accumulates across windows — it is
    an estimate of the *link*, not of any one run."""

    def __init__(self, channel: Optional[Channel] = None,
                 telemetry: Optional[LinkTelemetry] = None):
        self.channel = channel or Channel(bandwidth_bytes_per_s=float("inf"))
        self.telemetry = telemetry or LinkTelemetry()

    def _transfer(self, stats: ServeStats, nbytes: int) -> float:
        """Move one message across the channel; returns the seconds the
        sender spent on it.  ``ReliableTransport`` overrides this with
        the deadline/retry machinery — every ``charge``/``account_*``
        path goes through here, so reliability is a transport swap, not
        an engine change."""
        t = self.channel.transfer_time(nbytes)
        self.telemetry.observe_transfer(nbytes, t)
        return t

    def charge(self, stats: ServeStats, nbytes: int, *, phase: str,
               log: bool = True) -> None:
        """One uplink message of ``nbytes`` (header included by caller
        or via the ``account_*`` wrappers)."""
        t = self._transfer(stats, nbytes)
        stats.transmitted_bytes += int(nbytes)
        stats.channel_latency_s += t
        if phase == "prefill":
            stats.prefill_bytes += int(nbytes)
        else:
            stats.decode_bytes += int(nbytes)
            if log:
                stats.decode_bytes_log.append(int(nbytes))

    def account_blob(self, stats: ServeStats, blob: jax.Array, *, phase: str,
                     rows: Optional[int] = None,
                     row_elems=None) -> None:
        """Charge the wire for the occupied batch rows of ``blob``.

        The jit'd decode step always computes the full fixed-shape
        [max_batch, 1, D] delta, but idle slots would never be sent, so
        the simulated wire carries only the active rows — each framed
        with its own Eq.(1) scale/zero-point (per-row quantization).
        ``row_elems`` overrides the per-row payload element count: the
        prefill blob is bucket-padded on device, but only each request's
        true prompt activations cross the wire."""
        itemsize = blob.dtype.itemsize
        if row_elems is not None:
            nbytes = int(sum(int(e) * itemsize + _QP_BYTES
                             for e in row_elems))
        else:
            n_rows = blob.shape[0] if rows is None else rows
            per_row = (blob.size // blob.shape[0]) * itemsize
            nbytes = n_rows * (per_row + _QP_BYTES)
        self.charge(stats, nbytes + _MSG_BYTES, phase=phase)

    def account_downlink(self, stats: ServeStats, n_rows: int, *, k: int = 1,
                         phase: str = "decode") -> None:
        """The cloud→edge return: the sampled (or corrected) token per
        live request, plus — when a round verified k > 1 drafts — the
        accept mask (one bit per draft, byte-packed).  The edge can't
        start the next round until it arrives, so every round pays this
        second transfer and its channel RTT.  Counted in
        ``transmitted_bytes``/``downlink_bytes``, never in the uplink
        ``decode_bytes`` split."""
        nbytes = n_rows * (_TOK_BYTES + (_cdiv(k, 8) if k > 1 else 0)) \
            + _MSG_BYTES
        t = self._transfer(stats, nbytes)
        stats.transmitted_bytes += nbytes
        stats.channel_latency_s += t
        stats.downlink_bytes += nbytes
        if phase == "decode":
            stats.decode_downlink_bytes += nbytes


def checksum(payload) -> int:
    """CRC32 of a boundary blob (or any array/bytes) — the integrity
    check a receiver runs before acking a message.  The simulator's
    ``FaultyChannel`` flags corruption explicitly so the hot path never
    syncs a device blob to hash it, but the mechanism is this one, and
    the chaos tests exercise it on real payloads."""
    if isinstance(payload, (bytes, bytearray, memoryview)):
        return zlib.crc32(payload) & 0xFFFFFFFF
    return zlib.crc32(np.ascontiguousarray(payload).tobytes()) & 0xFFFFFFFF


class CloudUnreachable(RuntimeError):
    """Raised by ``ReliableTransport`` when a message exhausts its retry
    budget — the signal on which a resilient engine declares the cloud
    down and degrades to edge-only serving."""


class ReliableTransport(Transport):
    """``Transport`` with sequencing, deadlines, and bounded retries.

    Every message gets a sequence number (``seq``) — retransmissions
    reuse it, so the receiver can both discard duplicates and ack a
    retransmitted copy of an earlier send (which is what makes a
    downlink loss after a committed verify harmless: the state advanced,
    only the ack is re-requested).  A send's deadline comes from the
    link telemetry — ``margin *`` the EWMA-fit prediction
    ``nbytes/bandwidth + rtt`` — so the timeout tightens as the
    estimate locks on; until then a fixed ``fallback_deadline_s``
    applies.  A miss (silent drop, outage, or an arrival past the
    deadline) costs the sender the full deadline of waiting, then an
    exponentially backed-off, seeded-jitter pause before the retransmit;
    a checksum failure retransmits immediately.  All of it is charged:
    waiting to ``channel_latency_s``, events to the
    ``retries``/``timeouts``/``corrupt_msgs`` counters, and every
    attempt to the telemetry's loss EWMA.  After ``max_retries``
    retransmits the send raises ``CloudUnreachable``.

    Channels without an ``attempt`` method (the plain deterministic
    ``Channel``/``DriftingChannel``) degenerate to the base transport —
    reliability is free when nothing fails."""

    def __init__(self, channel=None, telemetry: Optional[LinkTelemetry] = None,
                 *, max_retries: int = 3, deadline_margin: float = 3.0,
                 fallback_deadline_s: float = 0.5, min_deadline_s: float = 0.01,
                 backoff_base_s: float = 0.02, backoff_max_s: float = 1.0,
                 seed: int = 0):
        super().__init__(channel, telemetry)
        self.max_retries = max_retries
        self.deadline_margin = deadline_margin
        self.fallback_deadline_s = fallback_deadline_s
        self.min_deadline_s = min_deadline_s
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self._rng = np.random.default_rng(seed)
        self.seq = 0

    def deadline_for(self, nbytes: float) -> float:
        bw, rtt = self.telemetry.bandwidth_bytes_per_s, self.telemetry.rtt_s
        if bw is None:
            return self.fallback_deadline_s
        return max(self.min_deadline_s,
                   self.deadline_margin * (nbytes / bw + (rtt or 0.0)))

    def _backoff(self, attempt: int) -> float:
        base = min(self.backoff_max_s, self.backoff_base_s * (2 ** attempt))
        return base * (1.0 + float(self._rng.random()))   # full jitter

    def _transfer(self, stats: ServeStats, nbytes: int) -> float:
        attempt = getattr(self.channel, "attempt", None)
        if attempt is None:
            return super()._transfer(stats, nbytes)
        self.seq += 1
        deadline = self.deadline_for(nbytes)
        wait = getattr(self.channel, "wait", lambda s: None)
        spent = 0.0
        for i in range(self.max_retries + 1):
            out = attempt(nbytes)
            ok = out.delivered and not out.corrupt \
                and out.seconds <= deadline
            self.telemetry.observe_delivery(ok)
            if ok:
                self.telemetry.observe_transfer(nbytes, out.seconds)
                return spent + out.seconds
            if out.delivered and out.corrupt:
                stats.corrupt_msgs += 1          # caught at arrival: resend
                spent += out.seconds
            else:
                stats.timeouts += 1              # discovered at the deadline
                pause = max(0.0, deadline - out.seconds) \
                    if out.delivered else deadline
                wait(pause)
                spent += out.seconds + pause
            if i < self.max_retries:
                stats.retries += 1
                back = self._backoff(i)
                wait(back)
                spent += back
        stats.channel_latency_s += spent
        raise CloudUnreachable(
            f"seq {self.seq}: {nbytes} B undelivered after "
            f"{self.max_retries + 1} attempts ({spent:.3f}s)")

    def probe(self, stats: ServeStats) -> Tuple[bool, float]:
        """One single-attempt heartbeat (header-only message): is the
        cloud reachable right now?  Returns (ok, seconds consumed) —
        a miss costs one deadline of waiting, charged to ``stats``."""
        attempt = getattr(self.channel, "attempt", None)
        if attempt is None:
            return True, 0.0
        deadline = self.deadline_for(_MSG_BYTES)
        out = attempt(_MSG_BYTES)
        ok = out.delivered and not out.corrupt and out.seconds <= deadline
        self.telemetry.observe_delivery(ok)
        spent = out.seconds
        if not ok:
            pause = deadline if not out.delivered \
                else max(0.0, deadline - out.seconds)
            getattr(self.channel, "wait", lambda s: None)(pause)
            spent += pause
            stats.timeouts += 1
        stats.channel_latency_s += spent
        return ok, spent
