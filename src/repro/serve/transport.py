"""Channel framing, wire accounting, and link telemetry for serving.

Everything that touches the simulated wireless channel lives here:

* the framing constants (``_QP_BYTES``/``_TOK_BYTES``/``_MSG_BYTES``) —
  canonical values come from ``core.costmodel`` so the engine's
  accounting and the cost model's round predictions can never drift
  apart;
* ``ServeStats`` — the per-phase byte/token/latency counters both
  engines populate;
* ``Transport`` — the charge/account methods the collaborative engine
  calls for every uplink blob and downlink return;
* ``LinkTelemetry`` — online EWMA estimates of the observed bandwidth,
  RTT, and draft acceptance, the measurement half of the
  telemetry → policy → engine control loop (``serve.policy``);
* ``DriftingChannel`` — a channel whose (bandwidth, rtt) follow a
  schedule over simulated time, for exercising that loop.

Accounting semantics (shared by every engine):

``transmitted_bytes`` is the total over the wire — prefill and decode
uplinks plus every cloud→edge downlink, each *message* carrying its
``_MSG_BYTES`` protocol header on top of the payload (headers, like the
RTT, are paid per traversal — the quantity a draft/verify round
amortizes k-fold).  ``decode_bytes`` is the decode-phase *uplink*:
per-row-quantized boundary deltas plus, in speculative rounds, the 4 B
draft-token ids the cloud grades.  ``downlink_bytes`` counts the return
direction — the sampled/corrected token (4 B/row) plus, in speculative
rounds, the byte-packed accept mask.  Prefill uplinks are charged by
each request's *true* prompt length — bucket padding is a compile-shape
artifact and never crosses the wire.  ``decode_tokens`` counts
**accepted (committed) tokens**.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.costmodel import Channel, MSG_BYTES, QP_BYTES, TOK_BYTES

# wire framing overhead for one quantized blob: f32 scale + f32 zero-point
_QP_BYTES = int(QP_BYTES)
# wire bytes for one token id (cloud→edge return / edge→cloud draft)
_TOK_BYTES = int(TOK_BYTES)
# per-*message* protocol framing (TCP/IP-class headers + slot ids/round
# counter): every channel traversal pays it once, which is exactly what a
# draft/verify round amortizes k-fold alongside the RTT
_MSG_BYTES = int(MSG_BYTES)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass
class ServeStats:
    """Per-phase serving counters (see the module docstring for the
    accounting semantics).

    ``drafted_tokens`` / ``draft_hits`` grade the speculative drafts the
    verify step compared (k-1 per round per live slot), giving
    ``acceptance_rate``.  ``bytes_per_decode_token`` is uplink bytes per
    accepted token; ``wire_bytes_per_accepted_token`` adds the decode
    downlink.  ``spec_k_switches``/``cut_switches`` count online retune
    events applied by a ``serve.policy`` controller.

    ``prefill_s``/``decode_s`` are wall-clock phase totals, populated
    when the engine runs with ``timed=True`` (timing blocks on device
    results, so it is off by default to keep the decode loop fully
    async).

    The fault counters are populated by ``ReliableTransport`` and the
    resilient engine (``serve.resilience``): ``retries`` counts
    retransmission attempts after a deadline miss or checksum failure,
    ``timeouts`` counts the deadline misses themselves, ``corrupt_msgs``
    counts messages whose checksum failed on arrival, ``outage_s`` is
    simulated time spent with the cloud declared down, and
    ``edge_only_tokens``/``resyncs`` count tokens committed with zero
    wire bytes during degradation and the cloud KV rebuilds on
    reconnect.  Retransmissions' bytes and waiting are charged to
    ``transmitted_bytes``/``channel_latency_s`` like any other traffic —
    a lossy link is priced, not hidden."""
    prefill_calls: int = 0
    decode_steps: int = 0
    transmitted_bytes: int = 0
    channel_latency_s: float = 0.0
    # per-phase splits
    prefill_bytes: int = 0
    decode_bytes: int = 0
    decode_bytes_log: List[int] = dataclasses.field(default_factory=list)
    downlink_bytes: int = 0
    decode_downlink_bytes: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    # speculative draft/verify rounds
    spec_rounds: int = 0
    drafted_tokens: int = 0
    draft_hits: int = 0
    # online re-tuning events (serve.policy)
    spec_k_switches: int = 0
    cut_switches: int = 0
    # warm k-raise path: ``draft_rebuilds`` counts draft-cache rebuilds
    # from committed prefix state (raising out of k=1 with live slots no
    # longer drains); ``policy_holds`` counts scheduler turns admission
    # actually paused on a policy barrier (now only cut re-partitions)
    draft_rebuilds: int = 0
    policy_holds: int = 0
    # reliability layer (serve.faults / ReliableTransport / resilience)
    retries: int = 0
    timeouts: int = 0
    corrupt_msgs: int = 0
    outage_s: float = 0.0
    edge_only_tokens: int = 0
    resyncs: int = 0
    # overload robustness (serve.scheduler / serve.policy / faults):
    # ``preemptions`` counts live slots suspended to reclaim their pages,
    # ``shed`` counts requests refused at admission because their
    # predicted finish already missed their deadline, ``deadline_misses``
    # counts served requests that finished late anyway, ``queue_wait_s``
    # is total simulated time requests spent between (re-)enqueue and
    # admission, and ``stall_wait_s`` is simulated time the scheduler
    # itself idled — waiting out page-pool pressure or a gap until the
    # next request arrival.  The simulated clock decomposes exactly:
    # every advance is either a charged transfer (``channel_latency_s``)
    # or a charged scheduler wait (``stall_wait_s``) — property-tested
    # in ``tests/test_overload_serve.py``.
    preemptions: int = 0
    shed: int = 0
    deadline_misses: int = 0
    queue_wait_s: float = 0.0
    stall_wait_s: float = 0.0
    # pool-pressure snapshot (multi-tenant fleet serving): engines that
    # own a ``kvcache._PagedPool`` refresh these each scheduler turn via
    # ``observe_pool`` so benchmarks and the fairness policy read pool
    # pressure off a stats snapshot instead of poking pool privates
    pool_free_pages: int = -1          # -1 = engine has no paged pool
    pool_utilization: float = 0.0
    pool_utilization_peak: float = 0.0

    def observe_pool(self, pool) -> None:
        """Snapshot a ``_PagedPool``'s pressure (free pages, utilization,
        peak utilization) onto this stats object."""
        self.pool_free_pages = pool.free_pages()
        self.pool_utilization = pool.utilization()
        self.pool_utilization_peak = max(self.pool_utilization_peak,
                                         self.pool_utilization)

    @classmethod
    def aggregate(cls, parts: Sequence["ServeStats"]) -> "ServeStats":
        """Fleet-wide rollup of per-tenant stats: counters sum, the pool
        snapshot (shared pool — identical on every tenant) carries the
        worst case.  ``decode_bytes_log`` concatenates in input order."""
        total = cls()
        for p in parts:
            for f in dataclasses.fields(cls):
                if f.name == "decode_bytes_log":
                    total.decode_bytes_log.extend(p.decode_bytes_log)
                elif f.name == "pool_free_pages":
                    total.pool_free_pages = (
                        p.pool_free_pages if total.pool_free_pages < 0
                        else min(total.pool_free_pages,
                                 max(p.pool_free_pages, 0)))
                elif f.name.startswith("pool_utilization"):
                    setattr(total, f.name,
                            max(getattr(total, f.name), getattr(p, f.name)))
                else:
                    setattr(total, f.name,
                            getattr(total, f.name) + getattr(p, f.name))
        return total

    def bytes_per_decode_token(self) -> float:
        """Decode *uplink* bytes per accepted token (PR 1/PR 2 metric)."""
        return self.decode_bytes / max(self.decode_tokens, 1)

    def wire_bytes_per_accepted_token(self) -> float:
        """Both directions per accepted token: uplink deltas + drafts
        and the downlink accept-mask + corrected token."""
        return (self.decode_bytes + self.decode_downlink_bytes) \
            / max(self.decode_tokens, 1)

    def acceptance_rate(self) -> float:
        """Fraction of graded speculative drafts the verify accepted."""
        return self.draft_hits / max(self.drafted_tokens, 1)

    def report(self) -> Dict[str, float]:
        return {
            "prefill_calls": self.prefill_calls,
            "decode_steps": self.decode_steps,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "accepted_tokens": self.decode_tokens,
            "transmitted_bytes": self.transmitted_bytes,
            "prefill_bytes": self.prefill_bytes,
            "decode_bytes": self.decode_bytes,
            "downlink_bytes": self.downlink_bytes,
            "bytes_per_decode_token": self.bytes_per_decode_token(),
            "wire_bytes_per_accepted_token":
                self.wire_bytes_per_accepted_token(),
            "spec_rounds": self.spec_rounds,
            "drafted_tokens": self.drafted_tokens,
            "acceptance_rate": self.acceptance_rate(),
            "spec_k_switches": self.spec_k_switches,
            "cut_switches": self.cut_switches,
            "draft_rebuilds": self.draft_rebuilds,
            "policy_holds": self.policy_holds,
            "channel_latency_s": self.channel_latency_s,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "retries": self.retries,
            "timeouts": self.timeouts,
            "corrupt_msgs": self.corrupt_msgs,
            "outage_s": self.outage_s,
            "edge_only_tokens": self.edge_only_tokens,
            "resyncs": self.resyncs,
            "preemptions": self.preemptions,
            "shed": self.shed,
            "deadline_misses": self.deadline_misses,
            "queue_wait_s": self.queue_wait_s,
            "stall_wait_s": self.stall_wait_s,
            "pool_free_pages": self.pool_free_pages,
            "pool_utilization": self.pool_utilization,
            "pool_utilization_peak": self.pool_utilization_peak,
        }


class LinkTelemetry:
    """Online estimates of the link and the draft quality, from the
    traffic the engine sends anyway.

    Every charged message is an ``(nbytes, seconds)`` sample of
    ``seconds = nbytes / bandwidth + rtt`` — a line in ``nbytes`` — so
    an exponentially-weighted least-squares fit over the message stream
    recovers ``1/bandwidth`` (slope) and ``rtt`` (intercept).  Message
    sizes naturally span two orders of magnitude (prefill blobs vs
    per-round deltas vs 4 B token returns), which is what makes the
    regression well-conditioned; when recent traffic degenerates to one
    size the last well-conditioned estimate is held.  EWMA weighting
    makes the estimate track channel drift with a ~``1/alpha``-message
    memory.

    Draft/verify rounds contribute ``(graded, hits)`` samples giving an
    EWMA draft acceptance rate for ``autotune.tune_spec_k``, and every
    reliable-transport attempt contributes a delivered/lost sample
    giving an EWMA ``loss_rate`` — the expected-retransmit multiplier
    ``costmodel`` prices lossy links with.
    """

    # no physical last hop beats ~1 TB/s: a degenerate sample pair can
    # otherwise drive the fitted slope to ~0 and the bandwidth estimate
    # to absurdity (see observe_transfer's guard)
    BW_CEILING_BYTES_PER_S = 1e12

    def __init__(self, alpha: float = 0.25, min_samples: int = 4):
        self.alpha = alpha
        self.min_samples = min_samples
        self.n_samples = 0
        self.n_rounds = 0
        self._mx = self._my = self._mxx = self._mxy = 0.0
        self._bw: Optional[float] = None
        self._rtt: Optional[float] = None
        self._acc: Optional[float] = None
        self._loss: Optional[float] = None

    # -- observations -------------------------------------------------------
    def observe_transfer(self, nbytes: float, seconds: float) -> None:
        x, y = float(nbytes), float(seconds)
        # zero-duration samples carry no line information (the idealized
        # infinite channel) and, mixed with real samples, can drag the
        # fitted slope through zero — absurd bandwidth estimates
        if x <= 0 or y <= 0:
            return
        if self.n_samples == 0:
            self._mx, self._my = x, y
            self._mxx, self._mxy = x * x, x * y
        else:
            a = self.alpha
            self._mx += a * (x - self._mx)
            self._my += a * (y - self._my)
            self._mxx += a * (x * x - self._mxx)
            self._mxy += a * (x * y - self._mxy)
        self.n_samples += 1
        var = self._mxx - self._mx * self._mx
        cov = self._mxy - self._mx * self._my
        # refresh the held estimate only while the fit is well-conditioned
        if self.n_samples >= self.min_samples \
                and var > 1e-9 * max(self._mx * self._mx, 1.0) and cov > 0:
            slope = cov / var                       # seconds per byte
            self._bw = min(1.0 / slope, self.BW_CEILING_BYTES_PER_S)
            self._rtt = max(0.0, self._my - slope * self._mx)

    def observe_round(self, graded: int, hits: int) -> None:
        if graded <= 0:
            return
        r = hits / graded
        self._acc = r if self._acc is None \
            else self._acc + self.alpha * (r - self._acc)
        self.n_rounds += 1

    def observe_delivery(self, delivered: bool) -> None:
        """One reliable-transport attempt: EWMA of the loss indicator."""
        x = 0.0 if delivered else 1.0
        self._loss = x if self._loss is None \
            else self._loss + self.alpha * (x - self._loss)

    # -- estimates ----------------------------------------------------------
    @property
    def bandwidth_bytes_per_s(self) -> Optional[float]:
        return self._bw

    @property
    def rtt_s(self) -> Optional[float]:
        return self._rtt

    @property
    def loss_rate(self) -> float:
        return 0.0 if self._loss is None else self._loss

    def acceptance(self, prior: float = 0.8) -> float:
        return prior if self._acc is None else self._acc

    def channel(self, fallback: Channel) -> Channel:
        """The estimated channel, or ``fallback`` until the regression
        has locked on.  Carries the measured ``loss_rate`` either way,
        so the policy prices retransmissions even before the bandwidth
        fit converges."""
        if self._bw is None:
            return fallback if self._loss is None else dataclasses.replace(
                fallback, loss_rate=self.loss_rate)
        return Channel(bandwidth_bytes_per_s=self._bw, rtt_s=self._rtt or 0.0,
                       loss_rate=self.loss_rate, name="telemetry")


class DriftingChannel:
    """A channel whose conditions follow a schedule over *simulated*
    time (the cumulative transfer time it has charged), e.g. ::

        DriftingChannel([(0.0, Channel.from_kbps(2000, rtt_ms=20)),
                         (5.0, Channel.from_kbps(200, rtt_ms=150)),
                         (15.0, Channel.from_kbps(2000, rtt_ms=20))])

    Duck-types ``costmodel.Channel`` (``transfer_time``), so engines and
    telemetry are oblivious; the benchmark uses it to drive the online
    re-tuning loop through a bandwidth/RTT swing.
    """

    def __init__(self, schedule: Sequence[Tuple[float, Channel]]):
        assert schedule and schedule[0][0] == 0.0, \
            "schedule must start at simulated time 0"
        self.schedule = list(schedule)
        self.clock_s = 0.0

    @property
    def phase(self) -> Channel:
        cur = self.schedule[0][1]
        for t0, ch in self.schedule:
            if self.clock_s >= t0:
                cur = ch
        return cur

    @property
    def name(self) -> str:
        return f"drift[{self.phase.name}]"

    def transfer_time(self, nbytes: float) -> float:
        t = self.phase.transfer_time(nbytes)
        self.clock_s += t
        return t

    def wait(self, seconds: float) -> None:
        """Sender-side time passing (scheduler stalls, arrival gaps) —
        advances the schedule clock, the same convention as
        ``faults.FaultyChannel.wait``."""
        self.clock_s += max(0.0, float(seconds))


class Transport:
    """The collaborative engine's side of the wire: owns the channel and
    the telemetry, charges every message to a ``ServeStats``.

    ``stats`` is passed per call (not owned) so callers can swap in a
    fresh ``ServeStats`` between measurement windows without severing
    the telemetry, which deliberately accumulates across windows — it is
    an estimate of the *link*, not of any one run."""

    def __init__(self, channel: Optional[Channel] = None,
                 telemetry: Optional[LinkTelemetry] = None):
        self.channel = channel or Channel(bandwidth_bytes_per_s=float("inf"))
        self.telemetry = telemetry or LinkTelemetry()

    def _transfer(self, stats: ServeStats, nbytes: int) -> float:
        """Move one message across the channel; returns the seconds the
        sender spent on it.  ``ReliableTransport`` overrides this with
        the deadline/retry machinery — every ``charge``/``account_*``
        path goes through here, so reliability is a transport swap, not
        an engine change."""
        t = self.channel.transfer_time(nbytes)
        self.telemetry.observe_transfer(nbytes, t)
        return t

    def charge(self, stats: ServeStats, nbytes: int, *, phase: str,
               log: bool = True) -> None:
        """One uplink message of ``nbytes`` (header included by caller
        or via the ``account_*`` wrappers)."""
        t = self._transfer(stats, nbytes)
        stats.transmitted_bytes += int(nbytes)
        stats.channel_latency_s += t
        if phase == "prefill":
            stats.prefill_bytes += int(nbytes)
        else:
            stats.decode_bytes += int(nbytes)
            if log:
                stats.decode_bytes_log.append(int(nbytes))

    def account_blob(self, stats: ServeStats, blob: jax.Array, *, phase: str,
                     rows: Optional[int] = None,
                     row_elems=None) -> None:
        """Charge the wire for the occupied batch rows of ``blob``.

        The jit'd decode step always computes the full fixed-shape
        [max_batch, 1, D] delta, but idle slots would never be sent, so
        the simulated wire carries only the active rows — each framed
        with its own Eq.(1) scale/zero-point (per-row quantization).
        ``row_elems`` overrides the per-row payload element count: the
        prefill blob is bucket-padded on device, but only each request's
        true prompt activations cross the wire."""
        itemsize = blob.dtype.itemsize
        if row_elems is not None:
            nbytes = int(sum(int(e) * itemsize + _QP_BYTES
                             for e in row_elems))
        else:
            n_rows = blob.shape[0] if rows is None else rows
            per_row = (blob.size // blob.shape[0]) * itemsize
            nbytes = n_rows * (per_row + _QP_BYTES)
        self.charge(stats, nbytes + _MSG_BYTES, phase=phase)

    def account_downlink(self, stats: ServeStats, n_rows: int, *, k: int = 1,
                         phase: str = "decode") -> None:
        """The cloud→edge return: the sampled (or corrected) token per
        live request, plus — when a round verified k > 1 drafts — the
        accept mask (one bit per draft, byte-packed).  The edge can't
        start the next round until it arrives, so every round pays this
        second transfer and its channel RTT.  Counted in
        ``transmitted_bytes``/``downlink_bytes``, never in the uplink
        ``decode_bytes`` split."""
        nbytes = n_rows * (_TOK_BYTES + (_cdiv(k, 8) if k > 1 else 0)) \
            + _MSG_BYTES
        t = self._transfer(stats, nbytes)
        stats.transmitted_bytes += nbytes
        stats.channel_latency_s += t
        stats.downlink_bytes += nbytes
        if phase == "decode":
            stats.decode_downlink_bytes += nbytes


def checksum(payload) -> int:
    """CRC32 of a boundary blob (or any array/bytes) — the integrity
    check a receiver runs before acking a message.  The simulator's
    ``FaultyChannel`` flags corruption explicitly so the hot path never
    syncs a device blob to hash it, but the mechanism is this one, and
    the chaos tests exercise it on real payloads."""
    if isinstance(payload, (bytes, bytearray, memoryview)):
        return zlib.crc32(payload) & 0xFFFFFFFF
    return zlib.crc32(np.ascontiguousarray(payload).tobytes()) & 0xFFFFFFFF


class CloudUnreachable(RuntimeError):
    """Raised by ``ReliableTransport`` when a message exhausts its retry
    budget — the signal on which a resilient engine declares the cloud
    down and degrades to edge-only serving."""


class ReliableTransport(Transport):
    """``Transport`` with sequencing, deadlines, and bounded retries.

    Every message gets a sequence number (``seq``) — retransmissions
    reuse it, so the receiver can both discard duplicates and ack a
    retransmitted copy of an earlier send (which is what makes a
    downlink loss after a committed verify harmless: the state advanced,
    only the ack is re-requested).  A send's deadline comes from the
    link telemetry — ``margin *`` the EWMA-fit prediction
    ``nbytes/bandwidth + rtt`` — so the timeout tightens as the
    estimate locks on; until then a fixed ``fallback_deadline_s``
    applies.  A miss (silent drop, outage, or an arrival past the
    deadline) costs the sender the full deadline of waiting, then an
    exponentially backed-off, seeded-jitter pause before the retransmit;
    a checksum failure retransmits immediately.  All of it is charged:
    waiting to ``channel_latency_s``, events to the
    ``retries``/``timeouts``/``corrupt_msgs`` counters, and every
    attempt to the telemetry's loss EWMA.  After ``max_retries``
    retransmits the send raises ``CloudUnreachable``.

    Channels without an ``attempt`` method (the plain deterministic
    ``Channel``/``DriftingChannel``) degenerate to the base transport —
    reliability is free when nothing fails."""

    def __init__(self, channel=None, telemetry: Optional[LinkTelemetry] = None,
                 *, max_retries: int = 3, deadline_margin: float = 3.0,
                 fallback_deadline_s: float = 0.5, min_deadline_s: float = 0.01,
                 backoff_base_s: float = 0.02, backoff_max_s: float = 1.0,
                 seed: int = 0):
        super().__init__(channel, telemetry)
        self.max_retries = max_retries
        self.deadline_margin = deadline_margin
        self.fallback_deadline_s = fallback_deadline_s
        self.min_deadline_s = min_deadline_s
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self._rng = np.random.default_rng(seed)
        self.seq = 0

    def deadline_for(self, nbytes: float) -> float:
        bw, rtt = self.telemetry.bandwidth_bytes_per_s, self.telemetry.rtt_s
        if bw is None:
            return self.fallback_deadline_s
        return max(self.min_deadline_s,
                   self.deadline_margin * (nbytes / bw + (rtt or 0.0)))

    def _backoff(self, attempt: int) -> float:
        base = min(self.backoff_max_s, self.backoff_base_s * (2 ** attempt))
        return base * (1.0 + float(self._rng.random()))   # full jitter

    def _transfer(self, stats: ServeStats, nbytes: int) -> float:
        attempt = getattr(self.channel, "attempt", None)
        if attempt is None:
            return super()._transfer(stats, nbytes)
        self.seq += 1
        deadline = self.deadline_for(nbytes)
        wait = getattr(self.channel, "wait", lambda s: None)
        spent = 0.0
        for i in range(self.max_retries + 1):
            out = attempt(nbytes)
            ok = out.delivered and not out.corrupt \
                and out.seconds <= deadline
            self.telemetry.observe_delivery(ok)
            if ok:
                self.telemetry.observe_transfer(nbytes, out.seconds)
                return spent + out.seconds
            if out.delivered and out.corrupt:
                stats.corrupt_msgs += 1          # caught at arrival: resend
                spent += out.seconds
            else:
                stats.timeouts += 1              # discovered at the deadline
                pause = max(0.0, deadline - out.seconds) \
                    if out.delivered else deadline
                wait(pause)
                spent += out.seconds + pause
            if i < self.max_retries:
                stats.retries += 1
                back = self._backoff(i)
                wait(back)
                spent += back
        stats.channel_latency_s += spent
        raise CloudUnreachable(
            f"seq {self.seq}: {nbytes} B undelivered after "
            f"{self.max_retries + 1} attempts ({spent:.3f}s)")

    def probe(self, stats: ServeStats) -> Tuple[bool, float]:
        """One single-attempt heartbeat (header-only message): is the
        cloud reachable right now?  Returns (ok, seconds consumed) —
        a miss costs one deadline of waiting, charged to ``stats``."""
        attempt = getattr(self.channel, "attempt", None)
        if attempt is None:
            return True, 0.0
        deadline = self.deadline_for(_MSG_BYTES)
        out = attempt(_MSG_BYTES)
        ok = out.delivered and not out.corrupt and out.seconds <= deadline
        self.telemetry.observe_delivery(ok)
        spent = out.seconds
        if not ok:
            pause = deadline if not out.delivered \
                else max(0.0, deadline - out.seconds)
            getattr(self.channel, "wait", lambda s: None)(pause)
            spent += pause
            stats.timeouts += 1
        stats.channel_latency_s += spent
        return ok, spent
