"""Batched LM serving with KV caches + collaborative (cloud-edge) mode —
the deployment side of the paper.

Both engines share one slot-based continuous-batching scheduler
(``_SlotEngine``): requests queue up, same-length prompts are prefilled
together into free cache slots, every decode step advances all occupied
slots at their own positions (vector ``cache_index``), and a finished
request frees its slot for the next queued prompt mid-flight.  Sampled
tokens stay on device for the whole generation; the host sees them once,
after the last step.

``ServingEngine`` is the cloud-only baseline: one KV cache over the full
stack.

``CollaborativeServingEngine`` is the paper's mode rebuilt around
*incremental decode*: the INT8 edge prefix (first ``cut_layer+1``
blocks, fake-quant lattice == the Pallas int8 kernel's math) and the
FP32 cloud suffix each own a KV cache covering only their block
sub-range.  After a one-time split prefill, each decode step runs just
the new token through the edge blocks, quantizes a single ``[B, 1, D]``
boundary delta per Eq.(1), "transmits" those few bytes through the
simulated wireless channel, dequantizes per Eq.(2), and finishes on the
cloud side — so per-token wire traffic is O(1) in sequence length
instead of re-shipping the whole boundary blob.  All four phase
functions (edge/cloud x prefill/decode) are jit'd once; decode shapes
are fixed, so there is no per-step recompilation.  The auto-tuner
(Algorithm 1) chooses the cut.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import Channel
from repro.core.quant import compute_qparams, dequantize, quantize
from repro.models import layers as ML
from repro.models import transformer as TF

Params = Any

# wire framing overhead for one quantized blob: f32 scale + f32 zero-point
_QP_BYTES = 8


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    """Per-phase serving counters.

    ``transmitted_bytes`` is the total over the wire — prefill and
    decode uplinks plus the cloud→edge sampled-token downlinks.  The
    per-step ``decode_bytes_log`` records only the boundary-delta
    uplinks: each entry is ``n_active * (D·itemsize + 8)``, i.e. one
    per-row-quantized [1, D] delta per *live* request — it shrinks as
    slots free and never grows with sequence length, which is the O(1)
    per-token property.  ``prefill_s``/``decode_s`` are wall-clock phase
    totals, populated when the engine runs with ``timed=True`` (timing
    blocks on device results, so it is off by default to keep the
    decode loop fully async)."""
    prefill_calls: int = 0
    decode_steps: int = 0
    transmitted_bytes: int = 0
    channel_latency_s: float = 0.0
    # per-phase splits
    prefill_bytes: int = 0
    decode_bytes: int = 0
    decode_bytes_log: List[int] = dataclasses.field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0
    decode_tokens: int = 0

    def bytes_per_decode_token(self) -> float:
        return self.decode_bytes / max(self.decode_tokens, 1)

    def report(self) -> Dict[str, float]:
        return {
            "prefill_calls": self.prefill_calls,
            "decode_steps": self.decode_steps,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "transmitted_bytes": self.transmitted_bytes,
            "prefill_bytes": self.prefill_bytes,
            "decode_bytes": self.decode_bytes,
            "bytes_per_decode_token": self.bytes_per_decode_token(),
            "channel_latency_s": self.channel_latency_s,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
        }


class _SlotEngine:
    """Slot-based continuous-batching scheduler shared by both engines.

    Subclasses implement ``_admit`` (prefill a same-length prompt group
    into specific slots) and ``_decode_all`` (advance every slot one
    token).  The scheduler keeps the current token and position of every
    slot on device; request outputs are transferred to the host once,
    after the final step.
    """

    def __init__(self, cfg: TF.LMConfig, *, max_batch: int, max_len: int,
                 timed: bool = False):
        self.cfg = dataclasses.replace(cfg, remat=False)
        self.max_batch = max_batch
        self.max_len = max_len
        self.timed = timed
        self.stats = ServeStats()

    # -- subclass interface -------------------------------------------------
    def _admit(self, toks: jax.Array, slots: jax.Array, cur: jax.Array,
               pos: jax.Array) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _decode_all(self, cur: jax.Array, pos: jax.Array,
                    n_active: int) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    # -- timing helper ------------------------------------------------------
    def _timed(self, phase: str, fn):
        if not self.timed:
            return fn()
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        setattr(self.stats, phase,
                getattr(self.stats, phase) + time.perf_counter() - t0)
        return out

    # -- scheduler ----------------------------------------------------------
    def generate(self, prompts: List[np.ndarray], *,
                 max_new_tokens: int = 16) -> List[List[int]]:
        """Greedy-decode a list of prompts with continuous batching."""
        reqs = [Request(uid=i, prompt=np.asarray(p),
                        max_new_tokens=max_new_tokens)
                for i, p in enumerate(prompts)]
        if reqs:
            self._run(reqs)
        return [r.out_tokens for r in reqs]

    def _run(self, reqs: List[Request]) -> None:
        queue = deque(reqs)
        active: Dict[int, Tuple[Request, int]] = {}   # slot -> (req, t0)
        free = list(range(self.max_batch))
        cur = jnp.zeros((self.max_batch,), jnp.int32)
        pos = jnp.zeros((self.max_batch,), jnp.int32)
        step_toks: List[jax.Array] = []
        placements: List[Tuple[Request, int, int]] = []
        step = 0
        while queue or active:
            # admit queued prompts into free slots, grouping equal lengths
            # so one batched prefill call covers the whole group
            while free and queue:
                plen = len(queue[0].prompt)
                assert plen + queue[0].max_new_tokens <= self.max_len, \
                    "prompt + generation exceeds cache max_len"
                group, slots = [], []
                while free and queue and len(queue[0].prompt) == plen:
                    group.append(queue.popleft())
                    slots.append(free.pop(0))
                toks = jnp.asarray(
                    np.stack([r.prompt for r in group]).astype(np.int32))
                slots_a = jnp.asarray(np.asarray(slots, np.int32))
                cur, pos = self._timed(
                    "prefill_s", lambda: self._admit(toks, slots_a, cur, pos))
                self.stats.prefill_calls += 1
                self.stats.prefill_tokens += plen * len(group)
                for r, s in zip(group, slots):
                    active[s] = (r, step)
                    placements.append((r, s, step))
            step_toks.append(cur)
            step += 1
            # retire requests whose final token was just recorded — before
            # decoding, so no request pays for a step it never reads and
            # its slot frees one step earlier for the queue
            for s in [s for s, (r, t0) in active.items()
                      if step - t0 >= r.max_new_tokens]:
                r, _ = active.pop(s)
                r.done = True
                free.append(s)
            if active:
                cur, pos = self._timed(
                    "decode_s",
                    lambda: self._decode_all(cur, pos, len(active)))
                self.stats.decode_steps += 1
                self.stats.decode_tokens += len(active)
        # single device→host transfer for the whole run
        all_toks = np.asarray(jnp.stack(step_toks, axis=0))  # [T, max_batch]
        for r, s, t0 in placements:
            r.out_tokens = [int(t)
                            for t in all_toks[t0:t0 + r.max_new_tokens, s]]


class ServingEngine(_SlotEngine):
    """Cloud-only batched engine (greedy decode, continuous batching)."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *,
                 max_batch: int = 4, max_len: int = 128,
                 timed: bool = False):
        super().__init__(cfg, max_batch=max_batch, max_len=max_len,
                         timed=timed)
        self.params = params
        self._cache = TF.init_cache(self.cfg, max_batch, max_len=max_len)
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    def _prefill_impl(self, params, toks, cache, slots, cur, pos):
        n, plen = toks.shape
        small = TF.init_cache(self.cfg, n, max_len=self.max_len)
        logits, small = TF.prefill(params, toks, self.cfg, cache=small)
        cache = {k: cache[k].at[:, slots].set(small[k]) for k in cache}
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plen)
        return cache, cur, pos

    def _decode_impl(self, params, cur, cache, pos):
        logits, cache = TF.decode_step(params, cur, cache, pos, self.cfg)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return nxt, cache, jnp.minimum(pos + 1, self.max_len - 1)

    def _admit(self, toks, slots, cur, pos):
        self._cache, cur, pos = self._prefill(
            self.params, toks, self._cache, slots, cur, pos)
        return cur, pos

    def _decode_all(self, cur, pos, n_active):
        cur, self._cache, pos = self._decode(self.params, cur,
                                             self._cache, pos)
        return cur, pos


class CollaborativeServingEngine(_SlotEngine):
    """Paper mode with incremental decode: INT8 edge prefix and FP32
    cloud suffix hold *split* KV caches over their own block sub-ranges;
    each decode step ships one quantized ``[B, 1, D]`` boundary delta
    (Eq.1/2) through the channel instead of the whole growing blob."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *, cut_layer: int,
                 channel: Optional[Channel] = None, max_len: int = 128,
                 a_bits: int = 8, max_batch: int = 4, timed: bool = False):
        assert 0 <= cut_layer < cfg.n_layers, \
            f"cut_layer {cut_layer} outside [0, {cfg.n_layers})"
        super().__init__(cfg, max_batch=max_batch, max_len=max_len,
                         timed=timed)
        self.cut = cut_layer
        self.channel = channel or Channel(bandwidth_bytes_per_s=float("inf"))
        self.a_bits = a_bits
        self.n_edge = cut_layer + 1
        self.n_cloud = cfg.n_layers - self.n_edge

        self.edge_blocks, self.cloud_blocks = TF.split_blocks(
            params, self.cfg, cut_layer)
        self.embed = params["embed"]
        self.tail = {"final_norm": params["final_norm"],
                     "lm_head": params["lm_head"]}
        # edge weights are INT8-quantized at deployment (fake-quant lattice)
        self._edge_qctx = ML.QuantCtx(mode="dynamic", a_bits=a_bits)
        # split KV caches: edge prefix / cloud suffix block sub-ranges
        self._edge_cache = TF.init_cache(self.cfg, max_batch, max_len,
                                         layers=self.n_edge)
        self._cloud_cache = TF.init_cache(self.cfg, max_batch, max_len,
                                          layers=self.n_cloud)
        self._edge = jax.jit(self._edge_impl)
        self._cloud = jax.jit(self._cloud_impl)
        self._edge_prefill = jax.jit(self._edge_prefill_impl)
        self._cloud_prefill = jax.jit(self._cloud_prefill_impl)
        self._edge_decode = jax.jit(self._edge_decode_impl)
        self._cloud_decode = jax.jit(self._cloud_decode_impl)

    # -- wire accounting ----------------------------------------------------
    def _account(self, blob: jax.Array, *, phase: str,
                 rows: Optional[int] = None) -> None:
        """Charge the wire for ``rows`` occupied batch rows of ``blob``.

        The jit'd decode step always computes the full fixed-shape
        [max_batch, 1, D] delta, but idle slots would never be sent, so
        the simulated wire carries only the active rows — each framed
        with its own Eq.(1) scale/zero-point (per-row quantization)."""
        n_rows = blob.shape[0] if rows is None else rows
        per_row = (blob.size // blob.shape[0]) * blob.dtype.itemsize
        nbytes = n_rows * (per_row + _QP_BYTES)
        self.stats.transmitted_bytes += int(nbytes)
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)
        if phase == "prefill":
            self.stats.prefill_bytes += int(nbytes)
        else:
            self.stats.decode_bytes += int(nbytes)
            self.stats.decode_bytes_log.append(int(nbytes))

    def _account_downlink(self, n_rows: int) -> None:
        """The cloud→edge return of the sampled tokens: the edge can't
        embed the next token until it arrives, so every serial step pays
        a second transfer (4 B token per live request + channel RTT).
        Counted in ``transmitted_bytes``/``channel_latency_s`` but not in
        the decode-delta uplink split."""
        nbytes = 4 * n_rows
        self.stats.transmitted_bytes += nbytes
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)

    # -- incremental split-cache phases --------------------------------------
    def _rope(self):
        return ML.rope_table(self.max_len, self.cfg.hd,
                             base=self.cfg.rope_base, dtype=self.cfg.dtype)

    def _edge_prefill_impl(self, blocks, embed, toks, cache, slots):
        cfg = self.cfg
        n = toks.shape[0]
        small = TF.init_cache(cfg, n, self.max_len, layers=self.n_edge)
        x = ML.embed(embed, toks).astype(cfg.dtype)
        h, small = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                 cache=small, cache_index=jnp.int32(0),
                                 qctx=self._edge_qctx)
        cache = {k: cache[k].at[:, slots].set(small[k]) for k in cache}
        # Eq.(1), per batch row: each request gets its own thresholds, so
        # one request's range never depends on its neighbours' activations
        qp = compute_qparams(h, axis=0, bits=self.a_bits)
        return quantize(h, qp), qp, cache

    def _cloud_prefill_impl(self, blocks, tail, blob, qp, cache, slots,
                            cur, pos):
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2)
        n, plen, _ = h.shape
        small = TF.init_cache(cfg, n, self.max_len, layers=self.n_cloud)
        x, small = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=small, cache_index=jnp.int32(0))
        cache = {k: cache[k].at[:, slots].set(small[k]) for k in cache}
        logits = TF.lm_head(tail, x[:, -1:])[:, 0]
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plen)
        return cache, cur, pos

    def _edge_decode_impl(self, blocks, embed, cur, cache, pos):
        cfg = self.cfg
        x = ML.embed(embed, cur[:, None]).astype(cfg.dtype)
        h, cache = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 qctx=self._edge_qctx)
        # Eq.(1) per row: stale activations in idle/freed slots must not
        # set the quant range of live requests' deltas
        qp = compute_qparams(h, axis=0, bits=self.a_bits)
        return quantize(h, qp), qp, cache                  # [B, 1, D] delta

    def _cloud_decode_impl(self, blocks, tail, blob, qp, cache, pos):
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2)
        x, cache = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos)
        logits = TF.lm_head(tail, x)[:, 0]
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return nxt, cache, jnp.minimum(pos + 1, self.max_len - 1)

    # -- scheduler hooks ----------------------------------------------------
    def _admit(self, toks, slots, cur, pos):
        blob, qp, self._edge_cache = self._edge_prefill(
            self.edge_blocks, self.embed, toks, self._edge_cache, slots)
        self._account(blob, phase="prefill")
        self._cloud_cache, cur, pos = self._cloud_prefill(
            self.cloud_blocks, self.tail, blob, qp, self._cloud_cache,
            slots, cur, pos)
        self._account_downlink(toks.shape[0])
        return cur, pos

    def _decode_all(self, cur, pos, n_active):
        blob, qp, self._edge_cache = self._edge_decode(
            self.edge_blocks, self.embed, cur, self._edge_cache, pos)
        self._account(blob, phase="decode", rows=n_active)
        cur, self._cloud_cache, pos = self._cloud_decode(
            self.cloud_blocks, self.tail, blob, qp, self._cloud_cache, pos)
        self._account_downlink(n_active)
        return cur, pos

    # -- seed recompute path (kept as the benchmark baseline) ----------------
    def _edge_impl(self, blocks, embed, tokens):
        cfg = self.cfg
        x = ML.embed(embed, tokens).astype(cfg.dtype)
        rope = ML.rope_table(tokens.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)
        x, _ = TF.run_blocks(blocks, x, cfg, rope=rope, qctx=self._edge_qctx)
        return x

    def _cloud_impl(self, blocks, tail, h):
        cfg = self.cfg
        rope = ML.rope_table(h.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)
        h, _ = TF.run_blocks(blocks, h, cfg, rope=rope)
        return TF.lm_head(tail, h)

    def forward(self, tokens: np.ndarray) -> jax.Array:
        """Mixed-precision collaborative forward → logits [B, S, V]
        (cache-less: re-runs the whole split stack; the seed path)."""
        toks = jnp.asarray(tokens, jnp.int32)
        h = self._edge(self.edge_blocks, self.embed, toks)
        # Eq.(1): quantize boundary blob for the wire
        qp = compute_qparams(h, bits=self.a_bits)
        blob = quantize(h, qp)
        nbytes = blob.size * blob.dtype.itemsize + _QP_BYTES
        self.stats.transmitted_bytes += int(nbytes)
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)
        h = dequantize(blob, qp).astype(self.cfg.dtype)       # Eq.(2)
        return self._cloud(self.cloud_blocks, self.tail, h)

    def generate_recompute(self, prompts: List[np.ndarray], *,
                           max_new_tokens: int = 8) -> List[List[int]]:
        """Seed greedy decode: re-run the split forward on the full,
        growing sequence every step (KV-less edge, O(S²·L) per token and
        the whole boundary blob retransmitted).  Kept as the baseline the
        incremental path is benchmarked against."""
        toks = np.stack(prompts).astype(np.int32)
        out = [[] for _ in prompts]
        for _ in range(max_new_tokens):
            logits = self.forward(toks)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for j, t in enumerate(nxt):
                out[j].append(int(t))
            toks = np.concatenate([toks, nxt[:, None].astype(np.int32)], 1)
            self.stats.decode_steps += 1
        return out
