"""Batched LM serving with KV caches + collaborative (cloud-edge) mode —
the deployment side of the paper.

Both engines share one slot-based continuous-batching scheduler
(``_SlotEngine``): requests queue up, prompts are right-padded to
power-of-two *buckets* and same-bucket prompts are prefilled together
into free cache slots (bounding the number of distinct compiled prefill
shapes — see ``trace_counts``), every decode step advances all occupied
slots at their own positions (vector ``cache_index``), and a finished
request frees its slot — and its KV pages — for the next queued prompt
mid-flight.  Sampled tokens stay on device for the whole generation; the
host sees them once, after the last step.

KV cache layouts (see ``transformer.init_cache`` for shapes):

* **dense** — every slot owns ``max_len`` positions up front; the
  decode einsum streams the whole ``[B, max_len]`` cache each step.
* **paged** — slots own a block-table row into a shared page pool
  (``PageAllocator``); HBM is claimed page-by-page at admission and
  returned at retirement, and the decode read runs the paged
  flash-decode kernel (``kernels.paged_attention``) whose cost scales
  with *allocated* pages, not ``max_len``.
* **paged + INT8** — pages store 1 B/elem with per-slot symmetric
  scales calibrated from each prompt at prefill (paper Eq.1 applied to
  serving state); dequantization happens inside the kernel's QK/AV
  loops so the cache never materializes above 1 B/elem.

``ServingEngine`` is the cloud-only baseline: one KV cache over the full
stack (dense fp by default; ``paged=True``/``int8_kv=True`` opt in).

``CollaborativeServingEngine`` is the paper's mode rebuilt around
*incremental decode*: the INT8 edge prefix (first ``cut_layer+1``
blocks, fake-quant lattice == the Pallas int8 kernel's math) and the
FP32 cloud suffix each own a KV cache covering only their block
sub-range.  The edge cache defaults to the **paged INT8** layout — the
paper's storage/bandwidth axis applied to decode state on the
memory-constrained device.  After a one-time split prefill, each decode
step runs just the new token through the edge blocks, quantizes a single
``[B, 1, D]`` boundary delta per Eq.(1), "transmits" those few bytes
through the simulated wireless channel, dequantizes per Eq.(2), and
finishes on the cloud side — so per-token wire traffic is O(1) in
sequence length instead of re-shipping the whole boundary blob.  All
phase functions (edge/cloud x prefill/decode) are jit'd once; decode
shapes are fixed, so there is no per-step recompilation.  The auto-tuner
(Algorithm 1) chooses the cut.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import Channel
from repro.core.quant import compute_qparams, dequantize, quantize
from repro.models import layers as ML
from repro.models import transformer as TF

Params = Any

# wire framing overhead for one quantized blob: f32 scale + f32 zero-point
_QP_BYTES = 8


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _bucket_len(plen: int, max_len: int) -> int:
    """Power-of-two prefill bucket (floor 8, capped at ``max_len``)."""
    b = 8
    while b < plen:
        b *= 2
    return min(b, max_len)


# ---------------------------------------------------------------------------
# Paged-KV bookkeeping (host side)
# ---------------------------------------------------------------------------


class PageAllocator:
    """LIFO free-list allocator over a fixed pool of KV-cache pages.

    Page 0 is never handed out: retired/idle slots keep a zeroed block
    table row, so their (masked, harmless) decode writes land in page 0
    instead of corrupting a page that has been re-allocated to a live
    request.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least one allocatable page"
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))
        self._live: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def live(self) -> frozenset:
        return frozenset(self._live)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: need {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._live.update(pages)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p not in self._live:
                raise ValueError(f"double free of page {p}")
            self._live.remove(p)
            self._free.append(p)


class _PagedPool:
    """Block table + allocator for one engine-side page pool.

    Pages for a request are claimed once at admission — enough to cover
    its padded prompt plus its (known) generation budget — and returned
    the moment the scheduler retires the slot.
    """

    def __init__(self, max_batch: int, pages_per_slot: int, num_pages: int,
                 page_size: int):
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        self.allocator = PageAllocator(num_pages)
        self.bt = np.zeros((max_batch, pages_per_slot), np.int32)
        self._slot_pages: Dict[int, List[int]] = {}
        self._dev: Optional[jax.Array] = None

    @classmethod
    def build(cls, max_batch: int, max_len: int, page_size: int,
              num_pages: Optional[int] = None) -> "_PagedPool":
        """Standard sizing: worst case ``max_batch`` full-length slots
        plus the reserved dump page, unless ``num_pages`` undersizes the
        pool on purpose (admission then backpressures, see
        ``_SlotEngine._can_admit``)."""
        pages_per_slot = _cdiv(max_len, page_size)
        if num_pages is None:
            num_pages = max_batch * pages_per_slot + 1
        return cls(max_batch, pages_per_slot, num_pages, page_size)

    def pages_needed(self, plen: int, max_new: int, padded_len: int) -> int:
        return _cdiv(max(int(plen) + int(max_new), int(padded_len)),
                     self.page_size)

    def can_admit(self, shapes: Sequence[Tuple[int, int]],
                  padded_len: int) -> bool:
        """Would a prefill group of (plen, max_new) shapes fit the free
        list right now?"""
        return sum(self.pages_needed(p, m, padded_len)
                   for p, m in shapes) <= self.allocator.num_free

    def live_cache_bytes(self, cache: Dict[str, jax.Array]) -> int:
        """Bytes resident in currently-allocated pages (+ scales) of the
        paged ``cache`` this pool indexes — the demand-paging footprint,
        as opposed to the pool's capacity."""
        per_page = int(np.prod(cache["k_pages"].shape[2:])) \
            * cache["k_pages"].dtype.itemsize
        n_layers = cache["k_pages"].shape[0]
        scales = sum(v.size * v.dtype.itemsize
                     for k, v in cache.items() if "scale" in k)
        return 2 * n_layers * len(self.allocator.live) * per_page + scales

    def admit(self, slots: Sequence[int], plens: Sequence[int],
              max_news: Sequence[int], padded_len: int) -> jax.Array:
        """Allocate pages for a prefill group; returns the group's block
        table rows [n, pages_per_slot]."""
        for s, pl_, mn in zip(slots, plens, max_news):
            pages = self.allocator.alloc(
                self.pages_needed(pl_, mn, padded_len))
            self._slot_pages[int(s)] = pages
            self.bt[s, :] = 0
            self.bt[s, :len(pages)] = pages
        self._dev = None
        # explicit copy: jax on CPU may zero-copy-alias numpy buffers, and
        # ``bt`` is mutated on the host while async decode steps are still
        # in flight — sharing it would race
        return jnp.array(self.bt[np.asarray(slots)], copy=True)

    def retire(self, slot: int) -> None:
        pages = self._slot_pages.pop(int(slot), None)
        if pages is not None:
            self.allocator.free(pages)
            self.bt[slot, :] = 0
            self._dev = None

    def table_dev(self) -> jax.Array:
        """Block table on device, trimmed to the pages actually in use
        (rounded up to a power of two, so decode retraces are bounded by
        log2(pages_per_slot) widths, not every occupancy) — the decode
        read then costs O(allocated pages), not O(max_len).  Cached
        until the next admit/retire.  Copied, never aliased: the host
        mutates ``bt`` while earlier async decode steps may still be
        reading the device buffer."""
        if self._dev is None:
            used = max((len(p) for p in self._slot_pages.values()),
                       default=1)
            width = 1
            while width < used:
                width *= 2
            width = min(width, self.pages_per_slot)
            self._dev = jnp.array(self.bt[:, :width], copy=True)
        return self._dev


def _paged_prefill_view(cache: Dict[str, jax.Array], n_layers: int, n: int,
                        n_kv: int) -> Dict[str, jax.Array]:
    """Group-local view of a paged cache for one prefill call: the
    shared page pool plus fresh scale rows for the ``n``-row group (the
    prefill calibrates them; scatter back with _paged_prefill_merge)."""
    group = {"k_pages": cache["k_pages"], "v_pages": cache["v_pages"]}
    if "k_scale" in cache:
        group["k_scale"] = jnp.zeros((n_layers, n, n_kv), jnp.float32)
        group["v_scale"] = jnp.zeros_like(group["k_scale"])
    return group


def _paged_prefill_merge(cache: Dict[str, jax.Array],
                         group: Dict[str, jax.Array],
                         slots: jax.Array) -> Dict[str, jax.Array]:
    cache = dict(cache, k_pages=group["k_pages"], v_pages=group["v_pages"])
    if "k_scale" in cache:
        cache["k_scale"] = cache["k_scale"].at[:, slots].set(
            group["k_scale"])
        cache["v_scale"] = cache["v_scale"].at[:, slots].set(
            group["v_scale"])
    return cache


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    """Per-phase serving counters.

    ``transmitted_bytes`` is the total over the wire — prefill and
    decode uplinks plus the cloud→edge sampled-token downlinks.  The
    per-step ``decode_bytes_log`` records only the boundary-delta
    uplinks: each entry is ``n_active * (D·itemsize + 8)``, i.e. one
    per-row-quantized [1, D] delta per *live* request — it shrinks as
    slots free and never grows with sequence length, which is the O(1)
    per-token property.  Prefill uplinks are charged by each request's
    *true* prompt length — bucket padding is a compile-shape artifact
    and never crosses the wire.  ``prefill_s``/``decode_s`` are
    wall-clock phase totals, populated when the engine runs with
    ``timed=True`` (timing blocks on device results, so it is off by
    default to keep the decode loop fully async)."""
    prefill_calls: int = 0
    decode_steps: int = 0
    transmitted_bytes: int = 0
    channel_latency_s: float = 0.0
    # per-phase splits
    prefill_bytes: int = 0
    decode_bytes: int = 0
    decode_bytes_log: List[int] = dataclasses.field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0
    decode_tokens: int = 0

    def bytes_per_decode_token(self) -> float:
        return self.decode_bytes / max(self.decode_tokens, 1)

    def report(self) -> Dict[str, float]:
        return {
            "prefill_calls": self.prefill_calls,
            "decode_steps": self.decode_steps,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "transmitted_bytes": self.transmitted_bytes,
            "prefill_bytes": self.prefill_bytes,
            "decode_bytes": self.decode_bytes,
            "bytes_per_decode_token": self.bytes_per_decode_token(),
            "channel_latency_s": self.channel_latency_s,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
        }


class _SlotEngine:
    """Slot-based continuous-batching scheduler shared by both engines.

    Subclasses implement ``_admit`` (prefill a prompt group into specific
    slots), ``_decode_all`` (advance every slot one token), and may hook
    ``_retire`` (a slot's request finished — e.g. return its KV pages).
    The scheduler keeps the current token and position of every slot on
    device; request outputs are transferred to the host once, after the
    final step.

    Admission pads each prompt group to a power-of-two bucket
    (``_bucket_len``), so the number of distinct prefill trace shapes is
    bounded by O(log2(max_len) · max_batch) instead of growing with
    every unique prompt length.  ``trace_counts`` counts actual
    retraces of the jit'd phase functions; tests pin it.
    """

    def __init__(self, cfg: TF.LMConfig, *, max_batch: int, max_len: int,
                 timed: bool = False):
        self.cfg = dataclasses.replace(cfg, remat=False)
        self.max_batch = max_batch
        self.max_len = max_len
        self.timed = timed
        self.stats = ServeStats()
        self.trace_counts = {"prefill": 0, "decode": 0}

    # -- subclass interface -------------------------------------------------
    def _admit(self, toks: jax.Array, plens: np.ndarray, max_news: np.ndarray,
               slots: np.ndarray, cur: jax.Array, pos: jax.Array,
               ) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _decode_all(self, cur: jax.Array, pos: jax.Array,
                    n_active: int) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _retire(self, slot: int) -> None:
        """Hook: the request in ``slot`` finished (free paged KV, etc.)."""

    def _can_admit(self, group_shapes: List[Tuple[int, int]], plen: int,
                   max_new: int, bucket: int) -> bool:
        """Hook: may this request join the prefill group right now?
        ``group_shapes`` are the (plen, max_new) pairs already accepted
        into the group this round.  Paged engines refuse when the page
        pool can't cover the whole group, backpressuring admission until
        retirements return pages."""
        return True

    # -- shared helpers -----------------------------------------------------
    def _rope(self):
        return ML.rope_table(self.max_len, self.cfg.hd,
                             base=self.cfg.rope_base, dtype=self.cfg.dtype)

    def _timed(self, phase: str, fn):
        if not self.timed:
            return fn()
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        setattr(self.stats, phase,
                getattr(self.stats, phase) + time.perf_counter() - t0)
        return out

    # -- scheduler ----------------------------------------------------------
    def generate(self, prompts: List[np.ndarray], *,
                 max_new_tokens: int = 16) -> List[List[int]]:
        """Greedy-decode a list of prompts with continuous batching."""
        reqs = [Request(uid=i, prompt=np.asarray(p),
                        max_new_tokens=max_new_tokens)
                for i, p in enumerate(prompts)]
        if reqs:
            self._run(reqs)
        return [r.out_tokens for r in reqs]

    def _run(self, reqs: List[Request]) -> None:
        queue = deque(reqs)
        active: Dict[int, Tuple[Request, int]] = {}   # slot -> (req, t0)
        free = list(range(self.max_batch))
        cur = jnp.zeros((self.max_batch,), jnp.int32)
        pos = jnp.zeros((self.max_batch,), jnp.int32)
        step_toks: List[jax.Array] = []
        placements: List[Tuple[Request, int, int]] = []
        step = 0
        while queue or active:
            # admit queued prompts into free slots, grouping by prefill
            # bucket so one batched, fixed-shape prefill call covers the
            # whole group; a paged engine may refuse (pool backpressure),
            # in which case the request waits for a retirement
            stalled = False
            while free and queue and not stalled:
                bucket = _bucket_len(len(queue[0].prompt), self.max_len)
                group, slots = [], []
                shapes: List[Tuple[int, int]] = []
                while free and queue and _bucket_len(
                        len(queue[0].prompt), self.max_len) == bucket:
                    r = queue[0]
                    assert len(r.prompt) + r.max_new_tokens <= self.max_len, \
                        "prompt + generation exceeds cache max_len"
                    if not self._can_admit(shapes, len(r.prompt),
                                           r.max_new_tokens, bucket):
                        stalled = True
                        break
                    shapes.append((len(r.prompt), r.max_new_tokens))
                    group.append(queue.popleft())
                    slots.append(free.pop(0))
                if not group:
                    break
                toks = np.zeros((len(group), bucket), np.int32)
                for i, r in enumerate(group):
                    toks[i, :len(r.prompt)] = r.prompt
                plens = np.asarray([len(r.prompt) for r in group], np.int32)
                max_news = np.asarray([r.max_new_tokens for r in group],
                                      np.int32)
                slots_a = np.asarray(slots, np.int32)
                toks_j = jnp.asarray(toks)
                cur, pos = self._timed(
                    "prefill_s",
                    lambda: self._admit(toks_j, plens, max_news, slots_a,
                                        cur, pos))
                self.stats.prefill_calls += 1
                self.stats.prefill_tokens += int(plens.sum())
                for r, s in zip(group, slots):
                    active[s] = (r, step)
                    placements.append((r, s, step))
            if stalled and not active:
                r = queue[0]
                raise RuntimeError(
                    f"KV page pool too small for request uid={r.uid} "
                    f"(prompt {len(r.prompt)} + {r.max_new_tokens} new "
                    f"tokens) even with every slot idle")
            step_toks.append(cur)
            step += 1
            # retire requests whose final token was just recorded — before
            # decoding, so no request pays for a step it never reads and
            # its slot (and KV pages) free one step earlier for the queue
            for s in [s for s, (r, t0) in active.items()
                      if step - t0 >= r.max_new_tokens]:
                r, _ = active.pop(s)
                r.done = True
                self._retire(s)
                free.append(s)
            if active:
                cur, pos = self._timed(
                    "decode_s",
                    lambda: self._decode_all(cur, pos, len(active)))
                self.stats.decode_steps += 1
                self.stats.decode_tokens += len(active)
        # single device→host transfer for the whole run
        all_toks = np.asarray(jnp.stack(step_toks, axis=0))  # [T, max_batch]
        for r, s, t0 in placements:
            r.out_tokens = [int(t)
                            for t in all_toks[t0:t0 + r.max_new_tokens, s]]


class ServingEngine(_SlotEngine):
    """Cloud-only batched engine (greedy decode, continuous batching).

    ``paged=True`` swaps the dense per-slot cache for the block-table
    page pool (+ ``int8_kv=True`` for 1 B/elem pages with per-slot
    scales); ``cache_dtype`` overrides the dense cache's storage dtype
    (e.g. bf16 for the fp16-cache baseline in the benchmarks)."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *,
                 max_batch: int = 4, max_len: int = 128,
                 paged: bool = False, page_size: int = 16,
                 int8_kv: bool = False, num_pages: Optional[int] = None,
                 cache_dtype=None, timed: bool = False):
        super().__init__(cfg, max_batch=max_batch, max_len=max_len,
                         timed=timed)
        self.params = params
        self.paged = paged
        self.page_size = page_size
        self.int8_kv = int8_kv
        if paged:
            self._pool = _PagedPool.build(max_batch, max_len, page_size,
                                          num_pages)
            self._cache = TF.init_cache(
                self.cfg, max_batch, max_len, paged=True,
                page_size=page_size, quantized=int8_kv,
                num_pages=self._pool.allocator.num_pages, dtype=cache_dtype)
            self._prefill = jax.jit(self._paged_prefill_impl)
        else:
            self._cache = TF.init_cache(self.cfg, max_batch, max_len=max_len,
                                        dtype=cache_dtype,
                                        quantized=int8_kv)
            self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    def _prefill_impl(self, params, toks, cache, slots, cur, pos, plens):
        self.trace_counts["prefill"] += 1
        n, _ = toks.shape
        small = TF.init_cache(self.cfg, n, max_len=self.max_len,
                              quantized=self.int8_kv,
                              dtype=cache["k"].dtype)
        logits, small = TF.prefill(params, toks, self.cfg, cache=small,
                                   last_pos=plens - 1)
        cache = dict(cache, **{k: cache[k].at[:, slots].set(small[k])
                               for k in ("k", "v")})
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _paged_prefill_impl(self, params, toks, cache, bt_rows, slots, cur,
                            pos, plens):
        self.trace_counts["prefill"] += 1
        group = _paged_prefill_view(cache, self.cfg.n_layers, toks.shape[0],
                                    self.cfg.n_kv)
        logits, group = TF.prefill(params, toks, self.cfg, cache=group,
                                   block_tables=bt_rows, last_pos=plens - 1)
        cache = _paged_prefill_merge(cache, group, slots)
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _decode_impl(self, params, cur, cache, pos, bt):
        self.trace_counts["decode"] += 1
        logits, cache = TF.decode_step(params, cur, cache, pos, self.cfg,
                                       block_tables=bt)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return nxt, cache, jnp.minimum(pos + 1, self.max_len - 1)

    def _admit(self, toks, plens, max_news, slots, cur, pos):
        if self.paged:
            bt_rows = self._pool.admit(slots, plens, max_news, toks.shape[1])
            self._cache, cur, pos = self._prefill(
                self.params, toks, self._cache, bt_rows, jnp.asarray(slots),
                cur, pos, jnp.asarray(plens))
        else:
            self._cache, cur, pos = self._prefill(
                self.params, toks, self._cache, jnp.asarray(slots), cur, pos,
                jnp.asarray(plens))
        return cur, pos

    def _decode_all(self, cur, pos, n_active):
        bt = self._pool.table_dev() if self.paged else None
        cur, self._cache, pos = self._decode(self.params, cur,
                                             self._cache, pos, bt)
        return cur, pos

    def _retire(self, slot):
        if self.paged:
            self._pool.retire(slot)

    def _can_admit(self, group_shapes, plen, max_new, bucket):
        if not self.paged:
            return True
        return self._pool.can_admit(group_shapes + [(plen, max_new)], bucket)

    def cache_bytes(self, *, live_only: bool = False) -> int:
        """Cache footprint in bytes.  ``live_only`` counts just the
        pages currently allocated to requests (the demand-paging win)."""
        if self.paged and live_only:
            return self._pool.live_cache_bytes(self._cache)
        return sum(v.size * v.dtype.itemsize for v in self._cache.values())


class CollaborativeServingEngine(_SlotEngine):
    """Paper mode with incremental decode: INT8 edge prefix and FP32
    cloud suffix hold *split* KV caches over their own block sub-ranges;
    each decode step ships one quantized ``[B, 1, D]`` boundary delta
    (Eq.1/2) through the channel instead of the whole growing blob.

    The edge cache defaults to the paged INT8 layout: pages allocated on
    demand through ``PageAllocator``, per-slot symmetric scales
    calibrated from each prompt at edge prefill, and decode reads
    through the paged flash-decode kernel.  ``edge_paged=False`` /
    ``edge_int8=False`` fall back to the dense / fp layouts (the
    PR-1-era configuration, kept as the equivalence oracle in tests)."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *, cut_layer: int,
                 channel: Optional[Channel] = None, max_len: int = 128,
                 a_bits: int = 8, max_batch: int = 4,
                 edge_paged: bool = True, edge_int8: bool = True,
                 page_size: int = 16, edge_num_pages: Optional[int] = None,
                 timed: bool = False):
        assert 0 <= cut_layer < cfg.n_layers, \
            f"cut_layer {cut_layer} outside [0, {cfg.n_layers})"
        super().__init__(cfg, max_batch=max_batch, max_len=max_len,
                         timed=timed)
        self.cut = cut_layer
        self.channel = channel or Channel(bandwidth_bytes_per_s=float("inf"))
        self.a_bits = a_bits
        self.n_edge = cut_layer + 1
        self.n_cloud = cfg.n_layers - self.n_edge
        self.edge_paged = edge_paged
        self.edge_int8 = edge_int8
        self.page_size = page_size

        self.edge_blocks, self.cloud_blocks = TF.split_blocks(
            params, self.cfg, cut_layer)
        self.embed = params["embed"]
        self.tail = {"final_norm": params["final_norm"],
                     "lm_head": params["lm_head"]}
        # edge weights are INT8-quantized at deployment (fake-quant lattice)
        self._edge_qctx = ML.QuantCtx(mode="dynamic", a_bits=a_bits)
        # split KV caches: edge prefix / cloud suffix block sub-ranges
        self._edge_pool: Optional[_PagedPool] = None
        if edge_paged:
            self._edge_pool = _PagedPool.build(max_batch, max_len,
                                               page_size, edge_num_pages)
            self._edge_cache = TF.init_cache(
                self.cfg, max_batch, max_len, layers=self.n_edge,
                paged=True, quantized=edge_int8, page_size=page_size,
                num_pages=self._edge_pool.allocator.num_pages)
        else:
            self._edge_cache = TF.init_cache(self.cfg, max_batch, max_len,
                                             layers=self.n_edge,
                                             quantized=edge_int8)
        self._cloud_cache = TF.init_cache(self.cfg, max_batch, max_len,
                                          layers=self.n_cloud)
        self._edge = jax.jit(self._edge_impl)
        self._cloud = jax.jit(self._cloud_impl)
        self._edge_prefill = jax.jit(self._edge_prefill_impl)
        self._cloud_prefill = jax.jit(self._cloud_prefill_impl)
        self._edge_decode = jax.jit(self._edge_decode_impl)
        self._cloud_decode = jax.jit(self._cloud_decode_impl)

    # -- wire accounting ----------------------------------------------------
    def _account(self, blob: jax.Array, *, phase: str,
                 rows: Optional[int] = None,
                 row_elems: Optional[np.ndarray] = None) -> None:
        """Charge the wire for the occupied batch rows of ``blob``.

        The jit'd decode step always computes the full fixed-shape
        [max_batch, 1, D] delta, but idle slots would never be sent, so
        the simulated wire carries only the active rows — each framed
        with its own Eq.(1) scale/zero-point (per-row quantization).
        ``row_elems`` overrides the per-row payload element count: the
        prefill blob is bucket-padded on device, but only each request's
        true prompt activations cross the wire."""
        itemsize = blob.dtype.itemsize
        if row_elems is not None:
            nbytes = int(sum(int(e) * itemsize + _QP_BYTES
                             for e in row_elems))
        else:
            n_rows = blob.shape[0] if rows is None else rows
            per_row = (blob.size // blob.shape[0]) * itemsize
            nbytes = n_rows * (per_row + _QP_BYTES)
        self.stats.transmitted_bytes += int(nbytes)
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)
        if phase == "prefill":
            self.stats.prefill_bytes += int(nbytes)
        else:
            self.stats.decode_bytes += int(nbytes)
            self.stats.decode_bytes_log.append(int(nbytes))

    def _account_downlink(self, n_rows: int) -> None:
        """The cloud→edge return of the sampled tokens: the edge can't
        embed the next token until it arrives, so every serial step pays
        a second transfer (4 B token per live request + channel RTT).
        Counted in ``transmitted_bytes``/``channel_latency_s`` but not in
        the decode-delta uplink split."""
        nbytes = 4 * n_rows
        self.stats.transmitted_bytes += nbytes
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)

    # -- incremental split-cache phases --------------------------------------
    def _edge_prefill_impl(self, blocks, embed, toks, cache, slots, bt_rows,
                           plens):
        self.trace_counts["prefill"] += 1
        cfg = self.cfg
        n, s = toks.shape
        x = ML.embed(embed, toks).astype(cfg.dtype)
        if self.edge_paged:
            group = _paged_prefill_view(cache, self.n_edge, n, cfg.n_kv)
            h, group = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                     cache=group, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx,
                                     block_tables=bt_rows,
                                     calibrate_kv=self.edge_int8,
                                     kv_lengths=plens)
            cache = _paged_prefill_merge(cache, group, slots)
        else:
            small = TF.init_cache(cfg, n, self.max_len, layers=self.n_edge,
                                  quantized=self.edge_int8)
            h, small = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                     cache=small, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx)
            cache = dict(cache, **{k: cache[k].at[:, slots].set(small[k])
                                   for k in ("k", "v")})
        # Eq.(1), per batch row: each request gets its own thresholds, so
        # one request's range never depends on its neighbours' activations
        # — or on its own bucket padding (pad positions are clamped to a
        # real activation before the min/max reduction; the padded tail
        # never crosses the wire, see _account)
        ranged = jnp.where(jnp.arange(s)[None, :, None] <
                           plens[:, None, None], h, h[:, :1])
        qp = compute_qparams(ranged, axis=0, bits=self.a_bits)
        return quantize(h, qp), qp, cache

    def _cloud_prefill_impl(self, blocks, tail, blob, qp, cache, slots,
                            cur, pos, plens):
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2)
        n = h.shape[0]
        small = TF.init_cache(cfg, n, self.max_len, layers=self.n_cloud)
        x, small = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=small, cache_index=jnp.int32(0))
        cache = {k: cache[k].at[:, slots].set(small[k]) for k in cache}
        logits = TF.lm_head(tail, x[jnp.arange(n), plens - 1][:, None])[:, 0]
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _edge_decode_impl(self, blocks, embed, cur, cache, pos, bt):
        self.trace_counts["decode"] += 1
        cfg = self.cfg
        x = ML.embed(embed, cur[:, None]).astype(cfg.dtype)
        h, cache = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 qctx=self._edge_qctx, block_tables=bt)
        # Eq.(1) per row: stale activations in idle/freed slots must not
        # set the quant range of live requests' deltas
        qp = compute_qparams(h, axis=0, bits=self.a_bits)
        return quantize(h, qp), qp, cache                  # [B, 1, D] delta

    def _cloud_decode_impl(self, blocks, tail, blob, qp, cache, pos):
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2)
        x, cache = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos)
        logits = TF.lm_head(tail, x)[:, 0]
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return nxt, cache, jnp.minimum(pos + 1, self.max_len - 1)

    # -- scheduler hooks ----------------------------------------------------
    def _admit(self, toks, plens, max_news, slots, cur, pos):
        bt_rows = None
        if self.edge_paged:
            bt_rows = self._edge_pool.admit(slots, plens, max_news,
                                            toks.shape[1])
        slots_j = jnp.asarray(slots)
        plens_j = jnp.asarray(plens)
        blob, qp, self._edge_cache = self._edge_prefill(
            self.edge_blocks, self.embed, toks, self._edge_cache, slots_j,
            bt_rows, plens_j)
        self._account(blob, phase="prefill",
                      row_elems=plens.astype(np.int64) * self.cfg.d_model)
        self._cloud_cache, cur, pos = self._cloud_prefill(
            self.cloud_blocks, self.tail, blob, qp, self._cloud_cache,
            slots_j, cur, pos, plens_j)
        self._account_downlink(toks.shape[0])
        return cur, pos

    def _decode_all(self, cur, pos, n_active):
        bt = self._edge_pool.table_dev() if self.edge_paged else None
        blob, qp, self._edge_cache = self._edge_decode(
            self.edge_blocks, self.embed, cur, self._edge_cache, pos, bt)
        self._account(blob, phase="decode", rows=n_active)
        cur, self._cloud_cache, pos = self._cloud_decode(
            self.cloud_blocks, self.tail, blob, qp, self._cloud_cache, pos)
        self._account_downlink(n_active)
        return cur, pos

    def _retire(self, slot):
        if self.edge_paged:
            self._edge_pool.retire(slot)

    def _can_admit(self, group_shapes, plen, max_new, bucket):
        if not self.edge_paged:
            return True
        return self._edge_pool.can_admit(group_shapes + [(plen, max_new)],
                                         bucket)

    def edge_cache_bytes(self, *, live_only: bool = False) -> int:
        """Edge KV footprint; ``live_only`` counts allocated pages only."""
        if self.edge_paged and live_only:
            return self._edge_pool.live_cache_bytes(self._edge_cache)
        return sum(v.size * v.dtype.itemsize
                   for v in self._edge_cache.values())

    # -- seed recompute path (kept as the benchmark baseline) ----------------
    def _edge_impl(self, blocks, embed, tokens):
        cfg = self.cfg
        x = ML.embed(embed, tokens).astype(cfg.dtype)
        rope = ML.rope_table(tokens.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)
        x, _ = TF.run_blocks(blocks, x, cfg, rope=rope, qctx=self._edge_qctx)
        return x

    def _cloud_impl(self, blocks, tail, h):
        cfg = self.cfg
        rope = ML.rope_table(h.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)
        h, _ = TF.run_blocks(blocks, h, cfg, rope=rope)
        return TF.lm_head(tail, h)

    def forward(self, tokens: np.ndarray) -> jax.Array:
        """Mixed-precision collaborative forward → logits [B, S, V]
        (cache-less: re-runs the whole split stack; the seed path)."""
        toks = jnp.asarray(tokens, jnp.int32)
        h = self._edge(self.edge_blocks, self.embed, toks)
        # Eq.(1): quantize boundary blob for the wire
        qp = compute_qparams(h, bits=self.a_bits)
        blob = quantize(h, qp)
        nbytes = blob.size * blob.dtype.itemsize + _QP_BYTES
        self.stats.transmitted_bytes += int(nbytes)
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)
        h = dequantize(blob, qp).astype(self.cfg.dtype)       # Eq.(2)
        return self._cloud(self.cloud_blocks, self.tail, h)

    def generate_recompute(self, prompts: List[np.ndarray], *,
                           max_new_tokens: int = 8) -> List[List[int]]:
        """Seed greedy decode: re-run the split forward on the full,
        growing sequence every step (KV-less edge, O(S²·L) per token and
        the whole boundary blob retransmitted).  Kept as the baseline the
        incremental path is benchmarked against."""
        toks = np.stack(prompts).astype(np.int32)
        out = [[] for _ in prompts]
        for _ in range(max_new_tokens):
            logits = self.forward(toks)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for j, t in enumerate(nxt):
                out[j].append(int(t))
            toks = np.concatenate([toks, nxt[:, None].astype(np.int32)], 1)
            self.stats.decode_steps += 1
        return out
