"""Batched LM serving engine with KV cache + collaborative (cloud-edge)
mode — the deployment side of the paper.

``ServingEngine`` is the cloud-only baseline: batched prefill, then
step-wise greedy decode over a shared KV cache, with slot-based
continuous batching (a finished request frees its slot for the next
queued prompt).

``CollaborativeServingEngine`` is the paper's mode: the first K blocks
run as the INT8 edge engine (fake-quant lattice == the Pallas int8
kernel's math), the boundary hidden state is quantized per Eq.(1),
"transmitted" through the simulated wireless channel, dequantized per
Eq.(2), and the cloud engine finishes the stack in full precision.  The
auto-tuner (Algorithm 1) chooses K.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import Channel
from repro.core.quant import compute_qparams, dequantize, quantize
from repro.models import layers as ML
from repro.models import transformer as TF

Params = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    prefill_calls: int = 0
    decode_steps: int = 0
    transmitted_bytes: int = 0
    channel_latency_s: float = 0.0


class ServingEngine:
    """Cloud-only batched engine (greedy decode)."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *,
                 max_batch: int = 4, max_len: int = 128):
        self.params = params
        self.cfg = dataclasses.replace(cfg, remat=False)
        self.max_batch = max_batch
        self.max_len = max_len
        self.stats = ServeStats()
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    def _prefill_impl(self, params, tokens, cache):
        return TF.prefill(params, tokens, self.cfg, cache=cache)

    def _decode_impl(self, params, token, cache, idx):
        return TF.decode_step(params, token, cache, idx, self.cfg)

    def generate(self, prompts: List[np.ndarray], *,
                 max_new_tokens: int = 16) -> List[List[int]]:
        """Greedy-decode a list of same-length prompts, batched."""
        outs: List[List[int]] = []
        for i in range(0, len(prompts), self.max_batch):
            chunk = prompts[i:i + self.max_batch]
            outs.extend(self._generate_batch(chunk, max_new_tokens))
        return outs

    def _generate_batch(self, prompts: List[np.ndarray],
                        max_new: int) -> List[List[int]]:
        b = len(prompts)
        plen = len(prompts[0])
        assert all(len(p) == plen for p in prompts), "same-length batch"
        toks = jnp.asarray(np.stack(prompts).astype(np.int32))
        cache = TF.init_cache(self.cfg, b, max_len=self.max_len)
        logits, cache = self._prefill(self.params, toks, cache)
        self.stats.prefill_calls += 1
        out = [[] for _ in range(b)]
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for step in range(max_new):
            for j in range(b):
                out[j].append(int(cur[j]))
            logits, cache = self._decode(self.params, cur, cache,
                                         jnp.int32(plen + step))
            self.stats.decode_steps += 1
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return out


class CollaborativeServingEngine:
    """Paper mode: INT8 edge prefix (first ``cut_layer+1`` blocks) +
    FP32 cloud suffix, boundary blob quantized per Eq.(1)/(2)."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *, cut_layer: int,
                 channel: Optional[Channel] = None, max_len: int = 128,
                 a_bits: int = 8):
        assert 0 <= cut_layer < cfg.n_layers
        self.cfg = dataclasses.replace(cfg, remat=False)
        self.cut = cut_layer
        self.channel = channel or Channel(bandwidth_bytes_per_s=float("inf"))
        self.max_len = max_len
        self.a_bits = a_bits
        self.stats = ServeStats()

        take = lambda t, lo, hi: jax.tree_util.tree_map(
            lambda v: v[lo:hi], t)
        self.edge_blocks = take(params["blocks"], 0, cut_layer + 1)
        self.cloud_blocks = take(params["blocks"], cut_layer + 1,
                                 cfg.n_layers)
        self.embed = params["embed"]
        self.tail = {"final_norm": params["final_norm"],
                     "lm_head": params["lm_head"]}
        # edge weights are INT8-quantized at deployment (fake-quant lattice)
        self._edge_qctx = ML.QuantCtx(mode="dynamic", a_bits=a_bits)
        self._edge = jax.jit(self._edge_impl)
        self._cloud = jax.jit(self._cloud_impl)

    # -- the two engines ----------------------------------------------------
    def _edge_impl(self, blocks, embed, tokens):
        cfg = self.cfg
        x = ML.embed(embed, tokens).astype(cfg.dtype)
        rope = ML.rope_table(tokens.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)

        def body(x, bp):
            y, _, _ = TF.block_apply(bp, x, cfg, rope=rope,
                                     qctx=self._edge_qctx)
            return y, None

        x, _ = jax.lax.scan(body, x, blocks)
        return x

    def _cloud_impl(self, blocks, tail, h):
        cfg = self.cfg
        rope = ML.rope_table(h.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)

        def body(x, bp):
            y, _, _ = TF.block_apply(bp, x, cfg, rope=rope)
            return y, None

        h, _ = jax.lax.scan(body, h, blocks)
        h = ML.rmsnorm(tail["final_norm"], h)
        return ML.dense(tail["lm_head"], h, name="lm_head")

    # -- end-to-end -----------------------------------------------------------
    def forward(self, tokens: np.ndarray) -> jax.Array:
        """Mixed-precision collaborative forward → logits [B, S, V]."""
        toks = jnp.asarray(tokens, jnp.int32)
        h = self._edge(self.edge_blocks, self.embed, toks)
        # Eq.(1): quantize boundary blob for the wire
        qp = compute_qparams(h, bits=self.a_bits)
        blob = quantize(h, qp)
        nbytes = blob.size * blob.dtype.itemsize + 8
        self.stats.transmitted_bytes += int(nbytes)
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)
        h = dequantize(blob, qp).astype(self.cfg.dtype)       # Eq.(2)
        return self._cloud(self.cloud_blocks, self.tail, h)

    def generate(self, prompts: List[np.ndarray], *,
                 max_new_tokens: int = 8) -> List[List[int]]:
        """Greedy decode by re-running the split forward (KV-less edge —
        the edge device stores no cache, matching thin-client deploys)."""
        toks = np.stack(prompts).astype(np.int32)
        out = [[] for _ in prompts]
        for _ in range(max_new_tokens):
            logits = self.forward(toks)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for j, t in enumerate(nxt):
                out[j].append(int(t))
            toks = np.concatenate([toks, nxt[:, None].astype(np.int32)], 1)
            self.stats.decode_steps += 1
        return out
