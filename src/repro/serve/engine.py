"""Batched LM serving with KV caches + collaborative (cloud-edge) mode —
the deployment side of the paper.

Both engines share one slot-based continuous-batching scheduler
(``_SlotEngine``): requests queue up, prompts are right-padded to
power-of-two *buckets* and same-bucket prompts are prefilled together
into free cache slots (bounding the number of distinct compiled prefill
shapes — see ``trace_counts``), every **round** advances all occupied
slots at their own positions (vector ``cache_index``) by one or more
committed tokens, and a finished request frees its slot — and its KV
pages — for the next queued prompt mid-flight, including *mid-round*
when a round commits past its budget.  Sampled tokens stay on device for
the whole generation; the host sees them once, after the last round (a
speculative engine additionally syncs one small per-round accept-count
vector, which the edge needs anyway to schedule the next round).

KV cache layouts (see ``transformer.init_cache`` for shapes):

* **dense** — every slot owns ``max_len`` positions up front; the
  decode einsum streams the whole ``[B, max_len]`` cache each step.
* **paged** — slots own a block-table row into a shared page pool
  (``PageAllocator``); HBM is claimed page-by-page at admission and
  returned at retirement, and reads run the paged flash kernel
  (``kernels.paged_attention``) whose cost scales with *allocated*
  pages, not ``max_len``.
* **paged + INT8** — pages store 1 B/elem with per-slot symmetric
  scales calibrated from each prompt at prefill (paper Eq.1 applied to
  serving state); dequantization happens inside the kernel's QK/AV
  loops so the cache never materializes above 1 B/elem.

``ServingEngine`` is the cloud-only baseline: one KV cache over the full
stack (dense fp by default; ``paged=True``/``int8_kv=True`` opt in).

``CollaborativeServingEngine`` is the paper's mode rebuilt around
*incremental decode*: the INT8 edge prefix (first ``cut_layer+1``
blocks, fake-quant lattice == the Pallas int8 kernel's math) and the
FP32 cloud suffix each own a KV cache covering only their block
sub-range.  Both sides default to the **paged INT8** layout and share
one block table, so edge and cloud track identical page geometry and a
verify-round rollback is a per-slot length decrement on either side.
The auto-tuner (Algorithm 1) chooses the cut; a second auto-tuner
(``autotune.tune_spec_k``) chooses the draft length ``spec_k``.

Draft/verify wire protocol (``spec_k = k``)
-------------------------------------------
With ``spec_k == 1`` (the default) every decode round is PR 1's
incremental step, bit for bit: the edge runs the new token through its
INT8 prefix, ships one per-row-quantized ``[B, 1, D]`` boundary delta
(Eq.1) uplink, the cloud suffix finishes the token and returns it
4 B/row downlink.  Channel RTT is paid twice per generated token.

With ``spec_k = k > 1`` the serial loop is restructured into
**draft/verify rounds** that amortize that RTT over up to ``k`` tokens:

1. **Draft (edge, local).**  Starting from the last committed token,
   the edge runs the *full* split model ``k`` times at low precision —
   its INT8 prefix over the paged INT8 edge cache, then a lightweight
   INT8 copy of the cloud-suffix weights (the same fake-quant lattice
   the prefix uses) over a local *draft* KV cache that shares the edge
   block table.  Each step emits the Eq.(1)-quantized boundary delta
   and greedily drafts the next token from the local suffix.
2. **Uplink (one transfer).**  The edge ships the concatenated
   ``[B, k, D]`` quantized boundary blob — each of the k rows framed
   with its own per-row scale/zero-point so the cloud dequantizes
   exactly what a serial step would have seen — plus the ``k-1`` draft
   tokens the cloud must grade (4 B each).  One channel traversal.
3. **Verify (cloud, one batched step).**  The cloud suffix runs all
   ``k`` positions in a single multi-token cached step (the paged
   kernel's q-block form attends cache + the in-flight block under an
   intra-block causal mask) and takes the longest prefix of drafts that
   match its own greedy tokens: ``n_commit = 1 + #leading matches`` —
   the corrected/next token at the first divergence is always
   committed, so a round commits between 1 and k tokens and ``k = 1``
   degenerates to the non-speculative step.
4. **Rollback (both sides, O(1)).**  Rejected positions are *not*
   erased: both sides simply keep their per-slot committed length at
   ``pos + n_commit``.  Paged block tables make this exact — later
   reads mask stale entries by causality/length and later writes
   overwrite them in place — so rollback is a length decrement, never a
   copy.
5. **Downlink (one transfer).**  The cloud returns the accept mask
   (``ceil(k/8)`` B/row) and the corrected token (4 B/row); the edge
   rolls back its own prefix + draft caches the same way and starts the
   next round.  One channel traversal.

Accounting: ``ServeStats`` charges the uplink blob + draft tokens as
decode bytes, the accept-mask + token return as decode downlink bytes,
and counts *accepted* tokens — ``bytes_per_decode_token`` is uplink
bytes per accepted token (comparable with PR 1/PR 2 numbers, where
every token was trivially accepted) and
``wire_bytes_per_accepted_token`` adds the downlink.  Every message
additionally pays a fixed protocol header (``_MSG_BYTES``) — charged
once per round instead of once per token, which together with the RTT
is what speculation buys on the wire.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import Channel
from repro.core.quant import compute_qparams, dequantize, quantize
from repro.models import layers as ML
from repro.models import transformer as TF

Params = Any

# wire framing overhead for one quantized blob: f32 scale + f32 zero-point
_QP_BYTES = 8
# wire bytes for one token id (cloud→edge return / edge→cloud draft)
_TOK_BYTES = 4
# per-*message* protocol framing (TCP/IP-class headers + slot ids/round
# counter): every channel traversal pays it once, which is exactly what a
# draft/verify round amortizes k-fold alongside the RTT
_MSG_BYTES = 64


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _bucket_len(plen: int, max_len: int) -> int:
    """Power-of-two prefill bucket (floor 8, capped at ``max_len``)."""
    b = 8
    while b < plen:
        b *= 2
    return min(b, max_len)


def _jit_phase(fn, donate: Tuple[int, ...] = ()):
    """``jax.jit`` with the KV-cache argument(s) donated, so the page-pool
    scatter of every prefill/decode/verify updates the cache *in place*
    on TPU/GPU instead of doubling resident cache bytes per step.  The
    engines always consume the returned cache and never touch the donated
    buffer again, so donation is safe.  XLA:CPU ignores donation and
    warns per call, so off-accelerator we jit plain."""
    if donate and jax.default_backend() in ("tpu", "gpu"):
        return jax.jit(fn, donate_argnums=donate)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Paged-KV bookkeeping (host side)
# ---------------------------------------------------------------------------


class PageAllocator:
    """LIFO free-list allocator over a fixed pool of KV-cache pages.

    Page 0 is never handed out: retired/idle slots keep a zeroed block
    table row, so their (masked, harmless) decode writes land in page 0
    instead of corrupting a page that has been re-allocated to a live
    request.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least one allocatable page"
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))
        self._live: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def live(self) -> frozenset:
        return frozenset(self._live)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: need {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._live.update(pages)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p not in self._live:
                raise ValueError(f"double free of page {p}")
            self._live.remove(p)
            self._free.append(p)


class _PagedPool:
    """Block table + allocator for one engine-side page pool.

    Pages for a request are claimed once at admission — enough to cover
    its padded prompt plus its (known) generation budget, plus any
    speculative-round headroom — and returned the moment the scheduler
    retires the slot.  The collaborative engine shares one pool (one
    block table) across its edge-prefix, cloud-suffix, and draft caches:
    all three see identical page geometry, so a verify-round rollback is
    the same length decrement on every cache.
    """

    def __init__(self, max_batch: int, pages_per_slot: int, num_pages: int,
                 page_size: int):
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        self.allocator = PageAllocator(num_pages)
        self.bt = np.zeros((max_batch, pages_per_slot), np.int32)
        self._slot_pages: Dict[int, List[int]] = {}
        self._dev: Optional[jax.Array] = None

    @classmethod
    def build(cls, max_batch: int, max_len: int, page_size: int,
              num_pages: Optional[int] = None) -> "_PagedPool":
        """Standard sizing: worst case ``max_batch`` full-length slots
        plus the reserved dump page, unless ``num_pages`` undersizes the
        pool on purpose (admission then backpressures, see
        ``_SlotEngine._can_admit``)."""
        pages_per_slot = _cdiv(max_len, page_size)
        if num_pages is None:
            num_pages = max_batch * pages_per_slot + 1
        return cls(max_batch, pages_per_slot, num_pages, page_size)

    def pages_needed(self, plen: int, max_new: int, padded_len: int) -> int:
        return _cdiv(max(int(plen) + int(max_new), int(padded_len)),
                     self.page_size)

    def can_admit(self, shapes: Sequence[Tuple[int, int]],
                  padded_len: int) -> bool:
        """Would a prefill group of (plen, max_new) shapes fit the free
        list right now?"""
        return sum(self.pages_needed(p, m, padded_len)
                   for p, m in shapes) <= self.allocator.num_free

    def live_cache_bytes(self, cache: Dict[str, jax.Array]) -> int:
        """Bytes resident in currently-allocated pages (+ scales) of the
        paged ``cache`` this pool indexes — the demand-paging footprint,
        as opposed to the pool's capacity."""
        per_page = int(np.prod(cache["k_pages"].shape[2:])) \
            * cache["k_pages"].dtype.itemsize
        n_layers = cache["k_pages"].shape[0]
        scales = sum(v.size * v.dtype.itemsize
                     for k, v in cache.items() if "scale" in k)
        return 2 * n_layers * len(self.allocator.live) * per_page + scales

    def admit(self, slots: Sequence[int], plens: Sequence[int],
              max_news: Sequence[int], padded_len: int) -> jax.Array:
        """Allocate pages for a prefill group; returns the group's block
        table rows [n, pages_per_slot]."""
        for s, pl_, mn in zip(slots, plens, max_news):
            pages = self.allocator.alloc(
                self.pages_needed(pl_, mn, padded_len))
            self._slot_pages[int(s)] = pages
            self.bt[s, :] = 0
            self.bt[s, :len(pages)] = pages
        self._dev = None
        # trim to the pages the padded prompt can touch: the prefill's
        # q-block read costs O(table width), so handing it the full
        # pages_per_slot row would make prefill scale with max_len
        # instead of the bucket (the generation's later pages are only
        # reachable by decode, which re-reads through table_dev)
        width = max(1, _cdiv(padded_len, self.page_size))
        # explicit copy: jax on CPU may zero-copy-alias numpy buffers, and
        # ``bt`` is mutated on the host while async decode steps are still
        # in flight — sharing it would race
        return jnp.array(self.bt[np.asarray(slots)][:, :width], copy=True)

    def retire(self, slot: int) -> None:
        pages = self._slot_pages.pop(int(slot), None)
        if pages is not None:
            self.allocator.free(pages)
            self.bt[slot, :] = 0
            self._dev = None

    def table_dev(self) -> jax.Array:
        """Block table on device, trimmed to the pages actually in use
        (rounded up to a power of two, so decode retraces are bounded by
        log2(pages_per_slot) widths, not every occupancy) — the decode
        read then costs O(allocated pages), not O(max_len).  Cached
        until the next admit/retire.  Copied, never aliased: the host
        mutates ``bt`` while earlier async decode steps may still be
        reading the device buffer."""
        if self._dev is None:
            used = max((len(p) for p in self._slot_pages.values()),
                       default=1)
            width = 1
            while width < used:
                width *= 2
            width = min(width, self.pages_per_slot)
            self._dev = jnp.array(self.bt[:, :width], copy=True)
        return self._dev


def _paged_prefill_view(cache: Dict[str, jax.Array], n_layers: int, n: int,
                        n_kv: int) -> Dict[str, jax.Array]:
    """Group-local view of a paged cache for one prefill call: the
    shared page pool plus fresh scale rows for the ``n``-row group (the
    prefill calibrates them; scatter back with _paged_prefill_merge)."""
    group = {"k_pages": cache["k_pages"], "v_pages": cache["v_pages"]}
    if "k_scale" in cache:
        group["k_scale"] = jnp.zeros((n_layers, n, n_kv), jnp.float32)
        group["v_scale"] = jnp.zeros_like(group["k_scale"])
    return group


def _paged_prefill_merge(cache: Dict[str, jax.Array],
                         group: Dict[str, jax.Array],
                         slots: jax.Array) -> Dict[str, jax.Array]:
    cache = dict(cache, k_pages=group["k_pages"], v_pages=group["v_pages"])
    if "k_scale" in cache:
        cache["k_scale"] = cache["k_scale"].at[:, slots].set(
            group["k_scale"])
        cache["v_scale"] = cache["v_scale"].at[:, slots].set(
            group["v_scale"])
    return cache


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    """Per-phase serving counters.

    ``transmitted_bytes`` is the total over the wire — prefill and
    decode uplinks plus every cloud→edge downlink, each *message*
    carrying its ``_MSG_BYTES`` protocol header on top of the payload
    (headers, like the RTT, are paid per traversal — the quantity a
    draft/verify round amortizes k-fold).  ``decode_bytes`` is the
    decode-phase *uplink*: per-row-quantized boundary deltas (one
    ``[1, D]`` frame per live request per drafted position) plus, in
    speculative rounds, the 4 B draft-token ids the cloud grades.  The
    per-round ``decode_bytes_log`` records those uplinks: each entry
    shrinks as slots free and never grows with sequence length, which
    is the O(1)-per-token property.  ``downlink_bytes`` counts the
    return direction — the sampled/corrected token (4 B/row) plus, in
    speculative rounds, the accept mask (``ceil(k/8)`` B/row); its
    decode-phase share is ``decode_downlink_bytes``.  Prefill uplinks
    are charged by each request's *true* prompt length — bucket padding
    is a compile-shape artifact and never crosses the wire.

    ``decode_tokens`` counts **accepted (committed) tokens** — for the
    non-speculative engines every decoded token is trivially accepted,
    so the PR 1/PR 2 meaning is unchanged.  ``drafted_tokens`` /
    ``draft_hits`` grade the speculative drafts the verify step
    compared (k-1 per round per live slot), giving ``acceptance_rate``.
    ``bytes_per_decode_token`` is uplink bytes per accepted token;
    ``wire_bytes_per_accepted_token`` adds the decode downlink.

    ``prefill_s``/``decode_s`` are wall-clock phase totals, populated
    when the engine runs with ``timed=True`` (timing blocks on device
    results, so it is off by default to keep the decode loop fully
    async)."""
    prefill_calls: int = 0
    decode_steps: int = 0
    transmitted_bytes: int = 0
    channel_latency_s: float = 0.0
    # per-phase splits
    prefill_bytes: int = 0
    decode_bytes: int = 0
    decode_bytes_log: List[int] = dataclasses.field(default_factory=list)
    downlink_bytes: int = 0
    decode_downlink_bytes: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    # speculative draft/verify rounds
    spec_rounds: int = 0
    drafted_tokens: int = 0
    draft_hits: int = 0

    def bytes_per_decode_token(self) -> float:
        """Decode *uplink* bytes per accepted token (PR 1/PR 2 metric)."""
        return self.decode_bytes / max(self.decode_tokens, 1)

    def wire_bytes_per_accepted_token(self) -> float:
        """Both directions per accepted token: uplink deltas + drafts
        and the downlink accept-mask + corrected token."""
        return (self.decode_bytes + self.decode_downlink_bytes) \
            / max(self.decode_tokens, 1)

    def acceptance_rate(self) -> float:
        """Fraction of graded speculative drafts the verify accepted."""
        return self.draft_hits / max(self.drafted_tokens, 1)

    def report(self) -> Dict[str, float]:
        return {
            "prefill_calls": self.prefill_calls,
            "decode_steps": self.decode_steps,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "accepted_tokens": self.decode_tokens,
            "transmitted_bytes": self.transmitted_bytes,
            "prefill_bytes": self.prefill_bytes,
            "decode_bytes": self.decode_bytes,
            "downlink_bytes": self.downlink_bytes,
            "bytes_per_decode_token": self.bytes_per_decode_token(),
            "wire_bytes_per_accepted_token":
                self.wire_bytes_per_accepted_token(),
            "spec_rounds": self.spec_rounds,
            "drafted_tokens": self.drafted_tokens,
            "acceptance_rate": self.acceptance_rate(),
            "channel_latency_s": self.channel_latency_s,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
        }


class _SlotEngine:
    """Slot-based continuous-batching scheduler shared by both engines.

    Subclasses implement ``_admit`` (prefill a prompt group into specific
    slots), ``_decode_all`` (advance every slot one token) and/or
    ``_round`` (advance every slot by a *variable* number of committed
    tokens — the speculative draft/verify round), and may hook
    ``_retire`` (a slot's request finished — e.g. return its KV pages).
    The scheduler keeps the current token and position of every slot on
    device; request outputs are transferred to the host once, after the
    final round.

    The loop is organised around **rounds**: admission commits one token
    per new slot (the prefill's argmax), and every scheduler turn after
    that commits ``counts[s]`` tokens per occupied slot, where the
    non-speculative engines statically commit one (``counts is None`` —
    no device sync, the loop stays fully async) and a speculative round
    returns the verify step's per-slot accept counts.  Per-slot
    accepted-length bookkeeping trims a round that overshoots a
    request's budget and retires the slot mid-stream ("retire on
    accept"), so the next queued prompt gets the slot and its pages.

    Admission pads each prompt group to a power-of-two bucket
    (``_bucket_len``), so the number of distinct prefill trace shapes is
    bounded by O(log2(max_len) · max_batch) instead of growing with
    every unique prompt length.  ``trace_counts`` counts actual
    retraces of the jit'd phase functions; tests pin it.
    """

    def __init__(self, cfg: TF.LMConfig, *, max_batch: int, max_len: int,
                 timed: bool = False):
        self.cfg = dataclasses.replace(cfg, remat=False)
        self.max_batch = max_batch
        self.max_len = max_len
        self.timed = timed
        self.stats = ServeStats()
        self.trace_counts = {"prefill": 0, "decode": 0, "spec_draft": 0,
                             "verify": 0}

    # -- subclass interface -------------------------------------------------
    def _admit(self, toks: jax.Array, plens: np.ndarray, max_news: np.ndarray,
               slots: np.ndarray, cur: jax.Array, pos: jax.Array,
               ) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _decode_all(self, cur: jax.Array, pos: jax.Array,
                    n_active: int) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _round(self, cur: jax.Array, pos: jax.Array, slots: np.ndarray,
               ) -> Tuple[jax.Array, jax.Array, jax.Array,
                          Optional[np.ndarray]]:
        """Advance the occupied ``slots`` by one round.

        Returns ``(cur, pos, tokens, counts)``: ``tokens`` is the
        ``[max_batch, k]`` device block of tokens the round produced and
        ``counts`` the per-slot number of *committed* leading tokens —
        ``None`` means "statically one per slot" (the non-speculative
        path, which therefore never blocks on the device)."""
        cur, pos = self._decode_all(cur, pos, len(slots))
        return cur, pos, cur[:, None], None

    def _round_headroom(self) -> int:
        """Cache positions a round may write *past* a request's budget
        (speculative drafting overshoots by up to k-1); admission
        reserves them so overshoot writes can never alias another
        request's pages."""
        return 0

    def _retire(self, slot: int) -> None:
        """Hook: the request in ``slot`` finished (free paged KV, etc.)."""

    def _can_admit(self, group_shapes: List[Tuple[int, int]], plen: int,
                   max_new: int, bucket: int) -> bool:
        """Hook: may this request join the prefill group right now?
        ``group_shapes`` are the (plen, max_new) pairs already accepted
        into the group this round.  Paged engines refuse when the page
        pool can't cover the whole group, backpressuring admission until
        retirements return pages."""
        return True

    # -- shared helpers -----------------------------------------------------
    def _rope(self):
        return ML.rope_table(self.max_len, self.cfg.hd,
                             base=self.cfg.rope_base, dtype=self.cfg.dtype)

    def _timed(self, phase: str, fn):
        if not self.timed:
            return fn()
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        setattr(self.stats, phase,
                getattr(self.stats, phase) + time.perf_counter() - t0)
        return out

    # -- scheduler ----------------------------------------------------------
    def generate(self, prompts: List[np.ndarray], *,
                 max_new_tokens: int = 16) -> List[List[int]]:
        """Greedy-decode a list of prompts with continuous batching."""
        reqs = [Request(uid=i, prompt=np.asarray(p),
                        max_new_tokens=max_new_tokens)
                for i, p in enumerate(prompts)]
        if reqs:
            self._run(reqs)
        return [r.out_tokens for r in reqs]

    def _run(self, reqs: List[Request]) -> None:
        queue = deque(reqs)
        active: Dict[int, Tuple[Request, int]] = {}  # slot -> (req, n_committed)
        free = list(range(self.max_batch))
        cur = jnp.zeros((self.max_batch,), jnp.int32)
        pos = jnp.zeros((self.max_batch,), jnp.int32)
        # every admission and every round logs (token block [B, k], takes);
        # token blocks stay on device until one concat+transfer at the end
        rounds: List[Tuple[jax.Array, List[Tuple[Request, int, int]]]] = []
        while queue or active:
            # admit queued prompts into free slots, grouping by prefill
            # bucket so one batched, fixed-shape prefill call covers the
            # whole group; a paged engine may refuse (pool backpressure),
            # in which case the request waits for a retirement
            stalled = False
            while free and queue and not stalled:
                bucket = _bucket_len(len(queue[0].prompt), self.max_len)
                group, slots = [], []
                shapes: List[Tuple[int, int]] = []
                while free and queue and _bucket_len(
                        len(queue[0].prompt), self.max_len) == bucket:
                    r = queue[0]
                    assert (len(r.prompt) + r.max_new_tokens
                            + self._round_headroom()) <= self.max_len, \
                        "prompt + generation (+ draft headroom) exceeds " \
                        "cache max_len"
                    if not self._can_admit(shapes, len(r.prompt),
                                           r.max_new_tokens, bucket):
                        stalled = True
                        break
                    shapes.append((len(r.prompt), r.max_new_tokens))
                    group.append(queue.popleft())
                    slots.append(free.pop(0))
                if not group:
                    break
                toks = np.zeros((len(group), bucket), np.int32)
                for i, r in enumerate(group):
                    toks[i, :len(r.prompt)] = r.prompt
                plens = np.asarray([len(r.prompt) for r in group], np.int32)
                max_news = np.asarray([r.max_new_tokens for r in group],
                                      np.int32)
                slots_a = np.asarray(slots, np.int32)
                toks_j = jnp.asarray(toks)
                cur, pos = self._timed(
                    "prefill_s",
                    lambda: self._admit(toks_j, plens, max_news, slots_a,
                                        cur, pos))
                self.stats.prefill_calls += 1
                self.stats.prefill_tokens += int(plens.sum())
                # the prefill's argmax is the group's first committed token
                rounds.append((cur[:, None],
                               [(r, s, 1) for r, s in zip(group, slots)]))
                for r, s in zip(group, slots):
                    active[s] = (r, 1)
            if stalled and not active:
                r = queue[0]
                raise RuntimeError(
                    f"KV page pool too small for request uid={r.uid} "
                    f"(prompt {len(r.prompt)} + {r.max_new_tokens} new "
                    f"tokens) even with every slot idle")
            # retire requests whose budget just filled — before the next
            # round, so no request pays for a round it never reads and
            # its slot (and KV pages) free one round earlier for the queue
            for s in [s for s, (r, c) in active.items()
                      if c >= r.max_new_tokens]:
                r, _ = active.pop(s)
                r.done = True
                self._retire(s)
                free.append(s)
            if active:
                act_slots = np.asarray(sorted(active), np.int32)
                cur, pos, toks_r, counts = self._timed(
                    "decode_s",
                    lambda: self._round(cur, pos, act_slots))
                takes = []
                for s in act_slots:
                    r, c = active[int(s)]
                    n = 1 if counts is None else int(counts[s])
                    n = min(n, r.max_new_tokens - c)  # trim budget overshoot
                    active[int(s)] = (r, c + n)
                    takes.append((r, int(s), n))
                rounds.append((toks_r, takes))
                self.stats.decode_steps += 1
                self.stats.decode_tokens += sum(n for _, _, n in takes)
        # single device→host transfer for the whole run
        all_toks = np.asarray(
            jnp.concatenate([t for t, _ in rounds], axis=1))
        col = 0
        for toks_r, takes in rounds:
            for r, s, n in takes:
                r.out_tokens.extend(int(t) for t in all_toks[s, col:col + n])
            col += toks_r.shape[1]


class ServingEngine(_SlotEngine):
    """Cloud-only batched engine (greedy decode, continuous batching).

    ``paged=True`` swaps the dense per-slot cache for the block-table
    page pool (+ ``int8_kv=True`` for 1 B/elem pages with per-slot
    scales); ``cache_dtype`` overrides the dense cache's storage dtype
    (e.g. bf16 for the fp16-cache baseline in the benchmarks)."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *,
                 max_batch: int = 4, max_len: int = 128,
                 paged: bool = False, page_size: int = 16,
                 int8_kv: bool = False, num_pages: Optional[int] = None,
                 cache_dtype=None, timed: bool = False):
        super().__init__(cfg, max_batch=max_batch, max_len=max_len,
                         timed=timed)
        self.params = params
        self.paged = paged
        self.page_size = page_size
        self.int8_kv = int8_kv
        if paged:
            self._pool = _PagedPool.build(max_batch, max_len, page_size,
                                          num_pages)
            self._cache = TF.init_cache(
                self.cfg, max_batch, max_len, paged=True,
                page_size=page_size, quantized=int8_kv,
                num_pages=self._pool.allocator.num_pages, dtype=cache_dtype)
            self._prefill = _jit_phase(self._paged_prefill_impl, donate=(2,))
        else:
            self._cache = TF.init_cache(self.cfg, max_batch, max_len=max_len,
                                        dtype=cache_dtype,
                                        quantized=int8_kv)
            self._prefill = _jit_phase(self._prefill_impl, donate=(2,))
        self._decode = _jit_phase(self._decode_impl, donate=(2,))

    def _prefill_impl(self, params, toks, cache, slots, cur, pos, plens):
        self.trace_counts["prefill"] += 1
        n, _ = toks.shape
        small = TF.init_cache(self.cfg, n, max_len=self.max_len,
                              quantized=self.int8_kv,
                              dtype=cache["k"].dtype)
        logits, small = TF.prefill(params, toks, self.cfg, cache=small,
                                   last_pos=plens - 1)
        cache = dict(cache, **{k: cache[k].at[:, slots].set(small[k])
                               for k in ("k", "v")})
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _paged_prefill_impl(self, params, toks, cache, bt_rows, slots, cur,
                            pos, plens):
        self.trace_counts["prefill"] += 1
        group = _paged_prefill_view(cache, self.cfg.n_layers, toks.shape[0],
                                    self.cfg.n_kv)
        logits, group = TF.prefill(params, toks, self.cfg, cache=group,
                                   block_tables=bt_rows, last_pos=plens - 1)
        cache = _paged_prefill_merge(cache, group, slots)
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _decode_impl(self, params, cur, cache, pos, bt):
        self.trace_counts["decode"] += 1
        logits, cache = TF.decode_step(params, cur, cache, pos, self.cfg,
                                       block_tables=bt)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return nxt, cache, jnp.minimum(pos + 1, self.max_len - 1)

    def _admit(self, toks, plens, max_news, slots, cur, pos):
        if self.paged:
            bt_rows = self._pool.admit(slots, plens, max_news, toks.shape[1])
            self._cache, cur, pos = self._prefill(
                self.params, toks, self._cache, bt_rows, jnp.asarray(slots),
                cur, pos, jnp.asarray(plens))
        else:
            self._cache, cur, pos = self._prefill(
                self.params, toks, self._cache, jnp.asarray(slots), cur, pos,
                jnp.asarray(plens))
        return cur, pos

    def _decode_all(self, cur, pos, n_active):
        bt = self._pool.table_dev() if self.paged else None
        cur, self._cache, pos = self._decode(self.params, cur,
                                             self._cache, pos, bt)
        return cur, pos

    def _retire(self, slot):
        if self.paged:
            self._pool.retire(slot)

    def _can_admit(self, group_shapes, plen, max_new, bucket):
        if not self.paged:
            return True
        return self._pool.can_admit(group_shapes + [(plen, max_new)], bucket)

    def cache_bytes(self, *, live_only: bool = False) -> int:
        """Cache footprint in bytes.  ``live_only`` counts just the
        pages currently allocated to requests (the demand-paging win)."""
        if self.paged and live_only:
            return self._pool.live_cache_bytes(self._cache)
        return sum(v.size * v.dtype.itemsize for v in self._cache.values())


class CollaborativeServingEngine(_SlotEngine):
    """Paper mode with incremental decode: INT8 edge prefix and FP32
    cloud suffix hold *split* KV caches over their own block sub-ranges;
    each decode round ships quantized boundary deltas (Eq.1/2) through
    the channel instead of the whole growing blob.

    Both caches default to the paged INT8 layout over **one shared block
    table**: pages allocated on demand through ``PageAllocator``,
    per-slot symmetric scales calibrated from each prompt at prefill,
    reads through the paged flash kernel, and a rollback of rejected
    speculative positions that is a per-slot length decrement on either
    side of the cut.  ``edge_paged=False`` / ``edge_int8=False`` /
    ``cloud_paged=False`` / ``cloud_int8=False`` fall back to the dense
    / fp layouts (the PR-1-era configuration, kept as the equivalence
    oracle in tests).

    ``spec_k > 1`` turns each decode step into a speculative draft/verify
    round (see the module docstring for the wire protocol): the edge
    drafts k tokens locally through an INT8 copy of the cloud-suffix
    weights over a draft cache that shares the edge block table, and the
    cloud verifies all k in one batched multi-token step with
    longest-prefix acceptance.  ``spec_k=1`` (default) is PR 1's serial
    step, bit for bit.  ``spec_k="auto"`` asks ``autotune.tune_spec_k``
    for the round length that minimizes predicted time per accepted
    token on this engine's channel at ``spec_acceptance``."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *, cut_layer: int,
                 channel: Optional[Channel] = None, max_len: int = 128,
                 a_bits: int = 8, max_batch: int = 4,
                 edge_paged: bool = True, edge_int8: bool = True,
                 cloud_paged: bool = True, cloud_int8: bool = True,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 spec_k: Union[int, str] = 1, spec_acceptance: float = 0.8,
                 timed: bool = False):
        assert 0 <= cut_layer < cfg.n_layers, \
            f"cut_layer {cut_layer} outside [0, {cfg.n_layers})"
        super().__init__(cfg, max_batch=max_batch, max_len=max_len,
                         timed=timed)
        self.cut = cut_layer
        self.channel = channel or Channel(bandwidth_bytes_per_s=float("inf"))
        self.a_bits = a_bits
        self.n_edge = cut_layer + 1
        self.n_cloud = cfg.n_layers - self.n_edge
        self.edge_paged = edge_paged
        self.edge_int8 = edge_int8
        self.cloud_paged = cloud_paged
        self.cloud_int8 = cloud_int8
        self.page_size = page_size
        if spec_k == "auto":
            from repro.core.autotune import spec_k_for_lm
            spec_k = spec_k_for_lm(cfg, cut_layer, batch=max_batch,
                                   channel=self.channel,
                                   acceptance=spec_acceptance)[0].k
        assert isinstance(spec_k, int) and spec_k >= 1, spec_k
        self.spec_k = spec_k

        self.edge_blocks, self.cloud_blocks = TF.split_blocks(
            params, self.cfg, cut_layer)
        self.embed = params["embed"]
        self.tail = {"final_norm": params["final_norm"],
                     "lm_head": params["lm_head"]}
        # edge weights are INT8-quantized at deployment (fake-quant lattice)
        self._edge_qctx = ML.QuantCtx(mode="dynamic", a_bits=a_bits)
        # one shared page pool / block table for every split cache
        self._pool: Optional[_PagedPool] = None
        if edge_paged or cloud_paged:
            self._pool = _PagedPool.build(max_batch, max_len, page_size,
                                          num_pages)
        n_pool = self._pool.allocator.num_pages if self._pool else None
        # split KV caches: edge prefix / cloud suffix block sub-ranges
        if edge_paged:
            self._edge_cache = TF.init_cache(
                self.cfg, max_batch, max_len, layers=self.n_edge,
                paged=True, quantized=edge_int8, page_size=page_size,
                num_pages=n_pool)
        else:
            self._edge_cache = TF.init_cache(self.cfg, max_batch, max_len,
                                             layers=self.n_edge,
                                             quantized=edge_int8)
        if cloud_paged:
            self._cloud_cache = TF.init_cache(
                self.cfg, max_batch, max_len, layers=self.n_cloud,
                paged=True, quantized=cloud_int8, page_size=page_size,
                num_pages=n_pool)
        else:
            self._cloud_cache = TF.init_cache(self.cfg, max_batch, max_len,
                                              layers=self.n_cloud)
        self._edge = jax.jit(self._edge_impl)
        self._cloud = jax.jit(self._cloud_impl)
        self._edge_prefill = _jit_phase(self._edge_prefill_impl, donate=(3,))
        self._cloud_prefill = _jit_phase(self._cloud_prefill_impl,
                                         donate=(4,))
        self._edge_decode = _jit_phase(self._edge_decode_impl, donate=(3,))
        self._cloud_decode = _jit_phase(self._cloud_decode_impl, donate=(4,))
        if self.spec_k > 1:
            # the edge's draft model: the cloud-suffix weights served
            # through the same INT8 fake-quant lattice as the prefix
            # (1 B/elem deployed — see edge_model_bytes), plus a draft KV
            # cache in the edge's own layout over the shared block table
            self.draft_blocks = self.cloud_blocks
            if edge_paged:
                self._draft_cache = TF.init_cache(
                    self.cfg, max_batch, max_len, layers=self.n_cloud,
                    paged=True, quantized=edge_int8, page_size=page_size,
                    num_pages=n_pool)
            else:
                self._draft_cache = TF.init_cache(
                    self.cfg, max_batch, max_len, layers=self.n_cloud,
                    quantized=edge_int8)
            self._draft_prefill = _jit_phase(self._draft_prefill_impl,
                                             donate=(3,))
            self._spec_draft = _jit_phase(self._spec_draft_impl,
                                          donate=(5, 6))
            self._verify = _jit_phase(self._verify_impl, donate=(6,))

    # -- wire accounting ----------------------------------------------------
    def _charge(self, nbytes: int, *, phase: str, log: bool = True) -> None:
        self.stats.transmitted_bytes += int(nbytes)
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)
        if phase == "prefill":
            self.stats.prefill_bytes += int(nbytes)
        else:
            self.stats.decode_bytes += int(nbytes)
            if log:
                self.stats.decode_bytes_log.append(int(nbytes))

    def _account(self, blob: jax.Array, *, phase: str,
                 rows: Optional[int] = None,
                 row_elems: Optional[np.ndarray] = None) -> None:
        """Charge the wire for the occupied batch rows of ``blob``.

        The jit'd decode step always computes the full fixed-shape
        [max_batch, 1, D] delta, but idle slots would never be sent, so
        the simulated wire carries only the active rows — each framed
        with its own Eq.(1) scale/zero-point (per-row quantization).
        ``row_elems`` overrides the per-row payload element count: the
        prefill blob is bucket-padded on device, but only each request's
        true prompt activations cross the wire."""
        itemsize = blob.dtype.itemsize
        if row_elems is not None:
            nbytes = int(sum(int(e) * itemsize + _QP_BYTES
                             for e in row_elems))
        else:
            n_rows = blob.shape[0] if rows is None else rows
            per_row = (blob.size // blob.shape[0]) * itemsize
            nbytes = n_rows * (per_row + _QP_BYTES)
        self._charge(nbytes + _MSG_BYTES, phase=phase)

    def _account_downlink(self, n_rows: int, *, k: int = 1,
                          phase: str = "decode") -> None:
        """The cloud→edge return: the sampled (or corrected) token per
        live request, plus — when a round verified k > 1 drafts — the
        accept mask (one bit per draft, byte-packed).  The edge can't
        start the next round until it arrives, so every round pays this
        second transfer and its channel RTT.  Counted in
        ``transmitted_bytes``/``downlink_bytes``, never in the uplink
        ``decode_bytes`` split."""
        nbytes = n_rows * (_TOK_BYTES + (_cdiv(k, 8) if k > 1 else 0)) \
            + _MSG_BYTES
        self.stats.transmitted_bytes += nbytes
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)
        self.stats.downlink_bytes += nbytes
        if phase == "decode":
            self.stats.decode_downlink_bytes += nbytes

    # -- incremental split-cache phases --------------------------------------
    def _edge_prefill_impl(self, blocks, embed, toks, cache, slots, bt_rows,
                           plens):
        self.trace_counts["prefill"] += 1
        cfg = self.cfg
        n, s = toks.shape
        x = ML.embed(embed, toks).astype(cfg.dtype)
        if self.edge_paged:
            group = _paged_prefill_view(cache, self.n_edge, n, cfg.n_kv)
            h, group = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                     cache=group, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx,
                                     block_tables=bt_rows,
                                     calibrate_kv=self.edge_int8,
                                     kv_lengths=plens)
            cache = _paged_prefill_merge(cache, group, slots)
        else:
            small = TF.init_cache(cfg, n, self.max_len, layers=self.n_edge,
                                  quantized=self.edge_int8)
            h, small = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                     cache=small, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx)
            cache = dict(cache, **{k: cache[k].at[:, slots].set(small[k])
                                   for k in ("k", "v")})
        # Eq.(1), per batch row: each request gets its own thresholds, so
        # one request's range never depends on its neighbours' activations
        # — or on its own bucket padding (pad positions are clamped to a
        # real activation before the min/max reduction; the padded tail
        # never crosses the wire, see _account)
        ranged = jnp.where(jnp.arange(s)[None, :, None] <
                           plens[:, None, None], h, h[:, :1])
        qp = compute_qparams(ranged, axis=0, bits=self.a_bits)
        return quantize(h, qp), qp, cache

    def _cloud_prefill_impl(self, blocks, tail, blob, qp, cache, slots,
                            bt_rows, cur, pos, plens):
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2)
        n = h.shape[0]
        if self.cloud_paged:
            group = _paged_prefill_view(cache, self.n_cloud, n, cfg.n_kv)
            x, group = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                     cache=group, cache_index=jnp.int32(0),
                                     block_tables=bt_rows,
                                     calibrate_kv=self.cloud_int8,
                                     kv_lengths=plens)
            cache = _paged_prefill_merge(cache, group, slots)
        else:
            small = TF.init_cache(cfg, n, self.max_len, layers=self.n_cloud)
            x, small = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                     cache=small, cache_index=jnp.int32(0))
            cache = {k: cache[k].at[:, slots].set(small[k]) for k in cache}
        logits = TF.lm_head(tail, x[jnp.arange(n), plens - 1][:, None])[:, 0]
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _draft_prefill_impl(self, blocks, blob, qp, cache, slots, bt_rows,
                            plens):
        """Fill the edge's local draft cache: the INT8 suffix copy runs
        the same dequantized boundary blob the cloud saw, so the draft
        model starts every round from the committed prefix state."""
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2), locally
        n = h.shape[0]
        if self.edge_paged:
            group = _paged_prefill_view(cache, self.n_cloud, n, cfg.n_kv)
            _, group = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                     cache=group, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx,
                                     block_tables=bt_rows,
                                     calibrate_kv=self.edge_int8,
                                     kv_lengths=plens)
            cache = _paged_prefill_merge(cache, group, slots)
        else:
            small = TF.init_cache(cfg, n, self.max_len, layers=self.n_cloud,
                                  quantized=self.edge_int8)
            _, small = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                     cache=small, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx)
            cache = dict(cache, **{k: cache[k].at[:, slots].set(small[k])
                                   for k in ("k", "v")})
        return cache

    def _edge_decode_impl(self, blocks, embed, cur, cache, pos, bt):
        self.trace_counts["decode"] += 1
        cfg = self.cfg
        x = ML.embed(embed, cur[:, None]).astype(cfg.dtype)
        h, cache = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 qctx=self._edge_qctx, block_tables=bt)
        # Eq.(1) per row: stale activations in idle/freed slots must not
        # set the quant range of live requests' deltas
        qp = compute_qparams(h, axis=0, bits=self.a_bits)
        return quantize(h, qp), qp, cache                  # [B, 1, D] delta

    def _cloud_decode_impl(self, blocks, tail, blob, qp, cache, pos, bt):
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2)
        x, cache = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 block_tables=bt)
        logits = TF.lm_head(tail, x)[:, 0]
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return nxt, cache, jnp.minimum(pos + 1, self.max_len - 1)

    # -- speculative draft/verify round --------------------------------------
    def _spec_draft_impl(self, edge_blocks, draft_blocks, embed, tail, cur,
                         e_cache, d_cache, pos, bt):
        """k sequential local steps on the edge: INT8 prefix → Eq.(1)
        delta → local INT8 suffix copy → greedy draft token.  One jit'd
        ``lax.scan``, so a whole round costs one dispatch.  Emits the
        stacked ``[k, B, D]`` boundary blob with per-(row, position)
        quant params — bitwise the frames k serial steps would have
        shipped — and the k draft tokens."""
        self.trace_counts["spec_draft"] += 1
        cfg = self.cfg
        rope = self._rope()

        def step(carry, _):
            tok, p, ec, dc = carry
            x = ML.embed(embed, tok[:, None]).astype(cfg.dtype)
            h, ec = TF.run_blocks(edge_blocks, x, cfg, rope=rope, cache=ec,
                                  cache_index=p, qctx=self._edge_qctx,
                                  block_tables=bt)
            qp = compute_qparams(h, axis=0, bits=self.a_bits)   # per row
            blob = quantize(h, qp)
            hq = dequantize(blob, qp).astype(cfg.dtype)  # what the cloud sees
            y, dc = TF.run_blocks(draft_blocks, hq, cfg, rope=rope, cache=dc,
                                  cache_index=p, qctx=self._edge_qctx,
                                  block_tables=bt)
            logits = TF.lm_head(tail, y)[:, 0]
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            p = jnp.minimum(p + 1, self.max_len - 1)
            return (nxt, p, ec, dc), (blob[:, 0], qp.scale, qp.zero_point,
                                      nxt)

        (_, _, e_cache, d_cache), (blobs, scales, zps, drafts) = \
            jax.lax.scan(step, (cur, pos, e_cache, d_cache), None,
                         length=self.spec_k)
        return blobs, scales, zps, drafts, e_cache, d_cache

    def _verify_impl(self, blocks, tail, blobs, scales, zps, drafts, cache,
                     pos, bt):
        """One batched multi-token cloud step over all k drafted
        positions, with longest-prefix acceptance: position i's greedy
        token ``t_i`` is compared against draft ``d_i``; the round
        commits ``t_1..t_{j+1}`` where j is the number of leading
        matches — the token at the first divergence is the *corrected*
        token, so every round commits at least one exact greedy token.
        Rejected cache positions are rolled back by the returned
        per-slot position (a length decrement; stale page entries stay
        masked by causality until overwritten)."""
        self.trace_counts["verify"] += 1
        cfg = self.cfg
        k = self.spec_k
        # Eq.(2) per (row, position): same lattice the serial path ships
        h = (blobs.astype(jnp.float32) - zps[..., None]) * scales[..., None]
        h = h.transpose(1, 0, 2).astype(cfg.dtype)              # [B, k, D]
        x, cache = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 block_tables=bt)
        logits = TF.lm_head(tail, x)                            # [B, k, V]
        t = jnp.argmax(logits, -1).astype(jnp.int32)            # [B, k]
        d = drafts.T                                            # [B, k]
        ok = (d[:, :k - 1] == t[:, :k - 1]).astype(jnp.int32)
        n_commit = 1 + jnp.sum(jnp.cumprod(ok, axis=1), axis=1)  # [B]
        new_cur = jnp.take_along_axis(t, (n_commit - 1)[:, None],
                                      axis=1)[:, 0]
        new_pos = jnp.minimum(pos + n_commit, self.max_len - 1)
        return t, n_commit, new_cur, cache, new_pos

    # -- scheduler hooks ----------------------------------------------------
    def _round_headroom(self) -> int:
        return self.spec_k - 1

    def _admit(self, toks, plens, max_news, slots, cur, pos):
        bt_rows = None
        if self._pool is not None:
            # reserve the speculative overshoot so a round's rejected-tail
            # writes can never spill into another request's pages
            bt_rows = self._pool.admit(slots, plens,
                                       max_news + self._round_headroom(),
                                       toks.shape[1])
        slots_j = jnp.asarray(slots)
        plens_j = jnp.asarray(plens)
        blob, qp, self._edge_cache = self._edge_prefill(
            self.edge_blocks, self.embed, toks, self._edge_cache, slots_j,
            bt_rows, plens_j)
        self._account(blob, phase="prefill",
                      row_elems=plens.astype(np.int64) * self.cfg.d_model)
        self._cloud_cache, cur, pos = self._cloud_prefill(
            self.cloud_blocks, self.tail, blob, qp, self._cloud_cache,
            slots_j, bt_rows, cur, pos, plens_j)
        if self.spec_k > 1:
            self._draft_cache = self._draft_prefill(
                self.draft_blocks, blob, qp, self._draft_cache, slots_j,
                bt_rows, plens_j)
        self._account_downlink(toks.shape[0], phase="prefill")
        return cur, pos

    def _decode_all(self, cur, pos, n_active):
        bt = self._pool.table_dev() if self._pool is not None else None
        blob, qp, self._edge_cache = self._edge_decode(
            self.edge_blocks, self.embed, cur, self._edge_cache, pos, bt)
        self._account(blob, phase="decode", rows=n_active)
        cur, self._cloud_cache, pos = self._cloud_decode(
            self.cloud_blocks, self.tail, blob, qp, self._cloud_cache, pos,
            bt)
        self._account_downlink(n_active)
        return cur, pos

    def _round(self, cur, pos, slots):
        if self.spec_k == 1:
            return super()._round(cur, pos, slots)
        k, n_active = self.spec_k, len(slots)
        bt = self._pool.table_dev() if self._pool is not None else None
        blobs, scales, zps, drafts, self._edge_cache, self._draft_cache = \
            self._spec_draft(self.edge_blocks, self.draft_blocks, self.embed,
                             self.tail, cur, self._edge_cache,
                             self._draft_cache, pos, bt)
        # one uplink message: k per-row-framed [1, D] deltas + the k-1
        # graded drafts, amortizing the header (and the RTT) over a round
        self._charge(n_active * (k * (self.cfg.d_model * blobs.dtype.itemsize
                                      + _QP_BYTES)
                                 + (k - 1) * _TOK_BYTES) + _MSG_BYTES,
                     phase="decode")
        toks, n_commit, cur, self._cloud_cache, pos = self._verify(
            self.cloud_blocks, self.tail, blobs, scales, zps, drafts,
            self._cloud_cache, pos, bt)
        # the edge needs the accept counts to schedule the next round, so
        # this sync is part of the protocol, not a host-loop artifact
        counts = np.asarray(n_commit)
        self._account_downlink(n_active, k=k)
        self.stats.spec_rounds += 1
        self.stats.drafted_tokens += (k - 1) * n_active
        self.stats.draft_hits += int(np.minimum(counts[slots] - 1,
                                                k - 1).sum())
        return cur, pos, toks, counts

    def _retire(self, slot):
        if self._pool is not None:
            self._pool.retire(slot)

    def _can_admit(self, group_shapes, plen, max_new, bucket):
        if self._pool is None:
            return True
        head = self._round_headroom()
        shapes = [(p, m + head) for p, m in group_shapes]
        return self._pool.can_admit(shapes + [(plen, max_new + head)],
                                    bucket)

    def edge_cache_bytes(self, *, live_only: bool = False) -> int:
        """Edge KV footprint; ``live_only`` counts allocated pages only."""
        if self.edge_paged and live_only:
            return self._pool.live_cache_bytes(self._edge_cache)
        return sum(v.size * v.dtype.itemsize
                   for v in self._edge_cache.values())

    # -- seed recompute path (kept as the benchmark baseline) ----------------
    def _edge_impl(self, blocks, embed, tokens):
        cfg = self.cfg
        x = ML.embed(embed, tokens).astype(cfg.dtype)
        rope = ML.rope_table(tokens.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)
        x, _ = TF.run_blocks(blocks, x, cfg, rope=rope, qctx=self._edge_qctx)
        return x

    def _cloud_impl(self, blocks, tail, h):
        cfg = self.cfg
        rope = ML.rope_table(h.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)
        h, _ = TF.run_blocks(blocks, h, cfg, rope=rope)
        return TF.lm_head(tail, h)

    def forward(self, tokens: np.ndarray) -> jax.Array:
        """Mixed-precision collaborative forward → logits [B, S, V]
        (cache-less: re-runs the whole split stack; the seed path)."""
        toks = jnp.asarray(tokens, jnp.int32)
        h = self._edge(self.edge_blocks, self.embed, toks)
        # Eq.(1): quantize boundary blob for the wire
        qp = compute_qparams(h, bits=self.a_bits)
        blob = quantize(h, qp)
        nbytes = blob.size * blob.dtype.itemsize + _QP_BYTES + _MSG_BYTES
        self.stats.transmitted_bytes += int(nbytes)
        self.stats.channel_latency_s += self.channel.transfer_time(nbytes)
        h = dequantize(blob, qp).astype(self.cfg.dtype)       # Eq.(2)
        return self._cloud(self.cloud_blocks, self.tail, h)

    def generate_recompute(self, prompts: List[np.ndarray], *,
                           max_new_tokens: int = 8) -> List[List[int]]:
        """Seed greedy decode: re-run the split forward on the full,
        growing sequence every step (KV-less edge, O(S²·L) per token and
        the whole boundary blob retransmitted).  Kept as the baseline the
        incremental path is benchmarked against."""
        toks = np.stack(prompts).astype(np.int32)
        out = [[] for _ in prompts]
        for _ in range(max_new_tokens):
            logits = self.forward(toks)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for j, t in enumerate(nxt):
                out[j].append(int(t))
            toks = np.concatenate([toks, nxt[:, None].astype(np.int32)], 1)
            self.stats.decode_steps += 1
        return out
