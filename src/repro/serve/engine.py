"""Batched LM serving with KV caches + collaborative (cloud-edge) mode —
the deployment side of the paper, composed from the ``serve`` package:

* ``serve.scheduler`` — the slot/bucket/round continuous-batching loop
  (``_SlotEngine``) both engines ride, including the re-partition
  barrier of the online control loop;
* ``serve.kvcache``   — page-pool bookkeeping for the paged INT8 KV
  layouts (``PageAllocator``/``_PagedPool``);
* ``serve.transport`` — channel framing + wire accounting
  (``ServeStats``) and the EWMA link telemetry;
* ``serve.cloud``     — the cloud-only baseline ``ServingEngine``;
* ``serve.spec``      — the speculative draft/verify round machinery
  (wire protocol documented there);
* ``serve.policy``    — the telemetry → costmodel/autotune → engine
  re-tuning policy (``AdaptivePolicy``) + ``DeadlineAdmission``;
* ``serve.overload``  — the overload-robustness hooks (demand paging,
  pressure faults, deadline admission) mixed in ahead of the
  scheduler's defaults.

``CollaborativeServingEngine`` is the paper's mode rebuilt around
*incremental decode*: the INT8 edge prefix (first ``cut_layer+1``
blocks, fake-quant lattice == the Pallas int8 kernel's math) and the
FP32 cloud suffix each own a KV cache covering only their block
sub-range, defaulting to the paged INT8 layout over **one shared block
table**.  Each decode round ships a per-row-quantized ``[B, 1, D]``
boundary delta (Eq.1/2) uplink and the sampled token downlink; with
``spec_k = k > 1`` the serial loop restructures into the draft/verify
rounds of ``serve.spec``.  A ``policy.AdaptivePolicy`` closes the
auto-tuning loop online: ``spec_k`` switches between rounds, the cut
layer switches at request-admission boundaries out of the prequantized
``_CutBank`` (pointer swap, never a requantization), and ``a_bits=None``
runs the boundary lossless so fp re-partitions are output-transparent
(property-tested in ``tests/test_adaptive_serve.py``).

This module re-exports the package's public surface, so the historical
``from repro.serve.engine import X`` keeps working.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CLOUD_TITANXP_CLASS, Channel
from repro.models import layers as ML
from repro.models import transformer as TF
# re-export shims: the pre-split monolith lived at repro.serve.engine and
# external code imports these names from here
from repro.serve.cloud import ServingEngine
from repro.serve.kvcache import (PageAllocator, PoolExhausted, _cdiv,
                                 _PagedPool, _paged_prefill_merge,
                                 _paged_prefill_view)
from repro.serve.policy import (AdaptivePolicy, DeadlineAdmission, Decision,
                                _CutBank)
from repro.serve.scheduler import (Request, _bucket_len, _jit_phase,
                                   _SlotEngine)
from repro.serve.faults import FaultyChannel, PressureSchedule
from repro.serve.overload import _OverloadMixin
from repro.serve.phases import _SplitPhases
from repro.serve.sampling import SamplingParams
from repro.serve.seedpath import _SeedPathMixin
from repro.serve.sharding import place_collab_engine, tp_size
from repro.serve.spec import _SpecDraftMixin
from repro.serve.transport import (_MSG_BYTES, _QP_BYTES, _TOK_BYTES,
                                   CloudUnreachable, DriftingChannel,
                                   LinkTelemetry, ReliableTransport,
                                   ServeStats, Transport)

Params = Any

__all__ = ["ServingEngine", "CollaborativeServingEngine", "PageAllocator",
           "PoolExhausted", "ServeStats", "Request", "SamplingParams",
           "Transport", "LinkTelemetry", "DriftingChannel", "AdaptivePolicy",
           "DeadlineAdmission", "Decision", "FaultyChannel",
           "PressureSchedule", "ReliableTransport", "CloudUnreachable",
           "_MSG_BYTES", "_QP_BYTES", "_TOK_BYTES"]


class CollaborativeServingEngine(_SpecDraftMixin, _SeedPathMixin,
                                 _OverloadMixin, _SplitPhases, _SlotEngine):
    """Paper mode with incremental decode over split, shared-table paged
    INT8 KV caches (see the module docstring), plus the online tuning
    loop.

    ``edge_paged=False`` / ``edge_int8=False`` / ``cloud_paged=False`` /
    ``cloud_int8=False`` fall back to the dense / fp layouts (the
    PR-1-era configuration, kept as the equivalence oracle in tests).

    ``spec_k > 1`` turns each decode step into a speculative
    draft/verify round; ``spec_k=1`` (default) is PR 1's serial step,
    bit for bit.  ``spec_k="auto"`` asks ``autotune.tune_spec_k`` for
    the starting round length *and* keeps it self-correcting: the
    engine's measured ``acceptance_rate()`` feeds back into the tuner
    between requests, replacing the ``spec_acceptance`` prior.

    ``policy="auto"`` (or an explicit ``AdaptivePolicy``) closes the
    full loop: link telemetry re-tunes both ``spec_k`` (between rounds)
    and ``cut_layer`` (at request-admission boundaries, via the
    re-partition barrier + ``_CutBank``).  ``candidate_cuts`` overrides
    the default cut grid {0, mid, last-1} ∪ {cut_layer}.  Every k switch
    is immediate between rounds: raising out of k=1 with live requests
    — whose draft caches were never filled, k=1 rounds being the cheap
    serial step — rebuilds their draft K/V from committed prefix state
    (``serve.spec._rebuild_draft_caches``) instead of paying the drain
    barrier; only cut switches still drain.

    ``mesh`` places the engine on a ``("data", "model")`` device mesh
    (``launch.mesh.make_serve_mesh``): the cloud suffix weights and
    paged KV pool shard tensor-parallel over ``model`` while everything
    edge-side replicates, so cloud prefill/decode/verify run as
    mesh-jitted computations (``serve.sharding``) and the auto policy
    prices the mesh via a TP-scaled cloud device model."""

    def __init__(self, params: Params, cfg: TF.LMConfig, *, cut_layer: int,
                 channel: Optional[Channel] = None, max_len: int = 128,
                 a_bits: Optional[int] = 8, max_batch: int = 4,
                 edge_paged: bool = True, edge_int8: bool = True,
                 cloud_paged: bool = True, cloud_int8: bool = True,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 spec_k: Union[int, str] = 1, spec_acceptance: float = 0.8,
                 policy: Union[AdaptivePolicy, str, None] = None,
                 candidate_cuts: Optional[Tuple[int, ...]] = None,
                 demand_paged: bool = False,
                 pressure: Optional[PressureSchedule] = None,
                 admission: Union[DeadlineAdmission, str, None] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 timed: bool = False):
        assert 0 <= cut_layer < cfg.n_layers, \
            f"cut_layer {cut_layer} outside [0, {cfg.n_layers})"
        super().__init__(cfg, max_batch=max_batch, max_len=max_len,
                         timed=timed)
        self.mesh = mesh
        self.cut = cut_layer
        self.transport = Transport(channel)
        self.a_bits = a_bits
        self.edge_paged = edge_paged
        self.edge_int8 = edge_int8
        self.cloud_paged = cloud_paged
        self.cloud_int8 = cloud_int8
        self.page_size = page_size
        # the channel the offline tuners assume before telemetry locks on
        # (a DriftingChannel contributes its t=0 phase — the site survey)
        initial_ch = self.transport.channel
        initial_ch = getattr(initial_ch, "phase", initial_ch)

        spec_auto = spec_k == "auto"
        if spec_auto:
            from repro.core.autotune import spec_k_for_lm
            spec_k = spec_k_for_lm(cfg, cut_layer, batch=max_batch,
                                   channel=initial_ch,
                                   acceptance=spec_acceptance)[0].k
        assert isinstance(spec_k, int) and spec_k >= 1, spec_k
        self.spec_k = spec_k

        # -- control plane ---------------------------------------------------
        if policy == "auto":
            assert cut_layer <= cfg.n_layers - 2, \
                "the adaptive policy needs at least one cloud block at " \
                "every candidate cut"
            cuts = candidate_cuts or tuple(sorted(
                {0, (cfg.n_layers - 1) // 2, cfg.n_layers - 2, cut_layer}))
            # a TP mesh scales the cloud term of the policy's cost grid:
            # FLOPs/device (+ the per-layer all-reduce when link_bw > 0),
            # so a bigger mesh discovers its own edge-ward optimal cut
            policy = AdaptivePolicy(cfg, batch=max_batch, cuts=cuts,
                                    ks=(1, 2, 4, 8),
                                    cloud=CLOUD_TITANXP_CLASS.scaled(
                                        tp_size(mesh)),
                                    fallback_channel=initial_ch,
                                    acceptance_prior=spec_acceptance)
        elif policy is None and spec_auto:
            # spec_k="auto" alone: k-only self-correction between requests
            policy = AdaptivePolicy(cfg, batch=max_batch, cuts=None,
                                    ks=(1, 2, 4, 8, 16),
                                    fallback_channel=initial_ch,
                                    acceptance_prior=spec_acceptance,
                                    k_between_requests_only=True)
        self.policy: Optional[AdaptivePolicy] = policy or None
        if self.policy is not None and self.policy.cuts is not None:
            assert cut_layer in self.policy.cuts, \
                f"cut_layer {cut_layer} not in candidate cuts " \
                f"{self.policy.cuts}"
        # largest k any controller may pick — draft machinery and page
        # headroom are provisioned for it once, up front
        self._spec_max = self.spec_k if self.policy is None \
            else max(self.spec_k, *self.policy.ks)

        self.embed = params["embed"]
        self.tail = {"final_norm": params["final_norm"],
                     "lm_head": params["lm_head"]}
        # edge weights are INT8-quantized at deployment: the bank bakes
        # the fake-quant lattice into the stored params once
        # (quantize_weights=False at runtime — same math, no per-step
        # weight requantization); a_bits=None serves the edge lossless
        # act_axis=0: per-slot activation ranges — a shared-batch range
        # would couple each request's Eq.(1) lattice to its neighbours'
        # (and to stale values in idle slots), making decode output
        # depend on batch composition and slot-reuse history
        self._edge_qctx = None if a_bits is None else \
            ML.QuantCtx(mode="dynamic", a_bits=a_bits,
                        quantize_weights=False, act_axis=0)
        deploy_qctx = None if a_bits is None else \
            ML.QuantCtx(mode="dynamic", a_bits=a_bits)
        # one shared page pool / block table for every split cache; its
        # geometry is cut-independent, so it survives re-partitions
        self._pool: Optional[_PagedPool] = None
        if edge_paged or cloud_paged:
            self._pool = _PagedPool.build(max_batch, max_len, page_size,
                                          num_pages)
        # overload robustness (demand paging / pressure faults / deadline
        # admission) — hook implementations live in serve.overload
        self._init_overload(cfg, demand_paged=demand_paged,
                            pressure=pressure, admission=admission,
                            max_batch=max_batch, initial_ch=initial_ch,
                            spec_acceptance=spec_acceptance, a_bits=a_bits)
        # every cut the engine may ever serve goes into the bank up front
        # (policy candidates, or explicit candidate_cuts for externally
        # scripted re-partitions)
        bank_cuts = {cut_layer} | set(candidate_cuts or ())
        if self.policy is not None and self.policy.cuts is not None:
            bank_cuts |= set(self.policy.cuts)
        self._bank = _CutBank(params, cfg, bank_cuts, deploy_qctx)
        self._set_cut(cut_layer, count=False)

        self._edge = jax.jit(self._edge_impl)
        self._cloud = jax.jit(self._cloud_impl)
        self._edge_prefill = _jit_phase(self._edge_prefill_impl, donate=(3,))
        self._cloud_prefill = _jit_phase(self._cloud_prefill_impl,
                                         donate=(4,), mesh=mesh)
        self._edge_decode = _jit_phase(self._edge_decode_impl, donate=(3,))
        self._cloud_decode = _jit_phase(self._cloud_decode_impl, donate=(4,),
                                        mesh=mesh)
        if self._spec_max > 1:
            self._draft_prefill = _jit_phase(self._draft_prefill_impl,
                                             donate=(3,))
            # per-k jitted draft/verify (k is the scan length / q-block
            # width, a trace constant); built on first use of each k
            self._spec_jits: Dict[int, Tuple[Any, Any]] = {}
        # per-slot sampling state (serve.sampling): host mirrors of each
        # slot's (temperature, top_p, seed), refreshed at admission; the
        # device copies are cached until the slot mix changes.  Jitted
        # sampled phases are built lazily — all-greedy traffic never
        # traces them and runs the original phases untouched.
        self._samp_t = np.zeros((max_batch,), np.float32)
        self._samp_p = np.ones((max_batch,), np.float32)
        self._samp_s = np.zeros((max_batch,), np.int32)
        self._samp_dev: Optional[Tuple[jax.Array, ...]] = None
        self._samp_jits: Dict[str, Any] = {}

    # -- wire plumbing -------------------------------------------------------
    @property
    def channel(self):
        return self.transport.channel

    @channel.setter
    def channel(self, ch) -> None:
        self.transport.channel = ch

    @property
    def telemetry(self) -> LinkTelemetry:
        return self.transport.telemetry

    # -- online re-tuning ----------------------------------------------------
    def _set_cut(self, cut: int, *, count: bool = True) -> None:
        """Re-partition at ``cut`` — only ever called with no occupied
        slots (construction, or the scheduler's drained admission
        boundary).  Weights come out of the bank (pointer swap); the
        split caches are re-initialized for the new layer sub-ranges
        (their contents belong to retired requests); the page pool,
        block table, telemetry, and jitted phase callables all carry
        over (jax re-traces per layer-count automatically and caches
        each cut's traces, so flapping between two cuts compiles each
        side once)."""
        cfg = self.cfg
        self.cut = cut
        self.n_edge = cut + 1
        self.n_cloud = cfg.n_layers - self.n_edge
        self.edge_blocks, self.cloud_blocks, self.draft_blocks = \
            self._bank.get(cut)
        n_pool = self._pool.allocator.num_pages if self._pool else None
        if self.edge_paged:
            self._edge_cache = TF.init_cache(
                cfg, self.max_batch, self.max_len, layers=self.n_edge,
                paged=True, quantized=self.edge_int8,
                page_size=self.page_size, num_pages=n_pool)
        else:
            self._edge_cache = TF.init_cache(cfg, self.max_batch,
                                             self.max_len,
                                             layers=self.n_edge,
                                             quantized=self.edge_int8)
        if self.cloud_paged:
            self._cloud_cache = TF.init_cache(
                cfg, self.max_batch, self.max_len, layers=self.n_cloud,
                paged=True, quantized=self.cloud_int8,
                page_size=self.page_size, num_pages=n_pool)
        else:
            self._cloud_cache = TF.init_cache(cfg, self.max_batch,
                                              self.max_len,
                                              layers=self.n_cloud)
        if self._spec_max > 1:
            # the edge's draft model: the bank's INT8 copy of the
            # cloud-suffix weights, over a draft cache in the edge's
            # layout sharing the edge block table
            if self.edge_paged:
                self._draft_cache = TF.init_cache(
                    cfg, self.max_batch, self.max_len, layers=self.n_cloud,
                    paged=True, quantized=self.edge_int8,
                    page_size=self.page_size, num_pages=n_pool)
            else:
                self._draft_cache = TF.init_cache(
                    cfg, self.max_batch, self.max_len, layers=self.n_cloud,
                    quantized=self.edge_int8)
        if self.mesh is not None:
            # TP-shard the cloud half, replicate the edge half — one
            # placement pass per (re-)partition (serve.sharding)
            place_collab_engine(self)
        if count:
            self.stats.cut_switches += 1

    def _policy_tick(self, n_active: int) -> bool:
        if self.policy is None:
            return False
        live = self._sched_active or {}
        frac = (sum(1.0 for s in live if self._samp_t[s] > 0) / len(live)
                if live else 0.0)
        # kwarg only when sampled traffic exists: duck-typed policies
        # predating sampling keep working on greedy workloads
        kw = {"sampled_frac": frac} if frac > 0.0 else {}
        d = self.policy.decide(self.telemetry, cut=self.cut,
                               spec_k=self.spec_k, **kw)
        if d.spec_k != self.spec_k:
            if self.policy.k_between_requests_only and n_active > 0:
                pass                 # defer to the next drained tick
            else:
                if d.spec_k > 1 and self.spec_k == 1 and n_active > 0:
                    # k=1 rounds run the cheap serial step and leave the
                    # draft cache stale for the *live* slots: rebuild it
                    # from their committed prefix state instead of
                    # paying the old drain barrier (serve.spec)
                    self._rebuild_draft_caches()
                self.spec_k = d.spec_k
                self.stats.spec_k_switches += 1
        if d.cut != self.cut:
            if n_active:
                self.stats.policy_holds += 1
                return True          # re-partition barrier: drain first
            self._set_cut(d.cut)
        return False

    def _round_headroom(self) -> int:
        return self._spec_max - 1

    # boundary lattice + split-cache phase impls: _SplitPhases (shared
    # with the per-cut runtimes of serve.fleet)

    # -- sampling plumbing (serve.sampling) ---------------------------------
    def _note_samplings(self, slots, samplings) -> None:
        """Refresh the per-slot sampling mirrors at admission (a greedy
        or ``None`` request zeroes its slot, so slot reuse can never
        leak a previous tenant's temperature)."""
        for i, s in enumerate(slots):
            sp = None if samplings is None else samplings[i]
            sp = sp if (sp is not None and sp.sampled) else None
            self._samp_t[s] = sp.temperature if sp else 0.0
            self._samp_p[s] = sp.top_p if sp else 1.0
            self._samp_s[s] = sp.seed if sp else 0
        self._samp_dev = None

    def _samp_vecs(self) -> Tuple[jax.Array, ...]:
        if self._samp_dev is None:
            self._samp_dev = (jnp.asarray(self._samp_t),
                              jnp.asarray(self._samp_p),
                              jnp.asarray(self._samp_s))
        return self._samp_dev

    def _offsets(self) -> jax.Array:
        """[max_batch] absolute output index each live slot's next round
        starts at (its committed count) — what pins every sampled draw's
        key to (seed, index, stream) across preemption/replay."""
        off = np.zeros((self.max_batch,), np.int32)
        for s, (_r, c) in (self._sched_active or {}).items():
            off[s] = c
        return jnp.asarray(off)

    def _samp_jit(self, name: str, impl, donate=(), mesh=None):
        if name not in self._samp_jits:
            self._samp_jits[name] = _jit_phase(impl, donate=donate,
                                               mesh=mesh)
        return self._samp_jits[name]

    # -- scheduler hooks ----------------------------------------------------
    def _admit(self, toks, plens, max_news, slots, cur, pos, samplings=None):
        self._note_samplings(slots, samplings)
        bt_rows = None
        if self._pool is not None:
            bt_rows = self._pool.admit(slots, plens,
                                       self._admit_reserve(max_news),
                                       toks.shape[1])
        slots_j = jnp.asarray(slots)
        plens_j = jnp.asarray(plens)
        blob, qp, self._edge_cache = self._edge_prefill(
            self.edge_blocks, self.embed, toks, self._edge_cache, slots_j,
            bt_rows, plens_j)
        self.transport.account_blob(
            self.stats, blob, phase="prefill",
            row_elems=plens.astype(np.int64) * self.cfg.d_model)
        if (self._samp_t[slots] > 0).any():
            fn = self._samp_jit("cloud_prefill",
                                self._cloud_prefill_sample_impl,
                                donate=(4,), mesh=self.mesh)
            self._cloud_cache, cur, pos = fn(
                self.cloud_blocks, self.tail, blob, qp, self._cloud_cache,
                slots_j, bt_rows, cur, pos, plens_j,
                jnp.asarray(self._samp_t[slots]),
                jnp.asarray(self._samp_p[slots]),
                jnp.asarray(self._samp_s[slots]))
        else:
            self._cloud_cache, cur, pos = self._cloud_prefill(
                self.cloud_blocks, self.tail, blob, qp, self._cloud_cache,
                slots_j, bt_rows, cur, pos, plens_j)
        if self._spec_max > 1 and self.spec_k > 1:
            # requests served at k=1 never draft (and a later raise
            # drains them first — see _policy_tick), so the draft
            # prefill is pure overhead unless the engine is drafting now
            self._draft_cache = self._draft_prefill(
                self.draft_blocks, blob, qp, self._draft_cache, slots_j,
                bt_rows, plens_j)
        self.transport.account_downlink(self.stats, toks.shape[0],
                                        phase="prefill")
        return cur, pos

    def _decode_all(self, cur, pos, n_active):
        bt = self._pool.table_dev() if self._pool is not None else None
        blob, qp, self._edge_cache = self._edge_decode(
            self.edge_blocks, self.embed, cur, self._edge_cache, pos, bt)
        self.transport.account_blob(self.stats, blob, phase="decode",
                                    rows=n_active)
        cur, self._cloud_cache, pos = self._cloud_decode(
            self.cloud_blocks, self.tail, blob, qp, self._cloud_cache, pos,
            bt)
        self.transport.account_downlink(self.stats, n_active)
        return cur, pos

    def _decode_all_sample(self, cur, pos, n_active):
        """Serial (k=1) step with a sampled slot aboard: identical edge
        pass and wire bytes, the committed token is the ``CLOUD``-stream
        draw (greedy rows keep their argmax, bit for bit)."""
        bt = self._pool.table_dev() if self._pool is not None else None
        blob, qp, self._edge_cache = self._edge_decode(
            self.edge_blocks, self.embed, cur, self._edge_cache, pos, bt)
        self.transport.account_blob(self.stats, blob, phase="decode",
                                    rows=n_active)
        temps, top_ps, seeds = self._samp_vecs()
        fn = self._samp_jit("cloud_decode", self._cloud_decode_sample_impl,
                            donate=(4,), mesh=self.mesh)
        cur, self._cloud_cache, pos = fn(
            self.cloud_blocks, self.tail, blob, qp, self._cloud_cache, pos,
            bt, temps, top_ps, seeds, self._offsets())
        self.transport.account_downlink(self.stats, n_active)
        return cur, pos

    def _round(self, cur, pos, slots):
        sampled = bool((self._samp_t[slots] > 0).any())
        # k=1 is the fully-async serial step (PR 1's path, bit for bit)
        # whether or not draft machinery exists — drafting costs a full
        # local model pass per token, so it only runs when k > 1
        if self.spec_k == 1:
            if not sampled:
                return super()._round(cur, pos, slots)
            cur, pos = self._decode_all_sample(cur, pos, len(slots))
            return cur, pos, cur[:, None], None
        k, n_active = self.spec_k, len(slots)
        bt = self._pool.table_dev() if self._pool is not None else None
        if sampled:
            temps, top_ps, seeds = self._samp_vecs()
            offs = self._offsets()
            draft_fn, verify_fn = self._spec_sample_fns(k)
            (blobs, scales, zps, drafts, qs, self._edge_cache,
             self._draft_cache) = draft_fn(
                self.edge_blocks, self.draft_blocks, self.embed, self.tail,
                cur, self._edge_cache, self._draft_cache, pos, bt, temps,
                top_ps, seeds, offs)
        else:
            draft_fn, verify_fn = self._spec_fns(k)
            (blobs, scales, zps, drafts, self._edge_cache,
             self._draft_cache) = draft_fn(
                self.edge_blocks, self.draft_blocks, self.embed, self.tail,
                cur, self._edge_cache, self._draft_cache, pos, bt)
        # one uplink message: k per-row-framed [1, D] deltas + the k-1
        # graded drafts, amortizing the header (and the RTT) over a round;
        # a sampled row additionally ships the k-1 graded positions' f32
        # draft distributions the rejection test needs
        # (costmodel.speculative_round_time prices this as draft_q_bytes)
        n_samp = int((self._samp_t[slots] > 0).sum())
        self.transport.charge(
            self.stats,
            n_active * (k * (self.cfg.d_model * blobs.dtype.itemsize
                             + _QP_BYTES)
                        + (k - 1) * _TOK_BYTES) + _MSG_BYTES
            + n_samp * (k - 1) * self.cfg.vocab * 4,
            phase="decode")
        if sampled:
            toks, n_commit, cur, self._cloud_cache, pos = verify_fn(
                self.cloud_blocks, self.tail, blobs, scales, zps, drafts,
                qs, self._cloud_cache, pos, bt, temps, top_ps, seeds, offs)
        else:
            toks, n_commit, cur, self._cloud_cache, pos = verify_fn(
                self.cloud_blocks, self.tail, blobs, scales, zps, drafts,
                self._cloud_cache, pos, bt)
        # the edge needs the accept counts to schedule the next round, so
        # this sync is part of the protocol, not a host-loop artifact
        counts = np.asarray(n_commit)
        self.transport.account_downlink(self.stats, n_active, k=k)
        self.stats.spec_rounds += 1
        hits = int(np.minimum(counts[slots] - 1, k - 1).sum())
        self.stats.drafted_tokens += (k - 1) * n_active
        self.stats.draft_hits += hits
        self.telemetry.observe_round((k - 1) * n_active, hits)
        return cur, pos, toks, counts

    def _retire(self, slot):
        if self._pool is not None:
            self._pool.retire(slot)

    def _can_admit(self, group_shapes, plen, max_new, bucket):
        if self._pool is None:
            return True
        shapes = [(p, int(self._admit_reserve(np.int64(m))))
                  for p, m in group_shapes + [(plen, max_new)]]
        return self._pool.can_admit(shapes, bucket)

    def edge_cache_bytes(self, *, live_only: bool = False) -> int:
        """Edge KV footprint; ``live_only`` counts allocated pages only."""
        if self.edge_paged and live_only:
            return self._pool.live_cache_bytes(self._edge_cache)
        return sum(v.size * v.dtype.itemsize
                   for v in self._edge_cache.values())

    # seed recompute path (forward / generate_recompute): serve.seedpath
