"""Tensor-parallel placement of the cloud side of a serving engine.

The collaborative engine's cloud suffix is the fast half of the
partition; this module lets it actually scale with devices by placing
every piece of engine-owned device state onto a ``("data", "model")``
``jax.sharding.Mesh`` once, at construction / re-partition time:

* **cloud suffix weights** — Megatron-style TP via the role-based rules
  of ``launch.shardings.spec_for_param`` (``zero1=True``: serving
  replicates over the data axis, no FSDP): QKV/FFN-in column-split over
  ``model``, proj/FFN-out row-split, so each layer costs two
  all-reduces;
* **lm_head** — vocab column-split over ``model`` when divisible (the
  argmax reduces over the vocab dim, GSPMD inserts the gather);
* **paged cloud KV pool** — kv heads over ``model`` / pages over
  ``data`` via ``launch.shardings.paged_pool_shardings``, so each TP
  shard stores and dequantizes only its own INT8 KV slice;
* **everything edge-side** (embed, edge/draft blocks and caches) —
  replicated onto the *same* mesh.  This is load-bearing, not cosmetic:
  one jitted phase closes over both halves, and jax refuses committed
  arguments spanning different device sets.  Replication keeps the edge
  math bit-identical to the unsharded engine on every shard.

Why this preserves the committed streams (the property the mesh tests
pin): the scheduler commits only tokens that equal the *cloud's own
greedy stream* (longest-prefix acceptance + the corrected token), and
cloud argmaxes are stable across TP degrees at serving precision —
edge-side math is replicated, so drafts and boundary blobs are
bit-identical by construction and only affect the acceptance rate.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.shardings import (cache_spec, paged_pool_shardings,
                                    spec_for_param, _path_str)

__all__ = ["tp_size", "replicate_to_mesh", "shard_suffix_blocks",
           "shard_tail", "shard_cloud_cache", "place_collab_engine",
           "place_cloud_engine"]


def tp_size(mesh: Optional[Mesh]) -> int:
    """The tensor-parallel degree a serve mesh gives the cloud suffix."""
    if mesh is None:
        return 1
    return int(mesh.shape["model"]) if "model" in mesh.axis_names else 1


def replicate_to_mesh(tree: Any, mesh: Mesh) -> Any:
    """Place a pytree fully replicated on every device of ``mesh``."""
    return jax.device_put(tree, NamedSharding(mesh, P()))


def shard_suffix_blocks(blocks: Any, mesh: Mesh) -> Any:
    """TP-shard a stacked ``[L, ...]`` suffix block tree with the
    role-based param rules (paths resolved under a ``blocks/`` root so
    the stacked-layer lead dim stays unsharded)."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(blocks)
    placed = []
    for path, leaf in flat:
        spec = spec_for_param("blocks/" + _path_str(path),
                              tuple(leaf.shape), mesh, zero1=True)
        placed.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(tdef, placed)


def shard_tail(tail: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    """Place the head: ``lm_head`` vocab-column-split when divisible
    (rank-2 generic rule), norms replicated."""
    out = {}
    for name, sub in tail.items():
        flat, tdef = jax.tree_util.tree_flatten_with_path(sub)
        placed = []
        for path, leaf in flat:
            spec = spec_for_param(f"{name}/{_path_str(path)}",
                                  tuple(leaf.shape), mesh, zero1=True)
            placed.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
        out[name] = jax.tree_util.tree_unflatten(tdef, placed)
    return out


def shard_cloud_cache(cache: Dict[str, jax.Array],
                      mesh: Mesh) -> Dict[str, jax.Array]:
    """Place a cloud KV cache: paged pools shard kv-heads over ``model``
    and pages over ``data`` (divisibility-guarded); dense caches shard
    via ``cache_spec`` on k/v, scales replicated."""
    if "k_pages" in cache:
        shardings = paged_pool_shardings(cache, mesh)
        return {k: jax.device_put(v, shardings[k])
                for k, v in cache.items()}
    out = {}
    for k, v in cache.items():
        if k in ("k", "v"):
            _, b, s, h, d = v.shape
            spec = cache_spec(mesh, batch=b, seq=s, n_kv=h, head_dim=d)
        else:
            spec = P()
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


def place_collab_engine(eng) -> None:
    """Place ALL of a ``CollaborativeServingEngine``'s device state onto
    its mesh in one pass — cloud half TP-sharded, edge half replicated.
    Called at construction and after every re-partition (``_set_cut``),
    so a cut switch re-shards the new suffix slice.  Placing the edge
    half too (replicated) is required: every phase jit must see one
    consistent committed device set (see the module docstring)."""
    mesh = eng.mesh
    if mesh is None:
        return
    eng.embed = replicate_to_mesh(eng.embed, mesh)
    eng.tail = shard_tail(eng.tail, mesh)
    eng.edge_blocks = replicate_to_mesh(eng.edge_blocks, mesh)
    eng.cloud_blocks = shard_suffix_blocks(eng.cloud_blocks, mesh)
    if eng.draft_blocks is not None:
        eng.draft_blocks = replicate_to_mesh(eng.draft_blocks, mesh)
    eng._edge_cache = replicate_to_mesh(eng._edge_cache, mesh)
    eng._cloud_cache = shard_cloud_cache(eng._cloud_cache, mesh)
    if getattr(eng, "_draft_cache", None) is not None:
        eng._draft_cache = replicate_to_mesh(eng._draft_cache, mesh)


def place_cloud_engine(eng) -> None:
    """Mesh placement for the cloud-only ``ServingEngine``: the full
    param stack TP-shards under the role-based rules (``blocks`` is the
    stacked ``[L, ...]`` tree, so its layer dim stays unsharded) and the
    KV cache shards like the collaborative cloud cache."""
    mesh = eng.mesh
    if mesh is None:
        return
    from repro.launch.shardings import param_shardings
    eng.params = jax.device_put(
        eng.params, param_shardings(eng.params, mesh, zero1=True))
    eng._cache = shard_cloud_cache(eng._cache, mesh)
