"""Fault injection for the simulated cloud-edge channel.

``FaultyChannel`` wraps any channel (``costmodel.Channel`` or
``transport.DriftingChannel`` — anything duck-typing ``transfer_time``)
and injects message **drops**, payload **corruption**, tail-latency
**stalls**, and hard **outage windows**, either from a seeded RNG or
from an explicit per-message script.  All of it plays out on the
wrapper's simulated clock (``clock_s``), which only advances through
transfers and explicit ``wait`` calls — the same convention
``DriftingChannel`` uses — so fault schedules are deterministic and
replayable.

Two consumption modes, matching the two engines under test:

* ``attempt(nbytes)`` — one send attempt with the failure *exposed*:
  returns a ``FaultOutcome`` and never blocks past the attempt itself.
  A dropped message (or one inside an outage window) costs the sender
  nothing here — the sender discovers the loss by its own deadline and
  pays for it via ``wait`` (``transport.ReliableTransport``).
* ``transfer_time(nbytes)`` — the naive blocking semantics every
  pre-reliability engine assumes: retry forever on a fixed ``rto_s``
  until the message lands, so a cloud outage simply *stalls* the caller
  for the remainder of the window.  This is the baseline the chaos
  benchmark measures the resilient engine against.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["FaultOutcome", "FaultyChannel", "PressureSchedule"]


@dataclasses.dataclass(frozen=True)
class FaultOutcome:
    """One send attempt: did it arrive, did it arrive intact, and how
    much simulated time the *attempt* consumed on the sender's clock
    (0 for a silent drop — the sender only learns at its deadline)."""
    delivered: bool
    corrupt: bool
    seconds: float
    kind: str = "ok"             # ok | drop | corrupt | stall | outage


class FaultyChannel:
    """Wrap ``base`` with seeded or scripted faults.

    ``drop_p`` / ``corrupt_p`` / ``stall_p`` are independent per-message
    probabilities drawn from ``np.random.default_rng(seed)``;
    ``stall_s`` is added to a stalled message's transfer time (late
    arrival — a deadline-driven sender counts it as a miss).
    ``outages`` are hard ``(t0_s, t1_s)`` windows on the simulated
    clock during which nothing is delivered.  ``script`` overrides the
    RNG with an explicit event list (``"ok"``/``"drop"``/``"corrupt"``/
    ``"stall"``), consumed one entry per attempt; when it runs dry the
    channel falls back to the seeded probabilities (outage windows apply
    in both modes).
    """

    def __init__(self, base, *, seed: Optional[int] = 0,
                 drop_p: float = 0.0, corrupt_p: float = 0.0,
                 stall_p: float = 0.0, stall_s: float = 0.25,
                 outages: Sequence[Tuple[float, float]] = (),
                 script: Optional[Sequence[str]] = None,
                 rto_s: float = 1.0):
        self.base = base
        self.drop_p, self.corrupt_p, self.stall_p = drop_p, corrupt_p, stall_p
        self.stall_s = stall_s
        self.outages = [(float(a), float(b)) for a, b in outages]
        assert all(b > a for a, b in self.outages), self.outages
        self._script: List[str] = list(script or [])
        self._rng = np.random.default_rng(seed)
        self.rto_s = rto_s
        self.clock_s = 0.0
        self.attempts = 0
        self.faults = {"drop": 0, "corrupt": 0, "stall": 0, "outage": 0}

    # -- the underlying link -------------------------------------------------
    @property
    def phase(self):
        """The base channel's current conditions (a ``Channel``) — what
        a site survey at this instant would measure.  Engines use it to
        seed their offline tune, exactly as for ``DriftingChannel``."""
        base = self.base
        if hasattr(base, "phase"):            # DriftingChannel: sync clocks
            base.clock_s = self.clock_s
            return base.phase
        return base

    @property
    def name(self) -> str:
        return f"faulty[{getattr(self.base, 'name', '?')}]"

    def _base_time(self, nbytes: float) -> float:
        # never call DriftingChannel.transfer_time here — it advances its
        # own clock; this wrapper owns the clock and mirrors it across
        return self.phase.transfer_time(nbytes)

    # -- fault model ---------------------------------------------------------
    def in_outage(self, t: Optional[float] = None) -> bool:
        t = self.clock_s if t is None else t
        return any(a <= t < b for a, b in self.outages)

    def outage_end(self, t: Optional[float] = None) -> Optional[float]:
        t = self.clock_s if t is None else t
        for a, b in self.outages:
            if a <= t < b:
                return b
        return None

    def wait(self, seconds: float) -> None:
        """Sender-side time passing (deadline expiry, retry backoff)."""
        self.clock_s += max(0.0, float(seconds))

    def attempt(self, nbytes: float) -> FaultOutcome:
        """One send attempt at the current simulated time."""
        self.attempts += 1
        kind = "ok"
        if self.in_outage():
            kind = "outage"
        elif self._script:
            kind = self._script.pop(0)
        else:
            u = self._rng.random(3)
            if u[0] < self.drop_p:
                kind = "drop"
            elif u[1] < self.corrupt_p:
                kind = "corrupt"
            elif u[2] < self.stall_p:
                kind = "stall"
        if kind in ("drop", "outage"):
            self.faults[kind] += 1
            return FaultOutcome(False, False, 0.0, kind)
        t = self._base_time(nbytes)
        if kind == "stall":
            t += self.stall_s
        self.clock_s += t
        if kind != "ok":
            self.faults[kind] += 1
        return FaultOutcome(True, kind == "corrupt", t, kind)

    # -- naive blocking semantics (the baseline engines') --------------------
    def transfer_time(self, nbytes: float) -> float:
        """Deliver-or-die: retry on a fixed ``rto_s`` until the message
        lands intact.  An outage window stalls the caller until the
        window closes — the pre-reliability engines' behaviour, kept as
        the chaos benchmark's baseline."""
        total = 0.0
        while True:
            out = self.attempt(nbytes)
            total += out.seconds
            if out.delivered and not out.corrupt:
                return total
            if out.kind == "outage":
                # a blocked sender's next useful attempt is at window end
                end = self.outage_end()
                dt = max(self.rto_s, (end - self.clock_s)
                         if end is not None else self.rto_s)
                self.wait(dt)
                total += dt
            elif not out.delivered:
                self.wait(self.rto_s)
                total += self.rto_s
            # corrupt: checksum fails on arrival; retransmit immediately


class PressureSchedule:
    """Scripted *resource*-fault injection: the page-pool analogue of
    ``FaultyChannel``'s outage windows.

    ``windows`` is a list of ``(t0_s, t1_s, free_pages)`` intervals on
    the simulated clock; inside a window the schedule squeezes a
    ``kvcache.PageAllocator``'s free list down to at most ``free_pages``
    by holding pages itself (a co-tenant claiming HBM, a cgroup limit
    tightening), and past the window it gives them back.  ``apply`` is
    called by the scheduler at the top of every turn with the current
    simulated time, so the squeeze lands at deterministic points of the
    round structure — overload chaos tests are seeded and replayable,
    exactly like the outage tests.  The squeeze can only take pages that
    are actually free (live requests are never corrupted); if admission
    races it to the free list, the schedule simply grabs the remainder
    as retirements return pages.
    """

    def __init__(self, windows: Sequence[Tuple[float, float, int]]):
        self.windows = [(float(a), float(b), int(n)) for a, b, n in windows]
        assert all(b > a and n >= 0 for a, b, n in self.windows), \
            self.windows
        self._held: List[int] = []

    def target_free(self, t: float) -> Optional[int]:
        """The free-list ceiling at simulated time ``t`` (None = no
        pressure; overlapping windows compose to the tightest)."""
        targets = [n for a, b, n in self.windows if a <= t < b]
        return min(targets) if targets else None

    def next_change(self, t: float) -> Optional[float]:
        """The next window edge after ``t`` — how long a stalled
        scheduler must wait before the free list can look different."""
        edges = [e for a, b, _ in self.windows for e in (a, b) if e > t]
        return min(edges) if edges else None

    @property
    def held_pages(self) -> int:
        return len(self._held)

    def apply(self, allocator, t: float) -> None:
        """Move the allocator's free list toward the time-``t`` target:
        grab free pages down to the ceiling, or return held pages when
        the window has passed (all of them) or the ceiling rose."""
        target = self.target_free(t)
        if target is None:
            if self._held:
                allocator.free(self._held)
                self._held = []
            return
        while allocator.num_free > target:
            self._held.extend(allocator.alloc(1))
        while allocator.num_free < target and self._held:
            allocator.free([self._held.pop()])
