"""Serving subsystem: continuous-batching engines for the paper's
cloud-edge collaborative deployment, as a package of focused layers.

    scheduler   slot/bucket/round continuous batching (``_SlotEngine``)
    kvcache     paged INT8 KV bookkeeping (``PageAllocator``)
    transport   channel framing + wire accounting + link telemetry
    policy      online (cut_layer, spec_k) re-tuning control plane
    engine      ``ServingEngine`` / ``CollaborativeServingEngine``

``repro.serve.engine`` re-exports the whole public surface, so both
``from repro.serve import X`` and the historical
``from repro.serve.engine import X`` work.
"""
from repro.serve.engine import (AdaptivePolicy, CollaborativeServingEngine,
                                Decision, DriftingChannel, LinkTelemetry,
                                PageAllocator, Request, ServeStats,
                                ServingEngine, Transport)

__all__ = ["ServingEngine", "CollaborativeServingEngine", "PageAllocator",
           "ServeStats", "Request", "Transport", "LinkTelemetry",
           "DriftingChannel", "AdaptivePolicy", "Decision"]
