"""Serving subsystem: continuous-batching engines for the paper's
cloud-edge collaborative deployment, as a package of focused layers.

    scheduler   slot/bucket/round continuous batching (``_SlotEngine``)
    kvcache     paged INT8 KV bookkeeping (``PageAllocator``)
    transport   channel framing + wire accounting + link telemetry,
                plus the reliable (seq/deadline/retry) transport
    faults      seeded/scripted channel fault injection
    policy      online (cut_layer, spec_k) re-tuning control plane +
                deadline-aware admission prediction
    overload    demand paging / preemption / deadline-shedding hooks
                (``_OverloadMixin``)
    engine      ``ServingEngine`` / ``CollaborativeServingEngine``
    resilience  ``ResilientCollaborativeEngine`` — edge-only graceful
                degradation through outages + cloud KV resync
    fleet       ``FleetServingEngine`` — N tenant edges on one shared
                cloud engine: cross-tenant batched verify over one
                weight bank / page pool, weighted-fair sharing

``repro.serve.engine`` re-exports the whole public surface, so both
``from repro.serve import X`` and the historical
``from repro.serve.engine import X`` work (the resilient engine lives
one layer above ``engine`` and is exported from the package only).
"""
from repro.serve.engine import (AdaptivePolicy, CollaborativeServingEngine,
                                CloudUnreachable, DeadlineAdmission,
                                Decision, DriftingChannel, FaultyChannel,
                                LinkTelemetry, PageAllocator, PoolExhausted,
                                PressureSchedule, ReliableTransport, Request,
                                SamplingParams, ServeStats, ServingEngine,
                                Transport)
from repro.serve.faults import FaultOutcome
from repro.serve.fleet import FleetServingEngine, TenantSpec
from repro.serve.policy import FleetFairness
from repro.serve.resilience import ResilientCollaborativeEngine

__all__ = ["ServingEngine", "CollaborativeServingEngine",
           "ResilientCollaborativeEngine", "FleetServingEngine",
           "TenantSpec", "FleetFairness", "PageAllocator", "PoolExhausted",
           "ServeStats", "Request", "SamplingParams", "Transport",
           "ReliableTransport",
           "CloudUnreachable", "LinkTelemetry", "DriftingChannel",
           "FaultyChannel", "FaultOutcome", "PressureSchedule",
           "AdaptivePolicy", "DeadlineAdmission", "Decision"]
