"""Fault-tolerant collaborative serving: degradation and resync.

``ResilientCollaborativeEngine`` is ``CollaborativeServingEngine`` with
the cloud allowed to *disappear*.  Three pieces compose:

* **Reliable transport** (``transport.ReliableTransport``) — every
  boundary message gets a sequence number, a telemetry-derived
  deadline, and a bounded retry budget with exponential backoff.  When
  a send exhausts its budget it raises ``CloudUnreachable`` — the
  engine's signal, not its crash.
* **Graceful degradation** — on that signal the engine declares the
  cloud down and keeps streaming *edge-only*: the ``_CutBank``'s INT8
  copy of the cloud-suffix weights (normally the speculative draft
  model) becomes the serving model.  Zero wire bytes per token; the
  committed tokens are counted in ``ServeStats.edge_only_tokens``.  In
  the lossless ``a_bits=None`` mode the draft suffix *is* the cloud
  suffix bit for bit, so the stream does not change — property-tested
  in ``tests/test_chaos_serve.py``.
* **Resync on reconnect** — while down, the engine buffers each live
  slot's dequantized f32 boundary rows (exactly what the cloud suffix
  would have consumed).  A periodic single-attempt probe detects
  recovery; the buffered rows then replay through the cloud suffix in
  one multi-token cached step per slot group (vector ``cache_index`` —
  the verify machinery's q-block form), rebuilding the cloud's paged KV
  to the committed stream, after which draft/verify rounds resume.

Protocol fine print, chosen so state never forks:

* The draft cache is kept **hot** even in serial (k=1) rounds — the
  edge runs its suffix copy alongside every uplink — so failover needs
  no warm-up and loses no round.  That is the standby's price:
  one local INT8 suffix step per token.
* A downlink lost *after* the cloud committed (a verify result or a
  prefill ack) keeps the result: sequence numbers make the eventual
  retransmit idempotent, and the cloud-side state is already the truth.
* An uplink lost *mid-round* commits the round's local drafts instead
  of dropping them — the boundary rows are already computed, so the
  failed round costs nothing but the wire it never got.
* The policy is suspended while down (a re-partition would invalidate
  the replay rows, which are boundary activations *at the current
  cut*), and probing replaces it between rounds.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.quant import dequantize
from repro.serve.engine import CollaborativeServingEngine
from repro.serve.kvcache import _cdiv
from repro.serve.scheduler import _jit_phase
from repro.serve.transport import (_MSG_BYTES, _QP_BYTES, _TOK_BYTES,
                                   CloudUnreachable, ReliableTransport)

__all__ = ["ResilientCollaborativeEngine"]


class ResilientCollaborativeEngine(CollaborativeServingEngine):
    """Collaborative serving that survives drops, stalls, and outages.

    Accepts every ``CollaborativeServingEngine`` argument plus:

    ``transport``     a ``ReliableTransport`` to use (default: one is
                      built around the given channel with default retry
                      budget/deadline parameters);
    ``probe_every``   while down, send one heartbeat probe every this
                      many scheduler turns (each failed probe costs one
                      deadline of simulated waiting — which is also what
                      advances a fault schedule's clock toward the end
                      of an outage window).

    Requires the paged layouts (the resync replay addresses cloud KV
    through the shared block table)."""

    def __init__(self, params, cfg, *, transport: Optional[
            ReliableTransport] = None, probe_every: int = 2, **kw):
        super().__init__(params, cfg, **kw)
        assert self.edge_paged and self.cloud_paged, \
            "resilient serving needs the paged KV layouts (resync " \
            "replays through the shared block table)"
        if transport is None:
            transport = ReliableTransport(self.transport.channel,
                                          self.transport.telemetry)
        self.transport = transport
        self.probe_every = max(1, int(probe_every))
        # edge-only serving rides the draft machinery; provision it even
        # for a spec_k=1 engine (the standby must exist before the fault)
        if self._spec_max == 1:
            self._spec_max = 2
            self._spec_jits = {}
            self._draft_prefill = _jit_phase(self._draft_prefill_impl,
                                             donate=(3,))
            self._set_cut(self.cut, count=False)
        self._edge_only_step = _jit_phase(self._edge_only_step_impl,
                                          donate=(5, 6))
        self._edge_only_admit = _jit_phase(self._edge_only_prefill_impl,
                                           donate=(4,))
        # resync replays run the cloud suffix — under the mesh when TP'd
        self._resync_replay = _jit_phase(self._resync_replay_impl,
                                         donate=(2,),
                                         mesh=getattr(self, "mesh", None))
        self._resync_prefill = _jit_phase(self._resync_prefill_impl,
                                          donate=(2,),
                                          mesh=getattr(self, "mesh", None))
        self.cloud_down = False
        self._down_since: Optional[float] = None
        self._rounds_down = 0
        self._live_slots: Set[int] = set()
        # slot -> [start position, list of [r, D] f32 boundary-row chunks]
        self._replay: Dict[int, List] = {}
        # per-round availability trace: (sim time, tokens, cloud state)
        self.round_log: List[dict] = []

    # -- outage state machine ------------------------------------------------
    def _enter_outage(self, pos) -> None:
        if self.cloud_down:
            return
        self.cloud_down = True
        self._rounds_down = 0
        self._down_since = getattr(self.channel, "clock_s", None)
        p = np.asarray(pos)
        # every live slot resumes cloud KV from its position at the loss
        self._replay = {s: [int(p[s]), []] for s in self._live_slots}

    def _policy_tick(self, n_active: int) -> bool:
        # while down the control loop is probe-and-resync: a cut switch
        # would invalidate the replay rows (boundary at the current cut)
        if self.cloud_down:
            self._rounds_down += 1
            if self._rounds_down % self.probe_every == 0:
                self._try_reconnect()
            return False
        return super()._policy_tick(n_active)

    def _try_reconnect(self) -> None:
        ok, _ = self.transport.probe(self.stats)
        if not ok:
            return
        try:
            self._resync()
        except CloudUnreachable:
            return      # relapsed mid-resync: buffers intact, stay down
        clock = getattr(self.channel, "clock_s", None)
        if clock is not None and self._down_since is not None:
            self.stats.outage_s += clock - self._down_since
        self.cloud_down = False
        self._down_since = None
        self._rounds_down = 0
        self._replay = {}
        self.stats.resyncs += 1

    def _resync(self) -> None:
        """Replay every live slot's buffered boundary rows through the
        cloud suffix, rebuilding its paged KV to the committed stream.
        Slots sharing a replay length run as one multi-token cached
        step; outage-admitted slots (start position 0) additionally
        calibrate the cloud's per-slot INT8 scales, prefill-style."""
        groups: Dict[Tuple[int, bool], List] = {}
        for s, (p0, chunks) in self._replay.items():
            if not chunks:
                continue
            rows = np.concatenate(chunks, axis=0)      # [R, D] f32
            groups.setdefault((rows.shape[0], p0 == 0), []).append(
                (s, p0, rows))
        itemsize = 1 if self.a_bits is not None else 4
        for (r_len, fresh), members in sorted(groups.items()):
            slots = np.asarray([s for s, _, _ in members], np.int32)
            # the wire carries the rows re-framed on the Eq.(1) lattice
            # (they are dequantized lattice points — requantization is
            # exact), one message per group; a loss here aborts the
            # resync and the engine stays down with its buffers
            self.transport.charge(
                self.stats,
                len(members) * r_len * (self.cfg.d_model * itemsize
                                        + _QP_BYTES) + _MSG_BYTES,
                phase="decode", log=False)
            if fresh:
                w = max(1, _cdiv(r_len, self.page_size))
                bt_rows = jnp.array(self._pool.bt[slots][:, :w], copy=True)
                h = jnp.asarray(np.stack([r for _, _, r in members]))
                self._cloud_cache = self._resync_prefill(
                    self.cloud_blocks, h, self._cloud_cache,
                    jnp.asarray(slots), bt_rows,
                    jnp.full((len(members),), r_len, jnp.int32))
            else:
                hb = np.zeros((self.max_batch, r_len, self.cfg.d_model),
                              np.float32)
                posb = np.zeros((self.max_batch,), np.int32)
                bt = np.zeros_like(self._pool.bt)
                need = 1
                for s, p0, rows in members:
                    hb[s], posb[s] = rows, p0
                    bt[s] = self._pool.bt[s]
                    need = max(need, _cdiv(p0 + r_len, self.page_size))
                w = 1
                while w < need:
                    w *= 2
                w = min(w, self._pool.pages_per_slot)
                self._cloud_cache = self._resync_replay(
                    self.cloud_blocks, jnp.asarray(hb), self._cloud_cache,
                    jnp.asarray(posb), jnp.array(bt[:, :w], copy=True))

    # -- scheduler hooks, fault-aware ---------------------------------------
    def _round_width(self):
        # edge-only rounds are serial regardless of spec_k
        return 1 if (self.cloud_down or self.spec_k == 1) else self.spec_k

    def _edge_step(self, cur, pos, bt, slots):
        """One local step of the hot standby — sampled slots draw their
        token from the ``CLOUD`` stream on the draft suffix's filtered
        distribution (``serve.spec``), so a lossless edge-only stream is
        bitwise the cloud's serial sampled stream."""
        if (self._samp_t[slots] > 0).any():
            temps, top_ps, seeds = self._samp_vecs()
            fn = self._samp_jit("edge_only_step",
                                self._edge_only_step_sample_impl,
                                donate=(5, 6))
            return fn(self.edge_blocks, self.draft_blocks, self.embed,
                      self.tail, cur, self._edge_cache, self._draft_cache,
                      pos, bt, temps, top_ps, seeds, self._offsets())
        return self._edge_only_step(self.edge_blocks, self.draft_blocks,
                                    self.embed, self.tail, cur,
                                    self._edge_cache, self._draft_cache,
                                    pos, bt)

    def _admit(self, toks, plens, max_news, slots, cur, pos, samplings=None):
        self._note_samplings(slots, samplings)
        bt_rows = self._pool.admit(slots, plens,
                                   self._admit_reserve(max_news),
                                   toks.shape[1])
        slots_j, plens_j = jnp.asarray(slots), jnp.asarray(plens)
        blob, qp, self._edge_cache = self._edge_prefill(
            self.edge_blocks, self.embed, toks, self._edge_cache, slots_j,
            bt_rows, plens_j)
        if not self.cloud_down:
            try:
                self.transport.account_blob(
                    self.stats, blob, phase="prefill",
                    row_elems=plens.astype(np.int64) * self.cfg.d_model)
                if (self._samp_t[slots] > 0).any():
                    fn = self._samp_jit("cloud_prefill",
                                        self._cloud_prefill_sample_impl,
                                        donate=(4,), mesh=self.mesh)
                    self._cloud_cache, cur, pos = fn(
                        self.cloud_blocks, self.tail, blob, qp,
                        self._cloud_cache, slots_j, bt_rows, cur, pos,
                        plens_j, jnp.asarray(self._samp_t[slots]),
                        jnp.asarray(self._samp_p[slots]),
                        jnp.asarray(self._samp_s[slots]))
                else:
                    self._cloud_cache, cur, pos = self._cloud_prefill(
                        self.cloud_blocks, self.tail, blob, qp,
                        self._cloud_cache, slots_j, bt_rows, cur, pos,
                        plens_j)
                # the standby drafts regardless of the current spec_k
                self._draft_cache = self._draft_prefill(
                    self.draft_blocks, blob, qp, self._draft_cache, slots_j,
                    bt_rows, plens_j)
                self._live_slots.update(int(s) for s in slots)
                try:
                    self.transport.account_downlink(self.stats,
                                                    toks.shape[0],
                                                    phase="prefill")
                except CloudUnreachable:
                    # cloud committed the prefill; only the ack is lost —
                    # the seq-numbered retransmit is idempotent, keep it
                    self._enter_outage(pos)
                return cur, pos
            except CloudUnreachable:
                self._enter_outage(pos)
        # cloud down: the draft suffix serves the admission alone
        if (self._samp_t[slots] > 0).any():
            fn = self._samp_jit("edge_only_admit",
                                self._edge_only_prefill_sample_impl,
                                donate=(4,))
            self._draft_cache, cur, pos = fn(
                self.draft_blocks, self.tail, blob, qp, self._draft_cache,
                slots_j, bt_rows, plens_j, cur, pos,
                jnp.asarray(self._samp_t[slots]),
                jnp.asarray(self._samp_p[slots]),
                jnp.asarray(self._samp_s[slots]))
        else:
            self._draft_cache, cur, pos = self._edge_only_admit(
                self.draft_blocks, self.tail, blob, qp, self._draft_cache,
                slots_j, bt_rows, plens_j, cur, pos)
        rows = np.asarray(dequantize(blob, qp), np.float32)
        for i, s in enumerate(slots):
            self._replay[int(s)] = [0, [rows[i, :int(plens[i])]]]
        self._live_slots.update(int(s) for s in slots)
        self.stats.edge_only_tokens += len(slots)
        return cur, pos

    def _round(self, cur, pos, slots):
        if self.cloud_down:
            return self._edge_only_round(cur, pos, slots)
        if self.spec_k == 1:
            return self._serial_round(cur, pos, slots)
        return self._spec_round(cur, pos, slots)

    def _serial_round(self, cur, pos, slots):
        n_active = len(slots)
        bt = self._pool.table_dev()
        sampled = bool((self._samp_t[slots] > 0).any())
        # the edge half also advances the draft suffix — the hot standby
        blob, qp, hq, nxt, self._edge_cache, self._draft_cache, pos_e = \
            self._edge_step(cur, pos, bt, slots)
        try:
            self.transport.account_blob(self.stats, blob, phase="decode",
                                        rows=n_active)
        except CloudUnreachable:
            self._enter_outage(pos)
            return self._commit_local(nxt, pos_e, hq, slots)
        if sampled:
            temps, top_ps, seeds = self._samp_vecs()
            fn = self._samp_jit("cloud_decode",
                                self._cloud_decode_sample_impl,
                                donate=(4,), mesh=self.mesh)
            cur, self._cloud_cache, pos = fn(
                self.cloud_blocks, self.tail, blob, qp, self._cloud_cache,
                pos, bt, temps, top_ps, seeds, self._offsets())
        else:
            cur, self._cloud_cache, pos = self._cloud_decode(
                self.cloud_blocks, self.tail, blob, qp, self._cloud_cache,
                pos, bt)
        try:
            self.transport.account_downlink(self.stats, n_active)
        except CloudUnreachable:
            self._enter_outage(pos)   # committed cloud-side: keep the token
        return cur, pos, cur[:, None], None

    def _spec_round(self, cur, pos, slots):
        k, n_active = self.spec_k, len(slots)
        bt = self._pool.table_dev()
        sampled = bool((self._samp_t[slots] > 0).any())
        if sampled:
            temps, top_ps, seeds = self._samp_vecs()
            offs = self._offsets()
            draft_fn, verify_fn = self._spec_sample_fns(k)
            (blobs, scales, zps, drafts, qs, self._edge_cache,
             self._draft_cache) = draft_fn(
                self.edge_blocks, self.draft_blocks, self.embed, self.tail,
                cur, self._edge_cache, self._draft_cache, pos, bt, temps,
                top_ps, seeds, offs)
        else:
            draft_fn, verify_fn = self._spec_fns(k)
            (blobs, scales, zps, drafts, self._edge_cache,
             self._draft_cache) = draft_fn(
                self.edge_blocks, self.draft_blocks, self.embed, self.tail,
                cur, self._edge_cache, self._draft_cache, pos, bt)
        n_samp = int((self._samp_t[slots] > 0).sum())
        try:
            self.transport.charge(
                self.stats,
                n_active * (k * (self.cfg.d_model * blobs.dtype.itemsize
                                 + _QP_BYTES)
                            + (k - 1) * _TOK_BYTES) + _MSG_BYTES
                + n_samp * (k - 1) * self.cfg.vocab * 4,
                phase="decode")
        except CloudUnreachable:
            # the round's drafts are computed and locally consistent —
            # commit all k instead of wasting the round.  Sampled rows
            # commit their DRAFT-stream draws: in the lossless mode the
            # draft distribution *is* the cloud distribution, so the
            # committed tokens stay distributionally exact (the stream
            # itself is the documented chunking caveat, serve.sampling)
            self._enter_outage(pos)
            h = (np.asarray(blobs, np.float32)
                 - np.asarray(zps, np.float32)[..., None]) \
                * np.asarray(scales, np.float32)[..., None]   # [k, B, D]
            for s in slots:
                self._replay[int(s)][1].append(h[:, int(s), :])
            self.stats.edge_only_tokens += k * n_active
            counts = np.full((self.max_batch,), k, np.int64)
            return drafts[-1], jnp.minimum(pos + k, self.max_len - 1), \
                jnp.transpose(drafts), counts
        if sampled:
            toks, n_commit, cur, self._cloud_cache, pos = verify_fn(
                self.cloud_blocks, self.tail, blobs, scales, zps, drafts,
                qs, self._cloud_cache, pos, bt, temps, top_ps, seeds, offs)
        else:
            toks, n_commit, cur, self._cloud_cache, pos = verify_fn(
                self.cloud_blocks, self.tail, blobs, scales, zps, drafts,
                self._cloud_cache, pos, bt)
        counts = np.asarray(n_commit)
        try:
            self.transport.account_downlink(self.stats, n_active, k=k)
        except CloudUnreachable:
            self._enter_outage(pos)   # verify committed: keep its result
        self.stats.spec_rounds += 1
        hits = int(np.minimum(counts[slots] - 1, k - 1).sum())
        self.stats.drafted_tokens += (k - 1) * n_active
        self.stats.draft_hits += hits
        self.telemetry.observe_round((k - 1) * n_active, hits)
        return cur, pos, toks, counts

    def _edge_only_round(self, cur, pos, slots):
        bt = self._pool.table_dev()
        _, _, hq, nxt, self._edge_cache, self._draft_cache, pos = \
            self._edge_step(cur, pos, bt, slots)
        return self._commit_local(nxt, pos, hq, slots)

    def _commit_local(self, nxt, pos, hq, slots):
        rows = np.asarray(hq, np.float32)                    # [B, D]
        for s in slots:
            self._replay[int(s)][1].append(rows[int(s)][None, :])
        self.stats.edge_only_tokens += len(slots)
        return nxt, pos, nxt[:, None], None

    def _retire(self, slot):
        super()._retire(slot)
        self._live_slots.discard(int(slot))
        # a request finished on edge-only tokens owes the cloud nothing
        self._replay.pop(int(slot), None)

    def _after_round(self, n_active: int, committed: int) -> None:
        self.round_log.append({
            "t_s": float(getattr(self.channel, "clock_s", 0.0)),
            "committed": committed,
            "cloud_down": self.cloud_down,
        })
