"""Per-tenant building blocks of the fleet engine (``serve.fleet``):
the tenant spec + runtime state, the per-cut serving runtime (jitted
split-cache phases + caches shared by every tenant at that cut), and
the cross-tenant fair admission half of the scheduler
(``_FleetAdmitMixin``).  Split out of ``fleet.py`` so each serving
module stays within the size budget ``tests/test_adaptive_serve.py``
pins; ``fleet`` re-exports the public names."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.models import transformer as TF
from repro.serve.phases import _SplitPhases
from repro.serve.policy import AdaptivePolicy
from repro.serve.scheduler import (Request, _bucket_len, _jit_phase,
                                   _remove_is, _SlotEngine)
from repro.serve.spec import _SpecDraftMixin
from repro.serve.transport import ServeStats, Transport

__all__ = ["TenantSpec", "_Tenant", "_CutRuntime", "_FleetAdmitMixin"]


@dataclasses.dataclass
class TenantSpec:
    """One edge of the fleet: its link, its partition, its share.

    ``policy="auto"`` gives the tenant its own ``AdaptivePolicy`` over
    its own telemetry (candidate cuts default to the engine grid
    {0, mid, last-1} ∪ {cut_layer}); switches apply at the tenant's
    drained boundary.  ``weight`` is the tenant's share under
    ``FleetFairness``; ``max_pages`` is an optional hard KV page quota
    (None = uncapped — fairness then comes from admission ordering and
    over-share-first preemption alone)."""
    name: str
    channel: Any = None
    cut_layer: int = 0
    spec_k: int = 1
    weight: float = 1.0
    max_pages: Optional[int] = None
    policy: Union[AdaptivePolicy, str, None] = None


class _Tenant:
    """Runtime state of one edge: transport (channel + telemetry),
    stats, current (cut, spec_k), pending re-tune decision."""

    def __init__(self, spec: TenantSpec, policy: Optional[AdaptivePolicy]):
        self.name = spec.name
        self.spec = spec
        self.transport = Transport(spec.channel)
        self.stats = ServeStats()
        self.cut = spec.cut_layer
        self.spec_k = spec.spec_k
        self.policy = policy
        self.pending = None          # Decision awaiting a drained boundary
        self.hold = False            # pause this tenant's admission

    @property
    def telemetry(self):
        return self.transport.telemetry

    def now(self) -> float:
        return float(getattr(self.transport.channel, "clock_s", 0.0))

    def wait(self, seconds: float) -> bool:
        s = float(seconds)
        if s <= 0:
            return True
        w = getattr(self.transport.channel, "wait", None)
        if w is None:
            return False             # clockless channel
        w(s)
        self.stats.stall_wait_s += s
        return True


class _CutRuntime(_SpecDraftMixin, _SplitPhases):
    """Per-cut serving runtime: the jitted split-cache phases plus the
    edge/cloud/draft caches for one cut, shared by *every* tenant served
    at that cut.  Weights come out of the fleet's shared ``_CutBank``
    (pointer swap — building a runtime never requantizes); the caches
    index the fleet's single ``_PagedPool``, so all cuts see identical
    page geometry and one slot's pages mean the same thing in every
    runtime (writes from slots outside a phase call's group are masked
    to the dump page via ``table_for``)."""

    def __init__(self, fleet, cut: int):
        cfg = fleet.cfg
        self.cfg = cfg
        self.max_len = fleet.max_len
        self.max_batch = fleet.max_batch
        self.page_size = fleet.page_size
        self.a_bits = fleet.a_bits
        self.edge_paged = self.cloud_paged = True
        self.edge_int8 = fleet.edge_int8
        self.cloud_int8 = fleet.cloud_int8
        self._edge_qctx = fleet._edge_qctx
        self.trace_counts = fleet.trace_counts
        self.mesh = None
        self.cut = cut
        self.n_edge = cut + 1
        self.n_cloud = cfg.n_layers - self.n_edge
        self.edge_blocks, self.cloud_blocks, self.draft_blocks = \
            fleet._bank.get(cut)
        n_pool = fleet._pool.allocator.num_pages
        self._edge_cache = TF.init_cache(
            cfg, fleet.max_batch, fleet.max_len, layers=self.n_edge,
            paged=True, quantized=self.edge_int8,
            page_size=fleet.page_size, num_pages=n_pool)
        self._cloud_cache = TF.init_cache(
            cfg, fleet.max_batch, fleet.max_len, layers=self.n_cloud,
            paged=True, quantized=self.cloud_int8,
            page_size=fleet.page_size, num_pages=n_pool)
        self._spec_max = fleet._spec_max
        self._edge_prefill = _jit_phase(self._edge_prefill_impl, donate=(3,))
        self._cloud_prefill = _jit_phase(self._cloud_prefill_impl,
                                         donate=(4,))
        self._edge_decode = _jit_phase(self._edge_decode_impl, donate=(3,))
        self._cloud_decode = _jit_phase(self._cloud_decode_merge_impl,
                                        donate=(4,))
        self._samp_jits: Dict[str, Any] = {}
        if self._spec_max > 1:
            self._draft_cache = TF.init_cache(
                cfg, fleet.max_batch, fleet.max_len, layers=self.n_cloud,
                paged=True, quantized=self.edge_int8,
                page_size=fleet.page_size, num_pages=n_pool)
            self._draft_prefill = _jit_phase(self._draft_prefill_impl,
                                             donate=(3,))
            self._spec_jits: Dict[int, Tuple[Any, Any]] = {}
            self._fleet_jits: Dict[int, Tuple[Any, Any]] = {}
            self._fleet_sample_jits: Dict[int, Tuple[Any, Any]] = {}

    def _samp_jit(self, name: str, impl, donate=()):
        """Lazy per-runtime jit cache for the sampled phase variants —
        all-greedy fleets never trace them."""
        if name not in self._samp_jits:
            self._samp_jits[name] = _jit_phase(impl, donate=donate)
        return self._samp_jits[name]

    # Fleet variants of the round phases: the group-masked merge of the
    # round's cur/pos back into the fleet's global arrays happens INSIDE
    # the jitted phase (one dispatch per round), not as follow-up eager
    # gathers/scatters — those recompile per group size and on a small
    # model cost more than the round's own compute.
    def _cloud_decode_merge_impl(self, blocks, tail, blob, qp, cache, pos,
                                 bt, cur, gmask):
        nxt, cache, npos = self._cloud_decode_impl(blocks, tail, blob, qp,
                                                   cache, pos, bt)
        return (jnp.where(gmask, nxt, cur), cache,
                jnp.where(gmask, npos, pos))

    def _cloud_decode_sample_merge_impl(self, blocks, tail, blob, qp, cache,
                                        pos, bt, temps, top_ps, seeds,
                                        offsets, cur, gmask):
        nxt, cache, npos = self._cloud_decode_sample_impl(
            blocks, tail, blob, qp, cache, pos, bt, temps, top_ps, seeds,
            offsets)
        return (jnp.where(gmask, nxt, cur), cache,
                jnp.where(gmask, npos, pos))

    def _verify_merge_impl(self, k, blocks, tail, blobs, scales, zps,
                           drafts, cache, pos, bt, cur, gmask):
        t, n_commit, ncur, cache, npos = self._verify_impl(
            k, blocks, tail, blobs, scales, zps, drafts, cache, pos, bt)
        return (t, n_commit, jnp.where(gmask, ncur, cur), cache,
                jnp.where(gmask, npos, pos))

    def _verify_sample_merge_impl(self, k, blocks, tail, blobs, scales, zps,
                                  drafts, qs, cache, pos, bt, temps, top_ps,
                                  seeds, offsets, cur, gmask):
        t, n_commit, ncur, cache, npos = self._verify_sample_impl(
            k, blocks, tail, blobs, scales, zps, drafts, qs, cache, pos, bt,
            temps, top_ps, seeds, offsets)
        return (t, n_commit, jnp.where(gmask, ncur, cur), cache,
                jnp.where(gmask, npos, pos))

    def _fleet_spec_fns(self, k: int):
        if k not in self._fleet_jits:
            draft = _jit_phase(partial(self._spec_draft_impl, k),
                               donate=(5, 6))
            verify = _jit_phase(partial(self._verify_merge_impl, k),
                                donate=(6,))
            self._fleet_jits[k] = (draft, verify)
        return self._fleet_jits[k]

    def _fleet_spec_sample_fns(self, k: int):
        """Sampled twin of ``_fleet_spec_fns`` — used whenever a (cut,
        k) group carries at least one temperature>0 slot; greedy rows in
        the group stay on the argmax branch, bit for bit."""
        if k not in self._fleet_sample_jits:
            draft = _jit_phase(partial(self._spec_draft_sample_impl, k),
                               donate=(5, 6))
            verify = _jit_phase(partial(self._verify_sample_merge_impl, k),
                                donate=(7,))
            self._fleet_sample_jits[k] = (draft, verify)
        return self._fleet_sample_jits[k]


class _FleetAdmitMixin:
    """The admission half of ``FleetServingEngine`` plus its per-slot
    sampling-state plumbing (host mirrors of each slot's
    ``SamplingParams``, refreshed at admission — the same discipline as
    ``CollaborativeServingEngine``'s)."""

    def _note_samplings(self, slots, samplings) -> None:
        for i, s in enumerate(slots):
            sp = None if samplings is None else samplings[i]
            sp = sp if (sp is not None and sp.sampled) else None
            self._samp_t[s] = sp.temperature if sp else 0.0
            self._samp_p[s] = sp.top_p if sp else 1.0
            self._samp_s[s] = sp.seed if sp else 0
        self._samp_dev = None

    def _samp_vecs(self):
        if self._samp_dev is None:
            self._samp_dev = (jnp.asarray(self._samp_t),
                              jnp.asarray(self._samp_p),
                              jnp.asarray(self._samp_s))
        return self._samp_dev

    def _offsets(self):
        """[max_batch] absolute output index each live slot's next round
        starts at — key discipline identical to the solo engine's, which
        is why a tenant's sampled stream survives fleet co-batching
        bitwise."""
        off = np.zeros((self.max_batch,), np.int32)
        for s, (_r, c) in (self._sched_active or {}).items():
            off[s] = c
        return jnp.asarray(off)

    def _reserve(self, max_news: np.ndarray) -> np.ndarray:
        head = self._spec_max - 1
        if self.demand_paged:
            return np.minimum(max_news + head, self._spec_max)
        return max_news + head

    def _quota_blocked(self, tenant: str, pending: int, needed: int) -> bool:
        q = self.fairness.quotas.get(tenant)
        return q is not None and \
            self._pool.owner_pages(tenant) + pending + needed > q

    def _admit_turn(self, queue, active, free, cur, pos, rounds):
        """One admission turn: fair-ordered eligible requests grouped by
        (cut, bucket) into batched prefill calls over the shared slot
        table.  Returns (admitted_any, cur, pos, first_blocked_request).
        A quota-blocked request is skipped — its tenant waits without
        blocking the others (and never seeds a group); a pool-wide
        shortfall ends the turn (retirements must return pages first)."""
        admitted = False
        stalled: Optional[Request] = None
        while free:
            elig = [r for r in queue
                    if not self._tenants[r.tenant].hold
                    and r.arrival_s <= self._tenants[r.tenant].now() + 1e-12]
            elig.sort(key=self.fairness.admission_key)
            group: List[Request] = []
            rows: List[np.ndarray] = []
            slots: List[int] = []
            shapes: List[Tuple[int, int]] = []
            pending_pages: Dict[str, int] = {}
            gcut = gbucket = None
            pool_short = False
            for r in elig:
                if not free:
                    break
                t = self._tenants[r.tenant]
                bucket = _bucket_len(_SlotEngine._eff_plen(self, r),
                                     self.max_len)
                if gcut is not None and (t.cut, bucket) != (gcut, gbucket):
                    continue
                row = _SlotEngine._eff_prompt(r)
                eff_new = (r.max_new_tokens if r._parked is None
                           else r.max_new_tokens - len(r._parked) + 1)
                assert (len(row) + eff_new + self._spec_max - 1) \
                    <= self.max_len, \
                    "prompt + generation (+ draft headroom) exceeds max_len"
                needed = self._pool.pages_needed(
                    len(row), int(self._reserve(np.int64(eff_new))),
                    bucket)
                if self._quota_blocked(r.tenant,
                                       pending_pages.get(r.tenant, 0),
                                       needed):
                    stalled = stalled or r
                    continue
                if sum(self._pool.pages_needed(
                        p, int(self._reserve(np.int64(m))), bucket)
                        for p, m in shapes) + needed \
                        > self._pool.free_pages():
                    stalled = stalled or r
                    pool_short = True
                    break
                if gcut is None:
                    gcut, gbucket = t.cut, bucket
                pending_pages[r.tenant] = \
                    pending_pages.get(r.tenant, 0) + needed
                shapes.append((len(row), eff_new))
                group.append(r)
                rows.append(row)
                slots.append(free.pop(0))
            if not group:
                break
            for r in group:
                _remove_is(queue, r)
            cur, pos = self._admit_group(group, rows, slots, shapes,
                                         gcut, gbucket, cur, pos, rounds,
                                         active)
            admitted = True
            if pool_short:
                break
        return admitted, cur, pos, stalled

    def _admit_group(self, group, rows, slots, shapes, cut, bucket, cur,
                     pos, rounds, active):
        """Batched prefill of one (cut, bucket) admission group — rows
        may span tenants; each tenant's wire is charged separately."""
        runtime = self._runtime(cut)
        self._note_samplings(slots, [r.sampling for r in group])
        toks = np.zeros((len(group), bucket), np.int32)
        for i, row in enumerate(rows):
            toks[i, :len(row)] = row
        plens = np.asarray([len(row) for row in rows], np.int32)
        reserves = self._reserve(
            np.asarray([m for _, m in shapes], np.int64))
        # pool admission per tenant-run (owner tagging), one table read
        i = 0
        while i < len(group):
            j = i
            while j < len(group) and group[j].tenant == group[i].tenant:
                j += 1
            self._pool.admit(slots[i:j], plens[i:j], reserves[i:j], bucket,
                             owner=group[i].tenant)
            i = j
        bt_rows = self._pool.rows(np.asarray(slots, np.int32), bucket)
        slots_j = jnp.asarray(np.asarray(slots, np.int32))
        plens_j = jnp.asarray(plens)
        blob, qp, runtime._edge_cache = runtime._edge_prefill(
            runtime.edge_blocks, self.embed, jnp.asarray(toks),
            runtime._edge_cache, slots_j, bt_rows, plens_j)
        if (self._samp_t[slots] > 0).any():
            fn = runtime._samp_jit("cloud_prefill",
                                   runtime._cloud_prefill_sample_impl,
                                   donate=(4,))
            runtime._cloud_cache, cur, pos = fn(
                runtime.cloud_blocks, self.tail, blob, qp,
                runtime._cloud_cache, slots_j, bt_rows, cur, pos, plens_j,
                jnp.asarray(self._samp_t[slots]),
                jnp.asarray(self._samp_p[slots]),
                jnp.asarray(self._samp_s[slots]))
        else:
            runtime._cloud_cache, cur, pos = runtime._cloud_prefill(
                runtime.cloud_blocks, self.tail, blob, qp,
                runtime._cloud_cache, slots_j, bt_rows, cur, pos, plens_j)
        drafting = any(self._tenants[r.tenant].spec_k > 1 for r in group)
        if self._spec_max > 1 and drafting:
            runtime._draft_cache = runtime._draft_prefill(
                runtime.draft_blocks, blob, qp, runtime._draft_cache,
                slots_j, bt_rows, plens_j)
        # per-tenant wire accounting over the group's rows
        for name in {r.tenant for r in group}:
            t = self._tenants[name]
            idx = [i for i, r in enumerate(group) if r.tenant == name]
            t.transport.account_blob(
                t.stats, blob, phase="prefill",
                row_elems=plens[idx].astype(np.int64) * self.cfg.d_model)
            t.transport.account_downlink(t.stats, len(idx),
                                         phase="prefill")
            t.stats.prefill_calls += 1
            t.stats.prefill_tokens += int(plens[idx].sum())
        # resumed requests: pin the stream to the parked tokens
        resumes = [(s, r) for r, s in zip(group, slots)
                   if r._parked is not None]
        if resumes:
            rs = jnp.asarray([s for s, _ in resumes], jnp.int32)
            lasts = jnp.asarray([int(r._parked[-1]) for _, r in resumes],
                                jnp.int32)
            cur = cur.at[rs].set(lasts)
        fresh = [(r, s, 1) for r, s in zip(group, slots)
                 if r._parked is None]
        if fresh:
            rounds.append((cur[:, None], fresh))
        for r, s in zip(group, slots):
            t = self._tenants[r.tenant]
            active[s] = (r, 1 if r._parked is None else len(r._parked))
            if r.admit_s is None:
                r.admit_s = t.now()
            t.stats.queue_wait_s += max(0.0, t.now() - r._enq_s)
            r._parked = None
        return cur, pos
