"""Seed (cache-less) recompute path of the collaborative engine.

``forward``/``generate_recompute`` re-run the whole split stack on the
full, growing sequence every step — the PR-0 behavior kept verbatim as
the baseline the incremental paged path is benchmarked against.  Split
out of ``serve.engine`` purely for the package's module-size contract;
the methods mix back into ``CollaborativeServingEngine`` unchanged.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import compute_qparams, dequantize, quantize
from repro.models import layers as ML
from repro.models import transformer as TF
from repro.serve.transport import _MSG_BYTES, _QP_BYTES

__all__ = ["_SeedPathMixin"]


class _SeedPathMixin:
    """The cache-less split forward + greedy recompute decode (the seed
    baseline), mixed into ``CollaborativeServingEngine``."""

    def _edge_impl(self, blocks, embed, tokens):
        cfg = self.cfg
        x = ML.embed(embed, tokens).astype(cfg.dtype)
        rope = ML.rope_table(tokens.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)
        x, _ = TF.run_blocks(blocks, x, cfg, rope=rope, qctx=self._edge_qctx)
        return x

    def _cloud_impl(self, blocks, tail, h):
        cfg = self.cfg
        rope = ML.rope_table(h.shape[1], cfg.hd, base=cfg.rope_base,
                             dtype=cfg.dtype)
        h, _ = TF.run_blocks(blocks, h, cfg, rope=rope)
        return TF.lm_head(tail, h)

    def forward(self, tokens: np.ndarray) -> jax.Array:
        """Mixed-precision collaborative forward → logits [B, S, V]
        (cache-less: re-runs the whole split stack; the seed path)."""
        toks = jnp.asarray(tokens, jnp.int32)
        h = self._edge(self.edge_blocks, self.embed, toks)
        if self.a_bits is None:
            blob = h.astype(jnp.float32)
        else:
            # Eq.(1): quantize boundary blob for the wire
            qp = compute_qparams(h, bits=self.a_bits)
            blob = quantize(h, qp)
            h = dequantize(blob, qp).astype(self.cfg.dtype)   # Eq.(2)
        # raw total-bytes accounting (no phase split — the seed path
        # predates the prefill/decode breakdown and tests pin its totals)
        nbytes = blob.size * blob.dtype.itemsize + _QP_BYTES + _MSG_BYTES
        t = self.transport.channel.transfer_time(nbytes)
        self.telemetry.observe_transfer(nbytes, t)
        self.stats.transmitted_bytes += int(nbytes)
        self.stats.channel_latency_s += t
        return self._cloud(self.cloud_blocks, self.tail,
                           h.astype(self.cfg.dtype))

    def generate_recompute(self, prompts: List[np.ndarray], *,
                           max_new_tokens: int = 8) -> List[List[int]]:
        """Seed greedy decode: re-run the split forward on the full,
        growing sequence every step (KV-less edge, O(S²·L) per token and
        the whole boundary blob retransmitted).  Kept as the baseline the
        incremental path is benchmarked against."""
        toks = np.stack(prompts).astype(np.int32)
        out = [[] for _ in prompts]
        for _ in range(max_new_tokens):
            logits = self.forward(toks)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for j, t in enumerate(nxt):
                out[j].append(int(t))
            toks = np.concatenate([toks, nxt[:, None].astype(np.int32)], 1)
            self.stats.decode_steps += 1
        return out
