"""Online re-tuning policy: the control plane of collaborative serving.

The paper's Algorithm 1 picks a partition for *one* environment
snapshot; JointDNN (arXiv:1801.08618) observes that the optimal
partition moves with network state, and Shared Mobile-Cloud Inference
(arXiv:2002.00157) argues the edge/cloud split should adapt at runtime.
This module closes that loop for the serving engines:

    measurement  ``transport.LinkTelemetry`` — EWMA bandwidth/RTT from
                 every charged message, EWMA draft acceptance from every
                 verify round;
    model        ``costmodel.speculative_round_time`` over the joint
                 (cut_layer, spec_k) grid via ``autotune.tune_cut_and_k``
                 — the same predict-then-pick loop as the offline tuner,
                 re-evaluated against live estimates;
    actuation    the engine applies a new ``spec_k`` immediately
                 (between rounds — draft length is a per-round choice)
                 and a new ``cut_layer`` at the next request-admission
                 boundary (the scheduler drains occupied slots first,
                 because split KV caches change layer ownership); the
                 weights for every candidate cut sit in a prequantized
                 bank, so the re-partition itself is a pointer swap.

Hysteresis guards both switches: a re-partition costs a drain barrier
and fresh phase traces, so the predicted win must clear a higher bar
than a draft-length change before the policy acts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.autotune import tune_cut_and_k
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel, DeviceModel,
                                  EDGE_TX2_CLASS, predict_finish_time)
from repro.models import transformer as TF
from repro.serve.transport import (_MSG_BYTES, _QP_BYTES, _TOK_BYTES,
                                   LinkTelemetry)

__all__ = ["Decision", "AdaptivePolicy", "DeadlineAdmission", "_CutBank",
           "FleetFairness"]

# the param-dict keys ``layers.dense``/``layers.moe_*`` route through
# ``QuantCtx.weight`` — exactly these leaves carry the INT8 lattice
_WEIGHT_KEYS = ("w", "wi", "wg", "wo")


def _prequantize_blocks(blocks: Any, deploy_qctx) -> Any:
    """Apply the edge deployment lattice (``QuantCtx.weight``) to every
    weight leaf **once**.  Runtime contexts then run with
    ``quantize_weights=False`` — bitwise the same math, minus a per-call
    re-quantization of static weights (which the k-step draft scan would
    otherwise pay k times per round).

    Block params are stacked ``[n_layers, ...]`` and the runtime scan
    quantizes each *layer slice*, so the lattice is applied per layer
    (vmap over the leading axis) — identical thresholds, bit for bit."""
    flat, tree = jax.tree_util.tree_flatten_with_path(blocks)
    out = []
    for path, leaf in flat:
        key = next((p.key for p in reversed(path)
                    if isinstance(p, jax.tree_util.DictKey)), None)
        if key in _WEIGHT_KEYS and jnp.issubdtype(leaf.dtype, jnp.floating):
            leaf = jax.vmap(
                lambda w, k=str(key): deploy_qctx.weight(k, w))(leaf)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(tree, out)


class _CutBank:
    """Prequantized multi-cut weight bank — the actuation half of a
    re-partition.

    The full block stack is fake-quantized onto the edge's INT8
    deployment lattice **once** (per block, so every candidate cut
    shares the identical quantized blocks), then each candidate cut gets
    three slices: the quantized edge prefix, the fp cloud suffix, and
    the quantized suffix copy the edge drafts with.  Slices materialize
    lazily on first use and stay cached, so resident memory scales with
    the cuts actually *served*, not with the candidate grid, and a warm
    re-partition is a pointer swap — never a requantization.  The
    runtime ``QuantCtx(quantize_weights=False)`` consumes the lattice
    weights as-is."""

    def __init__(self, params: Any, cfg: TF.LMConfig, cuts,
                 deploy_qctx=None) -> None:
        self._fp = params["blocks"]
        self._q = self._fp if deploy_qctx is None \
            else _prequantize_blocks(self._fp, deploy_qctx)
        self._n_layers = cfg.n_layers
        self._cuts = tuple(sorted({int(c) for c in cuts}))
        assert all(0 <= c < cfg.n_layers for c in self._cuts)
        self._slices: Dict[int, Tuple[Any, Any, Any]] = {}

    @property
    def cuts(self) -> Tuple[int, ...]:
        return self._cuts

    def get(self, cut: int) -> Tuple[Any, Any, Any]:
        """(edge prefix @ INT8 lattice, cloud suffix @ fp, draft suffix
        copy @ INT8 lattice) for ``cut``."""
        if cut not in self._cuts:
            raise KeyError(f"cut {cut} not in weight bank {self.cuts}")
        if cut not in self._slices:
            def take(tree, lo, hi):
                return jax.tree_util.tree_map(lambda v: v[lo:hi], tree)
            self._slices[cut] = (take(self._q, 0, cut + 1),
                                 take(self._fp, cut + 1, self._n_layers),
                                 take(self._q, cut + 1, self._n_layers))
        return self._slices[cut]


@dataclasses.dataclass(frozen=True)
class Decision:
    """One output of the control loop: the (cut, k) the engine should
    run, plus the evidence it was decided on."""
    cut: int
    spec_k: int
    s_per_token: float           # predicted, at the decision's estimates
    current_s_per_token: float   # prediction for the config it replaces
    bandwidth_bytes_per_s: float
    rtt_s: float
    acceptance: float

    @property
    def predicted_speedup(self) -> float:
        return self.current_s_per_token / max(self.s_per_token, 1e-12)


class AdaptivePolicy:
    """Re-tunes ``(cut_layer, spec_k)`` for a collaborative engine from
    live link telemetry.

    ``cuts=None`` restricts the policy to the draft length only — the
    self-correcting ``spec_k="auto"`` mode: the engine's measured
    acceptance rate replaces the construction-time prior in
    ``tune_spec_k`` and k is revised between requests.  With candidate
    ``cuts`` the policy also re-partitions; every candidate's INT8
    prefix/suffix weights are prequantized into the engine's cut bank,
    so acting on a decision never requantizes anything.

    ``decide`` is cheap (a closed-form grid of |cuts| x |ks| roofline
    evaluations), so the engine calls it every scheduler turn; decisions
    only *change* when the predicted per-accepted-token win clears
    ``k_hysteresis`` (draft length — a free switch) or
    ``cut_hysteresis`` (re-partition — pays a drain barrier and fresh
    phase traces).
    """

    def __init__(self, cfg, *, batch: int,
                 cuts: Optional[Sequence[int]] = None,
                 ks: Sequence[int] = (1, 2, 4, 8, 16),
                 edge: DeviceModel = EDGE_TX2_CLASS,
                 cloud: DeviceModel = CLOUD_TITANXP_CLASS,
                 fallback_channel: Optional[Channel] = None,
                 acceptance_prior: float = 0.8,
                 k_hysteresis: float = 0.02,
                 cut_hysteresis: float = 0.15,
                 k_between_requests_only: bool = False,
                 min_dwell: int = 0):
        if cuts is not None:
            assert all(0 <= c < cfg.n_layers - 1 for c in cuts), \
                "candidate cuts must leave at least one cloud block"
        self.cfg = cfg
        self.batch = batch
        self.cuts = tuple(cuts) if cuts is not None else None
        self.ks = tuple(ks)
        self.edge = edge
        self.cloud = cloud
        self.fallback_channel = fallback_channel or Channel(
            bandwidth_bytes_per_s=float("inf"))
        self.acceptance_prior = acceptance_prior
        self.k_hysteresis = k_hysteresis
        self.cut_hysteresis = cut_hysteresis
        self.k_between_requests_only = k_between_requests_only
        # flap damping: after recommending a switch, hold the new config
        # for at least ``min_dwell`` decide() ticks before recommending
        # another — an oscillating or lossy channel (telemetry swinging
        # every round) must not thrash cut/spec_k between consecutive
        # scheduler turns.  0 disables (hysteresis alone).
        self.min_dwell = int(min_dwell)
        self._ticks_since_switch: Optional[int] = None
        self.history: List[Decision] = []

    def decide(self, telemetry: LinkTelemetry, *, cut: int,
               spec_k: int, sampled_frac: float = 0.0) -> Decision:
        """One control-loop evaluation: current telemetry → the (cut, k)
        the engine should be running, with hysteresis against the
        config it is running.  ``sampled_frac`` (live slots decoding at
        temperature>0) prices the q-row uplink sampled rounds ship, and
        the measured acceptance EWMA already reflects stochastic
        rejection — together they pull hot sampling traffic toward a
        smaller k than greedy traffic on the same link."""
        channel = telemetry.channel(self.fallback_channel)
        acc = telemetry.acceptance(self.acceptance_prior)
        cuts = self.cuts if self.cuts is not None else (cut,)
        best, grid = tune_cut_and_k(
            self.cfg, batch=self.batch, channel=channel, cuts=cuts,
            acceptance=acc, edge=self.edge, cloud=self.cloud, ks=self.ks,
            sampled_frac=sampled_frac)
        cur = [p for p in grid if p.cut == cut and p.k == spec_k]
        cur_s = cur[0].s_per_token if cur else float("inf")

        # hysteresis: keep the running config unless the win is real.  A
        # re-partition must beat the best *stay-at-this-cut* option by
        # the higher bar — a k-only win never justifies a drain barrier
        # when (almost) the same win is available at the current cut
        stay = min((p for p in grid if p.cut == cut),
                   key=lambda p: p.s_per_token)
        new_cut, new_k, new_s = best.cut, best.k, best.s_per_token
        if new_cut != cut and \
                new_s >= stay.s_per_token * (1.0 - self.cut_hysteresis):
            new_cut, new_k, new_s = cut, stay.k, stay.s_per_token
        if new_cut == cut and new_k != spec_k \
                and new_s >= cur_s * (1.0 - self.k_hysteresis):
            new_k, new_s = spec_k, cur_s

        # dwell-time floor: a fresh switch recommendation starts a hold
        # window of ``min_dwell`` ticks during which further changes are
        # suppressed — back-to-back flapping costs more than any
        # single-tick prediction can be trusted to win back
        if self._ticks_since_switch is not None:
            self._ticks_since_switch += 1
        if (new_cut, new_k) != (cut, spec_k):
            if self._ticks_since_switch is not None \
                    and self._ticks_since_switch <= self.min_dwell:
                new_cut, new_k, new_s = cut, spec_k, cur_s
            else:
                self._ticks_since_switch = 0

        d = Decision(cut=new_cut, spec_k=new_k, s_per_token=new_s,
                     current_s_per_token=cur_s,
                     bandwidth_bytes_per_s=channel.bandwidth_bytes_per_s,
                     rtt_s=channel.rtt_s, acceptance=acc)
        # log each *distinct* control action once: while the engine
        # defers a pending switch (drain barrier / between-requests), the
        # same recommendation recurs every scheduler turn and must not
        # spam the history
        if (d.cut != cut or d.spec_k != spec_k) and (
                not self.history
                or (self.history[-1].cut, self.history[-1].spec_k)
                != (d.cut, d.spec_k)):
            self.history.append(d)
        return d


class FleetFairness:
    """Cross-tenant weighted-fair sharing for the fleet engine — PR 6's
    priority/deadline admission and preemption discipline extended to a
    shared slot table and page pool serving many edges at once.

    Each tenant carries a ``weight`` (its share of the cloud) and an
    optional hard ``page quota``.  Fairness is virtual-service-time
    scheduling: every committed token charges its tenant
    ``1 / weight`` of virtual service, and admission orders eligible
    requests by ``(priority desc, tenant virtual service asc, FIFO)`` —
    a hot tenant's backlog keeps admitting only while its weighted
    service stays behind the others', so it can never starve a light
    tenant out of slots.  Preemption inverts the same ordering, with
    pool pressure first: victims come from the tenant *most over its
    fair page share* (measured through the pool's public
    ``owner_pages`` accounting), then lowest priority, then
    most-remaining-budget — the PR 6 rule, tenant-aware."""

    def __init__(self, weights: Dict[str, float],
                 quotas: Optional[Dict[str, Optional[int]]] = None):
        assert weights and all(w > 0 for w in weights.values()), weights
        self.weights = dict(weights)
        self.quotas = {t: (quotas or {}).get(t) for t in weights}
        self._wsum = sum(self.weights.values())
        self.vservice: Dict[str, float] = {t: 0.0 for t in weights}

    def charge(self, tenant: str, tokens: int) -> None:
        """``tokens`` committed for ``tenant``: advance its virtual
        service clock by the weighted amount."""
        self.vservice[tenant] += tokens / self.weights[tenant]

    def admission_key(self, req) -> Tuple:
        """Sort key for the eligible-request queue (ascending)."""
        return (-req.priority, self.vservice.get(req.tenant, 0.0), req._seq)

    def fair_pages(self, tenant: str, usable_pages: int) -> float:
        """``tenant``'s weighted fair share of the pool."""
        return usable_pages * self.weights[tenant] / self._wsum

    def over_quota(self, tenant: str, held: int) -> bool:
        """Hard quota check at admission/growth time (None = uncapped)."""
        q = self.quotas.get(tenant)
        return q is not None and held > q

    def victim_key(self, req, tenant_pages: int, usable_pages: int,
                   remaining: int) -> Tuple:
        """Sort key for preemption victims (ascending = preempt first):
        most over fair page share, then lowest priority, then
        most-remaining-budget (PR 6's tie-break), preserving slot-id
        determinism downstream."""
        over = tenant_pages - self.fair_pages(req.tenant, usable_pages)
        return (-over, req.priority, -remaining)


class DeadlineAdmission:
    """Deadline-aware admission control: the paper's predict-then-pick
    discipline (Algorithm 1) applied to the *admit/shed* decision.

    Where ``AdaptivePolicy`` asks "which (cut, k) is fastest right
    now?", this asks "can this request finish by its deadline at the
    engine's current (cut, k), behind the work already admitted?" — and
    if the answer is no *at admission time, with the request first in
    line for a slot*, the request can only finish even later, so the
    engine sheds it instead of letting it occupy pages and head-of-line
    block traffic that could still meet its deadline.

    The prediction reuses the same telemetry-fed roofline the tuner
    runs: ``tune_cut_and_k`` evaluated at the single live (cut, k) point
    gives the per-round phase breakdown — expected retransmissions on a
    lossy link are already priced into its channel term — and
    ``costmodel.predict_finish_time`` folds in the request's own budget,
    the queue's owed tokens, and the prefill round-trip.  ``margin``
    inflates the predicted service time (>1 = conservative: shed
    earlier, protect admitted work; <1 = optimistic)."""

    def __init__(self, cfg, *, batch: int,
                 fallback_channel: Optional[Channel] = None,
                 edge: DeviceModel = EDGE_TX2_CLASS,
                 cloud: DeviceModel = CLOUD_TITANXP_CLASS,
                 acceptance_prior: float = 0.8, margin: float = 1.1,
                 blob_itemsize: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.fallback_channel = fallback_channel or Channel(
            bandwidth_bytes_per_s=float("inf"))
        self.edge = edge
        self.cloud = cloud
        self.acceptance_prior = acceptance_prior
        self.margin = float(margin)
        self.blob_itemsize = int(blob_itemsize)

    def predict_finish(self, telemetry: LinkTelemetry, *, now: float,
                       cut: int, spec_k: int, plen: int, max_new: int,
                       slots: int, queue_tokens: float = 0.0) -> float:
        """Predicted absolute finish time of a request admitted now."""
        channel = telemetry.channel(self.fallback_channel)
        acc = telemetry.acceptance(self.acceptance_prior)
        best, _ = tune_cut_and_k(
            self.cfg, batch=self.batch, channel=channel, cuts=(cut,),
            ks=(spec_k,), acceptance=acc, edge=self.edge, cloud=self.cloud)
        # the admission prefill's wire cost: the [plen, D] boundary blob
        # up, the first token down, both paying expected retransmissions
        prefill_s = (channel.transfer_time(
            plen * self.cfg.d_model * self.blob_itemsize
            + _QP_BYTES + _MSG_BYTES)
            + channel.transfer_time(_TOK_BYTES + _MSG_BYTES)) \
            * channel.expected_retx()
        t = predict_finish_time(best.breakdown, now=now, max_new=max_new,
                                queue_tokens=queue_tokens, slots=slots,
                                prefill_s=prefill_s)
        return now + (t - now) * self.margin
