"""Temperature / top-p sampling for the serving engines, including the
speculative **rejection-sampling verify** (the standard speculative
sampling scheme) that keeps the cloud model's output distribution exact
while the edge drafts.

Distribution contract
---------------------

For a request with ``SamplingParams(temperature=T > 0, top_p=P,
seed=s)``, every committed token is distributed exactly as if the cloud
suffix had sampled it serially from ``nucleus(softmax(logits / T), P)``
— the draft only proposes.  Grading position i accepts draft ``d ~ q``
with probability ``min(1, p(d) / q(d))`` and, on the first rejection,
resamples from the normalized residual ``max(p - q, 0)``; if every
graded draft is accepted the bonus token at the round's last position
is sampled directly from ``p``.  Both identities hold per committed
prefix, so the accepted stream is *distributionally* indistinguishable
from non-speculative cloud sampling (gated by a TV-distance frequency
test in ``tests/test_sampled_spec.py``).  ``temperature=0`` (or
``sampling=None``) is the greedy fast path — it routes through the
pre-existing argmax phases untouched, bit for bit.

Seed discipline (replay determinism)
------------------------------------

Every random draw uses a key derived **only** from the request's
``(seed, absolute output index, stream tag)`` — never from slot ids,
batch composition, or wall clock:

    ``DRAFT``   the edge's proposal at an output index;
    ``ACCEPT``  the verify's accept/reject uniform for that index;
    ``RESID``   the residual resample on rejection;
    ``CLOUD``   direct cloud draws — the prefill's first token, serial
                (k=1) steps, the all-accepted bonus token, and the
                resilient engine's edge-only fallback (which in
                lossless mode therefore reproduces the cloud's serial
                stream bitwise).

Consequences: preemption replay, fleet co-batching, and outage resync
cannot perturb a request's stream (same indices → same keys), and
re-drafting a previously rejected index reuses its ``DRAFT`` key safely
— the discarded draw never influenced any committed token, so the redraw
is still an independent sample from the *new* conditional ``q``.  What
is **not** pinned across configurations is round chunking: a ``k=4``
stream consumes ``ACCEPT``/``RESID`` draws where a ``k=1`` stream
consumes ``CLOUD`` draws, so different (cut, k) schedules agree in
distribution (and at output index 0 bitwise), not token-for-token.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SamplingParams", "DRAFT", "ACCEPT", "RESID", "CLOUD",
           "token_keys", "uniform_rows", "filtered_probs", "sample_rows",
           "grade_and_correct"]

# stream tags (see module docstring) — folded into every per-token key
DRAFT, ACCEPT, RESID, CLOUD = 0, 1, 2, 3

# log-floor for zeroed (out-of-nucleus) probabilities: low enough that
# categorical's Gumbel noise (bounded by ~17 for 32-bit uniforms) can
# never resurrect a masked token, finite so no NaNs flow through where()
_LOG_FLOOR = 1e-38


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode-sampling controls.

    ``temperature=0`` means greedy (argmax) — such requests take the
    bit-identical pre-sampling fast path regardless of ``top_p``/
    ``seed``.  ``seed`` is the root of every random draw the request
    ever consumes (see the module docstring's stream discipline)."""
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        assert self.temperature >= 0.0, self.temperature
        assert 0.0 < self.top_p <= 1.0, self.top_p

    @property
    def sampled(self) -> bool:
        return self.temperature > 0.0


def token_keys(seeds: jax.Array, indices: jax.Array,
               stream: int) -> jax.Array:
    """[n, 2] uint32 PRNG keys for (seed, absolute output index, stream)
    triples — the whole replay-determinism story is that keys depend on
    nothing else."""
    def one(s, i):
        k = jax.random.PRNGKey(s)
        return jax.random.fold_in(jax.random.fold_in(k, i), stream)
    return jax.vmap(one)(seeds.astype(jnp.uint32), indices.astype(jnp.int32))


def uniform_rows(keys: jax.Array) -> jax.Array:
    """One U[0, 1) draw per key row."""
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)


def filtered_probs(logits: jax.Array, temps: jax.Array,
                   top_ps: jax.Array) -> jax.Array:
    """Row-wise temperature + top-p (nucleus) filtered probabilities.

    ``logits [n, V]`` f32, ``temps``/``top_ps`` ``[n]``.  Nucleus keeps
    the smallest prefix of descending-sorted probabilities whose
    *exclusive* cumulative mass is below ``top_p`` (ties at the
    threshold all kept), then renormalizes.  Rows with ``temp <= 0``
    return a one-hot at the argmax, so downstream categorical draws on
    greedy rows are deterministic — though engines never sample greedy
    rows; they take the argmax branch directly."""
    t = jnp.maximum(temps, 1e-6)[:, None]
    p = jax.nn.softmax(logits / t, axis=-1)
    sp = jnp.sort(p, axis=-1)[:, ::-1]
    cs = jnp.cumsum(sp, axis=-1)
    keep_sorted = (cs - sp) < top_ps[:, None]
    thresh = jnp.min(jnp.where(keep_sorted, sp, jnp.inf), axis=-1)
    p = jnp.where(p >= thresh[:, None], p, 0.0)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(jnp.argmax(logits, -1), logits.shape[-1],
                            dtype=p.dtype)
    return jnp.where((temps > 0.0)[:, None], p, onehot)


def sample_rows(p: jax.Array, keys: jax.Array) -> jax.Array:
    """One categorical draw per probability row (``p [n, V]``)."""
    logp = jnp.log(jnp.maximum(p, _LOG_FLOOR))
    return jax.vmap(lambda lp, k: jax.random.categorical(k, lp))(
        logp, keys).astype(jnp.int32)


def grade_and_correct(p: jax.Array, q: jax.Array, d: jax.Array,
                      sampled_row: jax.Array, greedy_t: jax.Array,
                      seeds: jax.Array, offsets: jax.Array,
                      ) -> tuple:
    """The rejection-sampling verify core, row-mixed with greedy.

    ``p``/``q`` are the cloud/draft filtered probabilities ``[B, k, V]``
    at each drafted position, ``d [B, k]`` the drafts, ``greedy_t`` the
    cloud argmaxes, ``offsets [B]`` each row's absolute output index of
    position 0.  Greedy rows (``~sampled_row``) grade by exact argmax
    match and correct with ``greedy_t`` — committing the identical
    tokens the greedy verify would.  Sampled rows accept position i iff
    ``u_i * q_i(d_i) <= p_i(d_i)`` (``u`` from the ``ACCEPT`` stream);
    the correction at the first rejection samples the normalized
    residual ``max(p - q, 0)`` (``RESID``; a numerically-empty residual
    — q covering p — falls back to ``p``), and an all-accepted round's
    bonus position samples ``p`` directly (``CLOUD``).  Returns
    ``(tokens [B, k], n_commit [B])`` with positions ``>= n_commit``
    unread by the scheduler."""
    B, k, V = p.shape
    ar = jnp.arange(k)[None, :]
    idx = (offsets[:, None] + ar).reshape(-1)            # [B*k] abs indices
    rep_seeds = jnp.repeat(seeds, k)
    u = uniform_rows(token_keys(rep_seeds, idx, ACCEPT)).reshape(B, k)
    p_d = jnp.take_along_axis(p, d[..., None], axis=-1)[..., 0]
    q_d = jnp.take_along_axis(q, d[..., None], axis=-1)[..., 0]
    ok_row = jnp.where(sampled_row[:, None], u * q_d <= p_d, d == greedy_t)
    ok = ok_row[:, :k - 1].astype(jnp.int32)
    n_commit = 1 + jnp.sum(jnp.cumprod(ok, axis=1), axis=1)  # [B] in 1..k
    resid = jnp.maximum(p - q, 0.0)
    mass = jnp.sum(resid, axis=-1, keepdims=True)
    resid = jnp.where(mass > 1e-9, resid / jnp.maximum(mass, 1e-9), p)
    resid_tok = sample_rows(resid.reshape(B * k, V),
                            token_keys(rep_seeds, idx, RESID)).reshape(B, k)
    bonus_tok = sample_rows(p.reshape(B * k, V),
                            token_keys(rep_seeds, idx, CLOUD)).reshape(B, k)
    corr = jnp.where(ar == k - 1, bonus_tok, resid_tok)
    corr = jnp.where(sampled_row[:, None], corr, greedy_t)
    toks = jnp.where(ar == (n_commit - 1)[:, None], corr, d)
    return toks, n_commit
