"""Multi-edge fleet serving: one shared cloud engine, N tenant edges.

The paper's auto-tuner picks one partition for one device/network
snapshot; JointDNN (arXiv:1801.08618) frames the decision as
per-device/per-network-state, and Shared Mobile-Cloud Inference
(arXiv:2002.00157) amortizes shared cloud compute across many mobile
clients.  ``FleetServingEngine`` makes both concrete: it admits request
streams from many *simulated edges* (tenants), each owning its own
channel (``Channel``/``DriftingChannel``/``FaultyChannel``), its own
``LinkTelemetry`` + ``ServeStats``, and its own ``(cut_layer, spec_k)``
served out of **one shared prequantized ``_CutBank``** — no per-tenant
weight copies — over **one shared slot table and KV page pool**.

The perf headline is the cross-tenant batched verify: every scheduler
turn groups the live slots by ``(cut, spec_k)`` and advances each group
with **one** phase call spanning the whole slot axis — one edge
draft scan, one uplink charge per tenant, one batched
``paged_flash_mq`` verify step over the shared ``_PagedPool`` — so N
tenants' rounds cost one compiled dispatch per group instead of one
per tenant.  Tenants at different cuts verify through their own suffix
slice (a per-cut ``_CutRuntime``: jitted phases + split caches over
the *same* pool geometry) but share the slot/page tables; slots riding
along in another group's call are masked to the allocator's dump page
(``_PagedPool.table_for``), the same convention the resync replay
established, so per-slot streams stay independent — in lossless
``a_bits=None`` mode a tenant's fleet stream is bit-identical to the
same tenant served alone (property-tested in
``tests/test_fleet_serve.py``).

Temperature>0 requests ride the same group rounds through the sampled
phase twins (``sampling.SamplingParams`` per request): a group with at
least one sampled slot drafts with per-slot seeded streams and verifies
by rejection sampling, while greedy slots in the same call stay on the
argmax branch bit for bit.  Seed keys depend only on (seed, absolute
output index, stream), never on co-tenants or slot number — a tenant's
sampled stream is the same whether it shares the batch or runs solo.
Sampled rows additionally ship the drafter's k-1 filtered q rows
uplink (charged to the owning tenant at f32 vocab width; see
``costmodel.speculative_round_time(draft_q_bytes=...)``).

Cross-tenant fairness extends PR 6's overload discipline: admission
orders eligible requests by ``policy.FleetFairness`` (priority, then
weighted virtual service, then FIFO), per-tenant page quotas bound a
hot tenant's pool claim, and a mid-round ``PoolExhausted`` preempts
the tenant most over its fair page share first (then PR 6's
lowest-priority / most-remaining rule) with the scheduler's
replay-based resume.  Per-tenant re-tuning runs through per-tenant
``AdaptivePolicy`` instances (fed each tenant's own sampled-traffic
fraction); a cut or draft-length switch applies at the *tenant's own*
drained boundary — other tenants never pay a fleet-wide drain barrier
for one edge's re-partition.

``TenantSpec`` and the per-cut runtime live in ``serve.tenant``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import Channel
from repro.models import layers as ML
from repro.models import transformer as TF
from repro.serve.kvcache import PoolExhausted, _PagedPool
from repro.serve.policy import AdaptivePolicy, FleetFairness, _CutBank
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request
from repro.serve.tenant import (TenantSpec, _CutRuntime, _FleetAdmitMixin,
                                _Tenant)
from repro.serve.transport import (_MSG_BYTES, _QP_BYTES, _TOK_BYTES,
                                   ServeStats)

__all__ = ["TenantSpec", "FleetServingEngine"]


class FleetServingEngine(_FleetAdmitMixin):
    """One cloud, N edges: continuous batching over a shared slot table
    with cross-tenant batched verify rounds (see the module docstring).

    ``tenants`` is a list of ``TenantSpec``; requests are submitted per
    tenant (``generate``/``generate_requests``) and served concurrently.
    Per-tenant wire traffic is charged to the tenant's own channel and
    ``ServeStats`` (``engine.tenant(name).stats``); ``engine.stats``
    aggregates the fleet.  ``demand_paged=True`` turns on PR 6's
    oversubscription discipline pool-wide, with ``FleetFairness``
    choosing cross-tenant preemption victims."""

    def __init__(self, params: Any, cfg: TF.LMConfig,
                 tenants: Sequence[TenantSpec], *, max_batch: int = 8,
                 max_len: int = 128, a_bits: Optional[int] = 8,
                 edge_int8: bool = True, cloud_int8: bool = True,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 demand_paged: bool = False,
                 spec_acceptance: float = 0.8):
        assert tenants, "a fleet needs at least one tenant"
        assert len({t.name for t in tenants}) == len(tenants), \
            "tenant names must be unique"
        self.cfg = dataclasses.replace(cfg, remat=False)
        self.max_batch = max_batch
        self.max_len = max_len
        self.a_bits = a_bits
        self.edge_int8 = edge_int8
        self.cloud_int8 = cloud_int8
        self.page_size = page_size
        self.demand_paged = bool(demand_paged)
        self.trace_counts = {"prefill": 0, "decode": 0, "spec_draft": 0,
                             "verify": 0, "edge_only": 0, "resync": 0,
                             "draft_rebuild": 0}
        # act_axis=0 keeps each slot's Eq.(1) activation lattice
        # independent of its batch neighbours — with cross-tenant
        # batching this is what guarantees one tenant's stream is
        # bit-identical whether it shares the batch or runs solo
        self._edge_qctx = None if a_bits is None else \
            ML.QuantCtx(mode="dynamic", a_bits=a_bits,
                        quantize_weights=False, act_axis=0)
        deploy_qctx = None if a_bits is None else \
            ML.QuantCtx(mode="dynamic", a_bits=a_bits)
        self._pool = _PagedPool.build(max_batch, max_len, page_size,
                                      num_pages)

        # per-tenant control planes + the shared weight bank
        self._tenants: Dict[str, _Tenant] = {}
        bank_cuts = set()
        spec_max = 1
        for spec in tenants:
            assert 0 <= spec.cut_layer < cfg.n_layers, spec
            policy = spec.policy
            if policy == "auto":
                assert spec.cut_layer <= cfg.n_layers - 2, \
                    "adaptive tenants need a cloud block at every cut"
                initial = spec.channel or Channel(
                    bandwidth_bytes_per_s=float("inf"))
                initial = getattr(initial, "phase", initial)
                cuts = tuple(sorted({0, (cfg.n_layers - 1) // 2,
                                     cfg.n_layers - 2, spec.cut_layer}))
                policy = AdaptivePolicy(cfg, batch=max_batch, cuts=cuts,
                                        ks=(1, 2, 4, 8),
                                        fallback_channel=initial,
                                        acceptance_prior=spec_acceptance)
            self._tenants[spec.name] = _Tenant(spec, policy or None)
            bank_cuts.add(spec.cut_layer)
            spec_max = max(spec_max, spec.spec_k)
            if policy is not None:
                bank_cuts |= set(policy.cuts or ())
                spec_max = max(spec_max, *policy.ks)
        self._spec_max = spec_max
        self.fairness = FleetFairness(
            {t.name: t.weight for t in tenants},
            {t.name: t.max_pages for t in tenants})

        self.embed = params["embed"]
        self.tail = {"final_norm": params["final_norm"],
                     "lm_head": params["lm_head"]}
        self._bank = _CutBank(params, self.cfg, bank_cuts, deploy_qctx)
        self._runtimes: Dict[int, _CutRuntime] = {}
        # batched phase dispatches actually issued (one per (cut, k)
        # group per turn) — the quantity cross-tenant batching divides
        # by up to N vs N independent engines; benchmarks report it
        self.round_calls = 0
        # device-resident group masks, keyed by slot tuple — groups
        # repeat across rounds, so the host->device put happens once
        # (the masked cur/pos merge itself runs inside the jitted
        # round phases, see ``_CutRuntime._cloud_decode_merge_impl``)
        self._gmasks: Dict[Tuple[int, ...], Any] = {}
        # per-slot sampling state (host mirror of each live request's
        # SamplingParams; see _FleetAdmitMixin._note_samplings)
        self._samp_t = np.zeros((max_batch,), np.float32)
        self._samp_p = np.ones((max_batch,), np.float32)
        self._samp_s = np.zeros((max_batch,), np.int32)
        self._samp_dev: Optional[Tuple[Any, Any, Any]] = None
        # scheduler-internal live view (mirrors _SlotEngine's)
        self._sched_active = None
        self._sched_committed = None

    # -- public surface ------------------------------------------------------
    def tenant(self, name: str) -> _Tenant:
        return self._tenants[name]

    @property
    def stats(self) -> ServeStats:
        """Fleet-wide rollup of the per-tenant stats."""
        return ServeStats.aggregate(
            [t.stats for t in self._tenants.values()])

    def generate(self, prompts: Dict[str, List[np.ndarray]], *,
                 max_new_tokens: int = 16,
                 sampling=None) -> Dict[str, List[List[int]]]:
        """Decode per-tenant prompt lists with cross-tenant continuous
        batching; returns token streams per tenant in input order.
        ``sampling`` is None (greedy), one ``SamplingParams`` applied to
        every prompt, or a dict mapping tenant name to either one
        ``SamplingParams`` or a per-prompt list."""
        def _samp(name: str, i: int) -> Optional[SamplingParams]:
            s = (sampling.get(name) if isinstance(sampling, dict)
                 else sampling)
            return s[i] if isinstance(s, (list, tuple)) else s
        reqs = {name: [Request(uid=i, prompt=np.asarray(p),
                               max_new_tokens=max_new_tokens,
                               sampling=_samp(name, i))
                       for i, p in enumerate(ps)]
                for name, ps in prompts.items()}
        return self.generate_requests(reqs)

    def generate_requests(self, reqs: Dict[str, List[Request]]
                          ) -> Dict[str, List[List[int]]]:
        """Run caller-built per-tenant ``Request`` lists (priorities,
        deadlines, arrival times on each tenant's own simulated clock)."""
        flat: List[Request] = []
        seq = 0
        for name, rl in reqs.items():
            assert name in self._tenants, f"unknown tenant {name!r}"
            for r in rl:
                r.tenant = name
                r._seq = seq
                r._enq_s = float(r.arrival_s)
                seq += 1
                flat.append(r)
        if flat:
            self._run(flat)
        return {name: [r.out_tokens for r in rl]
                for name, rl in reqs.items()}

    # -- internals -----------------------------------------------------------
    def _runtime(self, cut: int) -> _CutRuntime:
        if cut not in self._runtimes:
            self._runtimes[cut] = _CutRuntime(self, cut)
        return self._runtimes[cut]

    def _tenant_tick(self, t: _Tenant, n_active: int) -> None:
        """One control-loop turn for one tenant: re-decide (cut, k) from
        its telemetry; apply at its own drained boundary, holding only
        *its* admission while its slots drain (no fleet-wide barrier)."""
        if t.policy is not None:
            live = [s for s, (r, _c) in (self._sched_active or {}).items()
                    if r.tenant == t.name]
            frac = (sum(1 for s in live if self._samp_t[s] > 0)
                    / len(live) if live else 0.0)
            kw = {"sampled_frac": frac} if frac > 0.0 else {}
            d = t.policy.decide(t.telemetry, cut=t.cut, spec_k=t.spec_k,
                                **kw)
            t.pending = d if (d.cut, d.spec_k) != (t.cut, t.spec_k) else None
        if t.pending is None:
            t.hold = False
            return
        if n_active:
            t.hold = True
            t.stats.policy_holds += 1
            return
        if t.pending.cut != t.cut:
            t.cut = t.pending.cut
            t.stats.cut_switches += 1
        if t.pending.spec_k != t.spec_k:
            t.spec_k = t.pending.spec_k
            t.stats.spec_k_switches += 1
        t.pending = None
        t.hold = False

    def _run(self, reqs: List[Request]) -> None:
        queue: List[Request] = list(reqs)
        active: Dict[int, Tuple[Request, int]] = {}
        free = list(range(self.max_batch))
        cur = jnp.zeros((self.max_batch,), jnp.int32)
        pos = jnp.zeros((self.max_batch,), jnp.int32)
        rounds: List[Tuple[Any, List[Tuple[Request, int, int]]]] = []

        def parked_tokens(r: Request) -> np.ndarray:
            chunks = [np.asarray(t[s, :n])
                      for t, takes in rounds
                      for rr, s, n in takes if rr is r and n > 0]
            return (np.concatenate(chunks).astype(np.int32) if chunks
                    else np.zeros((0,), np.int32))

        self._sched_active = active
        self._sched_committed = parked_tokens

        def preempt(slot: int) -> None:
            r, _c = active.pop(slot)
            t = self._tenants[r.tenant]
            r._parked = parked_tokens(r)
            r._enq_s = t.now()
            r.preemptions += 1
            t.stats.preemptions += 1
            self._pool.retire(slot)
            free.append(slot)
            queue.append(r)

        while queue or active:
            # control plane: per-tenant policy ticks + pool snapshot
            n_active_by = {name: 0 for name in self._tenants}
            for r, _c in active.values():
                n_active_by[r.tenant] += 1
            for name, t in self._tenants.items():
                self._tenant_tick(t, n_active_by[name])
                t.stats.observe_pool(self._pool)

            # cross-tenant weighted-fair admission
            admitted, cur, pos, stalled = self._admit_turn(
                queue, active, free, cur, pos, rounds)

            if not admitted and not active and queue:
                # nothing running, nothing admitted: either requests
                # haven't arrived on their tenants' clocks yet (advance
                # each tenant's clock to its own next arrival — clocks
                # are independent, so this never charges one tenant for
                # another's idle gap), or the pool/quota can never fit
                # one (raise)
                progressed = False
                for name, t in self._tenants.items():
                    pend = [r.arrival_s for r in queue if r.tenant == name]
                    if pend and min(pend) > t.now():
                        progressed |= t.wait(min(pend) - t.now())
                if not progressed:
                    if stalled is not None:
                        r = stalled
                        raise RuntimeError(
                            f"fleet KV page pool (or tenant "
                            f"{r.tenant!r} quota) can never admit "
                            f"request uid={r.uid} (prompt "
                            f"{len(r.prompt)} + {r.max_new_tokens} new) "
                            f"even with every slot idle")
                    # clockless channels: batch semantics — everything
                    # queued on them counts as already arrived
                    for r in queue:
                        ch = self._tenants[r.tenant].transport.channel
                        if getattr(ch, "wait", None) is None:
                            r.arrival_s = 0.0
                continue

            # retire requests whose budget just filled
            for s in [s for s, (r, c) in active.items()
                      if c >= r.max_new_tokens]:
                r, _ = active.pop(s)
                t = self._tenants[r.tenant]
                r.done = True
                r.finish_s = t.now()
                if (r.deadline_s is not None
                        and r.finish_s > r.deadline_s + 1e-9):
                    t.stats.deadline_misses += 1
                self._pool.retire(s)
                free.append(s)

            # demand paging: grow live claims; PoolExhausted preempts
            # the tenant most over its fair share first (FleetFairness)
            if active and self.demand_paged:
                usable = self._pool.allocator.num_pages - 1
                for s in sorted(active,
                                key=lambda v: (-active[v][0].priority, v)):
                    if s not in active:
                        continue
                    r, c = active[s]
                    k_t = self._tenants[r.tenant].spec_k
                    horizon = min(len(r.prompt) + c - 1 + k_t, self.max_len)
                    while s in active:
                        try:
                            self._pool.ensure(s, horizon)
                            break
                        except PoolExhausted:
                            victims = sorted(
                                active,
                                key=lambda v: (*self.fairness.victim_key(
                                    active[v][0],
                                    self._pool.owner_pages(
                                        active[v][0].tenant),
                                    usable,
                                    active[v][0].max_new_tokens
                                    - active[v][1]), v))
                            preempt(victims[0])

            # decode rounds, grouped by (cut, spec_k): one batched
            # multi-tenant phase call per group
            if active:
                groups: Dict[Tuple[int, int], List[int]] = {}
                for s, (r, _c) in active.items():
                    t = self._tenants[r.tenant]
                    groups.setdefault((t.cut, t.spec_k), []).append(s)
                for (gcut, gk) in sorted(groups):
                    cur, pos = self._group_round(
                        self._runtime(gcut), gk,
                        np.asarray(sorted(groups[(gcut, gk)]), np.int32),
                        cur, pos, active, rounds)
        self._sched_active = None
        self._sched_committed = None
        if not rounds:
            return
        all_toks = np.asarray(
            jnp.concatenate([t for t, _ in rounds], axis=1))
        col = 0
        for toks_r, takes in rounds:
            for r, s, n in takes:
                r.out_tokens.extend(int(t) for t in all_toks[s, col:col + n])
            col += toks_r.shape[1]

    # -- the cross-tenant batched round --------------------------------------
    def _group_round(self, runtime, k, slots_g, cur, pos, active, rounds):
        """Advance one (cut, k) group of live slots — possibly spanning
        several tenants — with one batched phase sequence: one edge
        decode (k=1) or one k-step draft scan plus **one** multi-token
        verify over the shared paged pool.  Slots outside the group are
        masked to the dump page; only the group's rows merge back into
        the fleet's cur/pos.  A group with any temperature>0 slot rides
        the sampled phase twins; its greedy rows stay bit-identical to
        the greedy path, and sampled rows' q uplink is charged to the
        owning tenant."""
        self.round_calls += 1
        by_tenant: Dict[str, List[int]] = {}
        for s in slots_g:
            by_tenant.setdefault(active[int(s)][0].tenant, []).append(int(s))
        bt = self._pool.table_for(slots_g)
        gkey = tuple(int(s) for s in slots_g)
        gmask = self._gmasks.get(gkey)
        if gmask is None:
            gm = np.zeros((self.max_batch,), np.bool_)
            gm[list(gkey)] = True
            gmask = self._gmasks[gkey] = jnp.asarray(gm)
        sampled = bool((self._samp_t[slots_g] > 0).any())
        if sampled:
            temps, top_ps, seeds = self._samp_vecs()
            offs = self._offsets()
        if k == 1:
            blob, qp, runtime._edge_cache = runtime._edge_decode(
                runtime.edge_blocks, self.embed, cur, runtime._edge_cache,
                pos, bt)
            for name, srows in by_tenant.items():
                t = self._tenants[name]
                t.transport.account_blob(t.stats, blob, phase="decode",
                                         rows=len(srows))
            if sampled:
                fn = runtime._samp_jit(
                    "cloud_decode", runtime._cloud_decode_sample_merge_impl,
                    donate=(4,))
                cur, runtime._cloud_cache, pos = fn(
                    runtime.cloud_blocks, self.tail, blob, qp,
                    runtime._cloud_cache, pos, bt, temps, top_ps, seeds,
                    offs, cur, gmask)
            else:
                cur, runtime._cloud_cache, pos = runtime._cloud_decode(
                    runtime.cloud_blocks, self.tail, blob, qp,
                    runtime._cloud_cache, pos, bt, cur, gmask)
            for name, srows in by_tenant.items():
                t = self._tenants[name]
                t.transport.account_downlink(t.stats, len(srows))
            counts = None
            toks_block = cur[:, None]
        else:
            if sampled:
                draft_fn, verify_fn = runtime._fleet_spec_sample_fns(k)
                blobs, scales, zps, drafts, qs, runtime._edge_cache, \
                    runtime._draft_cache = draft_fn(
                        runtime.edge_blocks, runtime.draft_blocks,
                        self.embed, self.tail, cur, runtime._edge_cache,
                        runtime._draft_cache, pos, bt, temps, top_ps,
                        seeds, offs)
            else:
                draft_fn, verify_fn = runtime._fleet_spec_fns(k)
                blobs, scales, zps, drafts, runtime._edge_cache, \
                    runtime._draft_cache = draft_fn(
                        runtime.edge_blocks, runtime.draft_blocks,
                        self.embed, self.tail, cur, runtime._edge_cache,
                        runtime._draft_cache, pos, bt)
            for name, srows in by_tenant.items():
                t = self._tenants[name]
                n_samp = int((self._samp_t[srows] > 0).sum())
                t.transport.charge(
                    t.stats,
                    len(srows) * (k * (self.cfg.d_model
                                       * blobs.dtype.itemsize + _QP_BYTES)
                                  + (k - 1) * _TOK_BYTES)
                    + n_samp * (k - 1) * self.cfg.vocab * 4 + _MSG_BYTES,
                    phase="decode")
            if sampled:
                toks, n_commit, cur, runtime._cloud_cache, pos = verify_fn(
                    runtime.cloud_blocks, self.tail, blobs, scales, zps,
                    drafts, qs, runtime._cloud_cache, pos, bt, temps,
                    top_ps, seeds, offs, cur, gmask)
            else:
                toks, n_commit, cur, runtime._cloud_cache, pos = verify_fn(
                    runtime.cloud_blocks, self.tail, blobs, scales, zps,
                    drafts, runtime._cloud_cache, pos, bt, cur, gmask)
            counts = np.asarray(n_commit)
            for name, srows in by_tenant.items():
                t = self._tenants[name]
                t.transport.account_downlink(t.stats, len(srows), k=k)
                t.stats.spec_rounds += 1
                hits = int(np.minimum(counts[srows] - 1, k - 1).sum())
                t.stats.drafted_tokens += (k - 1) * len(srows)
                t.stats.draft_hits += hits
                t.telemetry.observe_round((k - 1) * len(srows), hits)
            toks_block = toks
        takes = []
        for s in slots_g:
            r, c = active[int(s)]
            n = 1 if counts is None else int(counts[s])
            n = min(n, r.max_new_tokens - c)
            active[int(s)] = (r, c + n)
            takes.append((r, int(s), n))
            self.fairness.charge(r.tenant, n)
            self._tenants[r.tenant].stats.decode_tokens += n
        for name in by_tenant:
            self._tenants[name].stats.decode_steps += 1
        rounds.append((toks_block, takes))
        return cur, pos
