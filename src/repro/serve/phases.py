"""The split-cache phase implementations (Eq.1/2 boundary lattice +
edge-prefix / cloud-suffix prefill and decode) of collaborative
serving, factored out of ``CollaborativeServingEngine`` so the
multi-tenant fleet engine (``serve.fleet``) can run the *identical*
math through its per-cut runtimes — one set of jitted phases per
served cut, shared by every tenant at that cut — without inheriting
the single-tenant scheduler.  Anything mixing ``_SplitPhases`` in
provides: ``cfg``, ``max_len``, ``a_bits``, ``edge_paged``/
``edge_int8``/``cloud_paged``/``cloud_int8``, ``n_edge``/``n_cloud``,
``_edge_qctx``, and ``trace_counts``.

The ``*_sample_impl`` variants are the temperature>0 cloud phases
(``serve.sampling``): identical suffix math, but the emitted token is a
seeded categorical draw from the row's filtered distribution instead of
the argmax.  Greedy rows (``temps <= 0``) riding in a mixed batch take
the argmax branch inside the same jitted call, so their streams stay
bit-identical to the pre-sampling phases.  Engines only dispatch these
variants when a live slot actually samples — all-greedy traffic runs
the original phases untouched."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantParams, compute_qparams, dequantize, \
    quantize
from repro.models import layers as ML
from repro.models import transformer as TF
from repro.serve import sampling as S
from repro.serve.kvcache import _paged_prefill_merge, _paged_prefill_view

__all__ = ["_SplitPhases"]


class _SplitPhases:
    """See the module docstring."""

    def _rope(self):
        return ML.rope_table(self.max_len, self.cfg.hd,
                             base=self.cfg.rope_base, dtype=self.cfg.dtype)

    # -- Eq.(1)/(2) boundary lattice -----------------------------------------
    def _quant_boundary(self, h: jax.Array, ranged: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, QuantParams]:
        """Per-row Eq.(1) framing of a boundary blob.  ``ranged``
        overrides the tensor the thresholds are computed from (prefill
        clamps bucket padding out of the min/max).  ``a_bits=None`` is
        the lossless mode: the blob ships as-is under a unit lattice, so
        ``dequantize`` is the identity bit for bit."""
        if self.a_bits is None:
            unit = QuantParams(scale=jnp.ones((h.shape[0],), jnp.float32),
                               zero_point=jnp.zeros((h.shape[0],),
                                                    jnp.float32),
                               axis=0, bits=8, signed=True)
            return h.astype(jnp.float32), unit
        qp = compute_qparams(h if ranged is None else ranged, axis=0,
                             bits=self.a_bits)
        return quantize(h, qp), qp

    # -- incremental split-cache phases --------------------------------------
    def _edge_prefill_impl(self, blocks, embed, toks, cache, slots, bt_rows,
                           plens):
        self.trace_counts["prefill"] += 1
        cfg = self.cfg
        n, s = toks.shape
        x = ML.embed(embed, toks).astype(cfg.dtype)
        if self.edge_paged:
            group = _paged_prefill_view(cache, self.n_edge, n, cfg.n_kv)
            h, group = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                     cache=group, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx,
                                     block_tables=bt_rows,
                                     calibrate_kv=self.edge_int8,
                                     kv_lengths=plens)
            cache = _paged_prefill_merge(cache, group, slots)
        else:
            small = TF.init_cache(cfg, n, self.max_len, layers=self.n_edge,
                                  quantized=self.edge_int8)
            h, small = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                     cache=small, cache_index=jnp.int32(0),
                                     qctx=self._edge_qctx)
            cache = dict(cache, **{k: cache[k].at[:, slots].set(small[k])
                                   for k in ("k", "v")})
        # Eq.(1), per batch row: each request gets its own thresholds, so
        # one request's range never depends on its neighbours' activations
        # — or on its own bucket padding (pad positions are clamped to a
        # real activation before the min/max reduction; the padded tail
        # never crosses the wire, see Transport.account_blob)
        ranged = jnp.where(jnp.arange(s)[None, :, None] <
                           plens[:, None, None], h, h[:, :1])
        blob, qp = self._quant_boundary(h, ranged)
        return blob, qp, cache

    def _cloud_prefill_body(self, blocks, tail, blob, qp, cache, slots,
                            bt_rows, plens):
        """Shared suffix prefill: returns the merged cache and the
        last-prompt-position logits the first token comes from."""
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2)
        n = h.shape[0]
        if self.cloud_paged:
            group = _paged_prefill_view(cache, self.n_cloud, n, cfg.n_kv)
            x, group = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                     cache=group, cache_index=jnp.int32(0),
                                     block_tables=bt_rows,
                                     calibrate_kv=self.cloud_int8,
                                     kv_lengths=plens)
            cache = _paged_prefill_merge(cache, group, slots)
        else:
            small = TF.init_cache(cfg, n, self.max_len, layers=self.n_cloud)
            x, small = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                     cache=small, cache_index=jnp.int32(0))
            cache = {k: cache[k].at[:, slots].set(small[k]) for k in cache}
        logits = TF.lm_head(tail, x[jnp.arange(n), plens - 1][:, None])[:, 0]
        return cache, logits

    def _cloud_prefill_impl(self, blocks, tail, blob, qp, cache, slots,
                            bt_rows, cur, pos, plens):
        cache, logits = self._cloud_prefill_body(blocks, tail, blob, qp,
                                                 cache, slots, bt_rows, plens)
        cur = cur.at[slots].set(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _cloud_prefill_sample_impl(self, blocks, tail, blob, qp, cache,
                                   slots, bt_rows, cur, pos, plens, temps,
                                   top_ps, seeds):
        """Sampled prefill: the first token (absolute output index 0) is
        a ``CLOUD``-stream draw from the filtered distribution; greedy
        rows in the group keep the argmax.  ``temps``/``top_ps``/
        ``seeds`` are group-row vectors aligned with ``slots``."""
        cache, logits = self._cloud_prefill_body(blocks, tail, blob, qp,
                                                 cache, slots, bt_rows, plens)
        greedy = jnp.argmax(logits, -1).astype(jnp.int32)
        p = S.filtered_probs(logits.astype(jnp.float32), temps, top_ps)
        draw = S.sample_rows(p, S.token_keys(seeds, jnp.zeros_like(seeds),
                                             S.CLOUD))
        cur = cur.at[slots].set(jnp.where(temps > 0.0, draw, greedy))
        pos = pos.at[slots].set(plens)
        return cache, cur, pos

    def _edge_decode_impl(self, blocks, embed, cur, cache, pos, bt):
        self.trace_counts["decode"] += 1
        cfg = self.cfg
        x = ML.embed(embed, cur[:, None]).astype(cfg.dtype)
        h, cache = TF.run_blocks(blocks, x, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 qctx=self._edge_qctx, block_tables=bt)
        # Eq.(1) per row: stale activations in idle/freed slots must not
        # set the quant range of live requests' deltas
        blob, qp = self._quant_boundary(h)
        return blob, qp, cache                             # [B, 1, D] delta

    def _cloud_decode_impl(self, blocks, tail, blob, qp, cache, pos, bt):
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2)
        x, cache = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 block_tables=bt)
        logits = TF.lm_head(tail, x)[:, 0]
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return nxt, cache, jnp.minimum(pos + 1, self.max_len - 1)

    def _cloud_decode_sample_impl(self, blocks, tail, blob, qp, cache, pos,
                                  bt, temps, top_ps, seeds, offsets):
        """Sampled serial (k=1) decode: the committed token at absolute
        output index ``offsets[b]`` is a ``CLOUD``-stream draw — the
        reference distribution the speculative verify must match."""
        cfg = self.cfg
        h = dequantize(blob, qp).astype(cfg.dtype)         # Eq.(2)
        x, cache = TF.run_blocks(blocks, h, cfg, rope=self._rope(),
                                 cache=cache, cache_index=pos,
                                 block_tables=bt)
        logits = TF.lm_head(tail, x)[:, 0]
        greedy = jnp.argmax(logits, -1).astype(jnp.int32)
        p = S.filtered_probs(logits.astype(jnp.float32), temps, top_ps)
        draw = S.sample_rows(p, S.token_keys(seeds, offsets, S.CLOUD))
        nxt = jnp.where(temps > 0.0, draw, greedy)
        return nxt, cache, jnp.minimum(pos + 1, self.max_len - 1)
