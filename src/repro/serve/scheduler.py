"""Slot-based continuous-batching scheduler shared by both engines.

Requests queue up, prompts are right-padded to power-of-two *buckets*
and same-bucket prompts are prefilled together into free cache slots
(bounding the number of distinct compiled prefill shapes — see
``trace_counts``), every **round** advances all occupied slots at their
own positions (vector ``cache_index``) by one or more committed tokens,
and a finished request frees its slot — and its KV pages — for the next
queued prompt mid-flight, including *mid-round* when a round commits
past its budget.  Sampled tokens stay on device for the whole
generation; the host sees them once, after the last round (a
speculative engine additionally syncs one small per-round accept-count
vector, which the edge needs anyway to schedule the next round).

The scheduler also hosts the engine-side half of the online re-tuning
loop: ``_policy_tick`` runs at the top of every scheduler turn, where a
policy may switch the speculative draft length immediately (between
rounds) and request a **re-partition barrier** — admission pauses until
the occupied slots drain, the cut switch applies at that
request-admission boundary, and the queue resumes on the new partition.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as ML
from repro.models import transformer as TF
from repro.serve.kvcache import PoolExhausted
from repro.serve.transport import ServeStats


def _bucket_len(plen: int, max_len: int) -> int:
    """Power-of-two prefill bucket (floor 8, capped at ``max_len``)."""
    b = 8
    while b < plen:
        b *= 2
    return min(b, max_len)


def _jit_phase(fn, donate: Tuple[int, ...] = (), mesh=None):
    """``jax.jit`` with the KV-cache argument(s) donated, so the page-pool
    scatter of every prefill/decode/verify updates the cache *in place*
    on TPU/GPU instead of doubling resident cache bytes per step.  The
    engines always consume the returned cache and never touch the donated
    buffer again, so donation is safe.  XLA:CPU ignores donation and
    warns per call, so off-accelerator we jit plain.

    ``mesh`` makes the phase a mesh-jitted computation: the call runs
    under the mesh context, and GSPMD propagates the committed input
    shardings (the TP-placed suffix weights and KV pool — see
    ``serve.sharding``) through the whole phase."""
    if donate and jax.default_backend() in ("tpu", "gpu"):
        jf = jax.jit(fn, donate_argnums=donate)
    else:
        jf = jax.jit(fn)
    if mesh is None:
        return jf

    def mesh_call(*args, **kwargs):
        with mesh:
            return jf(*args, **kwargs)

    return mesh_call


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # None or temperature=0 → bit-identical greedy (serve.sampling)
    sampling: Optional["SamplingParams"] = None  # noqa: F821
    # -- overload-robust serving (all optional; defaults = legacy batch) --
    priority: int = 0             # higher admits first / preempts last
    deadline_s: Optional[float] = None   # absolute, on the simulated clock
    arrival_s: float = 0.0        # when the request becomes admissible
    # -- multi-tenant fleet serving (serve.fleet) -------------------------
    tenant: Optional[str] = None  # owning edge/tenant; None = single-tenant
    shed: bool = False            # refused by deadline-aware admission
    preemptions: int = 0          # times this request was suspended
    admit_s: Optional[float] = None      # first admission time
    finish_s: Optional[float] = None     # retirement time
    # scheduler internals
    _seq: int = dataclasses.field(default=0, repr=False)
    _enq_s: float = dataclasses.field(default=0.0, repr=False)
    _parked: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)  # committed tokens across a preemption


def _remove_is(lst: List, item) -> None:
    """Remove by identity (dataclass ``==`` compares field values, and
    two requests may legitimately carry identical fields)."""
    for i, x in enumerate(lst):
        if x is item:
            del lst[i]
            return


class _SlotEngine:
    """Continuous-batching scheduler base class.

    Subclasses implement ``_admit`` (prefill a prompt group into specific
    slots), ``_decode_all`` (advance every slot one token) and/or
    ``_round`` (advance every slot by a *variable* number of committed
    tokens — the speculative draft/verify round), and may hook
    ``_retire`` (a slot's request finished — e.g. return its KV pages),
    ``_can_admit`` (admission backpressure), and ``_policy_tick``
    (online re-tuning).  The scheduler keeps the current token and
    position of every slot on device; request outputs are transferred to
    the host once, after the final round.

    The loop is organised around **rounds**: admission commits one token
    per new slot (the prefill's argmax), and every scheduler turn after
    that commits ``counts[s]`` tokens per occupied slot, where the
    non-speculative engines statically commit one (``counts is None`` —
    no device sync, the loop stays fully async) and a speculative round
    returns the verify step's per-slot accept counts.  Per-slot
    accepted-length bookkeeping trims a round that overshoots a
    request's budget and retires the slot mid-stream ("retire on
    accept"), so the next queued prompt gets the slot and its pages.

    Admission pads each prompt group to a power-of-two bucket
    (``_bucket_len``), so the number of distinct prefill trace shapes is
    bounded by O(log2(max_len) · max_batch) instead of growing with
    every unique prompt length.  ``trace_counts`` counts actual
    retraces of the jit'd phase functions; tests pin it.
    """

    def __init__(self, cfg: TF.LMConfig, *, max_batch: int, max_len: int,
                 timed: bool = False):
        self.cfg = dataclasses.replace(cfg, remat=False)
        self.max_batch = max_batch
        self.max_len = max_len
        self.timed = timed
        self.stats = ServeStats()
        self.trace_counts = {"prefill": 0, "decode": 0, "spec_draft": 0,
                             "verify": 0, "edge_only": 0, "resync": 0,
                             "draft_rebuild": 0}
        # populated by _run while a generate call is live (see there)
        self._sched_active = None
        self._sched_committed = None

    # -- subclass interface -------------------------------------------------
    def _admit(self, toks: jax.Array, plens: np.ndarray, max_news: np.ndarray,
               slots: np.ndarray, cur: jax.Array, pos: jax.Array,
               samplings=None) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _decode_all(self, cur: jax.Array, pos: jax.Array,
                    n_active: int) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _round(self, cur: jax.Array, pos: jax.Array, slots: np.ndarray,
               ) -> Tuple[jax.Array, jax.Array, jax.Array,
                          Optional[np.ndarray]]:
        """Advance the occupied ``slots`` by one round.

        Returns ``(cur, pos, tokens, counts)``: ``tokens`` is the
        ``[max_batch, k]`` device block of tokens the round produced and
        ``counts`` the per-slot number of *committed* leading tokens —
        ``None`` means "statically one per slot" (the non-speculative
        path, which therefore never blocks on the device)."""
        cur, pos = self._decode_all(cur, pos, len(slots))
        return cur, pos, cur[:, None], None

    def _round_headroom(self) -> int:
        """Cache positions a round may write *past* a request's budget
        (speculative drafting overshoots by up to k-1); admission
        reserves them so overshoot writes can never alias another
        request's pages."""
        return 0

    def _retire(self, slot: int) -> None:
        """Hook: the request in ``slot`` finished (free paged KV, etc.)."""

    def _after_round(self, n_active: int, committed: int) -> None:
        """Hook: one decode round just finished, having committed
        ``committed`` tokens across ``n_active`` slots.  The resilient
        engine logs (simulated time, committed, cloud state) here — the
        per-round availability trace the chaos benchmark integrates
        over its outage window."""

    def _can_admit(self, group_shapes: List[Tuple[int, int]], plen: int,
                   max_new: int, bucket: int) -> bool:
        """Hook: may this request join the prefill group right now?
        ``group_shapes`` are the (plen, max_new) pairs already accepted
        into the group this round.  Paged engines refuse when the page
        pool can't cover the whole group, backpressuring admission until
        retirements return pages."""
        return True

    def _policy_tick(self, n_active: int) -> bool:
        """Hook: one turn of the online re-tuning control loop, called at
        the top of every scheduler turn (and therefore between rounds,
        and with ``n_active == 0`` between requests/generate calls).

        Returns True to **pause admission** this turn — the re-partition
        barrier: a pending cut-layer switch needs the occupied slots to
        drain before it can apply (split KV caches change layer
        ownership), so the engine stops admitting, finishes the live
        requests, applies the switch at the now-empty admission
        boundary, and resumes.  Implementations MUST return False when
        ``n_active == 0`` (apply any pending switch instead), or the
        scheduler would livelock; the loop asserts this."""
        return False

    def _tick_resources(self) -> None:
        """Hook: top of every scheduler turn, before admission — a
        pressure-injecting engine applies its ``faults.PressureSchedule``
        to the page allocator here, at the current simulated time."""

    def _now(self) -> float:
        """Hook: current simulated time.  Clockless engines serve one
        batch at t=0; clocked engines mirror their channel's
        ``clock_s``."""
        return 0.0

    def _wait(self, seconds: float) -> bool:
        """Hook: advance the simulated clock by ``seconds`` (a scheduler
        stall or an inter-arrival gap), charging ``stats.stall_wait_s``.
        Returns False when the engine has no clock to advance — the
        scheduler then falls back to batch semantics (every queued
        request is treated as already arrived)."""
        return seconds <= 0

    def _on_stall(self) -> bool:
        """Hook: the engine is drained but admission still can't fit the
        next request.  Return True after waiting out a *transient* cause
        (e.g. a ``PressureSchedule`` window squeezing the pool) — the
        scheduler retries; False means the stall is permanent and the
        scheduler raises."""
        return False

    def _round_width(self) -> int:
        """Cache positions one round may write per slot (the speculative
        draft length); demand paging grows each slot's claim to cover
        them before the round runs."""
        return 1

    def _ensure_slot(self, slot: int, horizon: int) -> None:
        """Hook: grow ``slot``'s page claim to cover ``horizon`` cache
        positions before the coming round writes them; raises
        ``kvcache.PoolExhausted`` when the pool can't (the scheduler
        preempts a victim and retries).  Default: worst-case reservation
        at admission — nothing to grow."""

    def _preempt(self, slot: int) -> None:
        """Hook: ``slot`` is being suspended mid-flight — release its KV
        pages, but keep the request resumable (the scheduler has already
        parked its committed tokens and re-queues it)."""
        self._retire(slot)

    def _admission_policy(self, req: Request, *, now: float,
                          queue_tokens: float) -> bool:
        """Hook: may ``req`` be admitted at all?  False sheds it — a
        deadline-aware engine predicts the finish time from the live
        cost model and refuses requests that are already doomed.
        ``queue_tokens`` is the generation budget still owed to work
        admitted ahead of it."""
        return True

    # -- shared helpers -----------------------------------------------------
    def _rope(self):
        return ML.rope_table(self.max_len, self.cfg.hd,
                             base=self.cfg.rope_base, dtype=self.cfg.dtype)

    def _timed(self, phase: str, fn):
        if not self.timed:
            return fn()
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        setattr(self.stats, phase,
                getattr(self.stats, phase) + time.perf_counter() - t0)
        return out

    @staticmethod
    def _eff_prompt(r: Request) -> np.ndarray:
        """The token row a (re-)admission prefills: the prompt — extended
        for a preempted request with all but the last committed token.
        This is multi-token cached replay: the batched prefill rebuilds
        the suspended slot's KV in one call, and its argmax re-derives
        the last committed token, so resume recomputes no committed
        position one-by-one."""
        if r._parked is None or len(r._parked) == 0:
            return np.asarray(r.prompt, np.int32)
        return np.concatenate([np.asarray(r.prompt, np.int32),
                               r._parked[:-1]])

    def _eff_plen(self, r: Request) -> int:
        return len(r.prompt) + (0 if r._parked is None
                                else max(0, len(r._parked) - 1))

    # -- scheduler ----------------------------------------------------------
    def generate(self, prompts: List[np.ndarray], *, max_new_tokens: int = 16,
                 sampling=None) -> List[List[int]]:
        """Decode a list of prompts with continuous batching.  ``sampling``
        is one ``SamplingParams`` for all prompts, or a per-prompt list;
        ``None`` (default) is greedy."""
        samps = (list(sampling) if isinstance(sampling, (list, tuple))
                 else [sampling] * len(prompts))
        reqs = [Request(uid=i, prompt=np.asarray(p),
                        max_new_tokens=max_new_tokens, sampling=s)
                for i, (p, s) in enumerate(zip(prompts, samps))]
        if reqs:
            self._run(reqs)
        return [r.out_tokens for r in reqs]

    def generate_requests(self, reqs: List[Request]) -> List[List[int]]:
        """Run caller-built ``Request``s — priorities, deadlines,
        arrival times — through the scheduler; returns their token
        streams in input order.  A shed request comes back empty with
        ``r.shed`` set; completion metadata lands on ``admit_s`` /
        ``finish_s`` / ``preemptions``."""
        if reqs:
            self._run(reqs)
        return [r.out_tokens for r in reqs]

    def _run(self, reqs: List[Request]) -> None:
        for i, r in enumerate(reqs):
            r._seq = i
            r._enq_s = float(r.arrival_s)
        queue: List[Request] = list(reqs)
        active: Dict[int, Tuple[Request, int]] = {}  # slot -> (req, n_committed)
        free = list(range(self.max_batch))
        cur = jnp.zeros((self.max_batch,), jnp.int32)
        pos = jnp.zeros((self.max_batch,), jnp.int32)
        # every admission and every round logs (token block [B, k], takes);
        # token blocks stay on device until one concat+transfer at the end
        rounds: List[Tuple[jax.Array, List[Tuple[Request, int, int]]]] = []

        def parked_tokens(r: Request) -> np.ndarray:
            """Pull ``r``'s committed tokens off the logged round blocks
            — the one host sync a preemption costs."""
            chunks = [np.asarray(t[s, :n])
                      for t, takes in rounds
                      for rr, s, n in takes if rr is r and n > 0]
            return (np.concatenate(chunks).astype(np.int32) if chunks
                    else np.zeros((0,), np.int32))

        # live view for engine hooks that rebuild per-slot device state
        # mid-run (e.g. the draft-cache rebuild on a warm k raise): the
        # active map plus the one host sync that recovers a live slot's
        # committed tokens
        self._sched_active = active
        self._sched_committed = parked_tokens

        def preempt(slot: int) -> None:
            r, _c = active.pop(slot)
            r._parked = parked_tokens(r)
            r._enq_s = self._now()
            r.preemptions += 1
            self.stats.preemptions += 1
            self._preempt(slot)
            free.append(slot)
            queue.append(r)

        while queue or active:
            self._tick_resources()
            hold = self._policy_tick(len(active))
            assert not (hold and not active), \
                "_policy_tick must not pause admission on a drained engine"
            now = self._now()
            elig = sorted((r for r in queue if r.arrival_s <= now + 1e-12),
                          key=lambda r: (-r.priority, r._seq))
            if not elig and queue and not active and not hold:
                # nothing has arrived yet: advance the clock to the next
                # arrival, or — on a clockless engine — fall back to
                # batch semantics (everything queued is already here)
                nxt = min(r.arrival_s for r in queue)
                if self._wait(nxt - now):
                    continue
                elig = sorted(queue, key=lambda r: (-r.priority, r._seq))
            # admit eligible prompts into free slots, grouping by prefill
            # bucket so one batched, fixed-shape prefill call covers the
            # whole group; a paged engine may refuse (pool backpressure)
            # and a pending re-partition holds admission entirely — the
            # request then waits for retirements
            stalled = False
            stall_req: Optional[Request] = None
            while free and elig and not stalled and not hold:
                bucket = _bucket_len(self._eff_plen(elig[0]), self.max_len)
                group: List[Request] = []
                rows: List[np.ndarray] = []
                slots: List[int] = []
                shapes: List[Tuple[int, int]] = []
                while free and elig and _bucket_len(
                        self._eff_plen(elig[0]), self.max_len) == bucket:
                    r = elig[0]
                    row = self._eff_prompt(r)
                    eff_new = (r.max_new_tokens if r._parked is None
                               else r.max_new_tokens - len(r._parked) + 1)
                    assert (len(row) + eff_new
                            + self._round_headroom()) <= self.max_len, \
                        "prompt + generation (+ draft headroom) exceeds " \
                        "cache max_len"
                    if r._parked is None and r.deadline_s is not None:
                        # budget owed to work that will actually run
                        # ahead of this request: equal-or-higher
                        # priority only — lower-priority slots are
                        # preemptable, so they don't gate its finish
                        owed = (sum(rr.max_new_tokens - cc
                                    for rr, cc in active.values()
                                    if rr.priority >= r.priority)
                                + sum(m for _, m in shapes))
                        if not self._admission_policy(
                                r, now=now, queue_tokens=float(owed)):
                            # predicted to finish past its deadline even
                            # if admitted this instant: shed it instead
                            # of letting it poison the pool
                            r.shed = True
                            r.done = True
                            self.stats.shed += 1
                            elig.pop(0)
                            _remove_is(queue, r)
                            continue
                    if not self._can_admit(shapes, len(row), eff_new,
                                           bucket):
                        stalled = True
                        stall_req = r
                        break
                    shapes.append((len(row), eff_new))
                    group.append(r)
                    rows.append(row)
                    elig.pop(0)
                    _remove_is(queue, r)
                    slots.append(free.pop(0))
                if not group:
                    break
                toks = np.zeros((len(group), bucket), np.int32)
                for i, row in enumerate(rows):
                    toks[i, :len(row)] = row
                plens = np.asarray([len(row) for row in rows], np.int32)
                max_news = np.asarray([m for _, m in shapes], np.int32)
                slots_a = np.asarray(slots, np.int32)
                toks_j = jnp.asarray(toks)
                cur, pos = self._timed(
                    "prefill_s",
                    lambda: self._admit(toks_j, plens, max_news, slots_a,
                                        cur, pos,
                                        samplings=[r.sampling
                                                   for r in group]))
                self.stats.prefill_calls += 1
                self.stats.prefill_tokens += int(plens.sum())
                resumes = [(s, r) for r, s in zip(group, slots)
                           if r._parked is not None]
                if resumes:
                    # the replay prefill re-derives the last committed
                    # token; pin the stream to the parked value so resume
                    # can never diverge (INT8 recalibration over the
                    # longer prefix may legitimately flip the argmax —
                    # lossless mode is bitwise identical either way,
                    # which the preemption property tests pin)
                    rs = jnp.asarray([s for s, _ in resumes], jnp.int32)
                    lasts = jnp.asarray([int(r._parked[-1])
                                         for _, r in resumes], jnp.int32)
                    cur = cur.at[rs].set(lasts)
                # a fresh request's first committed token is the prefill
                # argmax; a resumed request's tokens are already logged
                # in its pre-preemption rounds
                fresh = [(r, s, 1) for r, s in zip(group, slots)
                         if r._parked is None]
                if fresh:
                    rounds.append((cur[:, None], fresh))
                for r, s in zip(group, slots):
                    active[s] = (r, 1 if r._parked is None
                                 else len(r._parked))
                    if r.admit_s is None:
                        r.admit_s = now
                    self.stats.queue_wait_s += max(0.0, now - r._enq_s)
                    r._parked = None
            if stalled and not active:
                # a drained engine that still can't admit: either a
                # transient squeeze (wait it out on the simulated clock
                # and retry) or a genuinely impossible request
                if not self._on_stall():
                    r = stall_req
                    raise RuntimeError(
                        f"KV page pool too small for request uid={r.uid} "
                        f"(prompt {len(r.prompt)} + {r.max_new_tokens} new "
                        f"tokens) even with every slot idle")
                continue
            # retire requests whose budget just filled — before the next
            # round, so no request pays for a round it never reads and
            # its slot (and KV pages) free one round earlier for the queue
            for s in [s for s, (r, c) in active.items()
                      if c >= r.max_new_tokens]:
                r, _ = active.pop(s)
                r.done = True
                r.finish_s = self._now()
                if (r.deadline_s is not None
                        and r.finish_s > r.deadline_s + 1e-9):
                    self.stats.deadline_misses += 1
                self._retire(s)
                free.append(s)
            # demand paging: grow every live slot's claim to cover the
            # positions the coming round will write; on PoolExhausted,
            # preempt victims — lowest priority first, then most
            # remaining budget — until the growth fits (possibly
            # preempting the grower itself, which also resolves it)
            if active:
                k = self._round_width()
                for s in sorted(active,
                                key=lambda t: (-active[t][0].priority, t)):
                    if s not in active:
                        continue  # already someone else's victim
                    r, c = active[s]
                    horizon = min(len(r.prompt) + c - 1 + k, self.max_len)
                    while s in active:
                        try:
                            self._ensure_slot(s, horizon)
                            break
                        except PoolExhausted:
                            victims = sorted(
                                active,
                                key=lambda t: (
                                    active[t][0].priority,
                                    -(active[t][0].max_new_tokens
                                      - active[t][1]),
                                    t))
                            preempt(victims[0])
            if active:
                act_slots = np.asarray(sorted(active), np.int32)
                cur, pos, toks_r, counts = self._timed(
                    "decode_s",
                    lambda: self._round(cur, pos, act_slots))
                takes = []
                for s in act_slots:
                    r, c = active[int(s)]
                    n = 1 if counts is None else int(counts[s])
                    n = min(n, r.max_new_tokens - c)  # trim budget overshoot
                    active[int(s)] = (r, c + n)
                    takes.append((r, int(s), n))
                rounds.append((toks_r, takes))
                self.stats.decode_steps += 1
                committed = sum(n for _, _, n in takes)
                self.stats.decode_tokens += committed
                self._after_round(len(takes), committed)
        self._sched_active = None
        self._sched_committed = None
        # single device→host transfer for the whole run
        if not rounds:
            return  # everything shed before a single token committed
        all_toks = np.asarray(
            jnp.concatenate([t for t, _ in rounds], axis=1))
        col = 0
        for toks_r, takes in rounds:
            for r, s, n in takes:
                r.out_tokens.extend(int(t) for t in all_toks[s, col:col + n])
            col += toks_r.shape[1]
