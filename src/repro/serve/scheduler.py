"""Slot-based continuous-batching scheduler shared by both engines.

Requests queue up, prompts are right-padded to power-of-two *buckets*
and same-bucket prompts are prefilled together into free cache slots
(bounding the number of distinct compiled prefill shapes — see
``trace_counts``), every **round** advances all occupied slots at their
own positions (vector ``cache_index``) by one or more committed tokens,
and a finished request frees its slot — and its KV pages — for the next
queued prompt mid-flight, including *mid-round* when a round commits
past its budget.  Sampled tokens stay on device for the whole
generation; the host sees them once, after the last round (a
speculative engine additionally syncs one small per-round accept-count
vector, which the edge needs anyway to schedule the next round).

The scheduler also hosts the engine-side half of the online re-tuning
loop: ``_policy_tick`` runs at the top of every scheduler turn, where a
policy may switch the speculative draft length immediately (between
rounds) and request a **re-partition barrier** — admission pauses until
the occupied slots drain, the cut switch applies at that
request-admission boundary, and the queue resumes on the new partition.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as ML
from repro.models import transformer as TF
from repro.serve.transport import ServeStats


def _bucket_len(plen: int, max_len: int) -> int:
    """Power-of-two prefill bucket (floor 8, capped at ``max_len``)."""
    b = 8
    while b < plen:
        b *= 2
    return min(b, max_len)


def _jit_phase(fn, donate: Tuple[int, ...] = ()):
    """``jax.jit`` with the KV-cache argument(s) donated, so the page-pool
    scatter of every prefill/decode/verify updates the cache *in place*
    on TPU/GPU instead of doubling resident cache bytes per step.  The
    engines always consume the returned cache and never touch the donated
    buffer again, so donation is safe.  XLA:CPU ignores donation and
    warns per call, so off-accelerator we jit plain."""
    if donate and jax.default_backend() in ("tpu", "gpu"):
        return jax.jit(fn, donate_argnums=donate)
    return jax.jit(fn)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class _SlotEngine:
    """Continuous-batching scheduler base class.

    Subclasses implement ``_admit`` (prefill a prompt group into specific
    slots), ``_decode_all`` (advance every slot one token) and/or
    ``_round`` (advance every slot by a *variable* number of committed
    tokens — the speculative draft/verify round), and may hook
    ``_retire`` (a slot's request finished — e.g. return its KV pages),
    ``_can_admit`` (admission backpressure), and ``_policy_tick``
    (online re-tuning).  The scheduler keeps the current token and
    position of every slot on device; request outputs are transferred to
    the host once, after the final round.

    The loop is organised around **rounds**: admission commits one token
    per new slot (the prefill's argmax), and every scheduler turn after
    that commits ``counts[s]`` tokens per occupied slot, where the
    non-speculative engines statically commit one (``counts is None`` —
    no device sync, the loop stays fully async) and a speculative round
    returns the verify step's per-slot accept counts.  Per-slot
    accepted-length bookkeeping trims a round that overshoots a
    request's budget and retires the slot mid-stream ("retire on
    accept"), so the next queued prompt gets the slot and its pages.

    Admission pads each prompt group to a power-of-two bucket
    (``_bucket_len``), so the number of distinct prefill trace shapes is
    bounded by O(log2(max_len) · max_batch) instead of growing with
    every unique prompt length.  ``trace_counts`` counts actual
    retraces of the jit'd phase functions; tests pin it.
    """

    def __init__(self, cfg: TF.LMConfig, *, max_batch: int, max_len: int,
                 timed: bool = False):
        self.cfg = dataclasses.replace(cfg, remat=False)
        self.max_batch = max_batch
        self.max_len = max_len
        self.timed = timed
        self.stats = ServeStats()
        self.trace_counts = {"prefill": 0, "decode": 0, "spec_draft": 0,
                             "verify": 0, "edge_only": 0, "resync": 0}

    # -- subclass interface -------------------------------------------------
    def _admit(self, toks: jax.Array, plens: np.ndarray, max_news: np.ndarray,
               slots: np.ndarray, cur: jax.Array, pos: jax.Array,
               ) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _decode_all(self, cur: jax.Array, pos: jax.Array,
                    n_active: int) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _round(self, cur: jax.Array, pos: jax.Array, slots: np.ndarray,
               ) -> Tuple[jax.Array, jax.Array, jax.Array,
                          Optional[np.ndarray]]:
        """Advance the occupied ``slots`` by one round.

        Returns ``(cur, pos, tokens, counts)``: ``tokens`` is the
        ``[max_batch, k]`` device block of tokens the round produced and
        ``counts`` the per-slot number of *committed* leading tokens —
        ``None`` means "statically one per slot" (the non-speculative
        path, which therefore never blocks on the device)."""
        cur, pos = self._decode_all(cur, pos, len(slots))
        return cur, pos, cur[:, None], None

    def _round_headroom(self) -> int:
        """Cache positions a round may write *past* a request's budget
        (speculative drafting overshoots by up to k-1); admission
        reserves them so overshoot writes can never alias another
        request's pages."""
        return 0

    def _retire(self, slot: int) -> None:
        """Hook: the request in ``slot`` finished (free paged KV, etc.)."""

    def _after_round(self, n_active: int, committed: int) -> None:
        """Hook: one decode round just finished, having committed
        ``committed`` tokens across ``n_active`` slots.  The resilient
        engine logs (simulated time, committed, cloud state) here — the
        per-round availability trace the chaos benchmark integrates
        over its outage window."""

    def _can_admit(self, group_shapes: List[Tuple[int, int]], plen: int,
                   max_new: int, bucket: int) -> bool:
        """Hook: may this request join the prefill group right now?
        ``group_shapes`` are the (plen, max_new) pairs already accepted
        into the group this round.  Paged engines refuse when the page
        pool can't cover the whole group, backpressuring admission until
        retirements return pages."""
        return True

    def _policy_tick(self, n_active: int) -> bool:
        """Hook: one turn of the online re-tuning control loop, called at
        the top of every scheduler turn (and therefore between rounds,
        and with ``n_active == 0`` between requests/generate calls).

        Returns True to **pause admission** this turn — the re-partition
        barrier: a pending cut-layer switch needs the occupied slots to
        drain before it can apply (split KV caches change layer
        ownership), so the engine stops admitting, finishes the live
        requests, applies the switch at the now-empty admission
        boundary, and resumes.  Implementations MUST return False when
        ``n_active == 0`` (apply any pending switch instead), or the
        scheduler would livelock; the loop asserts this."""
        return False

    # -- shared helpers -----------------------------------------------------
    def _rope(self):
        return ML.rope_table(self.max_len, self.cfg.hd,
                             base=self.cfg.rope_base, dtype=self.cfg.dtype)

    def _timed(self, phase: str, fn):
        if not self.timed:
            return fn()
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        setattr(self.stats, phase,
                getattr(self.stats, phase) + time.perf_counter() - t0)
        return out

    # -- scheduler ----------------------------------------------------------
    def generate(self, prompts: List[np.ndarray], *,
                 max_new_tokens: int = 16) -> List[List[int]]:
        """Greedy-decode a list of prompts with continuous batching."""
        reqs = [Request(uid=i, prompt=np.asarray(p),
                        max_new_tokens=max_new_tokens)
                for i, p in enumerate(prompts)]
        if reqs:
            self._run(reqs)
        return [r.out_tokens for r in reqs]

    def _run(self, reqs: List[Request]) -> None:
        queue = deque(reqs)
        active: Dict[int, Tuple[Request, int]] = {}  # slot -> (req, n_committed)
        free = list(range(self.max_batch))
        cur = jnp.zeros((self.max_batch,), jnp.int32)
        pos = jnp.zeros((self.max_batch,), jnp.int32)
        # every admission and every round logs (token block [B, k], takes);
        # token blocks stay on device until one concat+transfer at the end
        rounds: List[Tuple[jax.Array, List[Tuple[Request, int, int]]]] = []
        while queue or active:
            hold = self._policy_tick(len(active))
            assert not (hold and not active), \
                "_policy_tick must not pause admission on a drained engine"
            # admit queued prompts into free slots, grouping by prefill
            # bucket so one batched, fixed-shape prefill call covers the
            # whole group; a paged engine may refuse (pool backpressure)
            # and a pending re-partition holds admission entirely — the
            # request then waits for retirements
            stalled = False
            while free and queue and not stalled and not hold:
                bucket = _bucket_len(len(queue[0].prompt), self.max_len)
                group, slots = [], []
                shapes: List[Tuple[int, int]] = []
                while free and queue and _bucket_len(
                        len(queue[0].prompt), self.max_len) == bucket:
                    r = queue[0]
                    assert (len(r.prompt) + r.max_new_tokens
                            + self._round_headroom()) <= self.max_len, \
                        "prompt + generation (+ draft headroom) exceeds " \
                        "cache max_len"
                    if not self._can_admit(shapes, len(r.prompt),
                                           r.max_new_tokens, bucket):
                        stalled = True
                        break
                    shapes.append((len(r.prompt), r.max_new_tokens))
                    group.append(queue.popleft())
                    slots.append(free.pop(0))
                if not group:
                    break
                toks = np.zeros((len(group), bucket), np.int32)
                for i, r in enumerate(group):
                    toks[i, :len(r.prompt)] = r.prompt
                plens = np.asarray([len(r.prompt) for r in group], np.int32)
                max_news = np.asarray([r.max_new_tokens for r in group],
                                      np.int32)
                slots_a = np.asarray(slots, np.int32)
                toks_j = jnp.asarray(toks)
                cur, pos = self._timed(
                    "prefill_s",
                    lambda: self._admit(toks_j, plens, max_news, slots_a,
                                        cur, pos))
                self.stats.prefill_calls += 1
                self.stats.prefill_tokens += int(plens.sum())
                # the prefill's argmax is the group's first committed token
                rounds.append((cur[:, None],
                               [(r, s, 1) for r, s in zip(group, slots)]))
                for r, s in zip(group, slots):
                    active[s] = (r, 1)
            if stalled and not active:
                r = queue[0]
                raise RuntimeError(
                    f"KV page pool too small for request uid={r.uid} "
                    f"(prompt {len(r.prompt)} + {r.max_new_tokens} new "
                    f"tokens) even with every slot idle")
            # retire requests whose budget just filled — before the next
            # round, so no request pays for a round it never reads and
            # its slot (and KV pages) free one round earlier for the queue
            for s in [s for s, (r, c) in active.items()
                      if c >= r.max_new_tokens]:
                r, _ = active.pop(s)
                r.done = True
                self._retire(s)
                free.append(s)
            if active:
                act_slots = np.asarray(sorted(active), np.int32)
                cur, pos, toks_r, counts = self._timed(
                    "decode_s",
                    lambda: self._round(cur, pos, act_slots))
                takes = []
                for s in act_slots:
                    r, c = active[int(s)]
                    n = 1 if counts is None else int(counts[s])
                    n = min(n, r.max_new_tokens - c)  # trim budget overshoot
                    active[int(s)] = (r, c + n)
                    takes.append((r, int(s), n))
                rounds.append((toks_r, takes))
                self.stats.decode_steps += 1
                committed = sum(n for _, _, n in takes)
                self.stats.decode_tokens += committed
                self._after_round(len(takes), committed)
        # single device→host transfer for the whole run
        all_toks = np.asarray(
            jnp.concatenate([t for t, _ in rounds], axis=1))
        col = 0
        for toks_r, takes in rounds:
            for r, s, n in takes:
                r.out_tokens.extend(int(t) for t in all_toks[s, col:col + n])
            col += toks_r.shape[1]
