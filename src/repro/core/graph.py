"""Layer-graph IR for partition analysis (paper §2.2).

A ``LayerGraph`` is a DAG of named layers carrying the cost metadata the
auto-tuner needs (FLOPs, parameter count, output blob size).  Nodes must be
added in topological order; a *partition at node L* means the edge device
executes the topological prefix ending at L and the cloud executes the
rest (the paper's ``Net.Split(First, L_i)`` / ``Net.Split(L_i+1, Last)``).

The central primitive is ``crossing_blobs(cut)``: the set of tensors that
must travel edge→cloud for a given cut.  All of the paper's structural
rules (brother-branch, shortcut, non-parametric merge) reduce to
"a candidate cut crosses exactly one blob, and that blob is the cut
layer's own output" — see ``repro.core.partition``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Node", "Blob", "LayerGraph"]

# ops with no parameters; candidates for fusion into the producer
NON_PARAMETRIC_OPS = {
    "relu", "gelu", "silu", "tanh", "sigmoid", "softmax",
    "pool", "maxpool", "avgpool", "globalpool",
    "add", "concat", "mul", "dropout", "flatten", "reshape", "lrn",
    "identity", "input", "rope", "scale",
}


@dataclasses.dataclass
class Node:
    name: str
    op: str
    inputs: List[str]
    out_shape: Tuple[int, ...]
    flops: float = 0.0            # forward FLOPs (MACs*2)
    param_elems: int = 0
    parametric: Optional[bool] = None   # default: op not in NON_PARAMETRIC_OPS
    fused: List[str] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.parametric is None:
            self.parametric = self.op not in NON_PARAMETRIC_OPS

    @property
    def out_elems(self) -> int:
        n = 1
        for d in self.out_shape:
            n *= int(d)
        return n

    def out_bytes(self, bytes_per_elem: float = 4.0) -> float:
        return self.out_elems * bytes_per_elem

    def param_bytes(self, bytes_per_elem: float = 4.0) -> float:
        return self.param_elems * bytes_per_elem


@dataclasses.dataclass(frozen=True)
class Blob:
    """One tensor crossing a partition cut."""
    source: str                  # producing node
    elems: int
    precision: str               # "int8" | "uint8" | "fp32"

    @property
    def bytes(self) -> float:
        per = 4.0 if self.precision == "fp32" else 1.0
        overhead = 8.0 if self.precision == "int8" else 0.0  # scale+zp
        return self.elems * per + overhead


class LayerGraph:
    def __init__(self, name: str):
        self.name = name
        self.nodes: Dict[str, Node] = {}          # insertion order == topo

    # -- construction -----------------------------------------------------
    def add(self, name: str, op: str, inputs: Sequence[str],
            out_shape: Sequence[int], *, flops: float = 0.0,
            param_elems: int = 0, parametric: Optional[bool] = None,
            **meta) -> str:
        assert name not in self.nodes, f"duplicate node {name}"
        for i in inputs:
            assert i in self.nodes, (
                f"{name}: input {i} not yet added (topological order required)")
        self.nodes[name] = Node(name=name, op=op, inputs=list(inputs),
                                out_shape=tuple(int(d) for d in out_shape),
                                flops=float(flops), param_elems=int(param_elems),
                                parametric=parametric, meta=meta)
        return name

    # -- basic queries ------------------------------------------------------
    def topo(self) -> List[str]:
        return list(self.nodes)

    def __len__(self) -> int:
        return len(self.nodes)

    def __getitem__(self, name: str) -> Node:
        return self.nodes[name]

    def successors(self, name: str) -> List[str]:
        return [n for n, nd in self.nodes.items() if name in nd.inputs]

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes.values())

    def total_param_elems(self) -> int:
        return sum(n.param_elems for n in self.nodes.values())

    def prefix(self, cut: str) -> List[str]:
        order = self.topo()
        return order[: order.index(cut) + 1]

    def suffix(self, cut: str) -> List[str]:
        order = self.topo()
        return order[order.index(cut) + 1:]

    # -- the cut-set primitive ----------------------------------------------
    def crossing_blobs(self, cut: str) -> List[Blob]:
        """Tensors shipped edge→cloud when partitioning after ``cut``.

        Paper convention (§2.2 Tables 1-2): the cut layer's own output is
        the quantized INT8 boundary blob; any *other* prefix output needed
        by the FP32 cloud suffix ships in full precision.
        """
        order = self.topo()
        idx = {n: i for i, n in enumerate(order)}
        ci = idx[cut]
        sources: Dict[str, Node] = {}
        for n, nd in self.nodes.items():
            if idx[n] <= ci:
                continue
            for src in nd.inputs:
                if idx[src] <= ci:
                    sources[src] = self.nodes[src]
        # Deterministic order: topo order of sources.
        blobs = []
        for s in sorted(sources, key=idx.get):
            precision = "int8" if s == cut else "fp32"
            blobs.append(Blob(source=s, elems=sources[s].out_elems,
                              precision=precision))
        return blobs

    def validate(self) -> None:
        seen = set()
        for n, nd in self.nodes.items():
            for i in nd.inputs:
                assert i in seen, f"edge {i}->{n} violates topo order"
            seen.add(n)

    def summary(self) -> str:
        lines = [f"LayerGraph({self.name}): {len(self)} nodes, "
                 f"{self.total_flops()/1e9:.2f} GFLOPs, "
                 f"{self.total_param_elems()/1e6:.2f} M params"]
        for n, nd in self.nodes.items():
            fused = f" (+{','.join(nd.fused)})" if nd.fused else ""
            lines.append(
                f"  {n:32s} {nd.op:10s} in={nd.inputs} out={nd.out_shape}"
                f" flops={nd.flops/1e6:.1f}M params={nd.param_elems/1e3:.1f}K"
                f"{fused}")
        return "\n".join(lines)
