"""Cloud-edge collaborative inference runtime (paper Fig. 1, right side).

A model participates by exposing itself as a ``SegmentedModel``: an ordered
list of single-tensor-in/single-tensor-out segments whose boundaries are
exactly the candidate partition points of its ``LayerGraph`` (between two
consecutive single-blob cuts the subgraph is a tensor→tensor function by
construction, so this segmentation always exists).

``CollaborativeEngine`` then implements the deployment flow:

  edge:  INT8 engine — weights stored int8 per-channel (the "model
         download"), activations statically calibrated per-tensor
         (off-line profiling), executed via fake-quant (identical lattice
         math to the Pallas int8 kernel path).
  wire:  the boundary blob is quantized per Eq.(1) → int8 + (scale, zp),
         shipped through a simulated wireless ``Channel``.
  cloud: dequantizes per Eq.(2) and runs the FP32 suffix.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.costmodel import Channel, QP_BYTES
from repro.core.graph import LayerGraph
from repro.core.partition import candidate_partition_points
from repro.core.quant import (QuantParams, compute_qparams, dequantize,
                              pytree_quant_bytes, quantize, quantize_pytree,
                              dequantize_pytree)
from repro.models.layers import QuantCtx, make_calib_ctx

Params = Any
ApplyFn = Callable[..., jax.Array]     # (params, x, *, qctx=None) -> y


@dataclasses.dataclass
class Segment:
    name: str                  # must equal a candidate point in the graph
    apply: ApplyFn
    params: Params


@dataclasses.dataclass
class SegmentedModel:
    name: str
    graph: LayerGraph
    segments: List[Segment]
    max_blobs: int = 1

    def candidate_names(self) -> List[str]:
        return [c.name for c in candidate_partition_points(
            self.graph, max_blobs=self.max_blobs)]

    def full_apply(self, x: jax.Array) -> jax.Array:
        for seg in self.segments:
            x = seg.apply(seg.params, x)
        return x

    def verify_alignment(self) -> None:
        cands = set(self.candidate_names())
        for seg in self.segments:
            assert seg.name in cands, (
                f"segment {seg.name} is not a candidate partition point; "
                f"candidates: {sorted(cands)}")


@dataclasses.dataclass
class TransmissionRecord:
    blob_bytes: int
    precision: str
    simulated_latency_s: float
    edge_wall_s: float
    cloud_wall_s: float


class CollaborativeEngine:
    """Mixed-precision split inference at a chosen partition point."""

    def __init__(self, model: SegmentedModel, cut: str, *,
                 channel: Optional[Channel] = None,
                 calib_batches: Optional[Sequence[jax.Array]] = None,
                 a_bits: int = 8, w_bits: int = 8):
        names = [s.name for s in model.segments]
        if cut == "input":
            k = -1
        else:
            assert cut in names, f"{cut} not in segments {names}"
            k = names.index(cut)
        self.model = model
        self.cut = cut
        self.channel = channel or Channel(bandwidth_bytes_per_s=float("inf"))
        self.edge_segments = model.segments[: k + 1]
        self.cloud_segments = model.segments[k + 1:]
        self.a_bits, self.w_bits = a_bits, w_bits

        # --- off-line: quantize the edge model (the "model download") ----
        edge_params = [s.params for s in self.edge_segments]
        self._edge_q, self._edge_qp = quantize_pytree(
            edge_params, bits=w_bits)
        fp_bytes, q_bytes = pytree_quant_bytes(edge_params, bits=w_bits)
        self.edge_download_bytes = q_bytes
        self.edge_fp32_bytes = fp_bytes
        total_fp, _ = pytree_quant_bytes(
            [s.params for s in model.segments], bits=w_bits)
        self.storage_reduction = 1.0 - (q_bytes / total_fp if total_fp else 0.0)

        # --- off-line: calibrate edge activation thresholds --------------
        self.act_scales: Dict[str, QuantParams] = {}
        if calib_batches is not None and self.edge_segments:
            ctx = make_calib_ctx(a_bits=a_bits, w_bits=w_bits)
            for xb in calib_batches:
                h = xb
                for seg in self.edge_segments:
                    h = seg.apply(seg.params, h, qctx=ctx)
            self.act_scales = ctx.finalize_calibration()

        self._edge_jit = None
        self._cloud_jit = None

    # -- engines -----------------------------------------------------------
    def _edge_ctx(self) -> QuantCtx:
        if self.act_scales:
            return QuantCtx(mode="static", scales=self.act_scales,
                            a_bits=self.a_bits, w_bits=self.w_bits)
        return QuantCtx(mode="dynamic", a_bits=self.a_bits, w_bits=self.w_bits)

    def edge_forward(self, x: jax.Array) -> jax.Array:
        """INT8 engine: runs the prefix with quantized weights+acts."""
        if not self.edge_segments:
            return x
        if self._edge_jit is None:
            qctx = self._edge_ctx()
            segs = self.edge_segments
            # weights: use the int8-stored, dequantized lattice values —
            # exactly what the deployed edge engine computes with.
            deq_params = dequantize_pytree(self._edge_q, self._edge_qp)

            def run(params_list, h):
                for seg, p in zip(segs, params_list):
                    h = seg.apply(p, h, qctx=qctx)
                return h
            self._edge_jit = jax.jit(run)
            self._edge_params = deq_params
        return self._edge_jit(self._edge_params, x)

    def cloud_forward(self, x: jax.Array) -> jax.Array:
        if not self.cloud_segments:
            return x
        if self._cloud_jit is None:
            segs = self.cloud_segments

            def run(params_list, h):
                for seg, p in zip(segs, params_list):
                    h = seg.apply(p, h)
                return h
            self._cloud_jit = jax.jit(run)
            self._cloud_params = [s.params for s in segs]
        return self._cloud_jit(self._cloud_params, x)

    # -- end-to-end ----------------------------------------------------------
    def infer(self, x: jax.Array) -> tuple[jax.Array, TransmissionRecord]:
        t0 = time.perf_counter()
        if self.edge_segments:
            h = self.edge_forward(x)
            h = jax.block_until_ready(h)
            t1 = time.perf_counter()
            # Eq.(1): quantize the boundary blob for transmission
            qp = compute_qparams(h, bits=self.a_bits)
            blob = quantize(h, qp)
            # payload + the Eq.(1) scale/zero-point frame (the canonical
            # constant the serving engines and costmodel charge)
            blob_bytes = blob.size * blob.dtype.itemsize + int(QP_BYTES)
            precision = "int8"
            # Eq.(2): cloud dequantizes
            h = dequantize(blob, qp)
        else:
            t1 = time.perf_counter()
            blob_bytes = x.size * 4
            precision = "fp32"
            h = x
        latency = self.channel.transfer_time(blob_bytes)
        y = self.cloud_forward(h)
        y = jax.block_until_ready(y)
        t2 = time.perf_counter()
        return y, TransmissionRecord(
            blob_bytes=int(blob_bytes), precision=precision,
            simulated_latency_s=latency, edge_wall_s=t1 - t0,
            cloud_wall_s=t2 - t1)
