"""Candidate partition points — the paper's §2.2 structural rules.

Three rules from the paper, realized on the ``LayerGraph`` cut-set
primitive:

1. **Non-parametric merge**: ReLU / pool / add / concat … are fused into
   the nearest *previous* parametric layer (topo-latest parametric
   producer), so they never appear as candidates and their cost/output
   ride along with the fused parent.
2. **Brother-branch rule** (inception): a layer inside a parallel branch
   can never be a single-blob cut — its brothers' tensors also cross.
3. **Shortcut rule** (residual): a layer spanned by a live skip
   connection can never be a single-blob cut.

Rules 2 and 3 need no pattern matching: after rule 1, a node is a
candidate iff ``crossing_blobs(cut) == [cut's own output]``.  For
multi-stream architectures (e.g. MMDiT's parallel img/txt residual
streams) *no* interior cut is single-blob; we generalize per DESIGN.md
§4: a cut is a candidate iff its blob count equals the graph-wide minimum
achievable ("live stream count"), configurable via ``max_blobs``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.graph import Blob, LayerGraph

__all__ = ["merge_non_parametric", "candidate_partition_points",
           "CandidatePoint", "partition_report"]


def merge_non_parametric(g: LayerGraph) -> LayerGraph:
    """Fuse non-parametric nodes into their topo-latest parametric producer.

    Multi-input merge nodes (add/concat) fuse into the latest parametric
    input; the fused node inherits the merge output shape and the union of
    remaining inputs, exactly reproducing the paper's treatment (the
    residual *add* rides with the last conv of the main path; the
    inception *concat* rides with the last branch).
    """
    out = LayerGraph(g.name)
    # alias: original node name -> name of surviving node that now owns it
    alias: Dict[str, str] = {}

    def resolve(name: str) -> str:
        while name in alias:
            name = alias[name]
        return name

    order = g.topo()
    idx = {n: i for i, n in enumerate(order)}
    for name in order:
        nd = g.nodes[name]
        inputs = [resolve(i) for i in nd.inputs]
        # de-dup while preserving order
        seen, uniq = set(), []
        for i in inputs:
            if i not in seen:
                seen.add(i)
                uniq.append(i)
        inputs = uniq
        if nd.parametric or nd.op == "input" or not inputs:
            out.add(name, nd.op, inputs, nd.out_shape, flops=nd.flops,
                    param_elems=nd.param_elems, parametric=nd.parametric,
                    **nd.meta)
            out.nodes[name].fused = list(nd.fused)
        else:
            # choose the topo-latest producer that survives in `out`
            host = max(inputs, key=lambda i: idx.get(i, -1))
            alias[name] = host
            h = out.nodes[host]
            h.fused.append(name)
            h.flops += nd.flops
            h.out_shape = nd.out_shape           # output becomes fused output
            # absorb the merge node's other inputs (e.g. shortcut source)
            for i in inputs:
                if i != host and i not in h.inputs:
                    h.inputs.append(i)
    out.validate()
    return out


@dataclasses.dataclass(frozen=True)
class CandidatePoint:
    name: str
    blobs: List[Blob]
    edge_flops: float            # cumulative FLOPs of the prefix
    edge_param_elems: int
    transmit_bytes: float        # total bytes crossing the wire

    @property
    def n_blobs(self) -> int:
        return len(self.blobs)


def candidate_partition_points(
    g: LayerGraph,
    *,
    max_blobs: int = 1,
    merge: bool = True,
    include_input: bool = True,
    include_last: bool = True,
) -> List[CandidatePoint]:
    """Apply the paper's candidate rules; returns candidates in topo order.

    ``max_blobs=1`` is the paper's rule; multi-stream archs pass the
    stream count (DESIGN.md extension).  The virtual cut *at the input*
    (= cloud-only inference) is included when ``include_input`` so the
    auto-tuner can fall back to pure-cloud; the cut after the last node
    (= edge-only) likewise.
    """
    if merge:
        g = merge_non_parametric(g)
    order = g.topo()
    out: List[CandidatePoint] = []
    cum_flops = 0.0
    cum_params = 0
    last = order[-1]
    for name in order:
        nd = g.nodes[name]
        cum_flops += nd.flops
        cum_params += nd.param_elems
        blobs = g.crossing_blobs(name)
        if name == last:
            if not include_last:
                continue
            blobs = []           # edge-only: nothing crosses but the logits
            blobs = [Blob(source=name, elems=nd.out_elems, precision="int8")]
        elif nd.op == "input":
            if not include_input:
                continue
            # cloud-only: ship the raw input (images are uint8 on the wire)
            blobs = [Blob(source=name, elems=nd.out_elems,
                          precision="uint8")]
        else:
            own = [b for b in blobs if b.source == name]
            if len(blobs) > max_blobs or not own:
                continue
        out.append(CandidatePoint(
            name=name, blobs=blobs, edge_flops=cum_flops,
            edge_param_elems=cum_params,
            transmit_bytes=sum(b.bytes for b in blobs)))
    return out


def partition_report(g: LayerGraph, *, max_blobs: int = 1) -> str:
    merged = merge_non_parametric(g)
    cands = {c.name for c in candidate_partition_points(
        g, max_blobs=max_blobs)}
    lines = [f"Partition analysis for {g.name} "
             f"({len(merged)} fused layers, {len(cands)} candidates):"]
    for name in merged.topo():
        nd = merged.nodes[name]
        blobs = merged.crossing_blobs(name)
        mark = "*" if name in cands else " "
        desc = " + ".join(f"{b.precision}[{b.elems}]" for b in blobs) or "-"
        lines.append(f" {mark} {name:32s} crossing: {desc}")
    return "\n".join(lines)
