"""Scalar INT8 quantization core — paper §2.1, Eq. (1)/(2).

The paper quantizes with *asymmetric affine* (min/max-threshold) scalar
quantization:

    Eq.(1)  Data_Q(x) = (x - T_min) / |T_max - T_min| * Range_LP   (clipped)
    Eq.(2)  Data(x)   = |T_max - T_min| / Range_LP * Data_Q(x) + T_min

which is the standard affine scheme with

    scale      = (T_max - T_min) / Range_LP
    zero_point = round(-T_min / scale)
    q          = clip(round(x / scale + zero_point), q_min, q_max)
    x̂          = scale * (q - zero_point)

We keep *both* the paper's unsigned representation (q ∈ [0, 255]) and a
signed one (q ∈ [-128, 127], the MXU's native int8 operand format); they
differ only by a constant shift of 128 folded into the zero point.

Everything here is pure JAX (jit/grad/vmap-safe); the Pallas kernels in
``repro.kernels`` consume the same ``QuantParams``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QuantParams",
    "compute_qparams",
    "quantize",
    "dequantize",
    "fake_quant",
    "MinMaxCalibrator",
    "PercentileCalibrator",
    "EMACalibrator",
    "quantize_pytree",
    "dequantize_pytree",
    "pytree_quant_bytes",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Affine quantization parameters for one tensor.

    ``scale``/``zero_point`` are scalars (per-tensor) or 1-D arrays of
    length ``shape[axis]`` (per-channel).  ``signed`` selects the int8
    representation: unsigned [0, 255] is the paper's Eq.(1); signed
    [-128, 127] is the same lattice shifted by 128 (MXU operand format).
    """

    scale: jax.Array          # f32, () or (C,)
    zero_point: jax.Array     # f32 (kept float; rounded at use), () or (C,)
    axis: Optional[int] = None
    bits: int = 8
    signed: bool = True

    # -- pytree plumbing (axis/bits/signed are static) ------------------
    def tree_flatten(self):
        return (self.scale, self.zero_point), (self.axis, self.bits, self.signed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        scale, zero_point = children
        axis, bits, signed = aux
        return cls(scale=scale, zero_point=zero_point, axis=axis, bits=bits,
                   signed=signed)

    # -- derived constants ----------------------------------------------
    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1)) if self.signed else 0

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.signed else 2 ** self.bits - 1

    @property
    def range_lp(self) -> int:
        """The paper's Range_LP (255 for INT8)."""
        return 2 ** self.bits - 1

    @property
    def storage_dtype(self):
        if self.bits <= 8:
            return jnp.int8 if self.signed else jnp.uint8
        return jnp.int16 if self.signed else jnp.uint16

    def _bcast(self, arr: jax.Array, ndim: int) -> jax.Array:
        """Broadcast a per-channel vector against an ndim-rank tensor."""
        if self.axis is None or jnp.ndim(arr) == 0:
            return arr
        shape = [1] * ndim
        shape[self.axis] = -1
        return arr.reshape(shape)


def _minmax_to_qparams(t_min: jax.Array, t_max: jax.Array, *, bits: int,
                       signed: bool, axis: Optional[int]) -> QuantParams:
    """Thresholds → (scale, zero_point), the paper's "Step 1"."""
    t_min = jnp.minimum(t_min, 0.0)   # keep 0 representable (exact zero pad)
    t_max = jnp.maximum(t_max, 0.0)
    range_lp = float(2 ** bits - 1)
    span = jnp.maximum(t_max - t_min, 1e-12)
    scale = span / range_lp
    qmin = -(2 ** (bits - 1)) if signed else 0
    zero_point = jnp.round(qmin - t_min / scale)
    zero_point = jnp.clip(zero_point, qmin, qmin + range_lp)
    return QuantParams(scale=scale.astype(jnp.float32),
                       zero_point=zero_point.astype(jnp.float32),
                       axis=axis, bits=bits, signed=signed)


def compute_qparams(x: jax.Array, *, axis: Optional[int] = None,
                    bits: int = 8, signed: bool = True,
                    symmetric: bool = False) -> QuantParams:
    """One-shot min/max calibration of a single tensor (paper Step 1)."""
    if axis is None:
        t_min = jnp.min(x)
        t_max = jnp.max(x)
    else:
        red = tuple(d for d in range(x.ndim) if d != axis)
        t_min = jnp.min(x, axis=red)
        t_max = jnp.max(x, axis=red)
    if symmetric:
        amax = jnp.maximum(jnp.abs(t_min), jnp.abs(t_max))
        t_min, t_max = -amax, amax
    return _minmax_to_qparams(t_min, t_max, bits=bits, signed=signed, axis=axis)


def quantize(x: jax.Array, qp: QuantParams) -> jax.Array:
    """Paper Eq.(1): real → low-precision lattice, with saturation."""
    scale = qp._bcast(qp.scale, x.ndim)
    zp = qp._bcast(qp.zero_point, x.ndim)
    q = jnp.round(x / scale + zp)
    q = jnp.clip(q, qp.qmin, qp.qmax)
    return q.astype(qp.storage_dtype)


def dequantize(q: jax.Array, qp: QuantParams) -> jax.Array:
    """Paper Eq.(2): lattice → real."""
    scale = qp._bcast(qp.scale, q.ndim)
    zp = qp._bcast(qp.zero_point, q.ndim)
    return (q.astype(jnp.float32) - zp) * scale


@partial(jax.custom_vjp, nondiff_argnums=())
def _ste_roundtrip(x, scale, zp, qmin, qmax):
    q = jnp.clip(jnp.round(x / scale + zp), qmin, qmax)
    return (q - zp) * scale


def _ste_fwd(x, scale, zp, qmin, qmax):
    out = _ste_roundtrip(x, scale, zp, qmin, qmax)
    # Gradient passes wherever the *rounded* value is representable, i.e.
    # qmin - 0.5 <= x/scale + zp <= qmax + 0.5 (clipped-STE).
    t = x / scale + zp
    inside = jnp.logical_and(t >= qmin - 0.5, t <= qmax + 0.5)
    return out, (inside,)


def _ste_bwd(res, g):
    (inside,) = res
    # Straight-through: pass gradient where the value was representable,
    # zero where it saturated (clipped-STE).
    gx = jnp.where(inside, g, 0.0)
    return gx, None, None, None, None


_ste_roundtrip.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(x: jax.Array, qp: QuantParams) -> jax.Array:
    """Quantize→dequantize with a straight-through gradient (QAT)."""
    scale = qp._bcast(qp.scale, x.ndim)
    zp = qp._bcast(qp.zero_point, x.ndim)
    return _ste_roundtrip(x, scale, zp, float(qp.qmin), float(qp.qmax))


# ---------------------------------------------------------------------------
# Calibrators — the "off-line quantization Step 1" of the paper, run over a
# stream of calibration batches.
# ---------------------------------------------------------------------------


class MinMaxCalibrator:
    """Running global min/max over observed batches."""

    def __init__(self, *, axis: Optional[int] = None, bits: int = 8,
                 signed: bool = True, symmetric: bool = False):
        self.axis, self.bits, self.signed = axis, bits, signed
        self.symmetric = symmetric
        self._min = None
        self._max = None

    def _reduce(self, x):
        if self.axis is None:
            return jnp.min(x), jnp.max(x)
        red = tuple(d for d in range(x.ndim) if d != self.axis)
        return jnp.min(x, axis=red), jnp.max(x, axis=red)

    def observe(self, x: jax.Array) -> None:
        lo, hi = self._reduce(x)
        if self._min is None:
            self._min, self._max = lo, hi
        else:
            self._min = jnp.minimum(self._min, lo)
            self._max = jnp.maximum(self._max, hi)

    def qparams(self) -> QuantParams:
        assert self._min is not None, "observe() at least one batch first"
        t_min, t_max = self._min, self._max
        if self.symmetric:
            amax = jnp.maximum(jnp.abs(t_min), jnp.abs(t_max))
            t_min, t_max = -amax, amax
        return _minmax_to_qparams(t_min, t_max, bits=self.bits,
                                  signed=self.signed, axis=self.axis)


class PercentileCalibrator:
    """Clip thresholds at a percentile of the observed magnitude
    distribution — robust to activation outliers (per-tensor only)."""

    def __init__(self, percentile: float = 99.9, *, bits: int = 8,
                 signed: bool = True):
        assert 50.0 < percentile <= 100.0
        self.percentile, self.bits, self.signed = percentile, bits, signed
        self._samples: list[np.ndarray] = []
        self._budget = 1 << 22   # cap retained samples

    def observe(self, x: jax.Array) -> None:
        flat = np.asarray(x, dtype=np.float32).ravel()
        if flat.size > 65536:   # subsample deterministically
            stride = flat.size // 65536
            flat = flat[::stride]
        self._samples.append(flat)
        total = sum(s.size for s in self._samples)
        while total > self._budget and len(self._samples) > 1:
            total -= self._samples.pop(0).size

    def qparams(self) -> QuantParams:
        assert self._samples
        allv = np.concatenate(self._samples)
        lo = np.percentile(allv, 100.0 - self.percentile)
        hi = np.percentile(allv, self.percentile)
        return _minmax_to_qparams(jnp.float32(lo), jnp.float32(hi),
                                  bits=self.bits, signed=self.signed, axis=None)


class EMACalibrator:
    """Exponential-moving-average min/max (TensorRT-style smoothing)."""

    def __init__(self, momentum: float = 0.95, *, axis: Optional[int] = None,
                 bits: int = 8, signed: bool = True):
        self.momentum, self.axis, self.bits, self.signed = momentum, axis, bits, signed
        self._min = None
        self._max = None

    def observe(self, x: jax.Array) -> None:
        if self.axis is None:
            lo, hi = jnp.min(x), jnp.max(x)
        else:
            red = tuple(d for d in range(x.ndim) if d != self.axis)
            lo, hi = jnp.min(x, axis=red), jnp.max(x, axis=red)
        if self._min is None:
            self._min, self._max = lo, hi
        else:
            m = self.momentum
            self._min = m * self._min + (1 - m) * lo
            self._max = m * self._max + (1 - m) * hi

    def qparams(self) -> QuantParams:
        assert self._min is not None
        return _minmax_to_qparams(self._min, self._max, bits=self.bits,
                                  signed=self.signed, axis=self.axis)


# ---------------------------------------------------------------------------
# Pytree helpers — quantize a whole parameter tree (the edge engine's model
# download is the quantized tree; the paper's "model storage reduction").
# ---------------------------------------------------------------------------


def _leaf_axis(path, leaf) -> Optional[int]:
    """Per-channel along the output-feature axis for rank>=2 kernels."""
    if leaf.ndim >= 2:
        return leaf.ndim - 1
    return None


def quantize_pytree(params, *, bits: int = 8, signed: bool = True,
                    per_channel: bool = True, symmetric_weights: bool = False):
    """Quantize every float leaf. Returns (q_tree, qp_tree)."""

    def one(path, leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf, None
        axis = _leaf_axis(path, leaf) if per_channel else None
        qp = compute_qparams(leaf, axis=axis, bits=bits, signed=signed,
                             symmetric=symmetric_weights)
        return quantize(leaf, qp), qp

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    qs, qps = [], []
    for path, leaf in flat:
        q, qp = one(path, leaf)
        qs.append(q)
        qps.append(qp)
    return (jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, qps))


def dequantize_pytree(q_tree, qp_tree):
    def one(q, qp):
        if qp is None:
            return q
        return dequantize(q, qp)
    return jax.tree_util.tree_map(one, q_tree, qp_tree,
                                  is_leaf=lambda x: x is None)


def pytree_quant_bytes(params, *, bits: int = 8) -> tuple[int, int]:
    """(fp32_bytes, quantized_bytes incl. per-tensor scale/zp overhead)."""
    fp = 0
    qb = 0
    for leaf in jax.tree_util.tree_leaves(params):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        fp += n * 4
        qb += (n * bits + 7) // 8 + 8   # +8B for scale/zero_point
    return fp, qb
