"""Auto-tuning partition — the paper's Algorithm 1.

For every candidate cut L_i (from §2.2's rules):
  Net_edge  = Net.Split(First, L_i)   quantized to INT8
  Net_cloud = Net.Split(L_i+1, Last)  kept at FP32
  PredictPerformance(Engine_edge, Engine_cloud)   — from off-line profiles
and finally the best partition for the current environment (bandwidth)
is returned.  ``p_best`` minimizes end-to-end latency by default; the
paper also reports the "fastest" vs "best" distinction (best = fastest
subject to edge-storage/accuracy constraints) which we expose through
``constraints``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

from repro.core.costmodel import (Channel, DeviceModel, Profile,
                                  layer_time, subgraph_time)
from repro.core.graph import LayerGraph
from repro.core.partition import (CandidatePoint, candidate_partition_points,
                                  merge_non_parametric)

__all__ = ["PartitionPerf", "AutoTuner", "auto_tune"]


@dataclasses.dataclass(frozen=True)
class PartitionPerf:
    """The ``(L_i, info)`` record of Algorithm 1, line 8."""
    point: str
    edge_time_s: float
    upload_time_s: float
    cloud_time_s: float
    transmit_bytes: float
    edge_model_bytes: float          # quantized prefix download (paper Table 3)
    storage_reduction: float         # vs full fp32 model on device
    edge_flops: float
    n_blobs: int

    @property
    def total_s(self) -> float:
        return self.edge_time_s + self.upload_time_s + self.cloud_time_s


class AutoTuner:
    def __init__(self, graph: LayerGraph, edge: DeviceModel,
                 cloud: DeviceModel, *,
                 edge_profile: Optional[Profile] = None,
                 cloud_profile: Optional[Profile] = None,
                 max_blobs: int = 1,
                 loop_steps: int = 1,
                 quant_bits: int = 8):
        self.graph = graph
        self.merged = merge_non_parametric(graph)
        self.edge = edge
        self.cloud = cloud
        self.edge_profile = edge_profile
        self.cloud_profile = cloud_profile
        self.max_blobs = max_blobs
        self.loop_steps = loop_steps      # diffusion: transmissions per call
        self.quant_bits = quant_bits
        self.candidates: List[CandidatePoint] = candidate_partition_points(
            graph, max_blobs=max_blobs)
        self._total_param_bytes_fp32 = self.merged.total_param_elems() * 4.0

    # -- Algorithm 1 lines 3-9 -------------------------------------------
    def predict_performance(self, cand: CandidatePoint,
                            channel: Channel) -> PartitionPerf:
        order = self.merged.topo()
        ci = order.index(cand.name)
        prefix = order[: ci + 1]
        suffix = order[ci + 1:]
        edge_t = subgraph_time(self.merged, prefix, self.edge,
                               precision="int8", profile=self.edge_profile)
        cloud_t = subgraph_time(self.merged, suffix, self.cloud,
                                precision="fp32", profile=self.cloud_profile)
        # the input node itself costs nothing to "compute"
        upload_t = channel.transfer_time(cand.transmit_bytes)
        if self.loop_steps > 1:
            edge_t *= self.loop_steps
            cloud_t *= self.loop_steps
            upload_t *= self.loop_steps
        edge_param_bytes = cand.edge_param_elems * (self.quant_bits / 8.0)
        return PartitionPerf(
            point=cand.name,
            edge_time_s=edge_t,
            upload_time_s=upload_t,
            cloud_time_s=cloud_t,
            transmit_bytes=cand.transmit_bytes,
            edge_model_bytes=edge_param_bytes,
            storage_reduction=1.0 - (edge_param_bytes
                                     / max(self._total_param_bytes_fp32, 1.0)),
            edge_flops=cand.edge_flops,
            n_blobs=cand.n_blobs)

    # -- Algorithm 1 lines 10-14 -------------------------------------------
    def tune(self, channel: Channel, *,
             constraints: Optional[Callable[[PartitionPerf], bool]] = None,
             ) -> tuple[PartitionPerf, List[PartitionPerf]]:
        """Returns (p_best, P).  ``constraints`` filters feasible points
        (e.g. edge storage budget); best = argmin total latency among
        feasible, the paper's ``Env(p_i) is better than Env(p_best)``."""
        perfs = [self.predict_performance(c, channel) for c in self.candidates]
        feasible = [p for p in perfs if constraints is None or constraints(p)]
        if not feasible:
            feasible = perfs
        best = min(feasible, key=lambda p: p.total_s)
        return best, perfs

    def cloud_only(self, channel: Channel) -> PartitionPerf:
        """Baseline: ship the raw input, run everything in the cloud."""
        inp = [c for c in self.candidates
               if self.merged.nodes[c.name].op == "input"]
        assert inp, "graph has no input node"
        return self.predict_performance(inp[0], channel)

    def speedup_vs_cloud_only(self, channel: Channel) -> float:
        best, _ = self.tune(channel)
        return self.cloud_only(channel).total_s / best.total_s


def auto_tune(graph: LayerGraph, edge: DeviceModel, cloud: DeviceModel,
              channel: Channel, **kw) -> tuple[PartitionPerf, List[PartitionPerf]]:
    """One-shot convenience wrapper (Algorithm 1 end-to-end)."""
    return AutoTuner(graph, edge, cloud, **kw).tune(channel)
