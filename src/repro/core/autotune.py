"""Auto-tuning partition — the paper's Algorithm 1 — and its serving-time
sibling: auto-tuning the speculative draft length.

For every candidate cut L_i (from §2.2's rules):
  Net_edge  = Net.Split(First, L_i)   quantized to INT8
  Net_cloud = Net.Split(L_i+1, Last)  kept at FP32
  PredictPerformance(Engine_edge, Engine_cloud)   — from off-line profiles
and finally the best partition for the current environment (bandwidth)
is returned.  ``p_best`` minimizes end-to-end latency by default; the
paper also reports the "fastest" vs "best" distinction (best = fastest
subject to edge-storage/accuracy constraints) which we expose through
``constraints``.

``tune_spec_k`` applies the same predict-then-pick loop to the decode
round length k of the speculative collaborative engine: for every
candidate k it evaluates ``costmodel.speculative_round_time`` (draft k
tokens locally, one uplink, one batched verify, one downlink) at the
environment's channel and the measured/assumed draft acceptance rate,
and returns the k minimizing predicted time per *accepted* token.  k=1
is always a candidate and recovers the non-speculative step exactly, so
the tuner degrades gracefully on fast channels or poor drafts.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel, DeviceModel,
                                  EDGE_TX2_CLASS, MSG_BYTES, PhaseBreakdown,
                                  Profile, QP_BYTES, TOK_BYTES,
                                  expected_accepted_tokens, layer_time,
                                  speculative_round_time, subgraph_time)
from repro.core.graph import LayerGraph
from repro.core.partition import (CandidatePoint, candidate_partition_points,
                                  merge_non_parametric)

__all__ = ["PartitionPerf", "AutoTuner", "auto_tune", "SpecKPerf",
           "tune_spec_k", "spec_k_for_lm", "lm_round_args", "CutKPerf",
           "tune_cut_and_k"]


@dataclasses.dataclass(frozen=True)
class PartitionPerf:
    """The ``(L_i, info)`` record of Algorithm 1, line 8."""
    point: str
    edge_time_s: float
    upload_time_s: float
    cloud_time_s: float
    transmit_bytes: float
    edge_model_bytes: float          # quantized prefix download (paper Table 3)
    storage_reduction: float         # vs full fp32 model on device
    edge_flops: float
    n_blobs: int

    @property
    def total_s(self) -> float:
        return self.edge_time_s + self.upload_time_s + self.cloud_time_s


class AutoTuner:
    def __init__(self, graph: LayerGraph, edge: DeviceModel,
                 cloud: DeviceModel, *,
                 edge_profile: Optional[Profile] = None,
                 cloud_profile: Optional[Profile] = None,
                 max_blobs: int = 1,
                 loop_steps: int = 1,
                 quant_bits: int = 8):
        self.graph = graph
        self.merged = merge_non_parametric(graph)
        self.edge = edge
        self.cloud = cloud
        self.edge_profile = edge_profile
        self.cloud_profile = cloud_profile
        self.max_blobs = max_blobs
        self.loop_steps = loop_steps      # diffusion: transmissions per call
        self.quant_bits = quant_bits
        self.candidates: List[CandidatePoint] = candidate_partition_points(
            graph, max_blobs=max_blobs)
        self._total_param_bytes_fp32 = self.merged.total_param_elems() * 4.0

    # -- Algorithm 1 lines 3-9 -------------------------------------------
    def predict_performance(self, cand: CandidatePoint,
                            channel: Channel) -> PartitionPerf:
        order = self.merged.topo()
        ci = order.index(cand.name)
        prefix = order[: ci + 1]
        suffix = order[ci + 1:]
        edge_t = subgraph_time(self.merged, prefix, self.edge,
                               precision="int8", profile=self.edge_profile)
        cloud_t = subgraph_time(self.merged, suffix, self.cloud,
                                precision="fp32", profile=self.cloud_profile)
        # the input node itself costs nothing to "compute"
        upload_t = channel.transfer_time(cand.transmit_bytes)
        if self.loop_steps > 1:
            edge_t *= self.loop_steps
            cloud_t *= self.loop_steps
            upload_t *= self.loop_steps
        edge_param_bytes = cand.edge_param_elems * (self.quant_bits / 8.0)
        return PartitionPerf(
            point=cand.name,
            edge_time_s=edge_t,
            upload_time_s=upload_t,
            cloud_time_s=cloud_t,
            transmit_bytes=cand.transmit_bytes,
            edge_model_bytes=edge_param_bytes,
            storage_reduction=1.0 - (edge_param_bytes
                                     / max(self._total_param_bytes_fp32, 1.0)),
            edge_flops=cand.edge_flops,
            n_blobs=cand.n_blobs)

    # -- Algorithm 1 lines 10-14 -------------------------------------------
    def tune(self, channel: Channel, *,
             constraints: Optional[Callable[[PartitionPerf], bool]] = None,
             ) -> tuple[PartitionPerf, List[PartitionPerf]]:
        """Returns (p_best, P).  ``constraints`` filters feasible points
        (e.g. edge storage budget); best = argmin total latency among
        feasible, the paper's ``Env(p_i) is better than Env(p_best)``."""
        perfs = [self.predict_performance(c, channel) for c in self.candidates]
        feasible = [p for p in perfs if constraints is None or constraints(p)]
        if not feasible:
            feasible = perfs
        best = min(feasible, key=lambda p: p.total_s)
        return best, perfs

    def cloud_only(self, channel: Channel) -> PartitionPerf:
        """Baseline: ship the raw input, run everything in the cloud."""
        inp = [c for c in self.candidates
               if self.merged.nodes[c.name].op == "input"]
        assert inp, "graph has no input node"
        return self.predict_performance(inp[0], channel)

    def speedup_vs_cloud_only(self, channel: Channel) -> float:
        best, _ = self.tune(channel)
        return self.cloud_only(channel).total_s / best.total_s


def auto_tune(graph: LayerGraph, edge: DeviceModel, cloud: DeviceModel,
              channel: Channel, **kw) -> tuple[PartitionPerf, List[PartitionPerf]]:
    """One-shot convenience wrapper (Algorithm 1 end-to-end)."""
    return AutoTuner(graph, edge, cloud, **kw).tune(channel)


# ---------------------------------------------------------------------------
# Speculative draft-length auto-tuning (Algorithm 1's loop applied to k)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpecKPerf:
    """The ``(k, info)`` record of the spec-k tuning loop."""
    k: int
    breakdown: PhaseBreakdown                # one round, tokens = E[accepts]
    uplink_bytes_per_token: float            # wire bytes per accepted token

    @property
    def s_per_token(self) -> float:
        return self.breakdown.per_token_s


def tune_spec_k(*, edge_flops: float, cloud_flops: float, blob_bytes: float,
                edge: DeviceModel, cloud: DeviceModel, channel: Channel,
                draft_flops: float = 0.0, acceptance: float = 0.8,
                ks: Sequence[int] = (1, 2, 4, 8, 16, 32),
                return_bytes: float = 4.0, rows: int = 1,
                cloud_layers: int = 0, cloud_act_bytes: float = 0.0,
                draft_q_bytes: float = 0.0,
                ) -> Tuple[SpecKPerf, List[SpecKPerf]]:
    """Pick the draft length k minimizing predicted time per accepted
    token for this channel/acceptance-rate — per-step flop/byte inputs
    are exactly ``collab_decode_step_time``'s, and the k=1 candidate
    evaluates to exactly that non-speculative step.  ``draft_q_bytes``
    (sampled traffic's shipped draft distributions, see
    ``speculative_round_time``) makes large k pay its real uplink, so
    hot sampling traffic tunes to a smaller k than greedy."""
    perfs = []
    for k in ks:
        bd = speculative_round_time(
            k=k, edge_flops=edge_flops, cloud_flops=cloud_flops,
            blob_bytes=blob_bytes, edge=edge, cloud=cloud, channel=channel,
            draft_flops=draft_flops, acceptance=acceptance,
            return_bytes=return_bytes, rows=rows,
            cloud_layers=cloud_layers, cloud_act_bytes=cloud_act_bytes,
            draft_q_bytes=draft_q_bytes)
        uplink = k * blob_bytes \
            + (k - 1) * (TOK_BYTES * rows + draft_q_bytes) + MSG_BYTES
        perfs.append(SpecKPerf(
            k=k, breakdown=bd,
            uplink_bytes_per_token=uplink
            / expected_accepted_tokens(k, acceptance)))
    best = min(perfs, key=lambda p: p.s_per_token)
    return best, perfs


def lm_round_args(cfg, cut_layer: int, *, batch: int,
                  sampled_frac: float = 0.0) -> dict:
    """Per-step flop/byte arguments of ``tune_spec_k`` /
    ``speculative_round_time`` for an ``LMConfig`` split at
    ``cut_layer``: INT8 edge prefix of ``cut_layer + 1`` blocks, FP32
    cloud suffix + head, Eq.(1)-framed ``[B, 1, D]`` boundary delta.
    The edge's draft model is the INT8 suffix copy, so ``draft_flops``
    equals the cloud suffix's per-step flops (run at INT8 throughput).
    ``sampled_frac`` is the fraction of live slots decoding at
    temperature>0: each such row ships its f32 draft distribution per
    graded position (``draft_q_bytes`` — serve.spec's q-row uplink).

    This is the model half the online policy (``serve.policy``)
    re-evaluates against live telemetry — one dict per candidate cut,
    shared by the offline and online tuners."""
    blk = cfg.block_param_count()
    head = cfg.vocab * cfg.d_model + cfg.d_model
    suffix = 2 * (blk * (cfg.n_layers - cut_layer - 1) + head) * batch
    return dict(
        edge_flops=2 * blk * (cut_layer + 1) * batch,
        cloud_flops=suffix, draft_flops=suffix,
        blob_bytes=batch * (cfg.d_model + QP_BYTES),
        return_bytes=TOK_BYTES * batch, rows=batch,
        draft_q_bytes=sampled_frac * batch * cfg.vocab * 4.0,
        # TP all-reduce inputs: suffix depth and the [B, 1, D] f32
        # activation each of its blocks reduces (costmodel._tp_allreduce_s
        # charges them only when cloud.n_chips > 1 with a modeled link)
        cloud_layers=cfg.n_layers - cut_layer - 1,
        cloud_act_bytes=batch * cfg.d_model * 4.0)


def spec_k_for_lm(cfg, cut_layer: int, *, batch: int, channel: Channel,
                  acceptance: float = 0.8,
                  edge: DeviceModel = EDGE_TX2_CLASS,
                  cloud: DeviceModel = CLOUD_TITANXP_CLASS,
                  ks: Sequence[int] = (1, 2, 4, 8, 16),
                  sampled_frac: float = 0.0,
                  ) -> Tuple[SpecKPerf, List[SpecKPerf]]:
    """``tune_spec_k`` with the per-step flops/bytes of ``lm_round_args``
    — what ``CollaborativeServingEngine(spec_k="auto")`` calls."""
    return tune_spec_k(edge=edge, cloud=cloud, channel=channel,
                       acceptance=acceptance, ks=ks,
                       **lm_round_args(cfg, cut_layer, batch=batch,
                                       sampled_frac=sampled_frac))


# ---------------------------------------------------------------------------
# Joint (cut, k) tuning — Algorithm 1's loop over the full online grid
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CutKPerf:
    """One cell of the joint (cut_layer, spec_k) grid."""
    cut: int
    k: int
    breakdown: PhaseBreakdown

    @property
    def s_per_token(self) -> float:
        return self.breakdown.per_token_s


def tune_cut_and_k(cfg, *, batch: int, channel: Channel,
                   cuts: Sequence[int], acceptance: float = 0.8,
                   edge: DeviceModel = EDGE_TX2_CLASS,
                   cloud: DeviceModel = CLOUD_TITANXP_CLASS,
                   ks: Sequence[int] = (1, 2, 4, 8, 16),
                   sampled_frac: float = 0.0,
                   ) -> Tuple[CutKPerf, List[CutKPerf]]:
    """Algorithm 1's predict-then-pick loop over the joint grid of
    candidate partition points × speculative draft lengths, minimizing
    predicted time per *accepted* token — the decision the online
    control plane (``serve.policy``) re-evaluates as telemetry moves.

    The k=1 column degrades to the serial incremental step (there the
    smallest edge prefix tends to win: the slow INT8 edge runs only
    ``cut + 1`` blocks); the k>1 columns amortize the RTT and the
    per-message framing k-fold, and there the cut trades edge prefix
    steps against cloud verify flops.  All candidate cuts share one
    prequantized weight bank at serving time, so acting on a new best
    cut is a pointer swap (``serve.engine._CutBank``)."""
    perfs = []
    for cut in cuts:
        args = lm_round_args(cfg, cut, batch=batch,
                             sampled_frac=sampled_frac)
        for k in ks:
            bd = speculative_round_time(
                k=k, edge=edge, cloud=cloud, channel=channel,
                acceptance=acceptance, **args)
            perfs.append(CutKPerf(cut=cut, k=k, breakdown=bd))
    best = min(perfs, key=lambda p: p.s_per_token)
    return best, perfs
