"""Device / channel cost models for Algorithm 1's ``PredictPerformance``.

The paper profiles each operator off-line on the physical edge device
(Jetson TX2 + gemmlowp) and cloud server (TITAN Xp + cuDNN).  We model
both as roofline devices — ``time = max(compute, memory)`` per layer plus
a fixed launch overhead — and additionally support *measured* per-layer
profiles (``Profile``) that override the analytic model, which is exactly
the paper's off-line profiling mode.

The cloud can also be a multi-chip TPU pod; its per-layer time then
includes a collective term (bytes moved / link bandwidth) so the
auto-tuner sees the cost of distributed cloud inference (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

from repro.core.graph import LayerGraph, Node

__all__ = ["DeviceModel", "Channel", "Profile", "PhaseBreakdown",
           "EDGE_TX2_CLASS", "CLOUD_TITANXP_CLASS", "CLOUD_TPU_V5E_CHIP",
           "MSG_BYTES", "QP_BYTES", "TOK_BYTES",
           "layer_time", "subgraph_time", "tpu_v5e_pod",
           "collab_decode_step_time", "speculative_round_time",
           "expected_accepted_tokens", "predict_finish_time"]

# Canonical wire-framing constants, shared with the serving engines'
# accounting (``serve.transport``) so model predictions and measured
# byte counters can never drift apart:
#   MSG_BYTES — per-*message* protocol framing (TCP/IP-class headers +
#               slot ids/round counter); every channel traversal pays it
#               once, which is exactly what a draft/verify round
#               amortizes k-fold alongside the RTT.
#   QP_BYTES  — per-blob Eq.(1) framing: f32 scale + f32 zero-point.
#   TOK_BYTES — one token id (cloud→edge return / edge→cloud draft).
MSG_BYTES = 64.0
QP_BYTES = 8.0
TOK_BYTES = 4.0


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """A roofline device. Rates in ops/s and bytes/s."""
    name: str
    peak_flops_fp32: float
    peak_ops_int8: float
    dram_bw: float
    launch_overhead_s: float = 20e-6
    n_chips: int = 1
    link_bw: float = 0.0            # per-chip interconnect (pods)

    def scaled(self, n_chips: int) -> "DeviceModel":
        return dataclasses.replace(
            self, name=f"{self.name}x{n_chips}", n_chips=n_chips)


# Defaults approximating the paper's hardware (DESIGN.md §3):
# TX2-class edge — gemmlowp on 4xA57 delivers single-digit effective GOPS
# (the paper's AlexNet conv1-5 runs in ~0.3 s ≈ 1.4 GFLOP / 5 GOPS), and
# LPDDR4 effective bandwidth for streaming cold weights is a few GB/s.
EDGE_TX2_CLASS = DeviceModel(
    name="edge-tx2", peak_flops_fp32=2.0e9, peak_ops_int8=5.0e9,
    dram_bw=6e9, launch_overhead_s=200e-6)

# TITAN Xp-class cloud GPU: 12.1 TFLOP/s fp32, 547 GB/s.
CLOUD_TITANXP_CLASS = DeviceModel(
    name="cloud-titanxp", peak_flops_fp32=12.1e12, peak_ops_int8=12.1e12,
    dram_bw=547e9, launch_overhead_s=10e-6)

# One TPU v5e chip (the roofline constants of the assignment).
CLOUD_TPU_V5E_CHIP = DeviceModel(
    name="tpu-v5e", peak_flops_fp32=197e12, peak_ops_int8=394e12,
    dram_bw=819e9, launch_overhead_s=5e-6, link_bw=50e9)


def tpu_v5e_pod(n_chips: int = 256) -> DeviceModel:
    return CLOUD_TPU_V5E_CHIP.scaled(n_chips)


@dataclasses.dataclass(frozen=True)
class Channel:
    """Wireless link between edge and cloud (the paper's environment).

    ``loss_rate`` is the per-message loss probability a reliable
    transport observes (``serve.transport.LinkTelemetry``); with
    retransmit-until-delivered semantics the *expected* channel time per
    message is the clean time times ``expected_retx()`` = 1/(1-p), which
    is how the round-time models below price a lossy link — so the
    auto-tuner sees that a cut shipping more messages hurts more when
    messages are being lost."""
    bandwidth_bytes_per_s: float
    rtt_s: float = 0.0
    name: str = ""
    loss_rate: float = 0.0

    def expected_retx(self) -> float:
        """Expected transmissions per delivered message, clamped so a
        (transient) measured loss of ~1 can't predict infinity."""
        return 1.0 / (1.0 - min(max(self.loss_rate, 0.0), 0.95))

    def transfer_time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return nbytes / self.bandwidth_bytes_per_s + self.rtt_s

    @classmethod
    def from_kbps(cls, kilobytes_per_s: float, rtt_ms: float = 0.0):
        return cls(bandwidth_bytes_per_s=kilobytes_per_s * 1e3,
                   rtt_s=rtt_ms * 1e-3,
                   name=f"{kilobytes_per_s:g}KB/s")


# measured per-layer seconds, node name -> time
Profile = Mapping[str, float]


@dataclasses.dataclass(frozen=True)
class PhaseBreakdown:
    """Per-phase latency split of a collaborative serving round:
    one-time prefill, decode compute (edge + cloud), and the wireless
    transfer of the boundary blob.  Mirrors the phase fields
    ``ServeStats`` measures, so predictions and measurements line up.
    ``tokens`` is the expected number of *accepted* tokens the round
    commits (1 for the non-speculative step), so ``per_token_s`` is the
    per-accepted-token cost the spec-k auto-tuner minimizes."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    channel_s: float = 0.0
    tokens: float = 1.0

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s + self.channel_s

    @property
    def per_token_s(self) -> float:
        return self.total_s / max(self.tokens, 1e-9)


def _tp_allreduce_s(cloud: DeviceModel, cloud_layers: int,
                    cloud_act_bytes: float) -> float:
    """Per-step tensor-parallel collective cost of the cloud suffix:
    Megatron TP pays two all-reduces per block (after attention out-proj
    and after FFN-out), each moving ``2·(n-1)/n`` of the activation
    bytes per chip on a ring.  Zero for a single chip or an unmodeled
    interconnect — the term only kicks in when a mesh actually scales
    ``n_chips`` up, which is what lets the tuner trade cloud
    parallelism against channel cost."""
    if cloud.n_chips <= 1 or cloud.link_bw <= 0 or cloud_layers <= 0:
        return 0.0
    ring = 2.0 * (cloud.n_chips - 1) / cloud.n_chips \
        * cloud_act_bytes / cloud.link_bw
    return 2.0 * cloud_layers * ring


def collab_decode_step_time(*, edge_flops: float, cloud_flops: float,
                            blob_bytes: float, edge: DeviceModel,
                            cloud: DeviceModel, channel: Channel,
                            return_bytes: float = 4.0,
                            msg_bytes: float = MSG_BYTES,
                            cloud_layers: int = 0,
                            cloud_act_bytes: float = 0.0) -> PhaseBreakdown:
    """Predicted per-token cost of *incremental* collaborative decode.

    With split KV caches, each generated token runs only the new-token
    slice through the edge prefix (INT8) and the cloud suffix (FP32) and
    ships a single [B, 1, D] quantized boundary delta — so the wire term
    is O(1) in sequence length, which is what makes transmission stop
    dominating (JointDNN's observation applied per token).  Each step is
    a full round trip: the uplink delta plus the cloud→edge return of
    the sampled tokens (``return_bytes``), each a *message* paying the
    ``msg_bytes`` protocol framing the engines charge (``ServeStats``)
    on top of its payload, and each paying the channel RTT.  A lossy
    channel multiplies the whole wire term by the expected retransmit
    count (``Channel.expected_retx``)."""
    edge_s = edge_flops / edge.peak_ops_int8 + edge.launch_overhead_s
    cloud_s = (cloud_flops / (cloud.peak_flops_fp32 * cloud.n_chips)
               + cloud.launch_overhead_s
               + _tp_allreduce_s(cloud, cloud_layers, cloud_act_bytes))
    channel_s = (channel.transfer_time(blob_bytes + msg_bytes)
                 + channel.transfer_time(return_bytes + msg_bytes)) \
        * channel.expected_retx()
    return PhaseBreakdown(decode_s=edge_s + cloud_s, channel_s=channel_s)


def expected_accepted_tokens(k: int, acceptance: float) -> float:
    """Expected tokens a draft/verify round of length k commits, with
    i.i.d. per-position draft accuracy ``acceptance``: the round always
    commits the verify's corrected token and extends one position per
    leading accepted draft, so E = sum_{i=0}^{k-1} acceptance^i."""
    if acceptance >= 1.0:
        return float(k)
    return (1.0 - acceptance ** k) / (1.0 - acceptance)


def speculative_round_time(*, k: int, edge_flops: float, cloud_flops: float,
                           blob_bytes: float, edge: DeviceModel,
                           cloud: DeviceModel, channel: Channel,
                           draft_flops: float = 0.0,
                           acceptance: float = 1.0,
                           return_bytes: float = 4.0,
                           rows: int = 1,
                           msg_bytes: float = MSG_BYTES,
                           cloud_layers: int = 0,
                           cloud_act_bytes: float = 0.0,
                           draft_q_bytes: float = 0.0) -> PhaseBreakdown:
    """Predicted cost of one speculative *draft/verify round* of length
    ``k`` (the flop/byte arguments are per-step quantities, exactly
    ``collab_decode_step_time``'s).

    The edge pays k serial prefix steps plus — when actually drafting
    (k > 1) — k local INT8 suffix steps (``draft_flops``); the cloud
    verifies all k positions in ONE batched multi-token step (k× the
    flops, one launch); the channel carries one uplink (k boundary
    deltas + the k-1 graded draft-token ids, 4 B each across ``rows``
    live requests) and one downlink (the sampled/corrected token plus,
    for k > 1, a byte-packed accept mask) — so the RTT *and the
    per-message ``msg_bytes`` framing* are paid once per round instead
    of once per token.  ``tokens`` in the returned breakdown is the
    expected accepted-token count at the given per-position draft
    ``acceptance``, making ``per_token_s`` the quantity
    ``autotune.tune_spec_k`` minimizes.

    ``draft_q_bytes`` prices sampled (temperature>0) traffic: the
    rejection-sampling verify needs the draft's filtered distribution at
    each of the k-1 graded positions, so the uplink grows by
    ``(k-1) * draft_q_bytes`` per round (per-graded-position bytes, with
    the batch rows already baked in — see ``autotune.lm_round_args``).
    The default 0.0 keeps every greedy prediction bit-identical.

    ``k=1`` recovers ``collab_decode_step_time`` exactly: no draft
    model, no mask, one delta, one token, no shipped distributions — the
    auto-tuner can always fall back to today's serial step."""
    edge_step = edge_flops / edge.peak_ops_int8 + edge.launch_overhead_s
    draft_step = draft_flops / edge.peak_ops_int8 + edge.launch_overhead_s
    edge_s = k * edge_step + (k * draft_step if k > 1 else 0.0)
    # verify acts are [B, k, D]: the TP all-reduces move k× the bytes
    cloud_s = (k * cloud_flops / (cloud.peak_flops_fp32 * cloud.n_chips)
               + cloud.launch_overhead_s
               + _tp_allreduce_s(cloud, cloud_layers, k * cloud_act_bytes))
    uplink = k * blob_bytes + (k - 1) * (TOK_BYTES * rows + draft_q_bytes) \
        + msg_bytes
    downlink = return_bytes + msg_bytes \
        + (float(-(-k // 8)) * rows if k > 1 else 0.0)
    channel_s = (channel.transfer_time(uplink)
                 + channel.transfer_time(downlink)) \
        * channel.expected_retx()
    return PhaseBreakdown(decode_s=edge_s + cloud_s, channel_s=channel_s,
                          tokens=expected_accepted_tokens(k, acceptance))


def predict_finish_time(round: PhaseBreakdown, *, now: float, max_new: int,
                        queue_tokens: float = 0.0, slots: int = 1,
                        prefill_s: float = 0.0) -> float:
    """Predicted absolute completion time of a request entering service.

    ``round`` is one decode round's predicted cost (its ``tokens`` field
    is the expected accepted tokens per round, so a lossy channel's
    expected retransmissions — baked into ``channel_s`` by
    ``speculative_round_time`` via ``Channel.expected_retx`` — and a low
    draft acceptance both stretch the prediction).  ``queue_tokens`` is
    the budget the engine still owes work admitted *ahead* of this
    request; under continuous batching those tokens drain across
    ``slots`` parallel slots at the same per-round cadence, which is the
    queue-depth term of deadline-aware admission (``serve.policy.
    DeadlineAdmission``): a doomed request is one whose predicted finish
    already overshoots its deadline *before* it is granted a slot."""
    toks = max(float(round.tokens), 1e-9)
    rounds_own = -(-float(max_new) // toks)            # ceil
    rounds_queued = max(0.0, float(queue_tokens)) / (max(int(slots), 1)
                                                     * toks)
    return now + prefill_s + (rounds_own + rounds_queued) * round.total_s


def layer_time(node: Node, dev: DeviceModel, *, precision: str,
               profile: Optional[Profile] = None) -> float:
    """Roofline time of one (possibly fused) layer on ``dev``."""
    if profile is not None and node.name in profile:
        return profile[node.name]
    if precision == "int8":
        compute = node.flops / (dev.peak_ops_int8 * dev.n_chips)
        pbytes = node.param_elems * 1.0
        abytes = node.out_elems * 1.0
    else:
        compute = node.flops / (dev.peak_flops_fp32 * dev.n_chips)
        pbytes = node.param_elems * 4.0
        abytes = node.out_elems * 4.0
    # per-chip memory traffic: weights stream once, activations in+out
    in_elems = sum(1 for _ in node.inputs) * node.out_elems  # approx
    mem_bytes = pbytes / dev.n_chips + abytes * 2
    memory = mem_bytes / dev.dram_bw
    t = max(compute, memory) + dev.launch_overhead_s
    # distributed cloud: moving activations between chips each layer
    if dev.n_chips > 1 and dev.link_bw > 0:
        t += abytes / (dev.link_bw * dev.n_chips)
    return t


def subgraph_time(g: LayerGraph, names, dev: DeviceModel, *, precision: str,
                  profile: Optional[Profile] = None) -> float:
    return sum(layer_time(g.nodes[n], dev, precision=precision,
                          profile=profile) for n in names)
