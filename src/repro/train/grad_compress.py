"""INT8 gradient compression with error feedback (distributed-optimization
trick; paper-adjacent: the same Eq.1/Eq.2 scalar quantization applied to
the gradient all-reduce instead of the activations).

    c_t   = Q(g_t + e_t)            # int8 per-leaf, per-tensor scale
    e_t+1 = (g_t + e_t) - Q⁻¹(c_t)  # residual carried to the next step

The all-reduce then moves 1 byte/grad element instead of 4 (plus an 8-byte
scale), a 4x cut of the gradient collective — error feedback keeps SGD
convergence (Seide et al.; Karimireddy et al. 2019).

``compress``/``decompress`` are jit-safe; ``compressed_allreduce_bytes``
reports the wire saving for the roofline.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantParams, compute_qparams, dequantize, quantize

Params = Any

__all__ = ["init_error_feedback", "compress", "decompress",
           "compress_with_feedback", "compressed_allreduce_bytes"]


def init_error_feedback(params: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(tree: Params, *, bits: int = 8) -> Tuple[Params, Params]:
    """Per-leaf symmetric quantization → (int8 tree, qparams tree)."""
    def one(g):
        qp = compute_qparams(g.astype(jnp.float32), bits=bits,
                             symmetric=True)
        return quantize(g.astype(jnp.float32), qp), qp
    flat, tdef = jax.tree_util.tree_flatten(tree)
    qs, qps = zip(*[one(g) for g in flat]) if flat else ((), ())
    return (jax.tree_util.tree_unflatten(tdef, list(qs)),
            jax.tree_util.tree_unflatten(tdef, list(qps)))


def decompress(q_tree: Params, qp_tree: Params) -> Params:
    return jax.tree_util.tree_map(dequantize, q_tree, qp_tree,
                                  is_leaf=lambda x: isinstance(x, QuantParams))


def compress_with_feedback(grads: Params, error: Params, *, bits: int = 8
                           ) -> Tuple[Params, Params]:
    """Returns (decompressed grads as transmitted, new error state)."""
    corrected = jax.tree_util.tree_map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error)
    q, qp = compress(corrected, bits=bits)
    transmitted = decompress(q, qp)
    new_error = jax.tree_util.tree_map(lambda c, t: c - t, corrected,
                                       transmitted)
    return transmitted, new_error


def compressed_allreduce_bytes(params: Params, *, bits: int = 8
                               ) -> Tuple[int, int]:
    """(fp32 all-reduce bytes, compressed bytes) for the wire model."""
    n = sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(params))
    n_leaves = len(jax.tree_util.tree_leaves(params))
    return n * 4, n * bits // 8 + n_leaves * 8
