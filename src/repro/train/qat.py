"""Quantization-aware training: train with the paper's Eq.1/Eq.2 lattice in
the loop (fake-quant STE from repro.core.quant) so the INT8 edge engine
loses (almost) nothing at deployment.

Usage: wrap any model loss that threads ``qctx``:

    qat_loss = make_qat_loss(lambda p, b, qctx: my_loss(p, b, qctx=qctx))
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from repro.models.layers import QuantCtx

__all__ = ["make_qat_loss", "qat_ctx"]


def qat_ctx(*, w_bits: int = 8, a_bits: int = 8,
            per_channel: bool = True) -> QuantCtx:
    """Dynamic fake-quant context (jit-safe; thresholds from each batch,
    mirroring the paper's per-tensor activation quantization)."""
    return QuantCtx(mode="dynamic", w_bits=w_bits, a_bits=a_bits,
                    per_channel=per_channel)


def make_qat_loss(loss_with_qctx: Callable[..., Any], *, w_bits: int = 8,
                  a_bits: int = 8) -> Callable[..., Any]:
    ctx = qat_ctx(w_bits=w_bits, a_bits=a_bits)

    def loss(params, batch):
        return loss_with_qctx(params, batch, ctx)

    return loss
