"""Trainer: the generic training loop used by the examples and tests.

Features: jitted step (AdamW + cosine LR + clipping), gradient
accumulation over microbatches (lax.scan), optional QAT (fake-quant in
the loss), optional int8 error-feedback gradient compression, periodic
checkpointing, metric history.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.distributed.checkpoint import CheckpointManager, latest_step, \
    restore_checkpoint
from repro.train.grad_compress import (compress_with_feedback,
                                       init_error_feedback)
from repro.train.optim import (AdamWConfig, AdamWState, adamw_init,
                               adamw_update, cosine_schedule)

Params = Any


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    lr: float = 3e-4
    warmup: int = 10
    grad_accum: int = 1
    grad_compress: bool = False
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, loss_fn: Callable[[Params, Dict], jax.Array],
                 params: Params, cfg: TrainerConfig):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.params = params
        self.opt = adamw_init(params)
        self.error = init_error_feedback(params) if cfg.grad_compress else None
        self.schedule = cosine_schedule(cfg.lr, cfg.warmup, cfg.n_steps)
        self.history: List[Dict] = []
        self._step_jit = jax.jit(self._step)
        self._mgr = (CheckpointManager(cfg.ckpt_dir, every=cfg.ckpt_every)
                     if cfg.ckpt_dir else None)

    # -- one optimizer step (possibly accumulating microbatches) ----------
    def _step(self, params, opt, error, batch):
        cfg = self.cfg

        if cfg.grad_accum > 1:
            def micro(c, mb):
                loss, g = jax.value_and_grad(self.loss_fn)(params, mb)
                acc_loss, acc_g = c
                return (acc_loss + loss,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.float32(0.0), zeros), batch)
            inv = 1.0 / cfg.grad_accum
            loss = loss * inv
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        else:
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)

        if error is not None:
            grads, error = compress_with_feedback(grads, error)

        lr = self.schedule(opt.step)
        params, opt, gnorm = adamw_update(grads, opt, params, cfg.adamw,
                                          lr=lr)
        return params, opt, error, {"loss": loss, "grad_norm": gnorm,
                                    "lr": lr}

    def maybe_restore(self) -> int:
        if self._mgr is None or latest_step(self.cfg.ckpt_dir) is None:
            return 0
        state = {"params": self.params, "opt": self.opt}
        state, step, _ = restore_checkpoint(self.cfg.ckpt_dir, state)
        self.params, self.opt = state["params"], state["opt"]
        return step

    def fit(self, data: Iterator[Dict], *, start_step: int = 0) -> List[Dict]:
        cfg = self.cfg
        step = start_step
        for batch in data:
            if step >= cfg.n_steps:
                break
            batch = jax.tree_util.tree_map(jnp.asarray, batch)
            t0 = time.perf_counter()
            self.params, self.opt, self.error, metrics = self._step_jit(
                self.params, self.opt, self.error, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_time_s"] = time.perf_counter() - t0
            step += 1
            metrics["step"] = step
            self.history.append(metrics)
            if self._mgr is not None:
                self._mgr.maybe_save(step, {"params": self.params,
                                            "opt": self.opt})
            if cfg.log_every and step % cfg.log_every == 0:
                print(f"step {step:5d}  loss {metrics['loss']:.4f}  "
                      f"gnorm {metrics['grad_norm']:.3f}  "
                      f"lr {metrics['lr']:.2e}  "
                      f"{metrics['step_time_s'] * 1e3:.0f} ms", flush=True)
        if self._mgr is not None:
            self._mgr.wait()
        return self.history
