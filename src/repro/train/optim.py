"""AdamW + schedules, pure JAX (no optax dependency).

Optimizer moments are fp32 regardless of param dtype (bf16 params keep
fp32 m/v — the standard mixed-precision recipe).  The m/v trees share the
params' sharding, so optimizer state is ZeRO-sharded wherever params are.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array            # i32 scalar
    m: Params                  # fp32, like params
    v: Params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def _maybe_layer_mapped(upd):
    """Apply a per-leaf update via lax.map over the stacked-layer axis for
    big rank>=3 leaves: bounds the f32 transients (dequantized moments,
    deltas) to one layer's worth instead of the whole stack."""
    def wrapped(*leaves):
        p = leaves[0]
        # measured on the dry-run: XLA CPU's buffer assignment for the
        # mapped form STACKS per-layer outputs (peak grew 25->35 GiB), so
        # the map path is disabled; elementwise chains fuse well enough.
        if False and p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda t: upd(*t), leaves)
        return upd(*leaves)
    return wrapped


def adamw_update(grads: Params, state: AdamWState, params: Params,
                 cfg: AdamWConfig, lr: Optional[jax.Array] = None
                 ) -> Tuple[Params, AdamWState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    upd = _maybe_layer_mapped(upd)
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm


# ---------------------------------------------------------------------------
# 8-bit AdamW (blockwise-quantized moments, Dettmers et al. 2021) — the
# paper's Eq.1/Eq.2 scalar quantization applied to optimizer state.  Cuts
# m+v from 8 bytes/param to ~2.06, which is what lets a 314B-param model
# train on a 256-chip 16 GB/v5e pod (see EXPERIMENTS.md §Dry-run).
# ---------------------------------------------------------------------------

_QBLOCK = 128


def _blockwise_quantize(x: jax.Array, *, signed: bool
                        ) -> Tuple[jax.Array, jax.Array]:
    """int8 quantization with one scale per 128-entry block of the last
    axis.  Scales keep the tensor's rank (shape[:-1] + [nblk]) so the
    parameter sharding rules apply unchanged."""
    if x.ndim == 0 or x.shape[-1] % _QBLOCK != 0:
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-20
        return jnp.round(x / scale).astype(jnp.int8), scale.reshape(())
    blocks = x.reshape(*x.shape[:-1], x.shape[-1] // _QBLOCK, _QBLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = amax / 127.0 + 1e-20
    q = jnp.round(blocks / scale).astype(jnp.int8)
    return q.reshape(x.shape), scale[..., 0]


def _blockwise_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    if scale.ndim == 0:
        return q.astype(jnp.float32) * scale
    blocks = q.reshape(*q.shape[:-1], q.shape[-1] // _QBLOCK, _QBLOCK)
    out = blocks.astype(jnp.float32) * scale[..., None]
    return out.reshape(q.shape)


class AdamW8bitState(NamedTuple):
    step: jax.Array
    m_q: Params                 # int8
    m_scale: Params             # f32 per-block
    v_q: Params
    v_scale: Params


def adamw8bit_init(params: Params) -> AdamW8bitState:
    def zq(p):
        return _blockwise_quantize(jnp.zeros(p.shape, jnp.float32),
                                   signed=True)
    flat, tdef = jax.tree_util.tree_flatten(params)
    pairs = [zq(p) for p in flat]
    unflat = lambda i: jax.tree_util.tree_unflatten(tdef,
                                                    [x[i] for x in pairs])
    return AdamW8bitState(step=jnp.zeros((), jnp.int32),
                          m_q=unflat(0), m_scale=unflat(1),
                          v_q=unflat(0), v_scale=unflat(1))


def adamw8bit_update(grads: Params, state: AdamW8bitState, params: Params,
                     cfg: AdamWConfig, lr: Optional[jax.Array] = None
                     ) -> Tuple[Params, AdamW8bitState, jax.Array]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mq, ms, vq, vs):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * _blockwise_dequantize(mq, ms) + (1 - cfg.b1) * g32
        v = cfg.b2 * _blockwise_dequantize(vq, vs) \
            + (1 - cfg.b2) * jnp.square(g32)
        delta = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        nmq, nms = _blockwise_quantize(m, signed=True)
        nvq, nvs = _blockwise_quantize(v, signed=False)
        return new_p, nmq, nms, nvq, nvs

    upd = _maybe_layer_mapped(upd)
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    zipped = [upd(p, g, mq, ms, vq, vs) for p, g, mq, ms, vq, vs in zip(
        flat_p, jax.tree_util.tree_leaves(grads),
        jax.tree_util.tree_leaves(state.m_q),
        jax.tree_util.tree_leaves(state.m_scale),
        jax.tree_util.tree_leaves(state.v_q),
        jax.tree_util.tree_leaves(state.v_scale))]
    unflat = lambda i: jax.tree_util.tree_unflatten(tdef,
                                                    [z[i] for z in zipped])
    return unflat(0), AdamW8bitState(step=step, m_q=unflat(1),
                                     m_scale=unflat(2), v_q=unflat(3),
                                     v_scale=unflat(4)), gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr
