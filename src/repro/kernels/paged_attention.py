"""Pallas TPU kernel: paged flash-decode attention over an INT8 block-table
KV cache.

Serving-side counterpart of ``int8_matmul``: where that kernel keeps the
paper's edge GEMMs at 1 B/elem, this one keeps the *KV cache* at 1 B/elem
end-to-end.  The cache is a pool of fixed-size pages
``[n_pages, page_size, n_kv, head_dim]`` (int8, or fp for the unquantized
variant); each sequence owns a row of a block table mapping its logical
page index to a physical page, so HBM is allocated on demand instead of
``max_len`` up front.

One decode step = one grid cell per (batch row, kv head, logical page):

  grid = (B, n_kv, pages_per_seq), pages innermost ("arbitrary" — the
  online-softmax state m/l/acc lives in VMEM scratch across the page axis)

The block table and per-row lengths ride in scalar-prefetch SMEM so the
K/V BlockSpec index maps can redirect the page DMA:

  index_map = lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)

INT8 K/V are dequantized *inside* the QK/AV loops — per-(layer, kv-head)
symmetric scales (optionally calibrated per slot, so shaped [B, n_kv])
sit in SMEM and multiply the page tile right after load, so the MXU sees
f32 while HBM only ever streams 1 B/elem.  GQA runs grouped: the q heads
sharing a kv head form the sublane dim of the score tile.

Off-TPU there are two fallbacks, mirroring ``ops.int8_matmul``:
``interpret=True`` runs the very same kernel through the Pallas
interpreter (used by the parity tests), while the serving engines default
to ``paged_attention_ref`` — an XLA implementation of identical math that
is fast enough to benchmark on CPU.  ``paged_attention`` dispatches.

VMEM residency per grid cell (defaults, page_size=64, hd=128, group=8):
  K page  int8 [page_size, hd]   8 KiB      m, l  f32 [group, 1]
  V page  int8 [page_size, hd]   8 KiB      acc   f32 [group, hd] 4 KiB
all « 16 MiB; on real TPU prefer page_size a multiple of 32 (int8
sublane) and group padded to 8 — the interpret/ref paths accept any size.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import compiler_params

__all__ = ["paged_attention", "paged_flash_decode", "paged_attention_ref"]

# finite stand-in for -inf: (-1e30) - (-1e30) = 0 keeps exp() NaN-free on
# fully-masked pages, where true -inf would poison the running max
_MASKED = -1e30

# module default for `paged_attention(impl=None)`; tests may override to
# "pallas_interpret" to drive the real kernel through the model stack
_DEFAULT_IMPL = "auto"


def _kernel(bt_ref, len_ref,            # scalar-prefetch: block table, lens
            q_ref, k_ref, v_ref,        # [1,1,G,hd], [1,P,1,hd], [1,P,1,hd]
            ks_ref, vs_ref,             # (1,1) SMEM per-(row, kv-head) scale
            o_ref,                      # [1,1,G,hd]
            m_ref, l_ref, acc_ref,      # scratch: online-softmax state
            *, page_size: int, sm_scale: float):
    b, h, p = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _MASKED)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dequant on load: HBM streamed the page at 1 B/elem; the scale is a
    # scalar broadcast fused into the VPU convert
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, 0]   # [P, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, 0]
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale             # [G, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, P]
    pos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = pos < len_ref[b]                                     # [1, P]
    s = jnp.where(valid, s, _MASKED)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # explicit re-mask: on an all-masked page exp(s - m) would be exp(0)
    w = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(w, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        w, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _norm_scales(scale: Optional[jax.Array], batch: int,
                 n_kv: int) -> jax.Array:
    """Broadcast per-cache scales to the kernel's [B, n_kv] layout.

    Accepts None (fp pages: identity), [n_kv] (per-(layer, head) deploy
    calibration) or [B, n_kv] (per-slot calibration at prefill)."""
    if scale is None:
        return jnp.ones((batch, n_kv), jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    if scale.ndim == 1:
        scale = jnp.broadcast_to(scale[None], (batch, n_kv))
    return scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode(
    q: jax.Array,                  # [B, n_heads, hd]
    k_pages: jax.Array,            # [n_pages, page_size, n_kv, hd] int8|fp
    v_pages: jax.Array,
    block_tables: jax.Array,       # [B, pages_per_seq] int32
    lengths: jax.Array,            # [B] int32, # of valid KV entries
    k_scale: Optional[jax.Array] = None,   # [n_kv] or [B, n_kv]
    v_scale: Optional[jax.Array] = None,
    *,
    interpret: bool = False,
) -> jax.Array:
    """One flash-decode step over the paged cache → [B, n_heads, hd]."""
    b, n_heads, hd = q.shape
    _, page_size, n_kv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    group = n_heads // n_kv
    assert group * n_kv == n_heads, (n_heads, n_kv)

    qg = q.reshape(b, n_kv, group, hd)
    ks = _norm_scales(k_scale, b, n_kv)
    vs = _norm_scales(v_scale, b, n_kv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_kv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd),
                         lambda b_, h, p, bt, ln: (b_, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b_, h, p, bt, ln: (bt[b_, p], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b_, h, p, bt, ln: (bt[b_, p], 0, h, 0)),
            pl.BlockSpec((1, 1), lambda b_, h, p, bt, ln: (b_, h),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b_, h, p, bt, ln: (b_, h),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda b_, h, p, bt, ln: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),      # running max
            pltpu.VMEM((group, 1), jnp.float32),      # running denominator
            pltpu.VMEM((group, hd), jnp.float32),     # un-normalized out
        ],
    )
    kernel = functools.partial(_kernel, page_size=page_size,
                               sm_scale=1.0 / math.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, qg, k_pages, v_pages, ks, vs)
    return out.reshape(b, n_heads, hd)


def paged_attention_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Pure-XLA oracle for the kernel — same math, gather-based.

    Also the production path off-TPU: it touches only the pages named in
    the block table (HBM/DRAM traffic ∝ allocated pages, not max_len),
    so the engines' CPU benchmarks measure the same asymptotics the TPU
    kernel delivers."""
    b, n_heads, hd = q.shape
    _, page_size, n_kv, _ = k_pages.shape
    group = n_heads // n_kv
    span = block_tables.shape[1] * page_size

    k = k_pages[block_tables].reshape(b, span, n_kv, hd).astype(jnp.float32)
    v = v_pages[block_tables].reshape(b, span, n_kv, hd).astype(jnp.float32)
    ks = _norm_scales(k_scale, b, n_kv)
    vs = _norm_scales(v_scale, b, n_kv)
    k = k * ks[:, None, :, None]
    v = v * vs[:, None, :, None]

    qg = q.reshape(b, n_kv, group, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bhgd,blhd->bhgl", qg, k)
    mask = jnp.arange(span)[None, None, None, :] \
        < lengths[:, None, None, None]
    s = jnp.where(mask, s, _MASKED)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v)
    return out.reshape(b, n_heads, hd).astype(q.dtype)


def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    *,
    impl: Optional[str] = None,
) -> jax.Array:
    """Dispatching front door: Pallas kernel on TPU, XLA ref elsewhere.

    ``impl``: "auto" (default), "pallas", "pallas_interpret", or "ref".
    """
    impl = impl or _DEFAULT_IMPL
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   lengths, k_scale, v_scale)
    return paged_flash_decode(q, k_pages, v_pages, block_tables, lengths,
                              k_scale, v_scale,
                              interpret=(impl == "pallas_interpret"))
