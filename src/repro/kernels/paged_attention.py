"""Pallas TPU kernel: paged flash attention over an INT8 block-table
KV cache — one query row (decode) or a small q-block (speculative
verify, multi-token prefill).

Serving-side counterpart of ``int8_matmul``: where that kernel keeps the
paper's edge GEMMs at 1 B/elem, this one keeps the *KV cache* at 1 B/elem
end-to-end.  The cache is a pool of fixed-size pages
``[n_pages, page_size, n_kv, head_dim]`` (int8, or fp for the unquantized
variant); each sequence owns a row of a block table mapping its logical
page index to a physical page, so HBM is allocated on demand instead of
``max_len`` up front.

One attention call = one grid cell per (batch row, kv head, logical page):

  grid = (B, n_kv, pages_per_seq), pages innermost ("arbitrary" — the
  online-softmax state m/l/acc lives in VMEM scratch across the page axis)

The block table, per-row KV lengths, and per-row *query start positions*
ride in scalar-prefetch SMEM so the K/V BlockSpec index maps can redirect
the page DMA:

  index_map = lambda b, h, p, bt, ln, qs: (bt[b, p], 0, h, 0)

The q tile carries all S query rows of the block (S=1 for plain decode):
query i of row b sits at absolute position ``q_start[b] + i`` and may
attend KV positions ``<= q_start[b] + i`` that are also ``< lengths[b]``
— the *intra-block causal mask* that makes the same kernel serve

* **decode** (S=1, ``q_start = lengths - 1``): the PR-2 behavior, bit
  for bit;
* **speculative verify** (S=k drafts written at ``q_start = committed
  length``): k queries attend cache + the in-flight draft block, and a
  rejected suffix is "rolled back" simply by never advancing the
  committed length past it — stale page entries are masked out by
  causality on every later read;
* **paged multi-token prefill** (S=prompt bucket, ``q_start = 0``):
  prompts attend their just-written pages directly, so prefill and
  decode share one read path (and one set of INT8 scales).

INT8 K/V are dequantized *inside* the QK/AV loops — per-(layer, kv-head)
symmetric scales (optionally calibrated per slot, so shaped [B, n_kv])
sit in SMEM and multiply the page tile right after load, so the MXU sees
f32 while HBM only ever streams 1 B/elem.  GQA runs grouped: the q heads
sharing a kv head form the sublane dim of the score tile, and a q-block
of S tokens stacks to an (S·group, hd) tile.

Off-TPU there are two fallbacks, mirroring ``ops.int8_matmul``:
``interpret=True`` runs the very same kernel through the Pallas
interpreter (used by the parity tests), while the serving engines default
to ``paged_attention_ref``/``paged_attention_mq_ref`` — XLA
implementations of identical math that are fast enough to benchmark on
CPU.  ``paged_attention`` / ``paged_multiquery_attention`` dispatch.

VMEM residency per grid cell (defaults, page_size=64, hd=128, group=8,
S=8):
  K page  int8 [page_size, hd]   8 KiB      m, l  f32 [S·group, 1]
  V page  int8 [page_size, hd]   8 KiB      acc   f32 [S·group, hd] 32 KiB
all « 16 MiB; on real TPU prefer page_size a multiple of 32 (int8
sublane) and S·group padded to 8 — the interpret/ref paths accept any
size.

Tensor-parallel: ``paged_flash_mq_sharded``/``paged_flash_decode_sharded``
partition the pool, scales, and query heads by kv head over a mesh's
``model`` axis via ``shard_map`` — each shard streams only its own KV
slice and no collective is needed (attention is per-head independent;
GQA groups never straddle shards because the guard requires
``n_kv % tp == 0``).  ``set_tp_mesh`` installs the mesh the dispatchers
route through on the pallas path.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import compiler_params

__all__ = ["paged_attention", "paged_multiquery_attention",
           "paged_flash_decode", "paged_flash_mq",
           "paged_flash_decode_sharded", "paged_flash_mq_sharded",
           "paged_attention_ref", "paged_attention_mq_ref",
           "set_tp_mesh"]

# finite stand-in for -inf: (-1e30) - (-1e30) = 0 keeps exp() NaN-free on
# fully-masked pages, where true -inf would poison the running max
_MASKED = -1e30

# module default for `paged_attention(impl=None)`; tests may override to
# "pallas_interpret" to drive the real kernel through the model stack
_DEFAULT_IMPL = "auto"


def _kernel(bt_ref, len_ref, qs_ref,    # scalar-prefetch: table, lens, q0
            q_ref, k_ref, v_ref,        # [1,1,S·G,hd], [1,P,1,hd], [1,P,1,hd]
            ks_ref, vs_ref,             # (1,1) SMEM per-(row, kv-head) scale
            o_ref,                      # [1,1,S·G,hd]
            m_ref, l_ref, acc_ref,      # scratch: online-softmax state
            *, page_size: int, group: int, sm_scale: float):
    b, h, p = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _MASKED)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dequant on load: HBM streamed the page at 1 B/elem; the scale is a
    # scalar broadcast fused into the VPU convert
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, 0]   # [P, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, 0]
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale             # [S·G, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [S·G, P]
    sg = q.shape[0]
    pos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (sg, page_size), 1)
    # row r of the tile is query token r // group at absolute position
    # q_start + r // group: intra-block causality + the KV length bound
    qpos = qs_ref[b] + jax.lax.broadcasted_iota(
        jnp.int32, (sg, page_size), 0) // group
    valid = jnp.logical_and(pos <= qpos, pos < len_ref[b])      # [S·G, P]
    s = jnp.where(valid, s, _MASKED)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # explicit re-mask: on an all-masked page exp(s - m) would be exp(0)
    w = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(w, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        w, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _norm_scales(scale: Optional[jax.Array], batch: int,
                 n_kv: int) -> jax.Array:
    """Broadcast per-cache scales to the kernel's [B, n_kv] layout.

    Accepts None (fp pages: identity), [n_kv] (per-(layer, head) deploy
    calibration) or [B, n_kv] (per-slot calibration at prefill)."""
    if scale is None:
        return jnp.ones((batch, n_kv), jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    if scale.ndim == 1:
        scale = jnp.broadcast_to(scale[None], (batch, n_kv))
    return scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_mq(
    q: jax.Array,                  # [B, S, n_heads, hd]
    k_pages: jax.Array,            # [n_pages, page_size, n_kv, hd] int8|fp
    v_pages: jax.Array,
    block_tables: jax.Array,       # [B, pages_per_seq] int32
    lengths: jax.Array,            # [B] int32, # of valid KV entries
    q_start: jax.Array,            # [B] int32, abs position of query row 0
    k_scale: Optional[jax.Array] = None,   # [n_kv] or [B, n_kv]
    v_scale: Optional[jax.Array] = None,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention of an S-query block over the paged cache →
    [B, S, n_heads, hd] (query i attends positions <= q_start + i)."""
    b, s, n_heads, hd = q.shape
    _, page_size, n_kv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    group = n_heads // n_kv
    assert group * n_kv == n_heads, (n_heads, n_kv)

    # [B, n_kv, S·group, hd]: the q heads sharing a kv head — for every
    # query token of the block — form the sublane dim of one tile
    qg = q.reshape(b, s, n_kv, group, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(b, n_kv, s * group, hd)
    ks = _norm_scales(k_scale, b, n_kv)
    vs = _norm_scales(v_scale, b, n_kv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_kv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, s * group, hd),
                         lambda b_, h, p, bt, ln, qs: (b_, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b_, h, p, bt, ln, qs: (bt[b_, p], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b_, h, p, bt, ln, qs: (bt[b_, p], 0, h, 0)),
            pl.BlockSpec((1, 1), lambda b_, h, p, bt, ln, qs: (b_, h),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b_, h, p, bt, ln, qs: (b_, h),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, s * group, hd),
                               lambda b_, h, p, bt, ln, qs: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((s * group, 1), jnp.float32),    # running max
            pltpu.VMEM((s * group, 1), jnp.float32),    # running denominator
            pltpu.VMEM((s * group, hd), jnp.float32),   # un-normalized out
        ],
    )
    kernel = functools.partial(_kernel, page_size=page_size, group=group,
                               sm_scale=1.0 / math.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, q_start.astype(jnp.int32), qg, k_pages,
      v_pages, ks, vs)
    return out.reshape(b, n_kv, s, group, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(b, s, n_heads, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode(
    q: jax.Array,                  # [B, n_heads, hd]
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    *,
    interpret: bool = False,
) -> jax.Array:
    """One flash-decode step over the paged cache → [B, n_heads, hd]
    (the S=1 case of ``paged_flash_mq``: the single query sits at the
    last valid position, so the causal mask degenerates to the length
    bound and PR-2 semantics are preserved exactly)."""
    out = paged_flash_mq(q[:, None], k_pages, v_pages, block_tables,
                         lengths, lengths - 1, k_scale, v_scale,
                         interpret=interpret)
    return out[:, 0]


def paged_attention_mq_ref(
    q: jax.Array,                  # [B, S, n_heads, hd]
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    q_start: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Pure-XLA oracle for the q-block kernel — same math, gather-based.

    Also the production path off-TPU: it touches only the pages named in
    the block table (HBM/DRAM traffic ∝ allocated pages, not max_len),
    so the engines' CPU benchmarks measure the same asymptotics the TPU
    kernel delivers."""
    b, s, n_heads, hd = q.shape
    _, page_size, n_kv, _ = k_pages.shape
    group = n_heads // n_kv
    span = block_tables.shape[1] * page_size

    k = k_pages[block_tables].reshape(b, span, n_kv, hd).astype(jnp.float32)
    v = v_pages[block_tables].reshape(b, span, n_kv, hd).astype(jnp.float32)
    ks = _norm_scales(k_scale, b, n_kv)
    vs = _norm_scales(v_scale, b, n_kv)
    k = k * ks[:, None, :, None]
    v = v * vs[:, None, :, None]

    qg = q.reshape(b, s, n_kv, group, hd).astype(jnp.float32) / math.sqrt(hd)
    logits = jnp.einsum("bsngd,blnd->bnsgl", qg, k)
    pos = jnp.arange(span)
    qpos = q_start[:, None] + jnp.arange(s)[None, :]            # [B, S]
    mask = jnp.logical_and(
        pos[None, None, :] <= qpos[:, :, None],
        pos[None, None, :] < lengths[:, None, None])            # [B, S, L]
    logits = jnp.where(mask[:, None, :, None, :], logits, _MASKED)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnsgl,blnd->bsngd", p, v)
    return out.reshape(b, s, n_heads, hd).astype(q.dtype)


def paged_attention_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """S=1 oracle (decode): the query sits at the last valid position."""
    out = paged_attention_mq_ref(q[:, None], k_pages, v_pages, block_tables,
                                 lengths, lengths - 1, k_scale, v_scale)
    return out[:, 0]


def paged_flash_mq_sharded(
    q: jax.Array,                  # [B, S, n_heads, hd]
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    q_start: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    *,
    mesh: jax.sharding.Mesh,
    interpret: bool = False,
) -> jax.Array:
    """Tensor-parallel ``paged_flash_mq`` via ``shard_map``: the page
    pool, scales, and query heads partition by kv head over the mesh's
    ``model`` axis, so each shard DMAs and dequantizes ONLY its own
    1 B/elem KV slice — the whole point of TP-ing the pool: per-device
    KV bandwidth drops by the TP degree.  Batch rides the ``data`` axis
    when it divides.  No inter-shard collective is needed at all —
    attention is independent per kv head, and GQA grouping survives the
    split exactly because ``n_kv % tp == 0`` keeps each kv head's q
    group on its shard.  Falls back to the unsharded kernel when the
    head dim doesn't divide (guard mirrors ``launch.shardings``)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, n_heads, hd = q.shape
    n_kv = k_pages.shape[2]
    tp = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
    if tp == 1 or n_kv % tp != 0:
        return paged_flash_mq(q, k_pages, v_pages, block_tables, lengths,
                              q_start, k_scale, v_scale, interpret=interpret)
    # normalize to [B, n_kv] OUTSIDE the map so scales partition by head
    ks = _norm_scales(k_scale, b, n_kv)
    vs = _norm_scales(v_scale, b, n_kv)
    b_ax = None
    if "data" in mesh.axis_names and b % int(mesh.shape["data"]) == 0:
        b_ax = "data"

    fn = shard_map(
        functools.partial(paged_flash_mq, interpret=interpret),
        mesh=mesh,
        in_specs=(P(b_ax, None, "model", None),        # q (heads split)
                  P(None, None, "model", None),        # k_pages (kv split)
                  P(None, None, "model", None),        # v_pages
                  P(b_ax, None),                       # block tables
                  P(b_ax), P(b_ax),                    # lengths, q_start
                  P(b_ax, "model"), P(b_ax, "model")),  # scales
        out_specs=P(b_ax, None, "model", None),
        check_rep=False,
    )
    return fn(q, k_pages, v_pages, block_tables,
              lengths, q_start.astype(jnp.int32), ks, vs)


def paged_flash_decode_sharded(
    q: jax.Array,                  # [B, n_heads, hd]
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    *,
    mesh: jax.sharding.Mesh,
    interpret: bool = False,
) -> jax.Array:
    """Tensor-parallel decode step (S=1 case of the sharded q-block)."""
    out = paged_flash_mq_sharded(q[:, None], k_pages, v_pages, block_tables,
                                 lengths, lengths - 1, k_scale, v_scale,
                                 mesh=mesh, interpret=interpret)
    return out[:, 0]


# Deployment hook: a TPU pod sets the serving mesh once and the
# dispatchers below route every pallas-path call through shard_map.  The
# engines deliberately DON'T set this (their CPU ref path shards via
# GSPMD on the jit boundary instead) — a module global would leak TP
# into same-process unsharded oracle engines.
_TP_MESH: Optional[jax.sharding.Mesh] = None


def set_tp_mesh(mesh: Optional[jax.sharding.Mesh]) -> None:
    """Install (or clear, with None) the mesh the pallas-path
    dispatchers shard over."""
    global _TP_MESH
    _TP_MESH = mesh


def _resolve_impl(impl: Optional[str]) -> str:
    impl = impl or _DEFAULT_IMPL
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    *,
    impl: Optional[str] = None,
) -> jax.Array:
    """Dispatching front door (decode, q [B, n_heads, hd]): Pallas
    kernel on TPU, XLA ref elsewhere.

    ``impl``: "auto" (default), "pallas", "pallas_interpret", or "ref".
    """
    impl = _resolve_impl(impl)
    if impl == "ref":
        return paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   lengths, k_scale, v_scale)
    if _TP_MESH is not None:
        return paged_flash_decode_sharded(
            q, k_pages, v_pages, block_tables, lengths, k_scale, v_scale,
            mesh=_TP_MESH, interpret=(impl == "pallas_interpret"))
    return paged_flash_decode(q, k_pages, v_pages, block_tables, lengths,
                              k_scale, v_scale,
                              interpret=(impl == "pallas_interpret"))


def paged_multiquery_attention(
    q: jax.Array,                  # [B, S, n_heads, hd]
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    q_start: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    *,
    impl: Optional[str] = None,
) -> jax.Array:
    """Dispatching front door for an S-query block (speculative verify,
    paged multi-token prefill): same dispatch rules as
    ``paged_attention``."""
    impl = _resolve_impl(impl)
    if impl == "ref":
        return paged_attention_mq_ref(q, k_pages, v_pages, block_tables,
                                      lengths, q_start, k_scale, v_scale)
    if _TP_MESH is not None:
        return paged_flash_mq_sharded(
            q, k_pages, v_pages, block_tables, lengths, q_start,
            k_scale, v_scale, mesh=_TP_MESH,
            interpret=(impl == "pallas_interpret"))
    return paged_flash_mq(q, k_pages, v_pages, block_tables, lengths,
                          q_start, k_scale, v_scale,
                          interpret=(impl == "pallas_interpret"))
