"""Version shim shared by the Pallas kernels: the TPU compiler-params
class is ``pltpu.TPUCompilerParams`` on jax<=0.4.x and
``pltpu.CompilerParams`` afterwards."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params(**kwargs):
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
