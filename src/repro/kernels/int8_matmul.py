"""Pallas TPU kernel: fused INT8 matmul with dequant→act→requant epilogue.

TPU adaptation of the paper's gemmlowp edge GEMM (§2.1, "On-device
Computation" steps 1-4). The MXU consumes int8 operand tiles natively with
int32 accumulation; instead of the paper's four separate passes
(int GEMM → Eq.2 dequantize → activation → Eq.1 requantize), everything
after the GEMM runs as a *fused epilogue* on the final K-step, so the
int32 accumulator never round-trips through HBM.

Tiling: grid = (M/bm, N/bn, K/bk), K innermost. Per-block VMEM residency:
  A-tile   int8  [bm, bk]
  B-tile   int8  [bk, bn]
  acc      int32 [bm, bn]  (scratch, lives across the K axis)
  rowsum_a int32 [bm, 1]   (scratch — zero-point correction term)
  colsum_b int32 [1,  bn]  (scratch)
Default (bm, bn, bk) = (256, 256, 256) →
  64 KiB + 64 KiB + 256 KiB + ~1 KiB ≈ 0.4 MiB « 16 MiB VMEM,
with all matmul dims multiples of 128 to keep the 128×128 systolic array
fully occupied (int8 packs 32×128 sublane tiles).

The asymmetric (paper Eq.1 has independent T_min/T_max for inputs AND
weights) correction is exact:

  real = sa·sb·(acc − za·colsum(Bq) − zb·rowsum(Aq) + za·zb·K)

with per-channel weight scale/zero-point supported as (1, bn) vectors.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import compiler_params

_ACTS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _kernel(
    # refs, in BlockSpec order
    a_ref, b_ref,              # int8 tiles
    sa_ref, za_ref,            # (1,1) f32 activation scale / zero-point
    sb_ref, zb_ref,            # (1,bn) f32 weight scale / zero-point
    bias_ref,                  # (1,bn) f32
    so_ref, zo_ref,            # (1,1) f32 output requant params
    out_ref,                   # [bm,bn] int8 or f32
    acc_ref, rs_ref, cs_ref,   # scratch
    *,
    k_steps: int,
    true_k: int,
    act: Optional[str],
    requant: bool,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rs_ref[...] = jnp.zeros_like(rs_ref)
        cs_ref[...] = jnp.zeros_like(cs_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    rs_ref[...] += jnp.sum(a.astype(jnp.int32), axis=1, keepdims=True)
    cs_ref[...] += jnp.sum(b.astype(jnp.int32), axis=0, keepdims=True)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        sa = sa_ref[0, 0]
        za = za_ref[0, 0]
        sb = sb_ref[...]                       # (1, bn)
        zb = zb_ref[...]
        acc = acc_ref[...].astype(jnp.float32)
        rs = rs_ref[...].astype(jnp.float32)   # (bm, 1)
        cs = cs_ref[...].astype(jnp.float32)   # (1, bn)
        real = (sa * sb) * (acc - za * cs - zb * rs + za * zb * float(true_k))
        real = real + bias_ref[...]
        real = _ACTS[act](real)
        if requant:
            so = so_ref[0, 0]
            zo = zo_ref[0, 0]
            q = jnp.round(real / so + zo)
            info = jnp.iinfo(out_ref.dtype)
            out_ref[...] = jnp.clip(q, info.min, info.max).astype(out_ref.dtype)
        else:
            out_ref[...] = real.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block", "act", "requant", "true_k", "interpret"))
def int8_matmul_pallas(
    a_q: jax.Array,            # int8 [M, K]   (M, K multiples of block)
    b_q: jax.Array,            # int8 [K, N]
    sa: jax.Array, za: jax.Array,         # () f32
    sb: jax.Array, zb: jax.Array,         # (N,) f32
    bias: jax.Array,                      # (N,) f32
    so: jax.Array, zo: jax.Array,         # () f32
    *,
    true_k: int,
    block: tuple[int, int, int] = (256, 256, 256),
    act: Optional[str] = None,
    requant: bool = False,
    interpret: bool = False,
) -> jax.Array:
    m, k = a_q.shape
    _, n = b_q.shape
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, block)
    grid = (m // bm, n // bn, k // bk)

    sa2 = sa.reshape(1, 1).astype(jnp.float32)
    za2 = za.reshape(1, 1).astype(jnp.float32)
    sb2 = sb.reshape(1, n).astype(jnp.float32)
    zb2 = zb.reshape(1, n).astype(jnp.float32)
    bias2 = bias.reshape(1, n).astype(jnp.float32)
    so2 = so.reshape(1, 1).astype(jnp.float32)
    zo2 = zo.reshape(1, 1).astype(jnp.float32)

    out_dtype = jnp.int8 if requant else jnp.float32
    kernel = functools.partial(
        _kernel, k_steps=grid[2], true_k=true_k, act=act, requant=requant)

    scalar_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))
    colvec_spec = pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            scalar_spec, scalar_spec,       # sa, za
            colvec_spec, colvec_spec,       # sb, zb
            colvec_spec,                    # bias
            scalar_spec, scalar_spec,       # so, zo
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
            pltpu.VMEM((1, bn), jnp.int32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_q, b_q, sa2, za2, sb2, zb2, bias2, so2, zo2)
