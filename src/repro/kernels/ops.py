"""Public jit'd wrappers around the Pallas kernels.

``int8_matmul`` / ``quantized_dense`` handle arbitrary shapes by padding to
block multiples (zero int8 padding is exact for the asymmetric correction —
padded K entries contribute 0 to acc, rowsum and colsum, and the za·zb·K
term uses the *true* K), and fall back to ``interpret=True`` automatically
when not running on a real TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantParams, compute_qparams, quantize
from repro.kernels.int8_matmul import int8_matmul_pallas

__all__ = ["int8_matmul", "quantized_dense", "default_interpret"]


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """Pallas runs compiled on TPU, interpreted (Python/CPU) elsewhere."""
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _pick_block(m: int, n: int, k: int,
                want: tuple[int, int, int]) -> tuple[int, int, int]:
    """Shrink the default block to the problem size (small test shapes)."""
    def fit(dim, b):
        while b > dim and b > 8:
            b //= 2
        return max(b, 8)
    return fit(m, want[0]), fit(n, want[1]), fit(k, want[2])


def int8_matmul(
    a_q: jax.Array,
    b_q: jax.Array,
    qa: QuantParams,
    qb: QuantParams,
    *,
    bias: Optional[jax.Array] = None,
    act: Optional[str] = None,
    out_qp: Optional[QuantParams] = None,
    block: tuple[int, int, int] = (256, 256, 256),
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused quantized matmul: int8[M,K] @ int8[K,N] → f32 or int8 [M,N]."""
    if interpret is None:
        interpret = default_interpret()
    m, k = a_q.shape
    _, n = b_q.shape
    bm, bn, bk = _pick_block(m, n, k, block)
    a_p = _pad_to(a_q, (bm, bk))
    b_p = _pad_to(b_q, (bk, bn))
    n_pad = b_p.shape[1]

    sb = jnp.broadcast_to(jnp.atleast_1d(qb.scale), (n,))
    zb = jnp.broadcast_to(jnp.atleast_1d(qb.zero_point), (n,))
    sb = _pad_to(sb, (bn,))
    # pad zb/bias with zeros; padded cols are sliced off anyway
    zb = _pad_to(zb, (bn,))
    bias_v = jnp.zeros((n,), jnp.float32) if bias is None else bias
    bias_v = _pad_to(bias_v.astype(jnp.float32), (bn,))

    requant = out_qp is not None
    so = out_qp.scale if requant else jnp.float32(1.0)
    zo = out_qp.zero_point if requant else jnp.float32(0.0)

    out = int8_matmul_pallas(
        a_p, b_p,
        jnp.asarray(qa.scale), jnp.asarray(qa.zero_point),
        sb, zb, bias_v, jnp.asarray(so), jnp.asarray(zo),
        true_k=k, block=(bm, bn, bk), act=act, requant=requant,
        interpret=interpret)
    return out[:m, :n]


def quantized_dense(
    x: jax.Array,
    w_q: jax.Array,
    qx: QuantParams,
    qw: QuantParams,
    *,
    bias: Optional[jax.Array] = None,
    act: Optional[str] = None,
    out_qp: Optional[QuantParams] = None,
    block: tuple[int, int, int] = (256, 256, 256),
    interpret: Optional[bool] = None,
) -> jax.Array:
    """fp activations → Eq.1 quantize → fused int8 matmul → epilogue.

    This is one full "layer" of the paper's on-device computation.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q = quantize(x2, qx)
    out = int8_matmul(x_q, w_q, qx, qw, bias=bias, act=act, out_qp=out_qp,
                      block=block, interpret=interpret)
    return out.reshape(*lead, out.shape[-1])
