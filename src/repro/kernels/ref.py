"""Pure-jnp oracles for the quantized-compute kernels.

These implement the paper's "On-device Computation" (§2.1 steps 1-4)
exactly, with int32 accumulation and the full asymmetric zero-point
correction — the Pallas kernels must match these bit-for-bit on the
integer path and to float tolerance on the epilogue.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantParams

_ACTS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def int8_matmul_ref(
    a_q: jax.Array,                 # int8 [M, K]
    b_q: jax.Array,                 # int8 [K, N]
    qa: QuantParams,                # per-tensor activation qparams
    qb: QuantParams,                # per-tensor or per-channel(axis=1) weights
    *,
    bias: Optional[jax.Array] = None,   # f32 [N]
    act: Optional[str] = None,
    out_qp: Optional[QuantParams] = None,
) -> jax.Array:
    """Paper steps 1-4: integer matmul → Eq.2 dequant → act → Eq.1 requant.

    real(A)·real(B) = sa·sb·(A_q·B_q − za·colsum(B_q) − zb·rowsum(A_q)
                            + za·zb·K)
    """
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2
    acc = jax.lax.dot_general(
        a_q, b_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)              # int32 [M, N]
    rowsum_a = jnp.sum(a_q.astype(jnp.int32), axis=1, keepdims=True)  # [M,1]
    colsum_b = jnp.sum(b_q.astype(jnp.int32), axis=0, keepdims=True)  # [1,N]

    sa = qa.scale.reshape(1, 1)
    za = qa.zero_point.reshape(1, 1)
    sb = qb.scale.reshape(1, -1)      # broadcasts per-tensor or per-channel
    zb = qb.zero_point.reshape(1, -1)

    real = sa * sb * (acc.astype(jnp.float32)
                      - za * colsum_b.astype(jnp.float32)
                      - zb * rowsum_a.astype(jnp.float32)
                      + za * zb * float(k))
    if bias is not None:
        real = real + bias.reshape(1, -1)
    real = _ACTS[act](real)
    if out_qp is None:
        return real
    q = jnp.round(real / out_qp.scale + out_qp.zero_point)
    return jnp.clip(q, out_qp.qmin, out_qp.qmax).astype(out_qp.storage_dtype)


def quantized_dense_ref(
    x: jax.Array,                   # f32 [..., K]
    w_q: jax.Array,                 # int8 [K, N]
    qx: QuantParams,
    qw: QuantParams,
    *,
    bias: Optional[jax.Array] = None,
    act: Optional[str] = None,
    out_qp: Optional[QuantParams] = None,
) -> jax.Array:
    """fp input → quantize (Eq.1) → int8 matmul → epilogue."""
    from repro.core.quant import quantize
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q = quantize(x2, qx)
    out = int8_matmul_ref(x_q, w_q, qx, qw, bias=bias, act=act, out_qp=out_qp)
    return out.reshape(*lead, out.shape[-1])
