"""Deterministic synthetic data pipelines (shard-aware, prefetching).

No ImageNet on box — these generate statistically-plausible stand-ins
with a *learnable* signal (labels derive from the inputs) so training
loops demonstrably reduce loss.  Sharding: each data-parallel rank draws
a disjoint, deterministic slice keyed by (seed, rank, step) — elastic
restarts replay exactly.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["TokenPipeline", "ImagePipeline", "LatentPipeline", "Prefetcher"]


@dataclasses.dataclass
class TokenPipeline:
    """Synthetic LM corpus: order-2 Markov chain over the vocab, so there
    is real next-token structure to learn."""
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    rank: int = 0
    world: int = 1

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        self._trans = rng.dirichlet(np.ones(min(self.vocab, 64)) * 0.1,
                                    size=min(self.vocab, 64))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * self.world + self.rank)
            % (2 ** 31))
        v = self._trans.shape[0]
        toks = np.zeros((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.randint(0, v, self.batch)
        for t in range(1, self.seq_len + 1):
            p = self._trans[toks[:, t - 1] % v]
            c = (p.cumsum(-1) > rng.rand(self.batch)[:, None]).argmax(-1)
            toks[:, t] = c
        toks = toks % self.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class ImagePipeline:
    """Synthetic classification: class-conditional Gaussian blobs."""
    img_res: int
    batch: int
    n_classes: int = 10
    seed: int = 0
    rank: int = 0
    world: int = 1

    def __post_init__(self):
        rng = np.random.RandomState(self.seed + 7)
        self._proto = rng.randn(self.n_classes, 8, 8, 3).astype(np.float32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(
            (self.seed * 999_983 + step * self.world + self.rank) % (2 ** 31))
        labels = rng.randint(0, self.n_classes, self.batch).astype(np.int32)
        base = self._proto[labels]
        reps = self.img_res // 8 + 1
        img = np.tile(base, (1, reps, reps, 1))[:, :self.img_res,
                                                :self.img_res]
        img = img + 0.3 * rng.randn(*img.shape).astype(np.float32)
        return {"image": img.astype(np.float32), "label": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class LatentPipeline:
    """Synthetic diffusion latents + conditioning."""
    latent_res: int
    channels: int
    batch: int
    ctx_len: int = 77
    ctx_dim: int = 768
    seed: int = 0
    rank: int = 0
    world: int = 1

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(
            (self.seed * 424_243 + step * self.world + self.rank) % (2 ** 31))
        lat = rng.randn(self.batch, self.latent_res, self.latent_res,
                        self.channels).astype(np.float32)
        ctx = rng.randn(self.batch, self.ctx_len,
                        self.ctx_dim).astype(np.float32)
        return {"latent": lat, "ctx": ctx}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a pipeline iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def work():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
