"""Cell builder: one jittable (step_fn, abstract args, shardings) per
(architecture × input shape) — the unit the dry-run lowers and the
trainer/server execute.

Kinds per family:
  lm:        train (causal LM + AdamW) | prefill | decode (KV cache)
  diffusion: train (eps/RF matching + AdamW) | denoise (one sampler step)
  vision:    train (CE + AdamW) | infer (forward logits)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec, get_arch, input_specs
from repro.launch import shardings as SH
from repro.launch.mesh import batch_axes, mesh_context
from repro.models import mmdit as MM
from repro.models import resnet as RN
from repro.models import transformer as TF
from repro.models import unet as UN
from repro.models import vit as VT
from repro.train.optim import (AdamW8bitState, AdamWConfig, AdamWState,
                               adamw8bit_init, adamw8bit_update, adamw_init,
                               adamw_update)

__all__ = ["Cell", "build_cell"]


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    step_fn: Callable
    args: Tuple[Any, ...]               # abstract (ShapeDtypeStruct trees)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    mesh: Optional[Mesh] = None
    donate_argnums: Tuple[int, ...] = ()
    model_flops: float = 0.0            # 6·N·D (dense) / 6·N_active·D (MoE)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def jit(self):
        return jax.jit(self.step_fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        # trace under the ambient mesh so bare-PartitionSpec sharding
        # constraints and shard_map calls inside model code resolve
        with self.mesh, mesh_context(self.mesh):
            return self.jit().lower(*self.args)


def _abstract(fn) -> Any:
    return jax.eval_shape(fn)


def _metrics_sh(mesh: Mesh):
    return {"loss": SH.replicated(mesh), "grad_norm": SH.replicated(mesh)}


def _opt_shardings(mesh: Mesh, abstract_opt) -> Any:
    if isinstance(abstract_opt, AdamW8bitState):
        sh = lambda t: SH.param_shardings(t, mesh)
        return AdamW8bitState(step=SH.replicated(mesh),
                              m_q=sh(abstract_opt.m_q),
                              m_scale=sh(abstract_opt.m_scale),
                              v_q=sh(abstract_opt.v_q),
                              v_scale=sh(abstract_opt.v_scale))
    return AdamWState(step=SH.replicated(mesh),
                      m=SH.param_shardings(abstract_opt.m, mesh),
                      v=SH.param_shardings(abstract_opt.v, mesh))


def _train_cell(arch_id: str, sh: ShapeSpec, mesh: Mesh, *, init_fn,
                loss_fn, batch_specs: Dict[str, jax.ShapeDtypeStruct],
                batch_shardings: Dict[str, NamedSharding],
                model_flops: float, opt_cfg: AdamWConfig = AdamWConfig(),
                grad_accum: int = 1, unroll: bool = False,
                zero1: bool = False) -> Cell:
    a_params = _abstract(init_fn)
    n_params = sum(int(jnp.prod(jnp.array(l.shape)))
                   for l in jax.tree_util.tree_leaves(a_params))
    # fp32 AdamW moments cost 8 B/param; when params+grads+moments would
    # blow the 16 GB/chip budget, switch to 8-bit blockwise moments
    # (grok-314B on 256 chips is the motivating case).
    use_8bit = n_params * 12.0 / mesh.size > 14e9
    opt_init = adamw8bit_init if use_8bit else adamw_init
    opt_update = adamw8bit_update if use_8bit else adamw_update
    a_opt = _abstract(lambda: opt_init(a_params))
    p_sh = SH.param_shardings(a_params, mesh, zero1=zero1)
    o_sh = _opt_shardings(mesh, a_opt)   # moments stay fully sharded
    ba = batch_axes(mesh)
    ba_spec = ba if len(ba) > 1 else (ba[0] if ba else None)

    def step(params, opt, batch):
        if grad_accum > 1:
            # microbatch gradient accumulation: bounds per-step activation
            # memory to (global_batch/grad_accum); grads accumulate f32.
            def micro(carry, mb):
                # keep each microbatch batch-sharded over the DP axes
                mb = jax.tree_util.tree_map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, P(ba_spec, *([None] * (x.ndim - 1)))), mb)
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_l, acc_g = carry
                return (acc_l + loss,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), None

            stacked = jax.tree_util.tree_map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            # accumulate in param dtype (bf16 for the big configs: the
            # f32 buffer alone would cost 4 B/param of HBM)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, p.dtype), params)
            # probe mode unrolls so every microbatch is cost-counted
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.float32(0.0), zeros), stacked,
                unroll=grad_accum if unroll else 1)
            inv = 1.0 / grad_accum
            loss = loss * inv
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_p, new_o, gnorm = opt_update(grads, opt, params, opt_cfg)
        return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

    return Cell(
        arch_id=arch_id, shape_name=sh.name, kind="train", step_fn=step,
        args=(a_params, a_opt, batch_specs),
        in_shardings=(p_sh, o_sh, batch_shardings),
        out_shardings=(p_sh, o_sh, _metrics_sh(mesh)), mesh=mesh,
        donate_argnums=(0, 1), model_flops=model_flops)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_model_flops(cfg: TF.LMConfig, tokens: int, *, train: bool) -> float:
    n = cfg.active_param_count()
    return (6.0 if train else 2.0) * n * tokens


def _act_pspec(mesh: Mesh, cfg: TF.LMConfig) -> Optional[tuple]:
    """Residual-stream constraint (batch over DP axes, d_model over TP) —
    bounds the remat-carry stash to (B·S·D)/(dp·tp) per device."""
    ba = batch_axes(mesh)
    if cfg.d_model % mesh.shape["model"] != 0:
        return None
    return (ba if len(ba) > 1 else ba[0], None, "model")


def _moe_shard_spec(mesh: Mesh, cfg: TF.LMConfig, batch: int,
                    ) -> Optional[tuple]:
    """shard_map spec for the MoE block: (batch_spec, model_axis)."""
    if cfg.moe is None:
        return None
    ba = batch_axes(mesh)
    dp = 1
    for a in ba:
        dp *= mesh.shape[a]
    bspec = (ba if len(ba) > 1 else ba[0]) if (ba and batch % dp == 0) \
        else None
    return (bspec, "model")


def _lm_cell(arch_id: str, sh: ShapeSpec, mesh: Mesh, cfg: TF.LMConfig,
             specs, *, unroll: bool = False,
             variant: Optional[str] = None) -> Cell:
    b, s = sh.global_batch, sh.seq_len
    ba = batch_axes(mesh)
    uf = cfg.n_layers if unroll else 1
    moe_shard = _moe_shard_spec(mesh, cfg, b)
    zero1 = variant == "zero1"
    int8kv = variant is not None and "int8kv" in variant
    s_shard = variant is not None and "sseq" in variant

    if sh.kind == "train":
        tr_cfg = dataclasses.replace(cfg, scan_unroll=uf,
                                     act_pspec=_act_pspec(mesh, cfg),
                                     moe_shard=moe_shard)
        # microbatching: bound per-device live activations; each
        # microbatch must stay divisible by the DP axes
        dp = 1
        for a in ba:
            dp *= mesh.shape[a]
        # bigger models get more accumulation (smaller live microbatch)
        want = 8 if cfg.param_count() > 1e11 else 4
        accum = 1
        for cand in (want, want // 2, 2):
            if cand >= 2 and b % (dp * cand) == 0:
                accum = cand
                break

        def loss(params, batch):
            return TF.lm_loss(params, batch, tr_cfg)
        bs = {k: SH.data_sharding(mesh, 2, batch=b) for k in specs}
        return dataclasses.replace(_train_cell(
            arch_id, sh, mesh,
            init_fn=lambda: TF.init_lm(jax.random.PRNGKey(0), tr_cfg),
            loss_fn=loss, batch_specs=specs, batch_shardings=bs,
            model_flops=_lm_model_flops(cfg, b * s, train=True),
            grad_accum=accum, unroll=unroll, zero1=zero1), mesh=mesh)

    a_params = _abstract(lambda: TF.init_lm(jax.random.PRNGKey(0), cfg))
    p_sh = SH.param_shardings(a_params, mesh)
    c_sh = SH.cache_sharding(mesh, batch=b, seq=s, n_kv=cfg.n_kv,
                             head_dim=cfg.hd)
    if s_shard:            # flash-decoding layout: sequence over model
        ba_ax = ba if len(ba) > 1 else (ba[0] if ba else None)
        b_ax = ba_ax if b % mesh.shape[ba[0]] == 0 else None
        c_sh = NamedSharding(mesh, P(None, b_ax, "model", None, None))
    cache_sh = {"k": c_sh, "v": c_sh}
    if int8kv:
        cache_sh.update(k_scale=SH.replicated(mesh),
                        v_scale=SH.replicated(mesh))
    logit_sh = SH.logits_sharding(mesh, 2, batch=b, vocab=cfg.vocab)

    if sh.kind == "prefill":
        pf_cfg = dataclasses.replace(cfg, q_chunk=2048, remat=False,
                                     scan_unroll=uf,
                                     act_pspec=_act_pspec(mesh, cfg),
                                     moe_shard=moe_shard)

        def step(params, tokens):
            cache = TF.init_cache(pf_cfg, b, max_len=s)
            return TF.prefill(params, tokens, pf_cfg, cache=cache)

        return Cell(
            arch_id=arch_id, shape_name=sh.name, kind="prefill",
            step_fn=step, args=(a_params, specs["tokens"]),
            in_shardings=(p_sh, SH.data_sharding(mesh, 2, batch=b)),
            out_shardings=(logit_sh, cache_sh), mesh=mesh,
            model_flops=_lm_model_flops(cfg, b * s, train=False))

    # decode: one new token against a seq_len cache
    score_pspec = None
    if s_shard:
        bax = (ba if len(ba) > 1 else ba[0]) \
            if (ba and b % mesh.shape[ba[0]] == 0) else None
        score_pspec = (bax, None, None, "model")
    dec_cfg = dataclasses.replace(cfg, remat=False, scan_unroll=uf,
                                  moe_shard=moe_shard,
                                  score_pspec=score_pspec)
    a_cache = _abstract(lambda: TF.init_cache(dec_cfg, b, max_len=s,
                                              quantized=int8kv))

    def step(params, cache, token, cache_index):
        return TF.decode_step(params, token, cache, cache_index, dec_cfg)

    return Cell(
        arch_id=arch_id, shape_name=sh.name, kind="decode", step_fn=step,
        args=(a_params, a_cache, specs["token"], specs["cache_index"]),
        in_shardings=(p_sh, cache_sh, SH.data_sharding(mesh, 1, batch=b),
                      SH.replicated(mesh)),
        out_shardings=(logit_sh, cache_sh), mesh=mesh,
        donate_argnums=(1,),
        model_flops=_lm_model_flops(dec_cfg, b, train=False))


# ---------------------------------------------------------------------------
# Diffusion cells
# ---------------------------------------------------------------------------


def _diff_input_sharding(mesh: Mesh, spec: jax.ShapeDtypeStruct,
                         batch: int) -> NamedSharding:
    """Batch-shard when divisible; else spatial/token-shard dim 1 over data
    (XLA spatial partitioning handles conv halos)."""
    ba = batch_axes(mesh)
    size = 1
    for a in ba:
        size *= mesh.shape[a]
    nd = len(spec.shape)
    if ba and batch % size == 0:
        return SH.data_sharding(mesh, nd, batch=batch)
    spec_axes: list = [None] * nd
    if nd >= 2 and spec.shape[1] % mesh.shape["data"] == 0:
        spec_axes[1] = "data"
    return NamedSharding(mesh, P(*spec_axes))


def _unet_cell(arch_id: str, sh: ShapeSpec, mesh: Mesh, cfg: UN.UNetConfig,
               specs) -> Cell:
    b = sh.global_batch
    lat = sh.img_res // 8
    # q-tile the full-res self-attention once the token count explodes
    qc = 2048 if lat * lat > 4096 else None
    run_cfg = dataclasses.replace(cfg, img_res=sh.img_res, q_chunk=qc)
    graph_flops = UN.make_graph(run_cfg, batch=b, latent_res=lat
                                ).total_flops()

    if sh.kind == "train":
        def loss(params, batch):
            _, alphas = UN.ddpm_schedule()
            a = alphas[batch["t"]][:, None, None, None]
            x_t = (jnp.sqrt(a) * batch["latent"]
                   + jnp.sqrt(1 - a) * batch["noise"])
            pred = UN.unet_forward(params, x_t, batch["t"], batch["ctx"],
                                   run_cfg)
            return jnp.mean(jnp.square(pred.astype(jnp.float32)
                                       - batch["noise"].astype(jnp.float32)))

        bs = {k: _diff_input_sharding(mesh, v, b) for k, v in specs.items()}
        return _train_cell(
            arch_id, sh, mesh,
            init_fn=lambda: UN.init_unet(jax.random.PRNGKey(0), run_cfg),
            loss_fn=loss, batch_specs=specs, batch_shardings=bs,
            model_flops=3.0 * graph_flops)

    a_params = _abstract(lambda: UN.init_unet(jax.random.PRNGKey(0), run_cfg))
    p_sh = SH.param_shardings(a_params, mesh)
    stride = max(1000 // max(sh.steps, 1), 1)

    def step(params, latent, t, ctx):
        return UN.ddim_step(params, latent, t, t - stride, ctx, run_cfg)

    lat_sh = _diff_input_sharding(mesh, specs["latent"], b)
    return Cell(
        arch_id=arch_id, shape_name=sh.name, kind="denoise", step_fn=step,
        args=(a_params, specs["latent"], specs["t"], specs["ctx"]),
        in_shardings=(p_sh, lat_sh, SH.replicated(mesh),
                      _diff_input_sharding(mesh, specs["ctx"], b)),
        out_shardings=lat_sh, mesh=mesh,
        model_flops=graph_flops)


def _mmdit_cell(arch_id: str, sh: ShapeSpec, mesh: Mesh, cfg: MM.MMDiTConfig,
                specs, *, unroll: bool = False) -> Cell:
    b = sh.global_batch
    uf = max(cfg.n_double, cfg.n_single) if unroll else 1
    ba = batch_axes(mesh)
    act = None
    if cfg.d_model % mesh.shape["model"] == 0:
        dp = 1
        for a in ba:
            dp *= mesh.shape[a]
        bax = (ba if len(ba) > 1 else ba[0]) if b % dp == 0 else None
        act = (bax, None, "model")
    run_cfg = dataclasses.replace(cfg, img_res=sh.img_res, scan_unroll=uf,
                                  act_pspec=act)
    n_tok = (sh.img_res // 16) ** 2 + cfg.txt_len
    graph_flops = MM.make_graph(run_cfg, batch=b).total_flops()

    if sh.kind == "train":
        def loss(params, batch):
            t = batch["t"][:, None, None]
            x_t = (1 - t) * batch["latent"] + t * batch["noise"]
            v = MM.mmdit_forward(params, x_t, batch["t"] * 1000,
                                 batch["txt"], batch["vec"], run_cfg)
            v_true = batch["noise"] - batch["latent"]
            return jnp.mean(jnp.square(v.astype(jnp.float32)
                                       - v_true.astype(jnp.float32)))

        bs = {k: _diff_input_sharding(mesh, v, b) for k, v in specs.items()}
        return _train_cell(
            arch_id, sh, mesh,
            init_fn=lambda: MM.init_mmdit(jax.random.PRNGKey(0), run_cfg),
            loss_fn=loss, batch_specs=specs, batch_shardings=bs,
            model_flops=3.0 * graph_flops)

    a_params = _abstract(lambda: MM.init_mmdit(jax.random.PRNGKey(0),
                                               run_cfg))
    p_sh = SH.param_shardings(a_params, mesh)
    dt = 1.0 / max(sh.steps, 1)

    def step(params, latent, t, txt, vec):
        return MM.rf_step(params, latent, t,
                          jnp.full_like(t, dt), txt, vec, run_cfg)

    lat_sh = _diff_input_sharding(mesh, specs["latent"], b)
    return Cell(
        arch_id=arch_id, shape_name=sh.name, kind="denoise", step_fn=step,
        args=(a_params, specs["latent"], specs["t"], specs["txt"],
              specs["vec"]),
        in_shardings=(p_sh, lat_sh, SH.replicated(mesh),
                      _diff_input_sharding(mesh, specs["txt"], b),
                      _diff_input_sharding(mesh, specs["vec"], b)),
        out_shardings=lat_sh, mesh=mesh,
        model_flops=graph_flops)


# ---------------------------------------------------------------------------
# Vision cells
# ---------------------------------------------------------------------------


def _vision_cell(arch_id: str, sh: ShapeSpec, mesh: Mesh, cfg,
                 specs, *, unroll: bool = False) -> Cell:
    b = sh.global_batch
    if isinstance(cfg, VT.ViTConfig):
        uf = cfg.n_layers if unroll else 1
        run_cfg = dataclasses.replace(cfg, img_res=sh.img_res,
                                      scan_unroll=uf)
        init_fn = lambda: VT.init_vit(jax.random.PRNGKey(0), run_cfg)
        fwd = lambda p, img: VT.forward(p, img, run_cfg)
        graph_flops = VT.make_graph(run_cfg, batch=b).total_flops()
    else:
        run_cfg = dataclasses.replace(cfg, img_res=sh.img_res)
        init_fn = lambda: RN.init_resnet(jax.random.PRNGKey(0), run_cfg)
        fwd = lambda p, img: RN.forward(p, img, run_cfg)
        graph_flops = RN.make_graph(run_cfg, batch=b).total_flops()

    if sh.kind == "train":
        def loss(params, batch):
            logits = fwd(params, batch["image"]).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, batch["label"][:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)

        bs = {k: SH.data_sharding(mesh, len(v.shape), batch=b)
              for k, v in specs.items()}
        return _train_cell(arch_id, sh, mesh, init_fn=init_fn, loss_fn=loss,
                           batch_specs=specs, batch_shardings=bs,
                           model_flops=3.0 * graph_flops)

    a_params = _abstract(init_fn)
    p_sh = SH.param_shardings(a_params, mesh)

    def step(params, image):
        return fwd(params, image)

    return Cell(
        arch_id=arch_id, shape_name=sh.name, kind="infer", step_fn=step,
        args=(a_params, specs["image"]),
        in_shardings=(p_sh, SH.data_sharding(mesh, 4, batch=b)),
        out_shardings=SH.data_sharding(mesh, 2, batch=b), mesh=mesh,
        model_flops=graph_flops)


# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh: Mesh, *,
               smoke: bool = False, unroll: bool = False,
               cfg_override: Optional[Dict[str, Any]] = None,
               variant: Optional[str] = None) -> Cell:
    """``unroll=True`` fully unrolls layer scans so compiled
    cost_analysis counts every layer (dry-run probe mode); ``False``
    keeps the compile-fast while-loop form (runtime mode).
    ``cfg_override`` replaces config fields (the dry-run's 1/2-layer
    cost-extrapolation probes use it)."""
    spec = get_arch(arch_id)
    cfg = spec.smoke if smoke else spec.full
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    sh = spec.shapes[shape_name]
    if smoke:           # shrink the shape to the smoke config's scale
        sh = _smoke_shape(spec.family, sh, cfg)
    specs = _input_specs_for(spec.family, cfg, sh)
    if spec.family == "lm":
        return _lm_cell(arch_id, sh, mesh, cfg, specs, unroll=unroll,
                        variant=variant)
    if spec.family == "diffusion":
        if isinstance(cfg, MM.MMDiTConfig):
            return _mmdit_cell(arch_id, sh, mesh, cfg, specs, unroll=unroll)
        return _unet_cell(arch_id, sh, mesh, cfg, specs)
    return _vision_cell(arch_id, sh, mesh, cfg, specs, unroll=unroll)


def _smoke_shape(family: str, sh: ShapeSpec, cfg) -> ShapeSpec:
    if family == "lm":
        return dataclasses.replace(sh, seq_len=min(sh.seq_len, 64),
                                   global_batch=min(sh.global_batch, 2))
    if family == "diffusion":
        return dataclasses.replace(sh, img_res=min(sh.img_res, 64),
                                   global_batch=min(sh.global_batch, 2))
    return dataclasses.replace(sh, img_res=min(sh.img_res, cfg.img_res),
                               global_batch=min(sh.global_batch, 2))


def _input_specs_for(family: str, cfg, sh: ShapeSpec):
    """input_specs() equivalent but honoring a (possibly smoke-shrunk)
    ShapeSpec and config object directly."""
    f32, i32 = jnp.float32, jnp.int32
    if family == "lm":
        b, s = sh.global_batch, sh.seq_len
        if sh.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if sh.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return {"token": jax.ShapeDtypeStruct((b,), i32),
                "cache_index": jax.ShapeDtypeStruct((), i32)}
    if family == "diffusion":
        b, r = sh.global_batch, sh.img_res
        if isinstance(cfg, MM.MMDiTConfig):
            n_img = (r // 16) ** 2
            lat = jax.ShapeDtypeStruct((b, n_img, cfg.in_ch), f32)
            base = {"latent": lat,
                    "txt": jax.ShapeDtypeStruct((b, cfg.txt_len,
                                                 cfg.txt_dim), f32),
                    "vec": jax.ShapeDtypeStruct((b, cfg.vec_dim), f32),
                    "t": jax.ShapeDtypeStruct((b,), f32)}
            if sh.kind == "train":
                base["noise"] = lat
            return base
        latr = r // 8
        lat = jax.ShapeDtypeStruct((b, latr, latr, cfg.in_ch), f32)
        base = {"latent": lat,
                "ctx": jax.ShapeDtypeStruct((b, cfg.ctx_len, cfg.ctx_dim),
                                            f32),
                "t": jax.ShapeDtypeStruct((b,), i32)}
        if sh.kind == "train":
            base["noise"] = lat
        return base
    b, r = sh.global_batch, sh.img_res
    base = {"image": jax.ShapeDtypeStruct((b, r, r, 3), f32)}
    if sh.kind == "train":
        base["label"] = jax.ShapeDtypeStruct((b,), i32)
    return base
