"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-moe-30b-a3b --shape train_4k --steps 20 --smoke

Builds the (arch × shape) cell with production shardings on the local
mesh (or the 16×16/2×16×16 production mesh under the dry-run env),
feeds the deterministic synthetic pipeline, and runs real optimizer
steps with periodic checkpointing and automatic restart-from-latest.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import ImagePipeline, LatentPipeline, TokenPipeline
from repro.distributed.checkpoint import (CheckpointManager, latest_step,
                                          restore_checkpoint)
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                              mesh_context)
from repro.launch.steps import build_cell


def _pipeline(spec, cfg, sh, smoke):
    if spec.family == "lm":
        return TokenPipeline(vocab=cfg.vocab, seq_len=sh["seq"],
                             batch=sh["batch"])
    if spec.family == "vision":
        return ImagePipeline(img_res=sh["img"], batch=sh["batch"],
                             n_classes=getattr(cfg, "n_classes", 10))
    return LatentPipeline(latent_res=sh["img"] // 8,
                          channels=getattr(cfg, "in_ch", 4),
                          batch=sh["batch"],
                          ctx_len=getattr(cfg, "ctx_len", 4),
                          ctx_dim=getattr(cfg, "ctx_dim", 16))


def _batch_for(cell, pipe, step, rng):
    """Fill the cell's abstract batch spec from the pipeline."""
    raw = pipe.batch_at(step)
    spec_tree = cell.args[2]
    out = {}
    for k, spec in spec_tree.items():
        if k in raw:
            arr = np.asarray(raw[k])
        elif k == "noise":
            arr = rng.randn(*spec.shape)
        elif k == "t":
            if np.issubdtype(np.dtype(spec.dtype), np.integer):
                arr = rng.randint(0, 1000, spec.shape)
            else:
                arr = rng.rand(*spec.shape)
        elif k in ("txt", "vec", "ctx", "latent"):
            arr = rng.randn(*spec.shape) * 0.5
        else:
            raise KeyError(f"no synthetic source for batch key {k}")
        out[k] = jnp.asarray(np.asarray(arr).astype(spec.dtype)
                             .reshape(spec.shape))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs the 512-device dry-run env)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    shape = args.shape or next(iter(spec.shapes))
    assert spec.shapes[shape].kind == "train", f"{shape} is not a train shape"
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    cell = build_cell(args.arch, shape, mesh, smoke=args.smoke)
    print(f"arch={args.arch} shape={shape} mesh={dict(mesh.shape)} "
          f"smoke={args.smoke}")
    compiled = cell.lower().compile()

    cfg = spec.smoke if args.smoke else spec.full
    sspec = spec.shapes[shape]
    b_spec = cell.args[2]
    lead = next(iter(b_spec.values())).shape[0]
    fam_sh = {"seq": (b_spec["tokens"].shape[1]
                      if spec.family == "lm" else 0),
              "batch": lead,
              "img": (b_spec["image"].shape[1] if "image" in b_spec
                      else getattr(cfg, "img_res", 0))}
    pipe = _pipeline(spec, cfg, fam_sh, args.smoke)
    rng = np.random.RandomState(0)

    params = _concrete_init(args.arch, shape, cfg, spec, mesh, args.smoke)
    from repro.train.optim import adamw_init
    opt = adamw_init(params)

    start = 0
    mgr = None
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt, every=args.ckpt_every,
                                async_save=False)
        if latest_step(args.ckpt) is not None:
            state, start, _ = restore_checkpoint(args.ckpt,
                                                 {"p": params, "o": opt})
            params, opt = state["p"], state["o"]
            print(f"restored checkpoint @ step {start}")

    for step in range(start, args.steps):
        batch = _batch_for(cell, pipe, step, rng)
        t0 = time.perf_counter()
        with mesh, mesh_context(mesh):
            params, opt, metrics = compiled(params, opt, batch)
        dt = time.perf_counter() - t0
        print(f"step {step + 1:4d} loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms",
              flush=True)
        if mgr:
            mgr.maybe_save(step + 1, {"p": params, "o": opt})
    if mgr:
        mgr.wait()


def _concrete_init(arch, shape, cfg, spec, mesh, smoke):
    import dataclasses as _dc
    import jax.random as jr
    from repro.models import mmdit as MM
    from repro.models import resnet as RN
    from repro.models import transformer as TF
    from repro.models import unet as UN
    from repro.models import vit as VT
    key = jr.PRNGKey(0)
    if spec.family == "lm":
        return TF.init_lm(key, cfg)
    if spec.family == "vision":
        if isinstance(cfg, VT.ViTConfig):
            run = _dc.replace(cfg, img_res=spec.shapes[shape].img_res
                              if not smoke else cfg.img_res)
            return VT.init_vit(key, run)
        return RN.init_resnet(key, cfg)
    if isinstance(cfg, MM.MMDiTConfig):
        return MM.init_mmdit(key, cfg)
    return UN.init_unet(key, cfg)


if __name__ == "__main__":
    main()
