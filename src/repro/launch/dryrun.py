import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# initialization.  This module is the ONLY place that forces 512 host
# devices — tests and benchmarks see the real device list.

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --cells 'phi3.*train'

Per cell it records into artifacts/dryrun/<arch>__<shape>__<mesh>.json:
  * cost_analysis flops / bytes accessed
  * memory_analysis per-device sizes (args/outputs/temp/peak)
  * per-collective-op byte totals parsed from the post-SPMD HLO
  * the three roofline terms (compute / memory / collective, seconds)
Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the framework and fail the run.
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import list_cells
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

# TPU v5e roofline constants (per chip)
PEAK_BF16 = 197e12            # FLOP/s
PEAK_INT8 = 394e12            # OP/s
HBM_BW = 819e9                # B/s
LINK_BW = 50e9                # B/s per ICI link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all arrays in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind byte totals from post-partitioning HLO.

    Shapes in partitioned HLO are per-device.  Wire-byte convention:
    all-reduce counts 2x its payload (ring = reduce-scatter + all-gather);
    everything else 1x its output.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match "= <shape> all-reduce(" and fusion variants like
            # "all-reduce-start("
            m = re.search(r"= ([^=]*?) " + kind + r"(?:-start)?\(", stripped)
            if m:
                out[kind] += _shape_bytes(m.group(1))
                counts[kind] += 1
                break
    wire = sum(b * (2 if k == "all-reduce" else 1) for k, b in out.items())
    return {"per_op_bytes": out, "per_op_counts": counts,
            "wire_bytes_per_device": wire}


def _module_costs(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["wire_bytes_per_device"]),
            "coll_detail": coll}


def _probe_costs(arch: str, shape: str, mesh) -> dict | None:
    """Scan-calibrated cost extrapolation.

    ``lax.scan`` compiles to a while loop whose body XLA cost analysis
    counts ONCE (trip counts are not multiplied in).  So the real cell's
    compiled module proves shardability and gives true peak memory, but
    its flop/byte/collective totals undercount by ~n_layers.  We recover
    exact totals by compiling tiny UNROLLED probes — 1 layer and 2 layers
    — and extrapolating linearly: total = c1 + (L-1)·(c2-c1).  The probe
    difference isolates exactly one layer's compute, memory traffic and
    collectives under the very same mesh/shardings.
    """
    from repro.configs import get_arch
    from repro.models import mmdit as MM
    from repro.models import transformer as TF
    from repro.models import vit as VT
    spec = get_arch(arch)
    cfg = spec.full

    def costs(override):
        cell = build_cell(arch, shape, mesh, unroll=True,
                          cfg_override=override)
        return _module_costs(cell.lower().compile())

    def extrapolate(c1, c2, n):
        out = {}
        for k in ("flops", "bytes", "coll"):
            delta = max(c2[k] - c1[k], 0.0)
            out[k] = c1[k] + (n - 1) * delta
        return out

    if isinstance(cfg, TF.LMConfig):
        c1 = costs({"n_layers": 1})
        c2 = costs({"n_layers": 2})
        return extrapolate(c1, c2, cfg.n_layers)
    if isinstance(cfg, VT.ViTConfig):
        c1 = costs({"n_layers": 1})
        c2 = costs({"n_layers": 2})
        return extrapolate(c1, c2, cfg.n_layers)
    if isinstance(cfg, MM.MMDiTConfig):
        c11 = costs({"n_double": 1, "n_single": 1})
        c21 = costs({"n_double": 2, "n_single": 1})
        c12 = costs({"n_double": 1, "n_single": 2})
        out = {}
        for k in ("flops", "bytes", "coll"):
            d_dbl = max(c21[k] - c11[k], 0.0)
            d_sgl = max(c12[k] - c11[k], 0.0)
            out[k] = (c11[k] + (cfg.n_double - 1) * d_dbl
                      + (cfg.n_single - 1) * d_sgl)
        return out
    return None          # unet / resnet: python-unrolled, counts are exact


def run_cell(arch: str, shape: str, mesh_name: str, outdir: Path, *,
             force: bool = False, verbose: bool = True) -> dict:
    tag = f"{arch}__{shape}__{mesh_name}"
    path = outdir / f"{tag}.json"
    if path.exists() and not force:
        if verbose:
            print(f"skip {tag} (exists)", flush=True)
        return json.loads(path.read_text())

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    n_chips = mesh.size
    # the real artifact: scan form — proves shardability, true peak memory
    cell = build_cell(arch, shape, mesh)
    lowered = cell.lower()
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    raw = _module_costs(compiled)
    probe = _probe_costs(arch, shape, mesh)
    if probe is not None:
        flops, bytes_accessed = probe["flops"], probe["bytes"]
        coll_wire = probe["coll"]
    else:
        flops, bytes_accessed = raw["flops"], raw["bytes"]
        coll_wire = raw["coll"]
    mem = compiled.memory_analysis()
    mem_stats = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
    }

    # NOTE: cost_analysis flops/bytes on a partitioned module are
    # per-device; the roofline terms below are per-device seconds.
    compute_s = flops / PEAK_BF16
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_wire / LINK_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": n_chips,
        "kind": cell.kind,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_wire_bytes_per_device": coll_wire,
        "raw_scan_module_costs": {k: raw[k] for k in ("flops", "bytes",
                                                      "coll")},
        "probe_extrapolated": probe is not None,
        "memory_analysis": mem_stats,
        "collectives": raw["coll_detail"],
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
        },
        "model_flops_total": cell.model_flops,
        "model_flops_per_device": cell.model_flops / n_chips,
        "useful_flop_ratio": (cell.model_flops / n_chips / flops
                              if flops else 0.0),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    outdir.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1))
    if verbose:
        print(f"OK {tag}: {cell.kind} flops/dev={flops:.3g} "
              f"bytes/dev={bytes_accessed:.3g} coll/dev={coll_wire:.3g} "
              f"dom={dominant} peak_temp={mem_stats['temp_bytes']/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--cells", default=".*",
                    help="regex over '<arch> <shape>'")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512, (
        "dry-run needs the 512 forced host devices")
    outdir = Path(args.out)
    meshes = {"single": ["16x16"], "multi": ["multipod"],
              "both": ["16x16", "multipod"]}[args.mesh]
    pat = re.compile(args.cells)
    failures = []
    cells = [(a, s) for a, s in list_cells() if pat.search(f"{a} {s}")]
    total = len(cells) * len(meshes)
    done = 0
    for mesh_name in meshes:
        for arch, shape in cells:
            done += 1
            print(f"[{done}/{total}] {arch} {shape} {mesh_name}", flush=True)
            try:
                run_cell(arch, shape, mesh_name, outdir, force=args.force)
            except Exception:
                failures.append((arch, shape, mesh_name))
                traceback.print_exc()
    if failures:
        print(f"\nFAILED cells: {failures}", flush=True)
        return 1
    print(f"\nAll {total} dry-run cells passed.", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
