"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --smoke \
        --requests 8 --collaborative --cut auto --bandwidth 250 \
        --spec-k auto --adaptive

Cloud-only mode runs the batched KV-cache engine; ``--collaborative``
splits the stack at the (auto-tuned or given) block and runs the paper's
INT8-edge / FP32-cloud mixed-precision pipeline over a simulated
wireless channel.  ``--spec-k`` turns decode into draft/verify rounds
(``auto`` self-corrects from measured acceptance between requests);
``--adaptive`` closes the whole tuning loop online — link telemetry
re-tunes both the draft length and the cut layer while serving.

``--temperature``/``--top-p``/``--sample-seed`` sample instead of
greedy decode: verify becomes exact rejection sampling against the
cloud distribution (outputs match non-speculative cloud sampling),
and the per-request seeds make every stream replay bit-identically.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.autotune import AutoTuner
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel,
                                  EDGE_TX2_CLASS)
from repro.models.transformer import init_lm, make_graph
from repro.serve.engine import (CollaborativeServingEngine, SamplingParams,
                                ServingEngine)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--collaborative", action="store_true")
    ap.add_argument("--cut", default="auto")
    ap.add_argument("--bandwidth", type=float, default=250.0,
                    help="wireless KB/s for the collaborative channel")
    ap.add_argument("--rtt", type=float, default=20.0,
                    help="wireless round-trip time in ms")
    ap.add_argument("--spec-k", default="1",
                    help="speculative draft length: an int, or 'auto' to "
                         "tune from the channel and keep self-correcting "
                         "from measured acceptance")
    ap.add_argument("--adaptive", action="store_true",
                    help="online control loop: telemetry re-tunes spec_k "
                         "between rounds and the cut layer at admission "
                         "boundaries")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="decode temperature; 0 keeps the greedy fast "
                         "path, >0 turns verify into exact rejection "
                         "sampling against the cloud distribution")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus cutoff applied to the cloud "
                         "distribution before sampling (1.0 = off)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base PRNG seed; request i samples with "
                         "seed+i so outputs replay bit-identically")
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    assert spec.family == "lm", "serving launcher targets the LM family"
    cfg = spec.smoke if args.smoke else spec.full
    print(f"serving {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab}")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]
    spec_k = args.spec_k if args.spec_k == "auto" else int(args.spec_k)
    max_len = args.prompt_len + args.max_new + 24
    # per-request seeds so every output stream replays bit-identically;
    # temperature 0 stays on the greedy fast path (sampling=None)
    sampling = None
    if args.temperature > 0:
        sampling = [SamplingParams(temperature=args.temperature,
                                   top_p=args.top_p,
                                   seed=args.sample_seed + i)
                    for i in range(args.requests)]

    if not args.collaborative:
        if sampling is not None:
            raise SystemExit("--temperature>0 needs --collaborative: the "
                             "rejection-sampling verify lives in the "
                             "collaborative engine")
        eng = ServingEngine(params, cfg, max_batch=4, max_len=max_len)
        t0 = time.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=args.max_new)
        dt = time.perf_counter() - t0
        print(f"cloud-only: {args.requests} reqs x {args.max_new} tokens "
              f"in {dt:.2f}s ({eng.stats.decode_steps} decode steps)")
        print("first output:", outs[0])
        return

    channel = Channel.from_kbps(args.bandwidth, rtt_ms=args.rtt)
    if args.cut == "auto":
        graph = make_graph(cfg, batch=1, seq=args.prompt_len)
        tuner = AutoTuner(graph, EDGE_TX2_CLASS, CLOUD_TITANXP_CLASS)
        best, _ = tuner.tune(channel)
        cut_layer = (int(best.point.split("/")[0][3:])
                     if best.point.startswith("blk") else 0)
        print(f"auto-tuned cut (Algorithm 1): {best.point} "
              f"-> edge blocks 0..{cut_layer}")
    else:
        cut_layer = int(args.cut)
    if args.adaptive and cut_layer > cfg.n_layers - 2:
        cut_layer = cfg.n_layers - 2
        print(f"adaptive mode: clamping cut to {cut_layer} so every "
              f"candidate partition keeps a cloud block")
    eng = CollaborativeServingEngine(
        params, cfg, cut_layer=cut_layer, channel=channel, max_len=max_len,
        spec_k=spec_k, policy="auto" if args.adaptive else None)
    if sampling is not None:
        print(f"sampling: temperature={args.temperature} "
              f"top_p={args.top_p} seeds {args.sample_seed}.."
              f"{args.sample_seed + args.requests - 1} "
              f"(exact cloud distribution via rejection-sampled verify)")
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new_tokens=args.max_new,
                        sampling=sampling)
    dt = time.perf_counter() - t0
    print(f"collaborative: {dt:.2f}s, int8 wire bytes "
          f"{eng.stats.transmitted_bytes / 1e3:.1f}KB "
          f"({eng.stats.prefill_bytes / 1e3:.1f}KB prefill + "
          f"{eng.stats.bytes_per_decode_token():.0f} B/token incremental "
          f"decode), simulated channel "
          f"time {eng.stats.channel_latency_s:.2f}s")
    if eng.spec_k > 1 or eng.policy is not None:
        print(f"control loop: spec_k={eng.spec_k} cut={eng.cut} "
              f"(switches: k={eng.stats.spec_k_switches}, "
              f"cut={eng.stats.cut_switches}; draft acceptance "
              f"{eng.stats.acceptance_rate():.0%})")
    print("first output:", outs[0])


if __name__ == "__main__":
    main()
