"""Role-based sharding rules (MaxText-style logical axes, path-driven).

Strategy per family:
  * params: ZeRO-3/FSDP over the batch axes (``data``, plus ``pod`` when
    multi-pod) on the input-feature dim × tensor-parallel over ``model``
    on the output-feature dim; output projections (wo/proj_out) flip the
    two so the TP collective pattern is all-reduce after the second
    matmul (Megatron).
  * MoE experts: expert-parallel over ``model`` when the expert count
    divides it (qwen3: 128/16=8 experts per group); otherwise the expert
    FFN dim takes the TP axis (grok: 8 experts, d_ff 32768/16).
  * stacked-layer leading axes ([L, ...] from scan) are never sharded.
  * activations/batch: shard dim 0 over the batch axes; decode KV caches
    shard batch when divisible, else spread sequence over everything.

Every rule is divisibility-guarded: a dim that does not divide its mesh
axes stays unsharded (GSPMD would pad, we prefer exact layouts).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import batch_axes, fsdp_axes

__all__ = ["param_shardings", "data_sharding", "replicated",
           "cache_sharding", "cache_spec", "logits_sharding",
           "spec_for_param", "paged_pool_spec", "paged_scale_spec",
           "paged_pool_shardings"]


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _fit(dim: int, mesh: Mesh, axes: Tuple[str, ...]) -> Optional[Any]:
    """Return axes (str or tuple) if dim divides their product, else None."""
    if not axes:
        return None
    if dim % _axes_size(mesh, axes) == 0:
        return axes[0] if len(axes) == 1 else axes
    return None


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return "/".join(out)


_STACKED_ROOTS = ("blocks", "double", "single")
_OUT_PROJ_TOKENS = ("wo", "proj_out", "out", "xo")


def spec_for_param(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
                   *, zero1: bool = False) -> P:
    """``zero1=True`` replicates params over the DP axes (ZeRO-1: only
    optimizer state and grads are sharded by the update math) — kills
    the per-layer FSDP weight all-gathers at the cost of a full param
    copy per model-parallel group.  Right trade for small-params cells
    (see EXPERIMENTS.md §Perf)."""
    fsdp = () if zero1 else fsdp_axes(mesh)
    toks = path_str.split("/")
    stacked = toks[0] in _STACKED_ROOTS
    dims = list(shape)
    lead: list = []
    if stacked and dims:
        lead = [None]                      # [L, ...] layer axis unsharded
        dims = dims[1:]

    def mk(*spec):
        return P(*lead, *spec)

    rank = len(dims)
    if rank <= 1:
        return mk(*([None] * rank))

    is_out_proj = any(t in _OUT_PROJ_TOKENS for t in toks[-2:])

    if rank == 2:
        d_in, d_out = dims
        if toks[-1] == "emb":              # embedding table [V, D]
            return mk(_fit(d_in, mesh, ("model",)),
                      _fit(d_out, mesh, fsdp))
        if is_out_proj:
            return mk(_fit(d_in, mesh, ("model",)),
                      _fit(d_out, mesh, fsdp))
        return mk(_fit(d_in, mesh, fsdp),
                  _fit(d_out, mesh, ("model",)))

    if rank == 3:
        # MoE experts: expert dim unsharded (ragged grouped-GEMM needs
        # every group's weights addressable); FSDP on d_model, TP on the
        # expert FFN dim — uniform for wi/wg [E, D, F] and wo [E, F, D].
        e, a, b = dims
        if "wo" in toks[-2:]:              # [E, F, D]
            return mk(None, _fit(a, mesh, ("model",)), _fit(b, mesh, fsdp))
        return mk(None, _fit(a, mesh, fsdp), _fit(b, mesh, ("model",)))

    if rank == 4:                          # conv [k, k, cin, cout]
        k1, k2, cin, cout = dims
        return mk(None, None, _fit(cin, mesh, fsdp),
                  _fit(cout, mesh, ("model",)))

    return mk(*([None] * rank))


def param_shardings(abstract_params: Any, mesh: Mesh, *,
                    zero1: bool = False) -> Any:
    """NamedSharding tree matching an (abstract) param tree."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for path, leaf in flat:
        spec = spec_for_param(_path_str(path), tuple(leaf.shape), mesh,
                              zero1=zero1)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(tdef, out)


def data_sharding(mesh: Mesh, ndim: int, *, batch_dim: int = 0,
                  batch: Optional[int] = None) -> NamedSharding:
    """Batch-parallel input sharding; replicates when batch doesn't fit."""
    ba = batch_axes(mesh)
    spec = [None] * ndim
    if ba and (batch is None or batch % _axes_size(mesh, ba) == 0):
        spec[batch_dim] = ba if len(ba) > 1 else ba[0]
    return NamedSharding(mesh, P(*spec))


def logits_sharding(mesh: Mesh, ndim: int, *, batch: int,
                    vocab: int) -> NamedSharding:
    ba = batch_axes(mesh)
    spec: list = [None] * ndim
    if ba and batch % _axes_size(mesh, ba) == 0:
        spec[0] = ba if len(ba) > 1 else ba[0]
    if vocab % mesh.shape["model"] == 0:
        spec[-1] = "model"
    return NamedSharding(mesh, P(*spec))


def cache_spec(mesh: Mesh, *, batch: int, seq: int, n_kv: int,
               head_dim: int = 128) -> P:
    """PartitionSpec for a dense KV cache [L, B, S, H, D]: batch over
    (pod,data) when divisible, head_dim over model (decode writes a
    dynamic S slice — sharding S would force SPMD full-rematerialization
    of the update; sharding D keeps the dynamic-update-slice local).
    batch=1 spreads S over the batch axes instead."""
    ba = batch_axes(mesh)
    b_ax = None
    s_ax = None
    if ba and batch % _axes_size(mesh, ba) == 0:
        b_ax = ba if len(ba) > 1 else ba[0]
    else:
        s_ax = _fit(seq, mesh, ba)
    d_ax = _fit(head_dim, mesh, ("model",))
    h_ax = None
    if d_ax is None:
        h_ax = _fit(n_kv, mesh, ("model",))
    return P(None, b_ax, s_ax, h_ax, d_ax)


def cache_sharding(mesh: Mesh, *, batch: int, seq: int, n_kv: int,
                   head_dim: int = 128) -> NamedSharding:
    """``cache_spec`` wrapped as a NamedSharding (the historical API)."""
    return NamedSharding(mesh, cache_spec(mesh, batch=batch, seq=seq,
                                          n_kv=n_kv, head_dim=head_dim))


def paged_pool_spec(mesh: Mesh, *, n_pages: int, n_kv: int,
                    head_dim: int) -> P:
    """PartitionSpec for a paged KV page pool
    ``[L, n_pages, page_size, n_kv, head_dim]``: kv heads over ``model``
    — each TP shard stores, dequantizes, and attends only its own KV
    slice — and the page dim over the batch axes when divisible (pages
    are slot-owned, so this is "slots on the data axis" at page
    granularity).  The layer dim and ``page_size`` stay unsharded (a
    page is the DMA unit); when ``n_kv`` doesn't divide the TP degree
    the pool replicates — splitting ``head_dim`` instead would tear the
    per-head dequant·softmax·gather apart and forces SPMD to fully
    rematerialize the gathered pages (measured, not hypothetical)."""
    h_ax = _fit(n_kv, mesh, ("model",))
    p_ax = _fit(n_pages, mesh, batch_axes(mesh))
    return P(None, p_ax, None, h_ax, None)


def paged_scale_spec(mesh: Mesh, *, batch: int, n_kv: int) -> P:
    """Per-slot INT8 scale rows ``[L, B, n_kv]`` of a paged cache: shard
    like the pool they calibrate — kv heads over ``model``, slots over
    the batch axes — under the same divisibility guards."""
    h_ax = _fit(n_kv, mesh, ("model",))
    ba = batch_axes(mesh)
    b_ax = None
    if ba and batch % _axes_size(mesh, ba) == 0:
        b_ax = ba if len(ba) > 1 else ba[0]
    return P(None, b_ax, h_ax)


def paged_pool_shardings(cache: Any, mesh: Mesh) -> Any:
    """NamedSharding tree for a paged cache dict (``k_pages``/``v_pages``
    + optional ``k_scale``/``v_scale`` — see ``transformer.init_cache``)."""
    out = {}
    for k, v in cache.items():
        if k.endswith("_pages"):
            _, n_pages, _, n_kv, hd = v.shape
            spec = paged_pool_spec(mesh, n_pages=n_pages, n_kv=n_kv,
                                   head_dim=hd)
        elif k.endswith("_scale"):
            _, b, n_kv = v.shape
            spec = paged_scale_spec(mesh, batch=b, n_kv=n_kv)
        else:
            spec = P()
        out[k] = NamedSharding(mesh, spec)
    return out
