"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the ``pod`` axis
is the slow (DCN-class) dimension; data-parallel replicas and ZeRO
sharding may span it (see repro.launch.shardings).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "batch_axes",
           "fsdp_axes"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def batch_axes(mesh: jax.sharding.Mesh) -> tuple:
    """Axes the global batch shards over (DP/FSDP dimension)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh: jax.sharding.Mesh) -> tuple:
    """Axes parameters are ZeRO-sharded over (== the batch axes)."""
    return batch_axes(mesh)
