"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the ``pod`` axis
is the slow (DCN-class) dimension; data-parallel replicas and ZeRO
sharding may span it (see repro.launch.shardings).
"""
from __future__ import annotations

import contextlib

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_serve_mesh",
           "batch_axes", "fsdp_axes", "mesh_context"]


def _mk_mesh(shape, axes, devices=None) -> jax.sharding.Mesh:
    # newer jax wants explicit Auto axis types; 0.4.x has no AxisType
    kw = {} if devices is None else {"devices": devices}
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes), **kw)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes, **kw)


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where it exists (newer jax); a no-op
    context on 0.4.x, where the plain ``with mesh:`` the callers pair
    this with already provides the ambient mesh."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return contextlib.nullcontext()


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return _mk_mesh((data, model), ("data", "model"))


def make_serve_mesh(model: int = 1, data: int = 1) -> jax.sharding.Mesh:
    """Cloud-verify TP mesh for the serving engines: ``model`` is the
    tensor-parallel degree the cloud suffix (and the paged KV pool's
    kv-head dim) shards over, ``data`` the slot-parallel axis.  Clamps
    like ``make_host_mesh`` so tests on few devices stay runnable, but
    keeps the requested ``model`` degree whenever enough devices exist —
    the serving meshes are (1, N) in practice."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    data = max(1, min(data, n // model))
    return _mk_mesh((data, model), ("data", "model"))


def batch_axes(mesh: jax.sharding.Mesh) -> tuple:
    """Axes the global batch shards over (DP/FSDP dimension)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh: jax.sharding.Mesh) -> tuple:
    """Axes parameters are ZeRO-sharded over (== the batch axes)."""
    return batch_axes(mesh)
