"""SD-1.5-style latent-diffusion U-Net (arXiv:2112.10752).

ch=320, ch_mult=(1,2,4,4), 2 res blocks per stage, cross-attention
transformer blocks at downsample factors 1,2,4 (not the deepest stage),
text context dim 773→768 stub embeddings, epsilon-prediction.

Partition-analysis view (paper §2.2 applied to a U-Net): the encoder's
long skip connections keep every interior encoder cut multi-blob, so the
only single-blob candidates are {conv_in, the post-bottleneck points
after each skip has been consumed, conv_out} — exactly DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import LayerGraph
from repro.models import layers as L
from repro.models.layers import QuantCtx

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str
    ch: int = 320
    ch_mult: Tuple[int, ...] = (1, 2, 4, 4)
    n_res_blocks: int = 2
    attn_stages: Tuple[int, ...] = (0, 1, 2)     # cross-attn at these stages
    ctx_dim: int = 768
    ctx_len: int = 77
    in_ch: int = 4
    n_heads: int = 8
    img_res: int = 512            # pixel space; latent = img_res // 8
    dtype: Any = jnp.float32
    q_chunk: Optional[int] = None  # q-tiled self-attn for hi-res latents
    remat: bool = True             # checkpoint each res/attn block

    @property
    def latent_res(self) -> int:
        return self.img_res // 8

    @property
    def t_dim(self) -> int:
        return self.ch * 4


def timestep_embed(t: jax.Array, dim: int) -> jax.Array:
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


# -- res block ---------------------------------------------------------------


def res_block_init(key, c_in, c_out, t_dim, *, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {"n1": L.norm_init(c_in, dtype=dtype),
         "conv1": L.conv2d_init(ks[0], 3, c_in, c_out, dtype=dtype),
         "temb": L.dense_init(ks[1], t_dim, c_out, dtype=dtype),
         "n2": L.norm_init(c_out, dtype=dtype),
         "conv2": L.conv2d_init(ks[2], 3, c_out, c_out, dtype=dtype)}
    if c_in != c_out:
        p["skip"] = L.conv2d_init(ks[3], 1, c_in, c_out, dtype=dtype)
    return p


def res_block(p: Params, x, temb, *, qctx=None, name="res"):
    h = L.conv2d(p["conv1"], jax.nn.silu(L.groupnorm(p["n1"], x)), qctx=qctx,
                 name=f"{name}/c1")
    h = h + L.dense(p["temb"], jax.nn.silu(temb), qctx=qctx,
                    name=f"{name}/t")[:, None, None, :]
    h = L.conv2d(p["conv2"], jax.nn.silu(L.groupnorm(p["n2"], h)), qctx=qctx,
                 name=f"{name}/c2")
    sc = x if "skip" not in p else L.conv2d(p["skip"], x, qctx=qctx,
                                            name=f"{name}/s")
    return sc + h


# -- cross-attn transformer block ---------------------------------------------


def xattn_block_init(key, c, ctx_dim, *, dtype) -> Params:
    ks = jax.random.split(key, 8)
    return {
        "gn": L.norm_init(c, dtype=dtype),
        "proj_in": L.dense_init(ks[0], c, c, dtype=dtype),
        "ln1": L.norm_init(c, dtype=dtype),
        "self": L.attention_init(ks[1], c, 8, 8, dtype=dtype),
        "ln2": L.norm_init(c, dtype=dtype),
        "q": L.dense_init(ks[2], c, c, bias=False, dtype=dtype),
        "k": L.dense_init(ks[3], ctx_dim, c, bias=False, dtype=dtype),
        "v": L.dense_init(ks[4], ctx_dim, c, bias=False, dtype=dtype),
        "xo": L.dense_init(ks[5], c, c, dtype=dtype),
        "ln3": L.norm_init(c, dtype=dtype),
        "ff": L.mlp_init(ks[6], c, 4 * c, dtype=dtype),
        "proj_out": L.dense_init(ks[7], c, c, dtype=dtype),
    }


def xattn_block(p: Params, x, ctx, *, n_heads=8, qctx=None, name="tr",
                q_chunk=None):
    b, h, w, c = x.shape
    res = x
    z = L.groupnorm(p["gn"], x).reshape(b, h * w, c)
    z = L.dense(p["proj_in"], z, qctx=qctx, name=f"{name}/pi")
    sa, _ = L.attention(p["self"], L.layernorm(p["ln1"], z), n_heads=n_heads,
                        n_kv=n_heads, causal=False, qctx=qctx,
                        name=f"{name}/sa", q_chunk=q_chunk)
    z = z + sa
    # cross attention to text context
    zq = L.layernorm(p["ln2"], z)
    hd = c // n_heads
    qh = L.dense(p["q"], zq, qctx=qctx, name=f"{name}/q").reshape(
        b, -1, n_heads, hd)
    kh = L.dense(p["k"], ctx, qctx=qctx, name=f"{name}/k").reshape(
        b, -1, n_heads, hd)
    vh = L.dense(p["v"], ctx, qctx=qctx, name=f"{name}/v").reshape(
        b, -1, n_heads, hd)
    att = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) / math.sqrt(hd)
    att = jax.nn.softmax(att.astype(jnp.float32), -1).astype(x.dtype)
    xa = jnp.einsum("bhqk,bkhd->bqhd", att, vh).reshape(b, -1, c)
    z = z + L.dense(p["xo"], xa, qctx=qctx, name=f"{name}/xo")
    z = z + L.mlp(p["ff"], L.layernorm(p["ln3"], z), qctx=qctx,
                  name=f"{name}/ff")
    z = L.dense(p["proj_out"], z, qctx=qctx, name=f"{name}/po")
    return res + z.reshape(b, h, w, c)


# -- full U-Net ----------------------------------------------------------------


def _stage_ch(cfg: UNetConfig) -> List[int]:
    return [cfg.ch * m for m in cfg.ch_mult]


def init_unet(key, cfg: UNetConfig) -> Params:
    ks = iter(jax.random.split(key, 256))
    chs = _stage_ch(cfg)
    dt = cfg.dtype
    p: Params = {
        "temb1": L.dense_init(next(ks), cfg.ch, cfg.t_dim, dtype=dt),
        "temb2": L.dense_init(next(ks), cfg.t_dim, cfg.t_dim, dtype=dt),
        "conv_in": L.conv2d_init(next(ks), 3, cfg.in_ch, cfg.ch, dtype=dt),
    }
    c = cfg.ch
    # encoder
    for s, c_out in enumerate(chs):
        for b in range(cfg.n_res_blocks):
            p[f"down{s}_{b}/res"] = res_block_init(next(ks), c, c_out,
                                                   cfg.t_dim, dtype=dt)
            c = c_out
            if s in cfg.attn_stages:
                p[f"down{s}_{b}/attn"] = xattn_block_init(
                    next(ks), c, cfg.ctx_dim, dtype=dt)
        if s < len(chs) - 1:
            p[f"down{s}/ds"] = L.conv2d_init(next(ks), 3, c, c, dtype=dt)
    # middle
    p["mid/res1"] = res_block_init(next(ks), c, c, cfg.t_dim, dtype=dt)
    p["mid/attn"] = xattn_block_init(next(ks), c, cfg.ctx_dim, dtype=dt)
    p["mid/res2"] = res_block_init(next(ks), c, c, cfg.t_dim, dtype=dt)
    # decoder (n_res_blocks+1 per stage, consuming skips)
    for s in reversed(range(len(chs))):
        c_out = chs[s]
        for b in range(cfg.n_res_blocks + 1):
            c_skip = chs[s] if b < cfg.n_res_blocks else \
                (chs[s - 1] if s > 0 else cfg.ch)
            p[f"up{s}_{b}/res"] = res_block_init(next(ks), c + c_skip, c_out,
                                                 cfg.t_dim, dtype=dt)
            c = c_out
            if s in cfg.attn_stages:
                p[f"up{s}_{b}/attn"] = xattn_block_init(
                    next(ks), c, cfg.ctx_dim, dtype=dt)
        if s > 0:
            p[f"up{s}/us"] = L.conv2d_init(next(ks), 3, c, c, dtype=dt)
    p["out_n"] = L.norm_init(c, dtype=dt)
    p["conv_out"] = L.conv2d_init(next(ks), 3, c, cfg.in_ch, dtype=dt)
    return p


def unet_forward(params: Params, x: jax.Array, t: jax.Array, ctx: jax.Array,
                 cfg: UNetConfig, *, qctx: Optional[QuantCtx] = None
                 ) -> jax.Array:
    """x: [B, h, w, 4] latent; t: [B] timesteps; ctx: [B, 77, 768]."""
    chs = _stage_ch(cfg)
    temb = timestep_embed(t, cfg.ch).astype(cfg.dtype)
    temb = L.dense(params["temb2"],
                   jax.nn.silu(L.dense(params["temb1"], temb)),)
    ctx = ctx.astype(cfg.dtype)

    # remat each block: the backward pass recomputes block interiors
    # (attention probs, GN stats) instead of stashing them
    def ckpt(fn):
        return jax.checkpoint(fn) if cfg.remat else fn

    res_block_ = ckpt(lambda p, h, temb: res_block(p, h, temb, qctx=qctx))
    xattn_block_ = ckpt(lambda p, h, ctx: xattn_block(
        p, h, ctx, n_heads=cfg.n_heads, qctx=qctx, q_chunk=cfg.q_chunk))

    h = L.conv2d(params["conv_in"], x.astype(cfg.dtype), qctx=qctx,
                 name="conv_in")
    skips = [h]
    for s in range(len(chs)):
        for b in range(cfg.n_res_blocks):
            h = res_block_(params[f"down{s}_{b}/res"], h, temb)
            if s in cfg.attn_stages:
                h = xattn_block_(params[f"down{s}_{b}/attn"], h, ctx)
            skips.append(h)
        if s < len(chs) - 1:
            h = L.conv2d(params[f"down{s}/ds"], h, stride=2, qctx=qctx,
                         name=f"down{s}/ds")
            skips.append(h)
    h = res_block_(params["mid/res1"], h, temb)
    h = xattn_block_(params["mid/attn"], h, ctx)
    h = res_block_(params["mid/res2"], h, temb)
    for s in reversed(range(len(chs))):
        for b in range(cfg.n_res_blocks + 1):
            sk = skips.pop()
            h = jnp.concatenate([h, sk], axis=-1)
            h = res_block_(params[f"up{s}_{b}/res"], h, temb)
            if s in cfg.attn_stages:
                h = xattn_block_(params[f"up{s}_{b}/attn"], h, ctx)
        if s > 0:
            bsz, hh, ww, cc = h.shape
            h = jax.image.resize(h, (bsz, hh * 2, ww * 2, cc), "nearest")
            h = L.conv2d(params[f"up{s}/us"], h, qctx=qctx, name=f"up{s}/us")
    h = jax.nn.silu(L.groupnorm(params["out_n"], h))
    return L.conv2d(params["conv_out"], h, qctx=qctx, name="conv_out")


# -- DDPM training / DDIM sampling ---------------------------------------------


def ddpm_schedule(n_steps: int = 1000):
    betas = jnp.linspace(1e-4, 0.02, n_steps)
    alphas = jnp.cumprod(1.0 - betas)
    return betas, alphas


def diffusion_loss(params: Params, batch: Dict[str, jax.Array],
                   cfg: UNetConfig, *, rng: jax.Array) -> jax.Array:
    """batch: {latent [B,h,w,4], ctx [B,77,768]}; eps-prediction MSE."""
    x0 = batch["latent"]
    b = x0.shape[0]
    _, alphas = ddpm_schedule()
    k_t, k_e = jax.random.split(rng)
    t = jax.random.randint(k_t, (b,), 0, alphas.shape[0])
    eps = jax.random.normal(k_e, x0.shape, x0.dtype)
    a = alphas[t][:, None, None, None]
    x_t = jnp.sqrt(a) * x0 + jnp.sqrt(1 - a) * eps
    pred = unet_forward(params, x_t, t, batch["ctx"], cfg)
    return jnp.mean(jnp.square(pred.astype(jnp.float32)
                               - eps.astype(jnp.float32)))


def ddim_step(params: Params, x_t: jax.Array, t: jax.Array, t_prev: jax.Array,
              ctx: jax.Array, cfg: UNetConfig, *,
              qctx: Optional[QuantCtx] = None) -> jax.Array:
    """One deterministic DDIM sampler step (the gen_* dry-run unit)."""
    _, alphas = ddpm_schedule()
    eps = unet_forward(params, x_t, t, ctx, cfg, qctx=qctx)
    a_t = alphas[t][:, None, None, None]
    a_p = jnp.where(t_prev >= 0, alphas[jnp.maximum(t_prev, 0)], 1.0
                    )[:, None, None, None]
    x0 = (x_t - jnp.sqrt(1 - a_t) * eps) / jnp.sqrt(a_t)
    return jnp.sqrt(a_p) * x0 + jnp.sqrt(1 - a_p) * eps


# -- partition graph -------------------------------------------------------------


def make_graph(cfg: UNetConfig, *, batch: int, latent_res: Optional[int] = None
               ) -> LayerGraph:
    """Stage-level graph with explicit long skips (encoder→decoder)."""
    r = latent_res or cfg.latent_res
    chs = _stage_ch(cfg)
    g = LayerGraph(cfg.name)
    g.add("input", "input", [], (batch, r, r, cfg.in_ch))
    prev = g.add("conv_in", "conv", ["input"], (batch, r, r, cfg.ch),
                 flops=2 * batch * r * r * 9 * cfg.in_ch * cfg.ch,
                 param_elems=9 * cfg.in_ch * cfg.ch + cfg.ch)
    skip_nodes = []
    c = cfg.ch
    for s, c_out in enumerate(chs):
        n_attn = 1 if s in cfg.attn_stages else 0
        flops = (2 * batch * r * r * (9 * c * c_out + 9 * c_out * c_out)
                 * cfg.n_res_blocks
                 + n_attn * 2 * batch * (r * r) ** 2 * c_out * 2)
        pcount = cfg.n_res_blocks * (9 * c * c_out + 9 * c_out ** 2
                                     + cfg.t_dim * c_out) \
            + n_attn * (8 * c_out ** 2 + 2 * c_out * cfg.ctx_dim
                        + 8 * c_out ** 2)
        prev = g.add(f"down{s}", "conv", [prev], (batch, r, r, c_out),
                     flops=flops, param_elems=int(pcount))
        skip_nodes.append(prev)      # one skip edge per stage (stage-level IR)
        c = c_out
        if s < len(chs) - 1:
            r //= 2
            prev = g.add(f"down{s}/ds", "conv", [prev], (batch, r, r, c),
                         flops=2 * batch * r * r * 9 * c * c,
                         param_elems=9 * c * c + c)
    prev = g.add("mid", "conv", [prev], (batch, r, r, c),
                 flops=2 * batch * r * r * (18 * c * c) + 2 * batch
                 * (r * r) ** 2 * c * 2,
                 param_elems=18 * c * c + 16 * c * c)
    for s in reversed(range(len(chs))):
        c_out = chs[s]
        sk = skip_nodes.pop() if skip_nodes else None
        inputs = [prev] + ([sk] if sk else [])
        flops = (2 * batch * r * r * (9 * 2 * c * c_out + 9 * c_out ** 2)
                 * (cfg.n_res_blocks + 1))
        prev = g.add(f"up{s}", "conv", inputs, (batch, r, r, c_out),
                     flops=flops,
                     param_elems=(cfg.n_res_blocks + 1)
                     * (18 * c * c_out + cfg.t_dim * c_out))
        c = c_out
        if s > 0:
            r *= 2
    g.add("conv_out", "conv", [prev], (batch, r, r, cfg.in_ch),
          flops=2 * batch * r * r * 9 * c * cfg.in_ch,
          param_elems=9 * c * cfg.in_ch + cfg.in_ch)
    g.validate()
    return g
