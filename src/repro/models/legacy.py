"""The paper's own benchmark networks: AlexNet, VGG16, GoogLeNet.

(ResNet-18 lives in ``repro.models.resnet``.)  These are the Table 3 /
Fig 3 subjects; graphs are exact at the paper's input resolutions so the
partition benchmark reproduces the paper's candidate sets (AlexNet
``conv5``, VGG16 ``conv1_2``, GoogLeNet ``conv2`` as the interesting cuts).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.graph import LayerGraph
from repro.models import layers as L
from repro.models.layers import QuantCtx

Params = Dict[str, Any]


def lrn(x: jax.Array, *, n: int = 5, alpha: float = 1e-4, beta: float = 0.75,
        k: float = 2.0) -> jax.Array:
    """AlexNet/GoogLeNet local response normalization (channel-wise)."""
    sq = jnp.square(x)
    c = x.shape[-1]
    pad = n // 2
    sq_pad = jnp.pad(sq, [(0, 0)] * (x.ndim - 1) + [(pad, pad)])
    windows = sum(sq_pad[..., i:i + c] for i in range(n))
    return x / jnp.power(k + alpha * windows, beta)


# ---------------------------------------------------------------------------
# AlexNet (227x227)
# ---------------------------------------------------------------------------

ALEXNET_CONVS = [
    # name, k, stride, pad, c_out, lrn?, pool?
    ("conv1", 11, 4, "VALID", 96, True, True),
    ("conv2", 5, 1, "SAME", 256, True, True),
    ("conv3", 3, 1, "SAME", 384, False, False),
    ("conv4", 3, 1, "SAME", 384, False, False),
    ("conv5", 3, 1, "SAME", 256, False, True),
]
ALEXNET_FCS = [("fc6", 4096), ("fc7", 4096), ("fc8", 1000)]


def init_alexnet(key, *, dtype=jnp.float32, img_res: int = 227) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {}
    c_in = 3
    for i, (name, k, s, pad, c_out, _, _) in enumerate(ALEXNET_CONVS):
        p[name] = L.conv2d_init(ks[i], k, c_in, c_out, dtype=dtype)
        c_in = c_out
    spatial = _alexnet_spatial(img_res)[-1]
    d_in = 256 * spatial * spatial
    for i, (name, width) in enumerate(ALEXNET_FCS):
        p[name] = L.dense_init(ks[5 + i], d_in, width, dtype=dtype)
        d_in = width
    return p


def _alexnet_spatial(img: int) -> List[int]:
    out = []
    r = img
    for name, k, s, pad, c_out, _, pool in ALEXNET_CONVS:
        if pad == "VALID":
            r = (r - k) // s + 1
        else:
            r = (r + s - 1) // s
        if pool:
            r = (r - 3) // 2 + 1
        out.append(r)
    return out


def alexnet_forward(params: Params, img: jax.Array, *,
                    qctx: Optional[QuantCtx] = None) -> jax.Array:
    x = img
    for name, k, s, pad, c_out, use_lrn, pool in ALEXNET_CONVS:
        x = L.conv2d(params[name], x, stride=s, padding=pad, qctx=qctx,
                     name=name, act="relu")
        if use_lrn:
            x = lrn(x)
        if pool:
            x = L.maxpool2d(x, window=3, stride=2, padding="VALID")
    x = x.reshape(x.shape[0], -1)
    for name, width in ALEXNET_FCS:
        act = "relu" if name != "fc8" else None
        x = L.dense(params[name], x, qctx=qctx, name=name, act=act)
    return x


def alexnet_graph(*, batch: int = 1, img_res: int = 227) -> LayerGraph:
    g = LayerGraph("alexnet")
    g.add("input", "input", [], (batch, img_res, img_res, 3))
    prev = "input"
    c_in, r_prev = 3, img_res
    spatials = _alexnet_spatial(img_res)
    rs_prepool = []
    r = img_res
    for name, k, s, pad, c_out, _, pool in ALEXNET_CONVS:
        r = (r - k) // s + 1 if pad == "VALID" else (r + s - 1) // s
        rs_prepool.append(r)
        if pool:
            r = (r - 3) // 2 + 1
    for i, (name, k, s, pad, c_out, use_lrn, pool) in enumerate(ALEXNET_CONVS):
        rp = rs_prepool[i]
        ro = spatials[i]
        prev = g.add(name, "conv", [prev], (batch, ro, ro, c_out),
                     flops=2 * batch * rp * rp * k * k * c_in * c_out,
                     param_elems=k * k * c_in * c_out + c_out)
        c_in = c_out
    d_in = 256 * spatials[-1] ** 2
    for name, width in ALEXNET_FCS:
        prev = g.add(name, "dense", [prev], (batch, width),
                     flops=2 * batch * d_in * width,
                     param_elems=d_in * width + width)
        d_in = width
    g.validate()
    return g


def alexnet_segments(params: Params, *, img_res: int = 227):
    from repro.core.collab import Segment, SegmentedModel

    def mk_conv(name, k, s, pad, use_lrn, pool):
        def apply(p, x, *, qctx=None):
            x = L.conv2d(p, x, stride=s, padding=pad, qctx=qctx, name=name,
                         act="relu")
            if use_lrn:
                x = lrn(x)
            if pool:
                x = L.maxpool2d(x, window=3, stride=2, padding="VALID")
            return x
        return apply

    def mk_fc(name, last):
        def apply(p, x, *, qctx=None):
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            return L.dense(p, x, qctx=qctx, name=name,
                           act=None if last else "relu")
        return apply

    segs = []
    for name, k, s, pad, c_out, use_lrn, pool in ALEXNET_CONVS:
        segs.append(Segment(name, mk_conv(name, k, s, pad, use_lrn, pool),
                            params[name]))
    for name, width in ALEXNET_FCS:
        segs.append(Segment(name, mk_fc(name, name == "fc8"), params[name]))
    return SegmentedModel(name="alexnet",
                          graph=alexnet_graph(img_res=img_res),
                          segments=segs)


# ---------------------------------------------------------------------------
# VGG16 (224x224)
# ---------------------------------------------------------------------------

VGG_PLAN = [  # (stage, n_convs, c_out)
    (1, 2, 64), (2, 2, 128), (3, 3, 256), (4, 3, 512), (5, 3, 512)]
VGG_FCS = [("fc6", 4096), ("fc7", 4096), ("fc8", 1000)]


def init_vgg16(key, *, dtype=jnp.float32) -> Params:
    n = sum(c for _, c, _ in VGG_PLAN) + 3
    ks = jax.random.split(key, n)
    p: Params = {}
    i, c_in = 0, 3
    for stage, n_convs, c_out in VGG_PLAN:
        for j in range(n_convs):
            p[f"conv{stage}_{j + 1}"] = L.conv2d_init(ks[i], 3, c_in, c_out,
                                                      dtype=dtype)
            c_in = c_out
            i += 1
    d_in = 512 * 7 * 7
    for name, width in VGG_FCS:
        p[name] = L.dense_init(ks[i], d_in, width, dtype=dtype)
        d_in = width
        i += 1
    return p


def vgg16_forward(params: Params, img: jax.Array, *,
                  qctx: Optional[QuantCtx] = None) -> jax.Array:
    x = img
    for stage, n_convs, c_out in VGG_PLAN:
        for j in range(n_convs):
            name = f"conv{stage}_{j + 1}"
            x = L.conv2d(params[name], x, qctx=qctx, name=name, act="relu")
        x = L.maxpool2d(x, window=2, stride=2, padding="VALID")
    x = x.reshape(x.shape[0], -1)
    for name, width in VGG_FCS:
        x = L.dense(params[name], x, qctx=qctx, name=name,
                    act="relu" if name != "fc8" else None)
    return x


def vgg16_graph(*, batch: int = 1, img_res: int = 224) -> LayerGraph:
    g = LayerGraph("vgg16")
    g.add("input", "input", [], (batch, img_res, img_res, 3))
    prev = "input"
    c_in, r = 3, img_res
    for stage, n_convs, c_out in VGG_PLAN:
        for j in range(n_convs):
            name = f"conv{stage}_{j + 1}"
            out_r = r if j < n_convs - 1 else r // 2   # pool folds into last
            prev = g.add(name, "conv", [prev], (batch, out_r, out_r, c_out),
                         flops=2 * batch * r * r * 9 * c_in * c_out,
                         param_elems=9 * c_in * c_out + c_out)
            c_in = c_out
        r //= 2
    d_in = 512 * r * r
    for name, width in VGG_FCS:
        prev = g.add(name, "dense", [prev], (batch, width),
                     flops=2 * batch * d_in * width,
                     param_elems=d_in * width + width)
        d_in = width
    g.validate()
    return g


def vgg16_segments(params: Params):
    from repro.core.collab import Segment, SegmentedModel

    def mk_conv(name, pool):
        def apply(p, x, *, qctx=None):
            x = L.conv2d(p, x, qctx=qctx, name=name, act="relu")
            if pool:
                x = L.maxpool2d(x, window=2, stride=2, padding="VALID")
            return x
        return apply

    def mk_fc(name, last):
        def apply(p, x, *, qctx=None):
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            return L.dense(p, x, qctx=qctx, name=name,
                           act=None if last else "relu")
        return apply

    segs = []
    for stage, n_convs, c_out in VGG_PLAN:
        for j in range(n_convs):
            name = f"conv{stage}_{j + 1}"
            segs.append(Segment(name, mk_conv(name, j == n_convs - 1),
                                params[name]))
    for name, _ in VGG_FCS:
        segs.append(Segment(name, mk_fc(name, name == "fc8"), params[name]))
    return SegmentedModel(name="vgg16", graph=vgg16_graph(), segments=segs)


# ---------------------------------------------------------------------------
# GoogLeNet (224x224) — 9 inception modules
# ---------------------------------------------------------------------------

# (name, b1, b2_in, b2_out, b3_in, b3_out, b4, pool_after)
INCEPTIONS = [
    ("inc3a", 64, 96, 128, 16, 32, 32, False),
    ("inc3b", 128, 128, 192, 32, 96, 64, True),
    ("inc4a", 192, 96, 208, 16, 48, 64, False),
    ("inc4b", 160, 112, 224, 24, 64, 64, False),
    ("inc4c", 128, 128, 256, 24, 64, 64, False),
    ("inc4d", 112, 144, 288, 32, 64, 64, False),
    ("inc4e", 256, 160, 320, 32, 128, 128, True),
    ("inc5a", 256, 160, 320, 32, 128, 128, False),
    ("inc5b", 384, 192, 384, 48, 128, 128, False),
]


def _inc_out(spec) -> int:
    _, b1, _, b2o, _, b3o, b4, _ = spec
    return b1 + b2o + b3o + b4


def init_googlenet(key, *, dtype=jnp.float32) -> Params:
    ks = iter(jax.random.split(key, 4 + 6 * len(INCEPTIONS) + 1))
    p: Params = {
        "conv1": L.conv2d_init(next(ks), 7, 3, 64, dtype=dtype),
        "conv2_reduce": L.conv2d_init(next(ks), 1, 64, 64, dtype=dtype),
        "conv2": L.conv2d_init(next(ks), 3, 64, 192, dtype=dtype),
    }
    c_in = 192
    for spec in INCEPTIONS:
        name, b1, b2i, b2o, b3i, b3o, b4, _ = spec
        p[name] = {
            "b1": L.conv2d_init(next(ks), 1, c_in, b1, dtype=dtype),
            "b2a": L.conv2d_init(next(ks), 1, c_in, b2i, dtype=dtype),
            "b2b": L.conv2d_init(next(ks), 3, b2i, b2o, dtype=dtype),
            "b3a": L.conv2d_init(next(ks), 1, c_in, b3i, dtype=dtype),
            "b3b": L.conv2d_init(next(ks), 5, b3i, b3o, dtype=dtype),
            "b4": L.conv2d_init(next(ks), 1, c_in, b4, dtype=dtype),
        }
        c_in = _inc_out(spec)
    p["fc"] = L.dense_init(next(ks), 1024, 1000, dtype=dtype)
    return p


def _inception_apply(p: Params, x: jax.Array, name: str, *,
                     qctx: Optional[QuantCtx] = None) -> jax.Array:
    y1 = L.conv2d(p["b1"], x, qctx=qctx, name=f"{name}/b1", act="relu")
    y2 = L.conv2d(p["b2a"], x, qctx=qctx, name=f"{name}/b2a", act="relu")
    y2 = L.conv2d(p["b2b"], y2, qctx=qctx, name=f"{name}/b2b", act="relu")
    y3 = L.conv2d(p["b3a"], x, qctx=qctx, name=f"{name}/b3a", act="relu")
    y3 = L.conv2d(p["b3b"], y3, qctx=qctx, name=f"{name}/b3b", act="relu")
    y4 = L.maxpool2d(x, window=3, stride=1)
    y4 = L.conv2d(p["b4"], y4, qctx=qctx, name=f"{name}/b4", act="relu")
    return jnp.concatenate([y1, y2, y3, y4], axis=-1)


def googlenet_forward(params: Params, img: jax.Array, *,
                      qctx: Optional[QuantCtx] = None) -> jax.Array:
    x = L.conv2d(params["conv1"], img, stride=2, qctx=qctx, name="conv1",
                 act="relu")
    x = L.maxpool2d(x, window=3, stride=2)
    x = lrn(x)
    x = L.conv2d(params["conv2_reduce"], x, qctx=qctx, name="conv2_reduce",
                 act="relu")
    x = L.conv2d(params["conv2"], x, qctx=qctx, name="conv2", act="relu")
    x = lrn(x)
    x = L.maxpool2d(x, window=3, stride=2)
    for spec in INCEPTIONS:
        x = _inception_apply(params[spec[0]], x, spec[0], qctx=qctx)
        if spec[-1]:
            x = L.maxpool2d(x, window=3, stride=2)
    x = jnp.mean(x, axis=(1, 2))
    return L.dense(params["fc"], x, qctx=qctx, name="fc")


def googlenet_graph(*, batch: int = 1, img_res: int = 224) -> LayerGraph:
    g = LayerGraph("googlenet")
    g.add("input", "input", [], (batch, img_res, img_res, 3))
    r = img_res // 2
    g.add("conv1", "conv", ["input"], (batch, r // 2, r // 2, 64),
          flops=2 * batch * r * r * 49 * 3 * 64, param_elems=49 * 3 * 64 + 64)
    r //= 2
    g.add("conv2_reduce", "conv", ["conv1"], (batch, r, r, 64),
          flops=2 * batch * r * r * 64 * 64, param_elems=64 * 64 + 64)
    g.add("conv2", "conv", ["conv2_reduce"], (batch, r // 2, r // 2, 192),
          flops=2 * batch * r * r * 9 * 64 * 192,
          param_elems=9 * 64 * 192 + 192)
    r //= 2
    prev = "conv2"
    c_in = 192
    for spec in INCEPTIONS:
        name, b1, b2i, b2o, b3i, b3o, b4, pool = spec
        c_out = _inc_out(spec)

        def cflops(k, ci, co):
            return 2 * batch * r * r * k * k * ci * co

        n1 = g.add(f"{name}/b1", "conv", [prev], (batch, r, r, b1),
                   flops=cflops(1, c_in, b1), param_elems=c_in * b1 + b1)
        n2a = g.add(f"{name}/b2a", "conv", [prev], (batch, r, r, b2i),
                    flops=cflops(1, c_in, b2i), param_elems=c_in * b2i + b2i)
        n2b = g.add(f"{name}/b2b", "conv", [n2a], (batch, r, r, b2o),
                    flops=cflops(3, b2i, b2o), param_elems=9 * b2i * b2o + b2o)
        n3a = g.add(f"{name}/b3a", "conv", [prev], (batch, r, r, b3i),
                    flops=cflops(1, c_in, b3i), param_elems=c_in * b3i + b3i)
        n3b = g.add(f"{name}/b3b", "conv", [n3a], (batch, r, r, b3o),
                    flops=cflops(5, b3i, b3o),
                    param_elems=25 * b3i * b3o + b3o)
        n4p = g.add(f"{name}/pool", "maxpool", [prev], (batch, r, r, c_in))
        n4 = g.add(f"{name}/b4", "conv", [n4p], (batch, r, r, b4),
                   flops=cflops(1, c_in, b4), param_elems=c_in * b4 + b4)
        out_r = r // 2 if pool else r
        prev = g.add(f"{name}/concat", "concat", [n1, n2b, n3b, n4],
                     (batch, out_r, out_r, c_out))
        if pool:
            r //= 2
        c_in = c_out
    g.add("fc", "dense", [prev], (batch, 1000),
          flops=2 * batch * 1024 * 1000, param_elems=1024 * 1000 + 1000)
    g.validate()
    return g


def googlenet_segments(params: Params):
    from repro.core.collab import Segment, SegmentedModel

    def stem1(p, x, *, qctx=None):
        x = L.conv2d(p, x, stride=2, qctx=qctx, name="conv1", act="relu")
        x = L.maxpool2d(x, window=3, stride=2)
        return lrn(x)

    def stem2r(p, x, *, qctx=None):
        return L.conv2d(p, x, qctx=qctx, name="conv2_reduce", act="relu")

    def stem2(p, x, *, qctx=None):
        x = L.conv2d(p, x, qctx=qctx, name="conv2", act="relu")
        x = lrn(x)
        return L.maxpool2d(x, window=3, stride=2)

    def mk_inc(spec):
        def apply(p, x, *, qctx=None):
            y = _inception_apply(p, x, spec[0], qctx=qctx)
            if spec[-1]:
                y = L.maxpool2d(y, window=3, stride=2)
            return y
        return apply

    def head(p, x, *, qctx=None):
        x = jnp.mean(x, axis=(1, 2))
        return L.dense(p, x, qctx=qctx, name="fc")

    segs = [Segment("conv1", stem1, params["conv1"]),
            Segment("conv2_reduce", stem2r, params["conv2_reduce"]),
            Segment("conv2", stem2, params["conv2"])]
    for spec in INCEPTIONS:
        # the concat fuses into the topo-latest branch conv (b4)
        segs.append(Segment(f"{spec[0]}/b4", mk_inc(spec), params[spec[0]]))
    segs.append(Segment("fc", head, params["fc"]))
    return SegmentedModel(name="googlenet", graph=googlenet_graph(),
                          segments=segs)
