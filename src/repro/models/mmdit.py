"""Flux-dev-style MM-DiT rectified-flow transformer (BFL tech report).

19 double-stream blocks (separate img/txt streams, joint attention) +
38 single-stream blocks (fused stream), d_model=3072, 24 heads, ~12B
params.  Conditioning vector (timestep ⊕ pooled text) drives adaLN
modulation.  The modality frontend is a STUB per the assignment: inputs
are precomputed latent patches [B, N_img, 64] and text embeddings
[B, N_txt, 4096].

Positional treatment: 2D sin-cos embeddings on image tokens (axial),
none on text (simplification of Flux's axial RoPE — noted in DESIGN.md).

Partition-analysis view: the double blocks carry TWO live residual
streams, so no interior single-blob cut exists; with the DESIGN.md §4
multi-stream extension (max_blobs=2) the double-block boundaries become
candidates, and after the streams merge the single blocks are ordinary
1-blob boundaries.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import LayerGraph
from repro.models import layers as L
from repro.models.layers import QuantCtx

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MMDiTConfig:
    name: str
    n_double: int = 19
    n_single: int = 38
    d_model: int = 3072
    n_heads: int = 24
    img_res: int = 1024           # pixel; latent = /8, patch 2x2 of 16ch
    txt_len: int = 512
    txt_dim: int = 4096
    vec_dim: int = 768
    in_ch: int = 64               # 16 latent channels x 2x2 patch
    mlp_ratio: int = 4
    dtype: Any = jnp.float32
    remat: bool = True
    scan_unroll: int = 1
    act_pspec: Optional[tuple] = None   # stream sharding constraint

    @property
    def n_img_tokens(self) -> int:
        return (self.img_res // 16) ** 2     # /8 VAE, /2 patch

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, m = self.d_model, self.mlp_ratio
        dbl = 2 * (4 * d * d + 4 * d + 2 * m * d * d + m * d + d
                   + 6 * d * d + 6 * d)          # per stream: attn+mlp+mod
        sgl = (3 + m) * d * d + (3 + m) * d + (d * (1 + m) * d) + d \
            + 3 * d * d + 3 * d                  # fused qkv+mlp_in, out, mod
        return (self.in_ch * d + d + self.txt_dim * d + d
                + self.vec_dim * d + d + 256 * d + d + d * d + d
                + self.n_double * dbl + self.n_single * sgl
                + d * 2 + 2 * d * self.in_ch + self.in_ch + self.in_ch)


def pos_embed_2d(n: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Axial sin-cos embedding for an n-token square grid."""
    side = int(math.sqrt(n))
    half = d // 2
    freqs = 1.0 / (10000 ** (jnp.arange(half // 2) / (half // 2)))
    pos = jnp.arange(side, dtype=jnp.float32)
    ang = jnp.outer(pos, freqs)
    emb1d = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)  # [side, half]
    row = jnp.repeat(emb1d[:, None, :], side, axis=1)
    col = jnp.repeat(emb1d[None, :, :], side, axis=0)
    return jnp.concatenate([row, col], -1).reshape(n, d).astype(dtype)


def _mod_init(key, vec_dim, d, n_mod, dtype):
    return L.dense_init(key, vec_dim, n_mod * d, dtype=dtype)


def _mod(p, vec, n_mod, d):
    m = L.dense(p, jax.nn.silu(vec))
    return jnp.split(m[:, None, :], n_mod, axis=-1)


def double_block_init(key, cfg: MMDiTConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    def stream(k1, k2, k3):
        return {
            "attn": L.attention_init(k1, d, cfg.n_heads, cfg.n_heads,
                                     dtype=cfg.dtype),
            "mlp": L.mlp_init(k2, d, cfg.mlp_ratio * d, dtype=cfg.dtype),
            "mod": _mod_init(k3, d, d, 6, cfg.dtype),
        }
    return {"img": stream(ks[0], ks[1], ks[2]),
            "txt": stream(ks[3], ks[4], ks[5])}


def single_block_init(key, cfg: MMDiTConfig) -> Params:
    d, m = cfg.d_model, cfg.mlp_ratio
    ks = jax.random.split(key, 3)
    return {
        "in": L.dense_init(ks[0], d, (3 + m) * d, dtype=cfg.dtype),
        "out": L.dense_init(ks[1], (1 + m) * d, d, dtype=cfg.dtype),
        "mod": _mod_init(ks[2], d, d, 3, cfg.dtype),
    }


def init_mmdit(key, cfg: MMDiTConfig) -> Params:
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    return {
        "img_in": L.dense_init(ks[0], cfg.in_ch, d, dtype=cfg.dtype),
        "txt_in": L.dense_init(ks[1], cfg.txt_dim, d, dtype=cfg.dtype),
        "vec_in": L.dense_init(ks[2], cfg.vec_dim, d, dtype=cfg.dtype),
        "t_in": L.dense_init(ks[3], 256, d, dtype=cfg.dtype),
        "t_in2": L.dense_init(ks[4], d, d, dtype=cfg.dtype),
        "double": jax.vmap(lambda k: double_block_init(k, cfg))(
            jax.random.split(ks[5], cfg.n_double)),
        "single": jax.vmap(lambda k: single_block_init(k, cfg))(
            jax.random.split(ks[6], cfg.n_single)),
        "final_mod": _mod_init(ks[7], d, d, 2, cfg.dtype),
        "final": L.dense_init(ks[8], d, cfg.in_ch, dtype=cfg.dtype),
    }


def _joint_attn(pi, pt, img, txt, vec, cfg, qctx, name):
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.hd
    b, ni, _ = img.shape
    nt = txt.shape[1]
    (i_a, i_b, i_g, i_d, i_e, i_f) = _mod(pi["mod"], vec, 6, d)
    (t_a, t_b, t_g, t_d, t_e, t_f) = _mod(pt["mod"], vec, 6, d)

    def ln(x):
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.var(x, -1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + 1e-6)

    zi = ln(img) * (1 + i_a) + i_b
    zt = ln(txt) * (1 + t_a) + t_b

    def qkv(p, z, nm):
        qh = L.dense(p["attn"]["wq"], z, qctx=qctx, name=f"{nm}/q")
        kh = L.dense(p["attn"]["wk"], z, qctx=qctx, name=f"{nm}/k")
        vh = L.dense(p["attn"]["wv"], z, qctx=qctx, name=f"{nm}/v")
        return (t.reshape(b, -1, nh, hd) for t in (qh, kh, vh))

    qi, ki, vi = qkv(pi, zi, f"{name}/img")
    qt, kt, vt = qkv(pt, zt, f"{name}/txt")
    qh = jnp.concatenate([qt, qi], axis=1)
    kh = jnp.concatenate([kt, ki], axis=1)
    vh = jnp.concatenate([vt, vi], axis=1)
    att = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) / math.sqrt(hd)
    att = jax.nn.softmax(att.astype(jnp.float32), -1).astype(img.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", att, vh).reshape(b, nt + ni, d)
    ot, oi = o[:, :nt], o[:, nt:]
    img = img + i_g * L.dense(pi["attn"]["wo"], oi, qctx=qctx,
                              name=f"{name}/img/o")
    txt = txt + t_g * L.dense(pt["attn"]["wo"], ot, qctx=qctx,
                              name=f"{name}/txt/o")
    img = img + i_f * L.mlp(pi["mlp"], ln(img) * (1 + i_d) + i_e, qctx=qctx,
                            name=f"{name}/img/mlp")
    txt = txt + t_f * L.mlp(pt["mlp"], ln(txt) * (1 + t_d) + t_e, qctx=qctx,
                            name=f"{name}/txt/mlp")
    return img, txt


def _single_block(p, x, vec, cfg, qctx, name):
    d, nh, hd, m = cfg.d_model, cfg.n_heads, cfg.hd, cfg.mlp_ratio
    b, n, _ = x.shape
    (a, bb, g) = _mod(p["mod"], vec, 3, d)
    mu = jnp.mean(x, -1, keepdims=True)
    z = (x - mu) * jax.lax.rsqrt(jnp.var(x, -1, keepdims=True) + 1e-6)
    z = z * (1 + a) + bb
    h = L.dense(p["in"], z, qctx=qctx, name=f"{name}/in")
    qh, kh, vh, mlp_h = jnp.split(h, [d, 2 * d, 3 * d], axis=-1)
    qh = qh.reshape(b, n, nh, hd)
    kh = kh.reshape(b, n, nh, hd)
    vh = vh.reshape(b, n, nh, hd)
    att = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) / math.sqrt(hd)
    att = jax.nn.softmax(att.astype(jnp.float32), -1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", att, vh).reshape(b, n, d)
    fused = jnp.concatenate([o, jax.nn.gelu(mlp_h)], axis=-1)
    return x + g * L.dense(p["out"], fused, qctx=qctx, name=f"{name}/out")


def mmdit_forward(params: Params, img_patches: jax.Array, t: jax.Array,
                  txt: jax.Array, vec: jax.Array, cfg: MMDiTConfig, *,
                  qctx: Optional[QuantCtx] = None) -> jax.Array:
    """img_patches [B, N_img, 64], t [B], txt [B, N_txt, 4096],
    vec [B, 768] → velocity [B, N_img, 64]."""
    from repro.models.unet import timestep_embed
    b, ni, _ = img_patches.shape
    d = cfg.d_model
    img = L.dense(params["img_in"], img_patches.astype(cfg.dtype))
    img = img + pos_embed_2d(ni, d, cfg.dtype)[None]
    txt_h = L.dense(params["txt_in"], txt.astype(cfg.dtype))
    temb = L.dense(params["t_in"], timestep_embed(t, 256).astype(cfg.dtype))
    vec_h = L.dense(params["vec_in"], vec.astype(cfg.dtype)) \
        + L.dense(params["t_in2"], jax.nn.silu(temb))

    def constrain(z):
        if cfg.act_pspec is None:
            return z
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(z, P(*cfg.act_pspec))

    def dbl_body(carry, bp):
        img, txt_h = carry
        img, txt_h = _joint_attn(bp["img"], bp["txt"], img, txt_h, vec_h,
                                 cfg, qctx, "dbl")
        return (constrain(img), constrain(txt_h)), None

    body = jax.checkpoint(dbl_body) if cfg.remat else dbl_body
    (img, txt_h), _ = jax.lax.scan(body, (img, txt_h), params["double"],
                                   unroll=cfg.scan_unroll)

    x = jnp.concatenate([txt_h, img], axis=1)

    def sgl_body(x, bp):
        return constrain(_single_block(bp, x, vec_h, cfg, qctx, "sgl")), None

    body = jax.checkpoint(sgl_body) if cfg.remat else sgl_body
    x, _ = jax.lax.scan(body, x, params["single"], unroll=cfg.scan_unroll)
    img = x[:, txt_h.shape[1]:]

    (sa, sb) = _mod(params["final_mod"], vec_h, 2, d)
    mu = jnp.mean(img, -1, keepdims=True)
    z = (img - mu) * jax.lax.rsqrt(jnp.var(img, -1, keepdims=True) + 1e-6)
    z = z * (1 + sa) + sb
    return L.dense(params["final"], z)


def rf_loss(params: Params, batch: Dict[str, jax.Array], cfg: MMDiTConfig, *,
            rng: jax.Array) -> jax.Array:
    """Rectified-flow velocity matching: v = x1 - x0 at x_t = (1-t)x0 + t·x1."""
    x0 = batch["latent"]                       # clean patches [B, N, 64]
    b = x0.shape[0]
    k_t, k_e = jax.random.split(rng)
    t = jax.random.uniform(k_t, (b,))
    x1 = jax.random.normal(k_e, x0.shape, x0.dtype)
    x_t = (1 - t[:, None, None]) * x0 + t[:, None, None] * x1
    v_pred = mmdit_forward(params, x_t, t * 1000, batch["txt"], batch["vec"],
                           cfg)
    v_true = x1 - x0
    return jnp.mean(jnp.square(v_pred.astype(jnp.float32)
                               - v_true.astype(jnp.float32)))


def rf_step(params: Params, x_t: jax.Array, t: jax.Array, dt: jax.Array,
            txt: jax.Array, vec: jax.Array, cfg: MMDiTConfig) -> jax.Array:
    """One Euler step of the rectified-flow ODE (gen_* dry-run unit)."""
    v = mmdit_forward(params, x_t, t * 1000, txt, vec, cfg)
    return x_t - dt[:, None, None] * v


def make_graph(cfg: MMDiTConfig, *, batch: int) -> LayerGraph:
    """Dual-stream region (double blocks) then single-stream region."""
    g = LayerGraph(cfg.name)
    d, ni, nt = cfg.d_model, cfg.n_img_tokens, cfg.txt_len
    n_all = ni + nt
    g.add("input", "input", [], (batch, ni, cfg.in_ch))
    g.add("img_in", "dense", ["input"], (batch, ni, d),
          flops=2 * batch * ni * cfg.in_ch * d, param_elems=cfg.in_ch * d + d)
    g.add("txt_in", "dense", ["input"], (batch, nt, d),
          flops=2 * batch * nt * cfg.txt_dim * d,
          param_elems=cfg.txt_dim * d + d, parametric=True)
    img_prev, txt_prev = "img_in", "txt_in"
    dbl_flops_stream = (2 * batch * ni * d * d * 4
                        + 2 * batch * ni * d * cfg.mlp_ratio * d * 2
                        + 2 * batch * cfg.n_heads * n_all * n_all * cfg.hd)
    dbl_params_stream = (4 * d * d + 2 * cfg.mlp_ratio * d * d + 6 * d * d)
    for i in range(cfg.n_double):
        ni_ = g.add(f"dbl{i}/img", "attention", [img_prev, txt_prev],
                    (batch, ni, d), flops=dbl_flops_stream,
                    param_elems=dbl_params_stream)
        nt_ = g.add(f"dbl{i}/txt", "attention", [txt_prev, img_prev],
                    (batch, nt, d), flops=dbl_flops_stream * nt // ni,
                    param_elems=dbl_params_stream)
        img_prev, txt_prev = ni_, nt_
    prev = g.add("merge", "concat", [txt_prev, img_prev], (batch, n_all, d))
    sgl_flops = (2 * batch * n_all * d * (3 + cfg.mlp_ratio) * d
                 + 2 * batch * n_all * (1 + cfg.mlp_ratio) * d * d
                 + 2 * batch * cfg.n_heads * n_all * n_all * cfg.hd)
    sgl_params = (3 + cfg.mlp_ratio) * d * d + (1 + cfg.mlp_ratio) * d * d \
        + 3 * d * d
    for i in range(cfg.n_single):
        prev = g.add(f"sgl{i}", "attention", [prev], (batch, n_all, d),
                     flops=sgl_flops, param_elems=sgl_params)
    g.add("final", "dense", [prev], (batch, ni, cfg.in_ch),
          flops=2 * batch * ni * d * cfg.in_ch,
          param_elems=d * cfg.in_ch + cfg.in_ch + 2 * d * d)
    g.validate()
    return g
