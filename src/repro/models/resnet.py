"""ResNets: resnet-152 (assigned) and resnet-18 (paper baseline, Table 3).

Bottleneck (152) and basic (18) residual blocks; normalization is
GroupNorm(32) instead of BatchNorm — a documented TPU/distribution
adaptation (no cross-replica batch-stats sync; see DESIGN.md §3).  The
residual structure is what the paper's §2.2 shortcut rule consumes:
candidates are exactly the block boundaries (post-add), reproducing the
paper's ``res4a``-style cut points for ResNet-18.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import LayerGraph
from repro.models import layers as L
from repro.models.layers import QuantCtx

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    depths: Tuple[int, int, int, int]
    width: int = 64
    bottleneck: bool = True
    n_classes: int = 1000
    img_res: int = 224
    dtype: Any = jnp.float32

    @property
    def expansion(self) -> int:
        return 4 if self.bottleneck else 1

    def stage_channels(self, s: int) -> int:
        return self.width * (2 ** s)


def _block_init(key, c_in: int, c_mid: int, c_out: int, *, bottleneck: bool,
                stride: int, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {}
    if bottleneck:
        p["conv1"] = L.conv2d_init(ks[0], 1, c_in, c_mid, bias=False, dtype=dtype)
        p["conv2"] = L.conv2d_init(ks[1], 3, c_mid, c_mid, bias=False, dtype=dtype)
        p["conv3"] = L.conv2d_init(ks[2], 1, c_mid, c_out, bias=False, dtype=dtype)
        p["n1"] = L.norm_init(c_mid, dtype=dtype)
        p["n2"] = L.norm_init(c_mid, dtype=dtype)
        p["n3"] = L.norm_init(c_out, dtype=dtype)
    else:
        p["conv1"] = L.conv2d_init(ks[0], 3, c_in, c_mid, bias=False, dtype=dtype)
        p["conv2"] = L.conv2d_init(ks[1], 3, c_mid, c_out, bias=False, dtype=dtype)
        p["n1"] = L.norm_init(c_mid, dtype=dtype)
        p["n2"] = L.norm_init(c_out, dtype=dtype)
    if stride != 1 or c_in != c_out:
        p["proj"] = L.conv2d_init(ks[3], 1, c_in, c_out, bias=False, dtype=dtype)
        p["nproj"] = L.norm_init(c_out, dtype=dtype)
    return p


def _block_apply(p: Params, x: jax.Array, *, bottleneck: bool, stride: int,
                 qctx: Optional[QuantCtx] = None, name: str = "blk") -> jax.Array:
    sc = x
    if "proj" in p:
        sc = L.conv2d(p["proj"], x, stride=stride, qctx=qctx,
                      name=f"{name}/proj")
        sc = L.groupnorm(p["nproj"], sc)
    if bottleneck:
        h = L.conv2d(p["conv1"], x, qctx=qctx, name=f"{name}/c1")
        h = jax.nn.relu(L.groupnorm(p["n1"], h))
        h = L.conv2d(p["conv2"], h, stride=stride, qctx=qctx, name=f"{name}/c2")
        h = jax.nn.relu(L.groupnorm(p["n2"], h))
        h = L.conv2d(p["conv3"], h, qctx=qctx, name=f"{name}/c3")
        h = L.groupnorm(p["n3"], h)
    else:
        h = L.conv2d(p["conv1"], x, stride=stride, qctx=qctx, name=f"{name}/c1")
        h = jax.nn.relu(L.groupnorm(p["n1"], h))
        h = L.conv2d(p["conv2"], h, qctx=qctx, name=f"{name}/c2")
        h = L.groupnorm(p["n2"], h)
    return jax.nn.relu(sc + h)


def _plan(cfg: ResNetConfig) -> List[dict]:
    """Flat list of block descriptors."""
    plan = []
    c_in = cfg.width
    for s, depth in enumerate(cfg.depths):
        c_mid = cfg.stage_channels(s)
        c_out = c_mid * cfg.expansion
        for b in range(depth):
            stride = 2 if (b == 0 and s > 0) else 1
            plan.append(dict(name=f"s{s + 1}b{b}", c_in=c_in, c_mid=c_mid,
                             c_out=c_out, stride=stride))
            c_in = c_out
    return plan


def init_resnet(key, cfg: ResNetConfig) -> Params:
    ks = jax.random.split(key, len(_plan(cfg)) + 3)
    p: Params = {
        "stem": L.conv2d_init(ks[0], 7, 3, cfg.width, bias=False,
                              dtype=cfg.dtype),
        "stem_n": L.norm_init(cfg.width, dtype=cfg.dtype),
    }
    for i, blk in enumerate(_plan(cfg)):
        p[blk["name"]] = _block_init(
            ks[i + 1], blk["c_in"], blk["c_mid"], blk["c_out"],
            bottleneck=cfg.bottleneck, stride=blk["stride"], dtype=cfg.dtype)
    c_last = cfg.stage_channels(3) * cfg.expansion
    p["head"] = L.dense_init(ks[-1], c_last, cfg.n_classes, dtype=cfg.dtype)
    return p


def forward(params: Params, img: jax.Array, cfg: ResNetConfig, *,
            qctx: Optional[QuantCtx] = None) -> jax.Array:
    x = L.conv2d(params["stem"], img.astype(cfg.dtype), stride=2, qctx=qctx,
                 name="stem")
    x = jax.nn.relu(L.groupnorm(params["stem_n"], x))
    x = L.maxpool2d(x, window=3, stride=2)
    for blk in _plan(cfg):
        x = _block_apply(params[blk["name"]], x, bottleneck=cfg.bottleneck,
                         stride=blk["stride"], qctx=qctx, name=blk["name"])
    x = jnp.mean(x, axis=(1, 2))
    return L.dense(params["head"], x, qctx=qctx, name="head")


def make_graph(cfg: ResNetConfig, *, batch: int) -> LayerGraph:
    g = LayerGraph(cfg.name)
    r = cfg.img_res
    g.add("input", "input", [], (batch, r, r, 3))
    r //= 2
    g.add("stem", "conv", ["input"], (batch, r, r, cfg.width),
          flops=2 * batch * r * r * 49 * 3 * cfg.width,
          param_elems=49 * 3 * cfg.width + 2 * cfg.width)
    r //= 2
    g.add("stem_pool", "maxpool", ["stem"], (batch, r, r, cfg.width))
    prev = "stem_pool"
    for blk in _plan(cfg):
        if blk["stride"] == 2:
            r //= 2
        c_in, c_mid, c_out = blk["c_in"], blk["c_mid"], blk["c_out"]
        if cfg.bottleneck:
            flops = 2 * batch * r * r * (c_in * c_mid + 9 * c_mid * c_mid
                                         + c_mid * c_out)
            pcount = c_in * c_mid + 9 * c_mid * c_mid + c_mid * c_out \
                + 2 * (2 * c_mid + c_out)
        else:
            flops = 2 * batch * r * r * (9 * c_in * c_mid + 9 * c_mid * c_out)
            pcount = 9 * c_in * c_mid + 9 * c_mid * c_out \
                + 2 * (c_mid + c_out)
        has_proj = blk["stride"] != 1 or c_in != c_out
        if has_proj:
            flops += 2 * batch * r * r * c_in * c_out
            pcount += c_in * c_out + 2 * c_out
        name = blk["name"]
        body = g.add(f"{name}/body", "conv", [prev],
                     (batch, r, r, c_out), flops=flops, param_elems=pcount)
        prev = g.add(f"{name}/add", "add", [body, prev],
                     (batch, r, r, c_out))
    c_last = cfg.stage_channels(3) * cfg.expansion
    g.add("head", "dense", [prev], (batch, cfg.n_classes),
          flops=2 * batch * c_last * cfg.n_classes,
          param_elems=c_last * cfg.n_classes + cfg.n_classes)
    g.validate()
    return g


def make_segments(params: Params, cfg: ResNetConfig):
    from repro.core.collab import Segment, SegmentedModel

    def stem_apply(p, img, *, qctx=None):
        x = L.conv2d(p["stem"], img.astype(cfg.dtype), stride=2, qctx=qctx,
                     name="stem")
        x = jax.nn.relu(L.groupnorm(p["stem_n"], x))
        return L.maxpool2d(x, window=3, stride=2)

    def mk_block(blk):
        def apply(p, x, *, qctx=None):
            return _block_apply(p, x, bottleneck=cfg.bottleneck,
                                stride=blk["stride"], qctx=qctx,
                                name=blk["name"])
        return apply

    def head_apply(p, x, *, qctx=None):
        x = jnp.mean(x, axis=(1, 2))
        return L.dense(p, x, qctx=qctx, name="head")

    segs = [Segment("stem", stem_apply,
                    {k: params[k] for k in ("stem", "stem_n")})]
    for blk in _plan(cfg):
        # the block's residual add fuses into its body node (§2.2)
        segs.append(Segment(f"{blk['name']}/body", mk_block(blk),
                            params[blk["name"]]))
    segs.append(Segment("head", head_apply, params["head"]))
    return SegmentedModel(name=cfg.name, graph=make_graph(cfg, batch=1),
                          segments=segs)
