"""Decoder-only LM family: dense (llama-arch) and MoE variants.

Covers the four assigned LM architectures (phi3-medium-14b, deepseek-7b,
qwen3-moe-30b-a3b, grok-1-314b): RoPE + GQA attention + SwiGLU (or MoE)
blocks, RMSNorm, untied LM head.

Layers are *stacked* (every block-param leaf carries a leading [L] axis)
and the forward pass scans over them — one compiled block body regardless
of depth, which is what makes the 512-device dry-run of a 64-layer model
compile in seconds (MaxText does the same).  Training wraps the block in
``jax.checkpoint`` (remat).

Partition-analysis view: each decoder block is wrapped by two residual
shortcuts, so by the paper's shortcut rule the only candidate cuts are
block boundaries (plus embed / final-norm / head) — see ``make_graph``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import LayerGraph
from repro.models import layers as L
from repro.models.layers import QuantCtx

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    moe: Optional[MoESpec] = None
    rope_base: float = 10000.0
    max_seq: int = 8192
    dtype: Any = jnp.float32          # params + compute dtype
    remat: bool = True
    q_chunk: Optional[int] = None     # flash-style q tiling for long prefill
    scan_unroll: int = 1              # lax.scan unroll (dry-run: n_layers,
                                      # so cost_analysis sees every layer)
    act_pspec: Optional[tuple] = None  # residual-stream sharding constraint,
                                       # e.g. (("pod","data"), None, "model");
                                       # resolved against the ambient mesh
    moe_shard: Optional[tuple] = None  # (batch_spec, model_axis): run MoE
                                       # under shard_map (production meshes)
    score_pspec: Optional[tuple] = None  # decode attention score layout,
                                         # e.g. (ba, None, None, "model")

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # -- parameter / FLOP accounting (MODEL_FLOPS = 6·N·D uses these) ------
    def block_param_count(self) -> int:
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv * hd) * 2
        norms = 2 * d
        if self.moe:
            ffn = self.moe.n_experts * 3 * d * self.d_ff + d * self.moe.n_experts
        else:
            ffn = 3 * d * self.d_ff
        return attn + ffn + norms

    def block_active_param_count(self) -> int:
        if not self.moe:
            return self.block_param_count()
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv * hd) * 2
        ffn = self.moe.top_k * 3 * d * self.d_ff + d * self.moe.n_experts
        return attn + ffn + 2 * d

    def param_count(self) -> int:
        return (self.vocab * self.d_model * 2 + self.d_model
                + self.n_layers * self.block_param_count())

    def active_param_count(self) -> int:
        return (self.vocab * self.d_model * 2 + self.d_model
                + self.n_layers * self.block_active_param_count())


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_block(key, cfg: LMConfig) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.norm_init(cfg.d_model, bias=False, dtype=cfg.dtype),
        "attn": L.attention_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                 cfg.hd, dtype=cfg.dtype),
        "ln2": L.norm_init(cfg.d_model, bias=False, dtype=cfg.dtype),
    }
    if cfg.moe:
        p["moe"] = L.moe_init(ks[1], cfg.d_model, cfg.d_ff,
                              cfg.moe.n_experts, dtype=cfg.dtype)
    else:
        p["mlp"] = L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype=cfg.dtype)
    return p


def init_lm(key, cfg: LMConfig) -> Params:
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(block_keys)
    return {
        "embed": L.embed_init(k_emb, cfg.vocab, cfg.d_model, dtype=cfg.dtype),
        "blocks": blocks,
        "final_norm": L.norm_init(cfg.d_model, bias=False, dtype=cfg.dtype),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.vocab, bias=False,
                                dtype=cfg.dtype),
    }


# ---------------------------------------------------------------------------
# Block + forward
# ---------------------------------------------------------------------------


def _constrain(x: jax.Array, cfg: LMConfig) -> jax.Array:
    if cfg.act_pspec is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*cfg.act_pspec))


def block_apply(p: Params, x: jax.Array, cfg: LMConfig, *,
                rope: Tuple[jax.Array, jax.Array],
                cache: Optional[Dict[str, jax.Array]] = None,
                cache_index: Optional[jax.Array] = None,
                qctx: Optional[QuantCtx] = None,
                kv_scales: Optional[Tuple[jax.Array, jax.Array]] = None,
                block_tables: Optional[jax.Array] = None,
                calibrate_kv: bool = False,
                kv_lengths: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    x = _constrain(x, cfg)
    h, new_cache = L.attention(
        p["attn"], L.rmsnorm(p["ln1"], x), n_heads=cfg.n_heads,
        n_kv=cfg.n_kv, causal=True, rope=rope, kv_cache=cache,
        cache_index=cache_index, qctx=qctx, q_chunk=cfg.q_chunk,
        kv_scales=kv_scales, block_tables=block_tables,
        calibrate_kv=calibrate_kv, kv_lengths=kv_lengths,
        score_pspec=cfg.score_pspec if cache is not None else None)
    # constrain the projection outputs too: the TP contraction's partial
    # sums then reduce-scatter straight into the sharded residual stream
    # instead of all-reducing a replicated copy (§Perf hillclimb #2)
    x = x + _constrain(h, cfg)
    z = L.rmsnorm(p["ln2"], x)
    if cfg.moe and cfg.moe_shard is not None:
        h, aux = L.moe_sharded(p["moe"], z, top_k=cfg.moe.top_k,
                               batch_spec=cfg.moe_shard[0],
                               model_axis=cfg.moe_shard[1],
                               capacity_factor=cfg.moe.capacity_factor,
                               qctx=qctx)
    elif cfg.moe:
        h, aux = L.moe(p["moe"], z, top_k=cfg.moe.top_k,
                       capacity_factor=cfg.moe.capacity_factor, qctx=qctx)
    else:
        h, aux = L.swiglu(p["mlp"], z, qctx=qctx), jnp.float32(0.0)
    return _constrain(x + _constrain(h, cfg), cfg), new_cache, aux


def forward(params: Params, tokens: jax.Array, cfg: LMConfig, *,
            qctx: Optional[QuantCtx] = None) -> Tuple[jax.Array, jax.Array]:
    """Full causal forward → (logits [B,S,V], moe aux loss)."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    rope = L.rope_table(s, cfg.hd, base=cfg.rope_base, dtype=cfg.dtype)

    def body(carry, bp):
        x, aux = carry
        x, _, a = block_apply(bp, x, cfg, rope=rope, qctx=qctx)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               params["blocks"], unroll=cfg.scan_unroll)
    x = L.rmsnorm(params["final_norm"], x)
    logits = L.dense(params["lm_head"], x, name="lm_head")
    return logits, aux


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: LMConfig,
            *, aux_weight: float = 0.01,
            qctx: Optional[QuantCtx] = None) -> jax.Array:
    logits, aux = forward(params, batch["tokens"], cfg, qctx=qctx)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux_weight * aux / cfg.n_layers


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None,
               *, quantized: bool = False,
               layers: Optional[int] = None,
               paged: bool = False, page_size: int = 16,
               num_pages: Optional[int] = None) -> Dict[str, jax.Array]:
    """Allocate a KV cache.  Three layouts:

    * **dense** (default): ``{"k", "v"}`` of shape
      ``[L, batch, max_len, n_kv, hd]`` — every slot pre-allocates
      ``max_len`` positions.
    * **dense + ``quantized=True``**: same shape at INT8 with
      per-(layer, kv-head) symmetric ``k_scale``/``v_scale`` ``[L, n_kv]``
      (calibrated off-line in deployment; init'd to a generic RMS).
    * **``paged=True``**: ``{"k_pages", "v_pages"}`` of shape
      ``[L, num_pages, page_size, n_kv, hd]`` — a shared pool of pages
      addressed through a per-slot block table (see
      ``serve.engine.PageAllocator``); HBM is claimed page-by-page on
      demand instead of ``max_len`` up front.  With ``quantized=True``
      the pages are INT8 and the scales are *per-slot*
      ``[L, batch, n_kv]``, calibrated from each prompt at prefill
      (``attention(calibrate_kv=True)``).  Page 0 is reserved as the
      dump page idle slots harmlessly write into.

    ``layers`` overrides the leading layer axis — cut-aware serving gives
    the edge prefix and the cloud suffix each their own cache covering
    only their block sub-range."""
    n_layers = cfg.n_layers if layers is None else layers
    if paged:
        n_pages = num_pages if num_pages is not None else (
            batch * ((max_len + page_size - 1) // page_size) + 1)
        pdtype = jnp.int8 if quantized else (dtype or cfg.dtype)
        shape = (n_layers, n_pages, page_size, cfg.n_kv, cfg.hd)
        c = {"k_pages": jnp.zeros(shape, pdtype),
             "v_pages": jnp.zeros(shape, pdtype)}
        if quantized:
            c["k_scale"] = jnp.full((n_layers, batch, cfg.n_kv), 0.05,
                                    jnp.float32)
            c["v_scale"] = jnp.full((n_layers, batch, cfg.n_kv), 0.05,
                                    jnp.float32)
        return c
    if quantized:
        shape = (n_layers, batch, max_len, cfg.n_kv, cfg.hd)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.full((n_layers, cfg.n_kv), 0.05,
                                    jnp.float32),
                "v_scale": jnp.full((n_layers, cfg.n_kv), 0.05,
                                    jnp.float32)}
    dtype = dtype or cfg.dtype
    shape = (n_layers, batch, max_len, cfg.n_kv, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def run_blocks(blocks: Params, x: jax.Array, cfg: LMConfig, *,
               rope: Tuple[jax.Array, jax.Array],
               cache: Optional[Dict[str, jax.Array]] = None,
               cache_index: Optional[jax.Array] = None,
               qctx: Optional[QuantCtx] = None,
               block_tables: Optional[jax.Array] = None,
               calibrate_kv: bool = False,
               kv_lengths: Optional[jax.Array] = None,
               ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Scan a *sub-range* of stacked decoder blocks over hidden states.

    This is the cut-aware workhorse shared by the monolithic serving path
    and the collaborative engines: the edge prefix and the cloud suffix
    each call it on their own block slice + KV cache.  ``cache_index``
    may be a scalar (uniform position) or a [B] vector of per-slot
    positions; with a vector index ``x`` may carry S > 1 tokens per row
    — the speculative verify step runs all k drafted positions of every
    slot through one cached call, each query causally masked to its own
    ``cache_index + i`` (and a rejected suffix is rolled back simply by
    not advancing the caller's per-slot position).  INT8 caches
    (``k_scale`` entries) are handled uniformly; paged caches
    (``k_pages`` entries, see ``init_cache``) additionally need
    ``block_tables`` and pass ``calibrate_kv=True`` at prefill so
    per-slot INT8 scales are derived from the prompt — prefill reads,
    like decode and verify reads, go through the paged kernel
    (``kernels.paged_attention``), so every phase shares one lattice and
    one read path.
    """
    if cache is None:
        def body_nc(x, bp):
            y, _, _ = block_apply(bp, x, cfg, rope=rope, qctx=qctx)
            return y, None

        x, _ = jax.lax.scan(body_nc, x, blocks, unroll=cfg.scan_unroll)
        return x, None

    def body(x, scan_in):
        bp, c = scan_in
        c = dict(c)
        scales = None
        if "k_scale" in c and "k_pages" not in c:
            scales = (c.pop("k_scale"), c.pop("v_scale"))
        x, new_c, _ = block_apply(bp, x, cfg, rope=rope, cache=c,
                                  cache_index=cache_index, qctx=qctx,
                                  kv_scales=scales,
                                  block_tables=block_tables,
                                  calibrate_kv=calibrate_kv,
                                  kv_lengths=kv_lengths)
        if scales is not None:
            new_c = dict(new_c, k_scale=scales[0], v_scale=scales[1])
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (blocks, cache),
                                unroll=cfg.scan_unroll)
    return x, new_cache


def lm_head(params: Params, x: jax.Array) -> jax.Array:
    """Final-norm + untied head over hidden states [B, S, D]."""
    x = L.rmsnorm(params["final_norm"], x)
    return L.dense(params["lm_head"], x, name="lm_head")


def _cache_span(cache: Dict[str, jax.Array],
                block_tables: Optional[jax.Array]) -> int:
    """Longest position the cache layout can address (for RoPE tables)."""
    if "k" in cache:
        return cache["k"].shape[2]
    return block_tables.shape[1] * cache["k_pages"].shape[2]


def prefill(params: Params, tokens: jax.Array, cfg: LMConfig, *,
            cache: Dict[str, jax.Array],
            qctx: Optional[QuantCtx] = None,
            block_tables: Optional[jax.Array] = None,
            last_pos: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Process the full prompt; returns (last-token logits, filled cache).

    ``last_pos`` [B]: per-row index of the last *real* token — used by
    the bucketed scheduler, whose prompts arrive right-padded to a
    power-of-two; without it the logits come from position S-1.
    Paged caches (``k_pages``) need ``block_tables`` and calibrate their
    per-slot INT8 scales from this prompt."""
    b, s = tokens.shape
    span = _cache_span(cache, block_tables)
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    rope = L.rope_table(span, cfg.hd, base=cfg.rope_base, dtype=cfg.dtype)
    x, new_cache = run_blocks(params["blocks"], x, cfg, rope=rope,
                              cache=cache, cache_index=jnp.int32(0),
                              qctx=qctx, block_tables=block_tables,
                              calibrate_kv="k_pages" in cache,
                              kv_lengths=(None if last_pos is None
                                          else last_pos + 1))
    if last_pos is not None:
        x = x[jnp.arange(b), last_pos][:, None]
    else:
        x = x[:, -1:]
    logits = lm_head(params, x)
    return logits[:, 0], new_cache


def decode_step(params: Params, token: jax.Array, cache: Dict[str, jax.Array],
                cache_index: jax.Array, cfg: LMConfig, *,
                qctx: Optional[QuantCtx] = None,
                block_tables: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One autoregressive step: token [B] int32 → logits [B, V].
    ``cache_index`` is a scalar (uniform position) or a [B] vector of
    per-slot positions (continuous batching).  Handles bf16,
    INT8-quantized, and paged caches (scale entries ride along in the
    cache dict and are sliced per layer by the scan; paged caches route
    the read through the paged flash-decode kernel — the S=1 case of
    the q-block kernel the speculative verify uses via ``run_blocks``)."""
    span = _cache_span(cache, block_tables)
    x = L.embed(params["embed"], token[:, None]).astype(cfg.dtype)
    rope = L.rope_table(span, cfg.hd, base=cfg.rope_base, dtype=cfg.dtype)
    x, new_cache = run_blocks(params["blocks"], x, cfg, rope=rope,
                              cache=cache, cache_index=cache_index,
                              qctx=qctx, block_tables=block_tables)
    logits = lm_head(params, x)
    return logits[:, 0], new_cache


def split_blocks(params: Params, cfg: LMConfig, cut_layer: int,
                 ) -> Tuple[Params, Params]:
    """Split the stacked block params at the paper's partition point:
    (edge prefix = blocks[0..cut], cloud suffix = blocks[cut+1..L))."""
    assert 0 <= cut_layer < cfg.n_layers

    def take(lo, hi):
        return jax.tree_util.tree_map(lambda v: v[lo:hi], params["blocks"])

    return take(0, cut_layer + 1), take(cut_layer + 1, cfg.n_layers)


# ---------------------------------------------------------------------------
# Partition-analysis graph (paper §2.2 applied to a decoder stack)
# ---------------------------------------------------------------------------


def make_graph(cfg: LMConfig, *, batch: int, seq: int) -> LayerGraph:
    """Block-interior nodes carry the residual structure so the shortcut
    rule excludes them; block boundaries survive as candidates."""
    g = LayerGraph(cfg.name)
    d, hd = cfg.d_model, cfg.hd
    tok = batch * seq
    g.add("input", "input", [], (batch, seq))
    g.add("embed", "embed", ["input"], (batch, seq, d),
          param_elems=cfg.vocab * d, flops=0)
    prev = "embed"
    attn_proj_flops = 2 * tok * d * (cfg.n_heads * hd) * 2 \
        + 2 * tok * d * (cfg.n_kv * hd) * 2
    attn_sdpa_flops = 2 * batch * cfg.n_heads * seq * seq * hd * 2
    if cfg.moe:
        ffn_flops = 2 * tok * 3 * d * cfg.d_ff * cfg.moe.top_k \
            * cfg.moe.capacity_factor
        ffn_params = cfg.moe.n_experts * 3 * d * cfg.d_ff \
            + d * cfg.moe.n_experts
    else:
        ffn_flops = 2 * tok * 3 * d * cfg.d_ff
        ffn_params = 3 * d * cfg.d_ff
    for i in range(cfg.n_layers):
        a = g.add(f"blk{i}/attn", "attention", [prev], (batch, seq, d),
                  flops=attn_proj_flops + attn_sdpa_flops,
                  param_elems=cfg.block_param_count() - ffn_params - 2 * d)
        add1 = g.add(f"blk{i}/add1", "add", [a, prev], (batch, seq, d))
        f = g.add(f"blk{i}/ffn", "moe" if cfg.moe else "mlp", [add1],
                  (batch, seq, d), flops=ffn_flops,
                  param_elems=ffn_params + 2 * d)
        prev = g.add(f"blk{i}/add2", "add", [f, add1], (batch, seq, d))
    g.add("lm_head", "dense", [prev], (batch, seq, cfg.vocab),
          flops=2 * tok * d * cfg.vocab, param_elems=d * cfg.vocab + d)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# Collaborative-serving segments (block granularity)
# ---------------------------------------------------------------------------


def make_segments(params: Params, cfg: LMConfig, *, seq: int):
    """SegmentedModel view: embed → per-block → head.  Cache-less forward
    (collaborative prefill/classification-style use)."""
    from repro.core.collab import Segment, SegmentedModel

    rope_const = L.rope_table(seq, cfg.hd, base=cfg.rope_base, dtype=cfg.dtype)

    def embed_apply(p, tokens, *, qctx=None):
        return L.embed(p, tokens).astype(cfg.dtype)

    def mk_block_apply():
        def apply(p, x, *, qctx=None):
            y, _, _ = block_apply(p, x, cfg, rope=rope_const, qctx=qctx)
            return y
        return apply

    def head_apply(p, x, *, qctx=None):
        x = L.rmsnorm(p["final_norm"], x)
        return L.dense(p["lm_head"], x, qctx=qctx, name="lm_head")

    segs = [Segment("embed", embed_apply, params["embed"])]
    for i in range(cfg.n_layers):
        bp = jax.tree_util.tree_map(lambda v, i=i: v[i], params["blocks"])
        # the block's residual add2 fuses into its ffn node (§2.2 rule 1),
        # so the candidate point carrying the block boundary is blk{i}/ffn
        segs.append(Segment(f"blk{i}/ffn", mk_block_apply(), bp))
    segs.append(Segment("lm_head", head_apply,
                        {"final_norm": params["final_norm"],
                         "lm_head": params["lm_head"]}))
    g = make_graph(cfg, batch=1, seq=seq)
    return SegmentedModel(name=cfg.name, graph=g, segments=segs)
