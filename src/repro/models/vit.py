"""Vision Transformers: vit-s16 / vit-h14 / deit-b (distillation token).

Pre-LN ViT with learned position embeddings, GELU MLP, scan over blocks.
DeiT adds a distillation token next to [CLS] (arXiv:2012.12877); its head
averages the cls- and distill-token logits at inference, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import LayerGraph
from repro.models import layers as L
from repro.models.layers import QuantCtx

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    distill_token: bool = False
    dtype: Any = jnp.float32
    remat: bool = True
    scan_unroll: int = 1

    @property
    def n_patches(self) -> int:
        return (self.img_res // self.patch) ** 2

    @property
    def n_tokens(self) -> int:
        return self.n_patches + 1 + (1 if self.distill_token else 0)

    def param_count(self) -> int:
        d = self.d_model
        block = 4 * d * d + 2 * d * self.d_ff + self.d_ff + d + 4 * d
        extra = 2 if self.distill_token else 1
        return (self.patch ** 2 * 3 * d + d            # patch embed
                + extra * d + self.n_tokens * d        # cls/distill + pos
                + self.n_layers * block
                + 2 * d                                # final ln
                + extra * (d * self.n_classes + self.n_classes))


def init_block(key, cfg: ViTConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_init(cfg.d_model, dtype=cfg.dtype),
        "attn": L.attention_init(k1, cfg.d_model, cfg.n_heads, cfg.n_heads,
                                 dtype=cfg.dtype),
        "ln2": L.norm_init(cfg.d_model, dtype=cfg.dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=cfg.dtype),
    }


def init_vit(key, cfg: ViTConfig) -> Params:
    ks = jax.random.split(key, 5)
    extra = 2 if cfg.distill_token else 1
    p = {
        "patch": L.patch_embed_init(ks[0], cfg.patch, 3, cfg.d_model,
                                    dtype=cfg.dtype),
        "cls": (jax.random.normal(ks[1], (extra, cfg.d_model)) * 0.02
                ).astype(cfg.dtype),
        "pos": (jax.random.normal(ks[2], (cfg.n_tokens, cfg.d_model)) * 0.02
                ).astype(cfg.dtype),
        "blocks": jax.vmap(lambda k: init_block(k, cfg))(
            jax.random.split(ks[3], cfg.n_layers)),
        "final_ln": L.norm_init(cfg.d_model, dtype=cfg.dtype),
        "head": L.dense_init(ks[4], cfg.d_model, extra * cfg.n_classes,
                             dtype=cfg.dtype),
    }
    return p


def block_apply(p: Params, x: jax.Array, cfg: ViTConfig, *,
                qctx: Optional[QuantCtx] = None) -> jax.Array:
    h, _ = L.attention(p["attn"], L.layernorm(p["ln1"], x),
                       n_heads=cfg.n_heads, n_kv=cfg.n_heads, causal=False,
                       qctx=qctx)
    x = x + h
    x = x + L.mlp(p["mlp"], L.layernorm(p["ln2"], x), qctx=qctx)
    return x


def forward(params: Params, img: jax.Array, cfg: ViTConfig, *,
            qctx: Optional[QuantCtx] = None) -> jax.Array:
    """img [B, H, W, 3] → logits [B, n_classes]."""
    b = img.shape[0]
    x = L.patch_embed(params["patch"], img.astype(cfg.dtype),
                      patch=cfg.patch, qctx=qctx)
    tok = jnp.broadcast_to(params["cls"][None],
                           (b,) + params["cls"].shape)
    x = jnp.concatenate([tok, x], axis=1) + params["pos"][None]

    def body(x, bp):
        return block_apply(bp, x, cfg, qctx=qctx), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"], unroll=cfg.scan_unroll)
    x = L.layernorm(params["final_ln"], x)
    extra = 2 if cfg.distill_token else 1
    heads = L.dense(params["head"], x[:, :extra], qctx=qctx, name="head")
    heads = heads.reshape(b, extra, extra, cfg.n_classes)
    logits = jnp.mean(
        jnp.stack([heads[:, i, i] for i in range(extra)], axis=1), axis=1)
    return logits


def cls_loss(params: Params, batch: Dict[str, jax.Array], cfg) -> jax.Array:
    logits = forward(params, batch["image"], cfg).astype(jnp.float32)
    labels = batch["label"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def make_graph(cfg: ViTConfig, *, batch: int) -> LayerGraph:
    g = LayerGraph(cfg.name)
    d, t = cfg.d_model, cfg.n_tokens
    g.add("input", "input", [], (batch, cfg.img_res, cfg.img_res, 3))
    g.add("patch", "conv", ["input"], (batch, t, d),
          flops=2 * batch * cfg.n_patches * cfg.patch ** 2 * 3 * d,
          param_elems=cfg.patch ** 2 * 3 * d + d + (t + 2) * d)
    prev = "patch"
    attn_flops = (2 * batch * t * d * d * 4 + 2 * batch * cfg.n_heads
                  * t * t * (d // cfg.n_heads) * 2)
    mlp_flops = 2 * batch * t * d * cfg.d_ff * 2
    for i in range(cfg.n_layers):
        a = g.add(f"blk{i}/attn", "attention", [prev], (batch, t, d),
                  flops=attn_flops, param_elems=4 * d * d + 6 * d)
        add1 = g.add(f"blk{i}/add1", "add", [a, prev], (batch, t, d))
        f = g.add(f"blk{i}/ffn", "mlp", [add1], (batch, t, d),
                  flops=mlp_flops, param_elems=2 * d * cfg.d_ff + cfg.d_ff + d)
        prev = g.add(f"blk{i}/add2", "add", [f, add1], (batch, t, d))
    extra = 2 if cfg.distill_token else 1
    g.add("head", "dense", [prev], (batch, cfg.n_classes),
          flops=2 * batch * d * extra * cfg.n_classes,
          param_elems=d * extra * cfg.n_classes + extra * cfg.n_classes + 2 * d)
    g.validate()
    return g


def make_segments(params: Params, cfg: ViTConfig):
    from repro.core.collab import Segment, SegmentedModel

    def patch_apply(p, img, *, qctx=None):
        b = img.shape[0]
        x = L.patch_embed(p["patch"], img.astype(cfg.dtype), patch=cfg.patch,
                          qctx=qctx)
        tok = jnp.broadcast_to(p["cls"][None], (b,) + p["cls"].shape)
        return jnp.concatenate([tok, x], axis=1) + p["pos"][None]

    def mk_block():
        def apply(p, x, *, qctx=None):
            return block_apply(p, x, cfg, qctx=qctx)
        return apply

    def head_apply(p, x, *, qctx=None):
        b = x.shape[0]
        x = L.layernorm(p["final_ln"], x)
        extra = 2 if cfg.distill_token else 1
        heads = L.dense(p["head"], x[:, :extra], qctx=qctx, name="head")
        heads = heads.reshape(b, extra, extra, cfg.n_classes)
        return jnp.mean(
            jnp.stack([heads[:, i, i] for i in range(extra)], axis=1), axis=1)

    segs = [Segment("patch", patch_apply,
                    {k: params[k] for k in ("patch", "cls", "pos")})]
    for i in range(cfg.n_layers):
        bp = jax.tree_util.tree_map(lambda v, i=i: v[i], params["blocks"])
        segs.append(Segment(f"blk{i}/ffn", mk_block(), bp))
    segs.append(Segment("head", head_apply,
                        {k: params[k] for k in ("final_ln", "head")}))
    return SegmentedModel(name=cfg.name, graph=make_graph(cfg, batch=1),
                          segments=segs)
