"""Functional layer library (pure JAX, MaxText-style init/apply pairs).

Every parametric layer threads an optional ``QuantCtx`` so the whole model
zoo supports the paper's mixed-precision mode: when a layer runs on the
*edge engine*, its weights are fake-quantized per-channel INT8 and its
input activations per-tensor INT8 (paper §2.1 steps 1-4 — fake-quant of
the same lattice the MXU int8 kernel consumes, so accuracy semantics match
the integer path bit-for-bit up to f32 rounding); on the *cloud engine*
``qctx=None`` and everything stays full precision.

Calibration (``mode="calib"``) records per-activation min/max off-line,
exactly the paper's profiling step; ``mode="static"`` replays the
calibrated thresholds; ``mode="dynamic"`` computes them per batch.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import (MinMaxCalibrator, QuantParams, compute_qparams,
                              fake_quant)

Params = Dict[str, Any]
_ACTS = {None: lambda x: x, "relu": jax.nn.relu, "gelu": jax.nn.gelu,
         "silu": jax.nn.silu, "tanh": jnp.tanh}


# ---------------------------------------------------------------------------
# Quantization context
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QuantCtx:
    mode: str = "dynamic"            # "dynamic" | "static" | "calib"
    w_bits: int = 8
    a_bits: int = 8
    per_channel: bool = True
    # dynamic-mode activation range axis: None = one range per tensor
    # (paper Eq.1 on a single stream); 0 = one range per leading-axis
    # row.  Batched serving engines MUST use 0 — a per-tensor range over
    # a multi-slot batch couples every request's lattice to its
    # neighbours' (and to garbage in idle slots), breaking per-request
    # determinism.  For B=1 the two are identical.
    act_axis: Optional[int] = None
    scales: Optional[Dict[str, QuantParams]] = None     # static mode
    recorder: Optional[Dict[str, MinMaxCalibrator]] = None  # calib mode
    # weights already sit on the deployment lattice (prequantized once,
    # e.g. serve.policy._CutBank), so per-call re-quantization would be
    # redundant compute — only activations stay dynamic
    quantize_weights: bool = True

    def weight(self, name: str, w: jax.Array) -> jax.Array:
        if not self.quantize_weights:
            return w
        axis = (w.ndim - 1) if self.per_channel else None
        qp = compute_qparams(w, axis=axis, bits=self.w_bits)
        return fake_quant(w, qp)

    def act(self, name: str, x: jax.Array) -> jax.Array:
        if self.mode == "calib":
            rec = self.recorder.setdefault(
                name, MinMaxCalibrator(bits=self.a_bits))
            rec.observe(x)
            return x
        if self.mode == "static":
            qp = self.scales.get(name)
            if qp is None:           # unseen activation: pass through
                return x
        else:
            qp = compute_qparams(x, axis=self.act_axis, bits=self.a_bits)
        return fake_quant(x, qp)

    def finalize_calibration(self) -> Dict[str, QuantParams]:
        assert self.mode == "calib" and self.recorder is not None
        return {k: c.qparams() for k, c in self.recorder.items()}


def make_calib_ctx(**kw) -> QuantCtx:
    return QuantCtx(mode="calib", recorder={}, **kw)


def q(qctx: Optional[QuantCtx], name: str, x: jax.Array) -> jax.Array:
    return x if qctx is None else qctx.act(name, x)


def qw(qctx: Optional[QuantCtx], name: str, w: jax.Array) -> jax.Array:
    return w if qctx is None else qctx.weight(name, w)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _fan_in_init(key, shape, fan_in, dtype):
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = True,
               dtype=jnp.float32) -> Params:
    p = {"w": _fan_in_init(key, (d_in, d_out), d_in, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def conv2d_init(key, k: int, c_in: int, c_out: int, *, bias: bool = True,
                dtype=jnp.float32) -> Params:
    p = {"w": _fan_in_init(key, (k, k, c_in, c_out), k * k * c_in, dtype)}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def norm_init(dim: int, *, bias: bool = True, dtype=jnp.float32) -> Params:
    p = {"scale": jnp.ones((dim,), dtype)}
    if bias:
        p["b"] = jnp.zeros((dim,), dtype)
    return p


def embed_init(key, vocab: int, dim: int, *, dtype=jnp.float32) -> Params:
    return {"emb": (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)}


# ---------------------------------------------------------------------------
# Apply functions
# ---------------------------------------------------------------------------


def dense(p: Params, x: jax.Array, *, qctx: Optional[QuantCtx] = None,
          name: str = "dense", act: Optional[str] = None) -> jax.Array:
    x = q(qctx, f"{name}/in", x)
    w = qw(qctx, f"{name}/w", p["w"])
    y = jnp.einsum("...i,io->...o", x, w)
    if "b" in p:
        y = y + p["b"]
    return _ACTS[act](y)


def conv2d(p: Params, x: jax.Array, *, stride: int = 1, padding="SAME",
           qctx: Optional[QuantCtx] = None, name: str = "conv",
           act: Optional[str] = None, groups: int = 1) -> jax.Array:
    """NHWC conv. On TPU this is an MXU matmul after im2col."""
    x = q(qctx, f"{name}/in", x)
    w = qw(qctx, f"{name}/w", p["w"])
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    if "b" in p:
        y = y + p["b"]
    return _ACTS[act](y)


def layernorm(p: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm(p: Params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * p["scale"]
    return y


def groupnorm(p: Params, x: jax.Array, *, groups: int = 32,
              eps: float = 1e-5) -> jax.Array:
    """NHWC group norm (diffusion U-Net default)."""
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(n, h, w, c) * p["scale"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed(p: Params, ids: jax.Array) -> jax.Array:
    return jnp.take(p["emb"], ids, axis=0)


# -- rotary position embedding ----------------------------------------------


def rope_table(seq_len: int, head_dim: int, *, base: float = 10000.0,
               dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    ang = jnp.outer(t, freqs)                       # [S, half]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, H, D]; cos/sin: [S, D/2] shared across the batch, or
    [B, S, D/2] when every sequence sits at its own position (per-slot
    decode in the continuous-batching scheduler)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 3:
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    else:
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


# -- attention ----------------------------------------------------------------


def attention_init(key, d_model: int, n_heads: int, n_kv: int,
                   head_dim: Optional[int] = None, *, bias: bool = False,
                   dtype=jnp.float32) -> Params:
    hd = head_dim or d_model // n_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * hd, bias=bias, dtype=dtype),
        "wk": dense_init(ks[1], d_model, n_kv * hd, bias=bias, dtype=dtype),
        "wv": dense_init(ks[2], d_model, n_kv * hd, bias=bias, dtype=dtype),
        "wo": dense_init(ks[3], n_heads * hd, d_model, bias=bias, dtype=dtype),
    }


def _sdpa(qh: jax.Array, kh: jax.Array, vh: jax.Array, *,
          causal: bool, q_offset: int | jax.Array = 0,
          q_chunk: Optional[int] = None,
          score_pspec: Optional[tuple] = None) -> jax.Array:
    """q: [B,Sq,H,D], k/v: [B,Skv,H,D] (kv already head-repeated).

    ``q_chunk`` bounds the live score tensor to [B,H,chunk,Skv] by
    scanning over query blocks (flash-attention-style tiling at the XLA
    level) — required for the 32k-prefill shapes where the full [S,S]
    f32 score tensor would not fit HBM.
    """
    if q_chunk is not None and qh.shape[1] > q_chunk \
            and qh.shape[1] % q_chunk == 0:
        b, sq, h, d = qh.shape
        qc = qh.reshape(b, sq // q_chunk, q_chunk, h, d)

        def one(args):
            q_blk, blk_idx = args
            off = q_offset + blk_idx * q_chunk
            return _sdpa(q_blk, kh, vh, causal=causal, q_offset=off)

        out = jax.lax.map(one, (qc.transpose(1, 0, 2, 3, 4),
                                jnp.arange(sq // q_chunk)))
        return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)

    scale = 1.0 / math.sqrt(qh.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) * scale
    if score_pspec is not None:
        # pin scores [B,H,q,KV] with KV sharded: forces GSPMD into the
        # flash-decoding partial-softmax strategy (tiny psum collectives)
        # instead of gathering the whole cache per layer.
        from jax.sharding import PartitionSpec as P
        logits = jax.lax.with_sharding_constraint(logits, P(*score_pspec))
    if causal:
        sq, sk = qh.shape[1], kh.shape[1]
        if jnp.ndim(q_offset) == 1:
            # per-batch offsets [B]: each slot decodes at its own position
            qpos = jnp.arange(sq)[None, :, None] + q_offset[:, None, None]
            kpos = jnp.arange(sk)[None, None, :]
            mask = kpos <= qpos                       # [B, Sq, Skv]
            logits = jnp.where(mask[:, None], logits,
                               jnp.finfo(logits.dtype).min)
        else:
            qpos = jnp.arange(sq)[:, None] + q_offset
            kpos = jnp.arange(sk)[None, :]
            mask = kpos <= qpos
            logits = jnp.where(mask[None, None], logits,
                               jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    probs = probs.astype(vh.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vh)


def attention(p: Params, x: jax.Array, *, n_heads: int, n_kv: int,
              causal: bool = True,
              rope: Optional[Tuple[jax.Array, jax.Array]] = None,
              kv_cache: Optional[Dict[str, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              qctx: Optional[QuantCtx] = None, name: str = "attn",
              q_chunk: Optional[int] = None,
              kv_scales: Optional[Tuple[jax.Array, jax.Array]] = None,
              score_pspec: Optional[tuple] = None,
              block_tables: Optional[jax.Array] = None,
              calibrate_kv: bool = False,
              kv_lengths: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """GQA attention.  With ``kv_cache`` given, x is the new-token slice
    (decode: S=1); cache is updated at ``cache_index`` and attention runs
    over the full cache length.

    ``kv_scales`` (k_scale, v_scale per kv head, [H]) enables the INT8
    KV cache: new entries are symmetrically quantized on write (paper
    Eq.1, zero-point-free) and dequantized on read — on TPU the convert
    fuses into the QK/AV matmuls so the cache streams at 1 B/elem.

    A *paged* cache (``"k_pages"`` key, see ``transformer.init_cache``)
    additionally takes ``block_tables`` [B, pages_per_seq] mapping each
    row's logical pages to physical pages in the shared pool.  Writes
    scatter into table-mapped pages; decode reads (S=1) go through the
    paged flash-decode kernel, and S>1 reads (multi-token prefill, the
    speculative verify block) go through the same kernel's q-block form
    with intra-block causal masking.  With an INT8 page
    pool, ``calibrate_kv=True`` (prefill) derives fresh per-(row, head)
    symmetric scales from the prompt's K/V instead of reading the
    ``k_scale``/``v_scale`` cache entries that decode steps replay.
    ``kv_lengths`` [B] gives each row's true token count during a
    bucket-padded prefill so padding positions cannot inflate the
    calibrated ranges."""
    b, s, d = x.shape
    hd = p["wq"]["w"].shape[1] // n_heads
    qh = dense(p["wq"], x, qctx=qctx, name=f"{name}/q").reshape(b, s, n_heads, hd)
    kh = dense(p["wk"], x, qctx=qctx, name=f"{name}/k").reshape(b, s, n_kv, hd)
    vh = dense(p["wv"], x, qctx=qctx, name=f"{name}/v").reshape(b, s, n_kv, hd)

    # vector cache_index [B] = per-slot positions (continuous batching).
    # S may exceed 1: a speculative verify step writes/attends a k-token
    # block starting at each slot's own position.
    vec_index = (cache_index is not None and jnp.ndim(cache_index) == 1)

    q_offset = 0
    if rope is not None:
        cos, sin = rope
        if kv_cache is not None and cache_index is not None:
            if vec_index:
                tpos = cache_index[:, None] + jnp.arange(s)[None]  # [B, S]
                cos_q = jnp.take(cos, tpos, axis=0)                # [B,S,·]
                sin_q = jnp.take(sin, tpos, axis=0)
            else:
                cos_q = jax.lax.dynamic_slice_in_dim(cos, cache_index, s,
                                                     axis=0)
                sin_q = jax.lax.dynamic_slice_in_dim(sin, cache_index, s,
                                                     axis=0)
        else:
            cos_q, sin_q = cos[:s], sin[:s]
        qh = apply_rope(qh, cos_q, sin_q)
        kh = apply_rope(kh, cos_q, sin_q)

    if kv_cache is not None and "k_pages" in kv_cache:
        assert block_tables is not None, "paged cache needs block_tables"
        out, new_cache = _paged_cache_attention(
            kv_cache, qh, kh, vh, block_tables=block_tables,
            cache_index=cache_index, vec_index=vec_index,
            calibrate_kv=calibrate_kv, kv_lengths=kv_lengths,
            n_heads=n_heads, n_kv=n_kv, q_chunk=q_chunk, dtype=x.dtype)
        out = out.reshape(b, s, n_heads * hd)
        out = dense(p["wo"], out, qctx=qctx, name=f"{name}/o")
        return out, new_cache

    new_cache = None
    if kv_cache is not None:
        if kv_scales is not None:
            ks, vs = kv_scales                     # [H] per kv head
            k_w = jnp.clip(jnp.round(kh / ks[None, None, :, None]),
                           -127, 127).astype(kv_cache["k"].dtype)
            v_w = jnp.clip(jnp.round(vh / vs[None, None, :, None]),
                           -127, 127).astype(kv_cache["v"].dtype)
        else:
            k_w = kh.astype(kv_cache["k"].dtype)
            v_w = vh.astype(kv_cache["v"].dtype)
        if vec_index:
            b_idx = jnp.arange(b)[:, None]
            tpos = cache_index[:, None] + jnp.arange(s)[None]     # [B, S]
            k_all = kv_cache["k"].at[b_idx, tpos].set(k_w)
            v_all = kv_cache["v"].at[b_idx, tpos].set(v_w)
        else:
            k_all = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k_w, cache_index, axis=1)
            v_all = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v_w, cache_index, axis=1)
        new_cache = {"k": k_all, "v": v_all}
        if kv_scales is not None:
            kh = k_all.astype(x.dtype) * ks.astype(x.dtype)[None, None, :,
                                                            None]
            vh = v_all.astype(x.dtype) * vs.astype(x.dtype)[None, None, :,
                                                            None]
        else:
            kh, vh = k_all.astype(x.dtype), v_all.astype(x.dtype)
        q_offset = cache_index

    if n_kv != n_heads:
        rep = n_heads // n_kv
        kh = jnp.repeat(kh, rep, axis=2)
        vh = jnp.repeat(vh, rep, axis=2)

    out = _sdpa(qh, kh, vh, causal=causal, q_offset=q_offset,
                q_chunk=q_chunk, score_pspec=score_pspec)
    out = out.reshape(b, s, n_heads * hd)
    out = dense(p["wo"], out, qctx=qctx, name=f"{name}/o")
    return out, new_cache


def _paged_cache_attention(cache: Dict[str, jax.Array], qh: jax.Array,
                           kh: jax.Array, vh: jax.Array, *,
                           block_tables: jax.Array,
                           cache_index: Optional[jax.Array],
                           vec_index: bool, calibrate_kv: bool,
                           kv_lengths: Optional[jax.Array],
                           n_heads: int, n_kv: int,
                           q_chunk: Optional[int], dtype
                           ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Write new K/V into block-table pages, then attend.

    qh/kh/vh: [B, S, H(, kv), D] post-RoPE.  Decode (S=1) reads back
    through ``kernels.paged_attention``; S>1 blocks (prefill, the
    speculative verify step) read back through its multi-query form —
    one paged read path for every phase.
    """
    from repro.kernels.paged_attention import (paged_attention,
                                               paged_multiquery_attention)

    b, s = kh.shape[:2]
    page_size = cache["k_pages"].shape[1]
    quantized = "k_scale" in cache

    if quantized:
        if calibrate_kv:
            # per-slot Eq.(1) symmetric calibration from the prompt's
            # own K/V range — [B, n_kv], replayed by every decode step.
            # Bucket-padding positions are masked out of the reduction:
            # their K/V (pad-token embeddings at tail RoPE phases) must
            # not set a request's scale for its whole lifetime.
            ak, av = jnp.abs(kh), jnp.abs(vh)
            if kv_lengths is not None:
                valid = (jnp.arange(s)[None, :]
                         < kv_lengths[:, None])[:, :, None, None]
                ak = jnp.where(valid, ak, 0.0)
                av = jnp.where(valid, av, 0.0)
            ks = jnp.maximum(jnp.max(ak, axis=(1, 3)), 1e-6) / 127.0
            vs = jnp.maximum(jnp.max(av, axis=(1, 3)), 1e-6) / 127.0
        else:
            ks, vs = cache["k_scale"], cache["v_scale"]
        k_w = jnp.clip(jnp.round(kh / ks[:, None, :, None]),
                       -127, 127).astype(cache["k_pages"].dtype)
        v_w = jnp.clip(jnp.round(vh / vs[:, None, :, None]),
                       -127, 127).astype(cache["v_pages"].dtype)
    else:
        k_w = kh.astype(cache["k_pages"].dtype)
        v_w = vh.astype(cache["v_pages"].dtype)

    # logical position of every written token, [B, S]
    if vec_index:
        t = cache_index[:, None] + jnp.arange(s)[None]
    else:
        t = jnp.broadcast_to(
            (cache_index + jnp.arange(s))[None], (b, s))
    page = jnp.take_along_axis(block_tables, t // page_size, axis=1)
    off = t % page_size
    k_pages = cache["k_pages"].at[page, off].set(k_w)
    v_pages = cache["v_pages"].at[page, off].set(v_w)

    new_cache = {"k_pages": k_pages, "v_pages": v_pages}
    if quantized:
        new_cache["k_scale"], new_cache["v_scale"] = ks, vs

    if s == 1:
        # flash-decode over the page pool (1 B/elem streamed, dequant
        # inside the QK/AV loops); lengths include the token just written
        vec = cache_index if vec_index else jnp.full((b,), cache_index)
        out = paged_attention(qh[:, 0].astype(jnp.float32), k_pages,
                              v_pages, block_tables, vec + 1,
                              ks if quantized else None,
                              vs if quantized else None)
        return out[:, None].astype(dtype), new_cache

    # q-block read (speculative verify / multi-token prefill): the S
    # queries attend cache + the just-written block through the paged
    # kernel's intra-block causal mask.  Query i of row b sits at
    # q_start[b] + i; ``kv_lengths`` (true prompt lengths) keeps bucket
    # padding out of a prefill read, while a verify read's stale entries
    # beyond each query's position — rolled-back drafts of an earlier
    # round — are masked by causality.  Reading back through the pages
    # also means prefill sees the cache's INT8 lattice, so prefill
    # logits match what decode later reconstructs from the same pages.
    start = cache_index if vec_index else jnp.full((b,), cache_index)
    lengths = (start + s) if kv_lengths is None else kv_lengths
    out = paged_multiquery_attention(qh.astype(jnp.float32), k_pages,
                                     v_pages, block_tables,
                                     lengths.astype(jnp.int32), start,
                                     ks if quantized else None,
                                     vs if quantized else None)
    return out.astype(dtype), new_cache


# -- MLPs ---------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {"wi": dense_init(ks[0], d_model, d_ff, bias=False, dtype=dtype),
            "wg": dense_init(ks[1], d_model, d_ff, bias=False, dtype=dtype),
            "wo": dense_init(ks[2], d_ff, d_model, bias=False, dtype=dtype)}


def swiglu(p: Params, x: jax.Array, *, qctx: Optional[QuantCtx] = None,
           name: str = "mlp") -> jax.Array:
    h = dense(p["wi"], x, qctx=qctx, name=f"{name}/wi")
    g = dense(p["wg"], x, qctx=qctx, name=f"{name}/wg", act="silu")
    return dense(p["wo"], h * g, qctx=qctx, name=f"{name}/wo")


def mlp_init(key, d_model: int, d_ff: int, *, bias: bool = True,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2)
    return {"wi": dense_init(ks[0], d_model, d_ff, bias=bias, dtype=dtype),
            "wo": dense_init(ks[1], d_ff, d_model, bias=bias, dtype=dtype)}


def mlp(p: Params, x: jax.Array, *, act: str = "gelu",
        qctx: Optional[QuantCtx] = None, name: str = "mlp") -> jax.Array:
    h = dense(p["wi"], x, qctx=qctx, name=f"{name}/wi", act=act)
    return dense(p["wo"], h, qctx=qctx, name=f"{name}/wo")


# -- Mixture of Experts (GShard-style, dropless-capacity top-k) ---------------


def moe_init(key, d_model: int, d_ff: int, n_experts: int,
             *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(d_ff)

    def ex(k, shape, std):
        return (jax.random.normal(k, shape) * std).astype(dtype)

    return {
        "router": dense_init(ks[0], d_model, n_experts, bias=False, dtype=dtype),
        "wi": ex(ks[1], (n_experts, d_model, d_ff), std_in),
        "wg": ex(ks[2], (n_experts, d_model, d_ff), std_in),
        "wo": ex(ks[3], (n_experts, d_ff, d_model), std_out),
    }


def _route(router: Params, xt: jax.Array, n_e: int, top_k: int):
    logits = jnp.einsum("td,de->te", xt, router["w"])
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # [T, E]
    gate_k, idx_k = jax.lax.top_k(gates, top_k)                   # [T, K]
    gate_k = gate_k / jnp.maximum(jnp.sum(gate_k, -1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch): E * sum(fraction * prob)
    density = jnp.mean(
        jax.nn.one_hot(idx_k[:, 0], n_e, dtype=jnp.float32), axis=0)
    aux = n_e * jnp.sum(density * jnp.mean(gates, axis=0))
    return gate_k, idx_k, aux


def _grouped_ffn(xt: jax.Array, gate_k: jax.Array, idx_k: jax.Array,
                 wi: jax.Array, wg: jax.Array, wo: jax.Array, *,
                 top_k: int, capacity_factor: float) -> jax.Array:
    """Sort-based static-capacity grouped FFN.

    Sorts (token, k) slots by expert, gathers each expert's first C
    tokens into a dense [E, C, D] buffer (C = T·k·cf/E), runs plain
    einsum GEMMs (E·C·D·F = active FLOPs × cf — never the O(T·E·C)
    one-hot dispatch), and scatter-adds gated results back.  Tokens past
    an expert's capacity are dropped, exactly GShard's overflow rule.
    """
    t, d = xt.shape
    n_e = wi.shape[0]
    # floor keeps tiny decode batches (a handful of tokens per shard)
    # from dropping on routing collisions
    cap = max(int(capacity_factor * t * top_k / n_e),
              min(t * top_k, 32))

    flat_e = idx_k.reshape(-1)                                    # [T*K]
    order = jnp.argsort(flat_e)                                   # stable
    tok_sorted = order // top_k                                   # [T*K]
    group_sizes = jnp.bincount(flat_e, length=n_e)
    starts = jnp.concatenate([jnp.zeros((1,), group_sizes.dtype),
                              jnp.cumsum(group_sizes)[:-1]])
    slot = starts[:, None] + jnp.arange(cap)[None, :]             # [E, C]
    valid = jnp.arange(cap)[None, :] < group_sizes[:, None]
    slot = jnp.clip(slot, 0, t * top_k - 1)
    tok_for_slot = jnp.take(tok_sorted, slot.reshape(-1))         # [E*C]
    gate_sorted = jnp.take(gate_k.reshape(-1), order)
    gate_slot = jnp.take(gate_sorted, slot.reshape(-1)).reshape(n_e, cap)
    gate_slot = jnp.where(valid, gate_slot, 0.0).astype(xt.dtype)

    xe = jnp.take(xt, tok_for_slot, axis=0).reshape(n_e, cap, d)  # [E, C, D]
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg))
    ye = jnp.einsum("ecf,efd->ecd", h * g, wo)                    # [E, C, D']
    ye = ye * gate_slot[..., None]
    return jnp.zeros((t, ye.shape[-1]), ye.dtype).at[tok_for_slot].add(
        ye.reshape(n_e * cap, -1))


def moe(p: Params, x: jax.Array, *, top_k: int,
        capacity_factor: float = 1.25,
        qctx: Optional[QuantCtx] = None, name: str = "moe",
        ) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE (sort + static-capacity grouped GEMM).

    x: [B, S, D] → ([B, S, D], aux_loss).  Experts are a brother-branch
    structure in the paper's sense: cuts inside an expert are excluded
    (repro.core.partition), but the combine output is a legal cut.
    """
    b, s, d = x.shape
    n_e = p["router"]["w"].shape[1]
    xt = x.reshape(b * s, d)
    gate_k, idx_k, aux = _route(p["router"], xt, n_e, top_k)
    wi = qw(qctx, f"{name}/wi", p["wi"])
    wg = qw(qctx, f"{name}/wg", p["wg"])
    wo = qw(qctx, f"{name}/wo", p["wo"])
    yt = _grouped_ffn(xt, gate_k, idx_k, wi, wg, wo, top_k=top_k,
                      capacity_factor=capacity_factor)
    return yt.reshape(b, s, d), aux


def moe_sharded(p: Params, x: jax.Array, *, top_k: int,
                batch_spec, model_axis: str = "model",
                capacity_factor: float = 1.25,
                qctx: Optional[QuantCtx] = None, name: str = "moe",
                ) -> Tuple[jax.Array, jax.Array]:
    """``moe`` under ``jax.shard_map``: the explicit-SPMD form for
    production meshes.

    XLA's auto-partitioner replicates the sort/gather/ragged_dot pattern
    (data-dependent indices defeat propagation), exploding memory and
    compute ~mesh-size-fold.  Here we pin the layout manually:
      * tokens stay sharded over the DP axes (``batch_spec``) — each
        shard routes and sorts only its local tokens (local dispatch,
        exactly GShard's per-core grouping);
      * expert FFN dim is tensor-parallel over ``model_axis``: wi/wg
        enter as [E, D, F/tp], wo as [E, F/tp, D];
      * the wo contraction is completed with a psum_scatter over
        ``model_axis``, leaving the output d_model-sharded (matches the
        residual-stream act_pspec), then re-gathered by the caller.

    Requires the ambient mesh (trace under ``with mesh:``).
    """
    b, s, d = x.shape
    n_e = p["router"]["w"].shape[1]

    def local_moe(router_w, wi, wg, wo, x_loc):
        bl, sl, _ = x_loc.shape
        xt = x_loc.reshape(bl * sl, d)
        gate_k, idx_k, aux = _route(router_w, xt, n_e, top_k)
        # wo enters F/tp-sharded; the grouped FFN's output is a partial
        # sum over the F contraction — complete it with a psum_scatter
        # that leaves the result d_model-sharded over tp.
        yt_partial = _grouped_ffn(xt, gate_k, idx_k, wi, wg, wo,
                                  top_k=top_k,
                                  capacity_factor=capacity_factor)
        yt = jax.lax.psum_scatter(yt_partial, model_axis,
                                  scatter_dimension=1, tiled=True)
        aux = jax.lax.pmean(aux, batch_spec) if batch_spec else aux
        aux = jax.lax.pmean(aux, model_axis)
        return yt.reshape(bl, sl, yt.shape[-1]), aux

    from jax.sharding import PartitionSpec as P
    wi = qw(qctx, f"{name}/wi", p["wi"])
    wg = qw(qctx, f"{name}/wg", p["wg"])
    wo = qw(qctx, f"{name}/wo", p["wo"])
    y, aux = _shard_map_compat(
        local_moe,
        in_specs=(P(), P(None, None, model_axis), P(None, None, model_axis),
                  P(None, model_axis, None), P(batch_spec, None, None)),
        out_specs=(P(batch_spec, None, model_axis), P()),
    )(p["router"], wi, wg, wo, x)
    return y, aux


def _shard_map_compat(f, *, in_specs, out_specs):
    """Unchecked shard_map over the ambient mesh: ``jax.shard_map`` with
    ``check_vma`` on newer jax, ``jax.experimental.shard_map.shard_map``
    with the ambient physical mesh made explicit (and ``check_rep``) on
    0.4.x, where no top-level alias exists."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    from jax._src.mesh import thread_resources
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=thread_resources.env.physical_mesh,
                     in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# -- vision helpers -----------------------------------------------------------


def patch_embed_init(key, patch: int, c_in: int, d_model: int,
                     *, dtype=jnp.float32) -> Params:
    return conv2d_init(key, patch, c_in, d_model, dtype=dtype)


def patch_embed(p: Params, img: jax.Array, *, patch: int,
                qctx: Optional[QuantCtx] = None,
                name: str = "patch") -> jax.Array:
    y = conv2d(p, img, stride=patch, padding="VALID", qctx=qctx, name=name)
    b, h, w, c = y.shape
    return y.reshape(b, h * w, c)


def maxpool2d(x: jax.Array, *, window: int, stride: int,
              padding="SAME") -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), padding)


def avgpool2d(x: jax.Array, *, window: int, stride: int,
              padding="SAME") -> jax.Array:
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1),
        (1, stride, stride, 1), padding)
    ones = jnp.ones_like(x)
    c = jax.lax.reduce_window(
        ones, 0.0, jax.lax.add, (1, window, window, 1),
        (1, stride, stride, 1), padding)
    return s / c
