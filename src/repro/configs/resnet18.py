"""resnet-18 — paper baseline (Table 3 subject, best cut res4a)."""
from repro.configs import ArchSpec
from repro.models.resnet import ResNetConfig

FULL = ResNetConfig(name="resnet-18", depths=(2, 2, 2, 2), width=64,
                    bottleneck=False, img_res=224)

SMOKE = ResNetConfig(name="r18-smoke", depths=(1, 1, 1, 1), width=8,
                     bottleneck=False, n_classes=10, img_res=32)

SPEC = ArchSpec(arch_id="resnet-18", family="vision", full=FULL, smoke=SMOKE,
                source="arXiv:1512.03385; paper", assigned=False)
