"""resnet-152 [arXiv:1512.03385]: depths 3-8-36-3, width 64, bottleneck."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.resnet import ResNetConfig

FULL = ResNetConfig(name="resnet-152", depths=(3, 8, 36, 3), width=64,
                    bottleneck=True, img_res=224, dtype=jnp.bfloat16)

SMOKE = ResNetConfig(name="r152-smoke", depths=(1, 1, 1, 1), width=8,
                     bottleneck=True, n_classes=10, img_res=32)

SPEC = ArchSpec(arch_id="resnet-152", family="vision", full=FULL,
                smoke=SMOKE, source="arXiv:1512.03385; paper")
