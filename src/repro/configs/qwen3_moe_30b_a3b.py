"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d=2048 32H (kv=4,
head_dim=128) expert d_ff=768 vocab=151936, MoE 128 experts top-8."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.transformer import LMConfig, MoESpec

FULL = LMConfig(name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048,
                n_heads=32, n_kv=4, head_dim=128, d_ff=768, vocab=151936,
                moe=MoESpec(n_experts=128, top_k=8), max_seq=524288,
                dtype=jnp.bfloat16)

SMOKE = LMConfig(name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
                 n_kv=2, head_dim=16, d_ff=32, vocab=256,
                 moe=MoESpec(n_experts=8, top_k=2), max_seq=128, remat=False)

SPEC = ArchSpec(arch_id="qwen3-moe-30b-a3b", family="lm", full=FULL,
                smoke=SMOKE, source="hf:Qwen/Qwen3-30B-A3B; hf")
