"""googlenet — paper baseline (Table 3 subject, best cut conv2)."""
from repro.configs import ArchSpec


class GoogLeNetConfig:
    name = "googlenet"
    img_res = 224


FULL = GoogLeNetConfig()
SMOKE = GoogLeNetConfig()

SPEC = ArchSpec(arch_id="googlenet", family="vision", full=FULL, smoke=SMOKE,
                source="arXiv:1409.4842; paper", assigned=False)
