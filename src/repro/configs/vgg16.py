"""vgg16 — paper baseline (Table 3 subject, best cut conv1_2)."""
from repro.configs import ArchSpec


class VGG16Config:
    name = "vgg16"
    img_res = 224


FULL = VGG16Config()
SMOKE = VGG16Config()

SPEC = ArchSpec(arch_id="vgg16", family="vision", full=FULL, smoke=SMOKE,
                source="arXiv:1409.1556; paper", assigned=False)
