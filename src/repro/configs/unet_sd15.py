"""unet-sd15 [arXiv:2112.10752]: ch=320 mult 1-2-4-4, 2 res blocks,
cross-attn at ds 1-2-4, ctx_dim=768, img 512 (latent 64)."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.unet import UNetConfig

FULL = UNetConfig(name="unet-sd15", ch=320, ch_mult=(1, 2, 4, 4),
                  n_res_blocks=2, attn_stages=(0, 1, 2), ctx_dim=768,
                  img_res=512, dtype=jnp.bfloat16)

SMOKE = UNetConfig(name="sd15-smoke", ch=8, ch_mult=(1, 2, 2),
                   n_res_blocks=1, attn_stages=(0, 1), ctx_dim=16, ctx_len=4,
                   n_heads=2, img_res=64)

SPEC = ArchSpec(arch_id="unet-sd15", family="diffusion", full=FULL,
                smoke=SMOKE, source="arXiv:2112.10752; paper")
