"""flux-dev [BFL tech report]: MMDiT rectified-flow, 19 double + 38 single
blocks, d=3072, 24 heads, ~12B params, img 1024 (latent 128)."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.mmdit import MMDiTConfig

FULL = MMDiTConfig(name="flux-dev", n_double=19, n_single=38, d_model=3072,
                   n_heads=24, img_res=1024, dtype=jnp.bfloat16)

SMOKE = MMDiTConfig(name="flux-smoke", n_double=2, n_single=3, d_model=32,
                    n_heads=4, img_res=64, txt_len=4, txt_dim=24, vec_dim=12,
                    in_ch=8, remat=False)

SPEC = ArchSpec(arch_id="flux-dev", family="diffusion", full=FULL,
                smoke=SMOKE, source="BFL tech report; unverified")
