"""Architecture registry: the 10 assigned archs + the paper's 4 baselines.

Each arch config module defines ``FULL`` (the exact published numbers) and
``SMOKE`` (a reduced same-family config for CPU tests).  The registry maps
``--arch <id>`` to family, configs, and the family's shape set; and
``input_specs(arch, shape)`` builds the ShapeDtypeStruct stand-ins every
dry-run cell lowers against (no device allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ArchSpec", "ShapeSpec", "REGISTRY", "get_arch", "list_archs",
           "list_cells", "input_specs", "LM_SHAPES", "DIFFUSION_SHAPES",
           "VISION_SHAPES"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                    # train | prefill | decode | denoise | infer
    # LM fields
    seq_len: int = 0
    global_batch: int = 0
    # diffusion fields
    img_res: int = 0
    steps: int = 0
    # vision fields (img_res + global_batch reused)


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096,
                          global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768,
                             global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768,
                            global_batch=128),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288,
                           global_batch=1),
}

DIFFUSION_SHAPES = {
    "train_256": ShapeSpec("train_256", "train", img_res=256,
                           global_batch=256, steps=1000),
    "gen_1024": ShapeSpec("gen_1024", "denoise", img_res=1024,
                          global_batch=4, steps=50),
    "gen_fast": ShapeSpec("gen_fast", "denoise", img_res=512,
                          global_batch=16, steps=4),
    "train_1024": ShapeSpec("train_1024", "train", img_res=1024,
                            global_batch=32, steps=1000),
}

VISION_SHAPES = {
    "cls_224": ShapeSpec("cls_224", "train", img_res=224, global_batch=256),
    "cls_384": ShapeSpec("cls_384", "train", img_res=384, global_batch=64),
    "serve_b1": ShapeSpec("serve_b1", "infer", img_res=224, global_batch=1),
    "serve_b128": ShapeSpec("serve_b128", "infer", img_res=224,
                            global_batch=128),
}

_FAMILY_SHAPES = {"lm": LM_SHAPES, "diffusion": DIFFUSION_SHAPES,
                  "vision": VISION_SHAPES}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                  # lm | diffusion | vision
    full: Any
    smoke: Any
    source: str = ""
    assigned: bool = True        # False for the paper's own baselines

    @property
    def shapes(self) -> Dict[str, ShapeSpec]:
        return _FAMILY_SHAPES.get(self.family, {})


def _build_registry() -> Dict[str, ArchSpec]:
    from repro.configs import (alexnet, deepseek_7b, deit_b, flux_dev,
                               googlenet, grok1_314b, phi3_medium_14b,
                               qwen3_moe_30b_a3b, resnet18, resnet152,
                               unet_sd15, vgg16, vit_h14, vit_s16)
    specs = [
        phi3_medium_14b.SPEC, deepseek_7b.SPEC, qwen3_moe_30b_a3b.SPEC,
        grok1_314b.SPEC, flux_dev.SPEC, unet_sd15.SPEC, deit_b.SPEC,
        vit_s16.SPEC, vit_h14.SPEC, resnet152.SPEC,
        alexnet.SPEC, vgg16.SPEC, resnet18.SPEC, googlenet.SPEC,
    ]
    return {s.arch_id: s for s in specs}


_REGISTRY: Optional[Dict[str, ArchSpec]] = None


def REGISTRY() -> Dict[str, ArchSpec]:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _build_registry()
    return _REGISTRY


def get_arch(arch_id: str) -> ArchSpec:
    reg = REGISTRY()
    if arch_id not in reg:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(reg)}")
    return reg[arch_id]


def list_archs(*, assigned_only: bool = False) -> List[str]:
    return [a for a, s in REGISTRY().items()
            if s.assigned or not assigned_only]


def list_cells() -> List[Tuple[str, str]]:
    """The 40 assigned (arch × shape) dry-run cells."""
    cells = []
    for a in list_archs(assigned_only=True):
        for sh in get_arch(a).shapes:
            cells.append((a, sh))
    return cells


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, shannon/kernels pattern)
# ---------------------------------------------------------------------------


def input_specs(arch_id: str, shape_name: str, *,
                smoke: bool = False) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for the (arch, shape) step function."""
    spec = get_arch(arch_id)
    cfg = spec.smoke if smoke else spec.full
    sh = spec.shapes[shape_name]
    f32 = jnp.float32
    i32 = jnp.int32

    if spec.family == "lm":
        b, s = sh.global_batch, sh.seq_len
        if sh.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if sh.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        # decode: one new token against a seq_len KV cache
        return {"token": jax.ShapeDtypeStruct((b,), i32),
                "cache_index": jax.ShapeDtypeStruct((), i32)}

    if spec.family == "diffusion":
        b, r = sh.global_batch, sh.img_res
        if cfg.name.startswith("flux") or type(cfg).__name__ == "MMDiTConfig":
            n_img = (r // 16) ** 2
            lat_sp = jax.ShapeDtypeStruct((b, n_img, cfg.in_ch), f32)
            base = {"latent": lat_sp,
                    "txt": jax.ShapeDtypeStruct((b, cfg.txt_len, cfg.txt_dim),
                                                f32),
                    "vec": jax.ShapeDtypeStruct((b, cfg.vec_dim), f32),
                    "t": jax.ShapeDtypeStruct((b,), f32)}
            if sh.kind == "train":          # deterministic distributed step
                base["noise"] = lat_sp
            return base
        lat = r // 8
        lat_sp = jax.ShapeDtypeStruct((b, lat, lat, cfg.in_ch), f32)
        base = {"latent": lat_sp,
                "ctx": jax.ShapeDtypeStruct((b, cfg.ctx_len, cfg.ctx_dim),
                                            f32),
                "t": jax.ShapeDtypeStruct((b,), i32)}
        if sh.kind == "train":
            base["noise"] = lat_sp
        return base

    # vision
    b, r = sh.global_batch, sh.img_res
    base = {"image": jax.ShapeDtypeStruct((b, r, r, 3), f32)}
    if sh.kind == "train":
        base["label"] = jax.ShapeDtypeStruct((b,), i32)
    return base
