"""phi3-medium-14b [arXiv:2404.14219]: 40L d=5120 40H (GQA kv=10)
d_ff=17920 vocab=100352 — RoPE SwiGLU GQA."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.transformer import LMConfig

FULL = LMConfig(name="phi3-medium-14b", n_layers=40, d_model=5120,
                n_heads=40, n_kv=10, d_ff=17920, vocab=100352,
                max_seq=524288, dtype=jnp.bfloat16)

SMOKE = LMConfig(name="phi3-medium-14b-smoke", n_layers=2, d_model=64,
                 n_heads=4, n_kv=1, d_ff=224, vocab=256, max_seq=128,
                 remat=False)

SPEC = ArchSpec(arch_id="phi3-medium-14b", family="lm", full=FULL,
                smoke=SMOKE, source="arXiv:2404.14219; unverified")
