"""vit-s16 [arXiv:2010.11929]: 224/16, 12L d=384 6H d_ff=1536."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.vit import ViTConfig

FULL = ViTConfig(name="vit-s16", img_res=224, patch=16, n_layers=12,
                 d_model=384, n_heads=6, d_ff=1536, dtype=jnp.bfloat16)

SMOKE = ViTConfig(name="vit-s-smoke", img_res=32, patch=8, n_layers=2,
                  d_model=32, n_heads=4, d_ff=64, n_classes=10, remat=False)

SPEC = ArchSpec(arch_id="vit-s16", family="vision", full=FULL, smoke=SMOKE,
                source="arXiv:2010.11929; paper")
