"""alexnet — paper baseline (Table 3 subject, best cut conv5).
Single-tower (ungrouped) variant; see DESIGN.md."""
from repro.configs import ArchSpec


class AlexNetConfig:
    name = "alexnet"
    img_res = 227


FULL = AlexNetConfig()
SMOKE = AlexNetConfig()

SPEC = ArchSpec(arch_id="alexnet", family="vision", full=FULL, smoke=SMOKE,
                source="arXiv:1404.5997-era; paper", assigned=False)
