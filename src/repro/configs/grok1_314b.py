"""grok-1-314b [hf:xai-org/grok-1]: 64L d=6144 48H (kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.transformer import LMConfig, MoESpec

FULL = LMConfig(name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48,
                n_kv=8, d_ff=32768, vocab=131072,
                moe=MoESpec(n_experts=8, top_k=2), max_seq=524288,
                dtype=jnp.bfloat16)

SMOKE = LMConfig(name="grok1-smoke", n_layers=2, d_model=48, n_heads=4,
                 n_kv=2, d_ff=128, vocab=256,
                 moe=MoESpec(n_experts=4, top_k=2), max_seq=128, remat=False)

SPEC = ArchSpec(arch_id="grok-1-314b", family="lm", full=FULL, smoke=SMOKE,
                source="hf:xai-org/grok-1; unverified")
