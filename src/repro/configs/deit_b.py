"""deit-b [arXiv:2012.12877]: 224/16, 12L d=768 12H d_ff=3072 + distill
token."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.vit import ViTConfig

FULL = ViTConfig(name="deit-b", img_res=224, patch=16, n_layers=12,
                 d_model=768, n_heads=12, d_ff=3072, distill_token=True,
                 dtype=jnp.bfloat16)

SMOKE = ViTConfig(name="deit-smoke", img_res=32, patch=8, n_layers=2,
                  d_model=32, n_heads=4, d_ff=64, n_classes=10,
                  distill_token=True, remat=False)

SPEC = ArchSpec(arch_id="deit-b", family="vision", full=FULL, smoke=SMOKE,
                source="arXiv:2012.12877; paper")
