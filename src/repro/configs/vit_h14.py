"""vit-h14 [arXiv:2010.11929]: 224/14, 32L d=1280 16H d_ff=5120."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.vit import ViTConfig

FULL = ViTConfig(name="vit-h14", img_res=224, patch=14, n_layers=32,
                 d_model=1280, n_heads=16, d_ff=5120, dtype=jnp.bfloat16)

SMOKE = ViTConfig(name="vit-h-smoke", img_res=28, patch=7, n_layers=2,
                  d_model=32, n_heads=4, d_ff=64, n_classes=10, remat=False)

SPEC = ArchSpec(arch_id="vit-h14", family="vision", full=FULL, smoke=SMOKE,
                source="arXiv:2010.11929; paper")
