"""deepseek-7b [arXiv:2401.02954]: 30L d=4096 32H (kv=32) d_ff=11008
vocab=102400 — llama-arch."""
import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.transformer import LMConfig

FULL = LMConfig(name="deepseek-7b", n_layers=30, d_model=4096, n_heads=32,
                n_kv=32, d_ff=11008, vocab=102400, max_seq=524288,
                dtype=jnp.bfloat16)

SMOKE = LMConfig(name="deepseek-7b-smoke", n_layers=2, d_model=64, n_heads=4,
                 n_kv=4, d_ff=172, vocab=256, max_seq=128, remat=False)

SPEC = ArchSpec(arch_id="deepseek-7b", family="lm", full=FULL, smoke=SMOKE,
                source="arXiv:2401.02954; hf")
