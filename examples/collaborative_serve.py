"""End-to-end driver: serve a small LM with batched requests, cloud-only
vs cloud-edge collaborative at the auto-tuned partition point.

This is the paper's deployment story on the LM family: Algorithm 1 picks
the cut from the layer graph + device/channel models, then the
collaborative engine runs the INT8 edge prefix and the FP32 cloud suffix
over *split* KV caches — one split prefill, then one quantized
[B, 1, D] boundary delta per generated token (Eq.1/2), so wire traffic
per token is O(1) in sequence length instead of re-shipping the whole
boundary blob.  On a high-RTT link the engine can further restructure
decode into speculative draft/verify rounds (spec_k, auto-tuned by
autotune.tune_spec_k): the edge drafts k tokens locally through an INT8
copy of the cloud suffix, and the cloud verifies all k in one batched
step — the channel round trip is paid per round instead of per token.

The final section closes the tuning loop *online*: link telemetry
(EWMA bandwidth/RTT/acceptance estimated from the serving traffic
itself) feeds the cost-model grid between rounds, and the engine
re-tunes spec_k and the cut layer while requests drain through a
channel swing — Algorithm 1 as a control plane instead of a
preprocessing step.

Run:  PYTHONPATH=src python examples/collaborative_serve.py
      PYTHONPATH=src python examples/collaborative_serve.py --overload
      (the flag appends the overload-robustness demo: a priority burst
      preempting a best-effort wave on a 2x oversubscribed KV pool)
      PYTHONPATH=src python examples/collaborative_serve.py --mesh 4
      (serves the collaborative engine with the cloud suffix + paged KV
      pool tensor-parallel over N emulated host devices)
      PYTHONPATH=src python examples/collaborative_serve.py --fleet 4
      (appends the multi-tenant demo: N simulated edges with
      heterogeneous links and per-tenant (cut, k) share ONE cloud
      engine — cross-tenant batched verify over a shared weight bank
      and KV page pool)
      PYTHONPATH=src python examples/collaborative_serve.py --sample
      (appends the temperature>0 demo: verify becomes exact rejection
      sampling against the cloud distribution, seeded for bit-identical
      replay; temperature=0 keeps the greedy fast path)
"""
import argparse
import os
import time

# --mesh N needs N XLA host-platform devices, and the device count is
# fixed the moment jax is imported — pre-parse just that flag here
_MESH = argparse.ArgumentParser(add_help=False)
_MESH.add_argument("--mesh", type=int, default=1)
_MESH = max(1, _MESH.parse_known_args()[0].mesh)
if _MESH > 1 and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={_MESH}").strip()

import jax
import numpy as np

from repro.core.autotune import AutoTuner
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel,
                                  EDGE_TX2_CLASS)
from repro.models.transformer import LMConfig, init_lm, make_graph
from repro.serve import FaultyChannel, Request
from repro.serve.engine import CollaborativeServingEngine, ServingEngine

CFG = LMConfig(name="edge-lm-25m", n_layers=6, d_model=256, n_heads=8,
               n_kv=4, d_ff=1024, vocab=2048, max_seq=128, remat=False)


def overload_demo(params, cut_layer):
    """Overload robustness: a late priority burst against a best-effort
    wave on a KV page pool sized at ~half the batch's worst-case demand.
    The naive engine reserves worst-case pages at admission and
    head-of-line blocks the burst; the robust engine demand-pages,
    preempts best-effort slots (replay-based resume — no tokens lost),
    and sheds only requests its cost model predicts are already doomed."""
    base = Channel.from_kbps(500, rtt_ms=10)
    # 4 slots x (9 prompt + 32 new) wants ~24 usable pages; pool has 10
    pool = dict(page_size=8, max_batch=4, max_len=64, num_pages=11)

    # calibrate the burst deadline from a lone-request service time
    fch = FaultyChannel(base, seed=0)
    lone = CollaborativeServingEngine(params, CFG, cut_layer=cut_layer,
                                      channel=fch, **pool)
    rng = np.random.RandomState(7)
    lone.generate([rng.randint(0, CFG.vocab, 9).astype(np.int32)],
                  max_new_tokens=12)
    deadline = 3.0 * float(fch.clock_s)
    print(f"\noverload demo: pool {pool['num_pages']} pages "
          f"(~2x oversubscribed), priority deadline {deadline:.2f}s")

    def traffic():
        r = np.random.RandomState(7)
        mk = lambda: r.randint(0, CFG.vocab, 9).astype(np.int32)  # noqa: E731
        reqs = [Request(uid=i, prompt=mk(), max_new_tokens=32, priority=0,
                        arrival_s=0.05 * i) for i in range(6)]
        reqs += [Request(uid=10 + i, prompt=mk(), max_new_tokens=12,
                         priority=1, arrival_s=0.3 + 0.05 * i,
                         deadline_s=0.3 + 0.05 * i + deadline)
                 for i in range(2)]
        return reqs

    for name, kw in [("naive", {}),
                     ("robust", dict(demand_paged=True,
                                     admission="deadline"))]:
        fch = FaultyChannel(base, seed=0)
        eng = CollaborativeServingEngine(params, CFG, cut_layer=cut_layer,
                                         channel=fch, **pool, **kw)
        reqs = traffic()
        eng.generate_requests(reqs)
        pri = [r for r in reqs if r.priority > 0]
        ontime = sum(1 for r in pri
                     if r.finish_s is not None and r.finish_s <= r.deadline_s)
        s = eng.stats
        print(f"  {name:>6}: {s.decode_tokens} tokens in "
              f"{float(fch.clock_s):.2f}s sim — priority on-time "
              f"{ontime}/{len(pri)}, preemptions={s.preemptions}, "
              f"shed={s.shed}, deadline_misses={s.deadline_misses}, "
              f"p99 admit wait "
              f"{max((r.admit_s - r.arrival_s) for r in reqs if r.admit_s is not None):.2f}s")
    print("  (identical traffic; the robust engine's preemption/resume "
          "is bit-transparent — see tests/test_overload_serve.py)")


def fleet_demo(params, cut_layer, n_tenants):
    """Multi-tenant fleet serving: ``n_tenants`` simulated edges — each
    with its own link, clock, telemetry, and (cut, spec_k) — stream at
    ONE shared cloud engine.  Weights come out of a single prequantized
    bank (no per-tenant copies), KV lives in one shared page pool under
    weighted-fair sharing, and every scheduler turn coalesces all
    tenants' due rounds into one batched verify per (cut, k) group —
    aggregate throughput scales far beyond N independent engines (see
    benchmarks/fleet_serve.py for the measured headline)."""
    from repro.core.costmodel import Channel as Ch
    from repro.serve import FleetServingEngine, TenantSpec

    links = [(2000, 20), (1000, 40), (500, 60), (250, 80)]
    cuts = [cut_layer, max(0, cut_layer - 1)]
    ks = [4, 1]
    tenants = [
        TenantSpec(f"edge{i}",
                   FaultyChannel(Ch.from_kbps(links[i % 4][0],
                                              rtt_ms=links[i % 4][1]),
                                 seed=i),
                   cut_layer=cuts[i % 2], spec_k=ks[i % 2])
        for i in range(n_tenants)]
    fleet = FleetServingEngine(params, CFG, tenants,
                               max_batch=2 * n_tenants, max_len=64,
                               page_size=8)
    rng = np.random.RandomState(5)
    prompts = {t.name: [rng.randint(0, CFG.vocab, 12).astype(np.int32)
                        for _ in range(2)] for t in tenants}
    print(f"\nfleet demo: {n_tenants} tenants on one cloud engine "
          f"(shared weight bank @ cuts {sorted(set(t.cut_layer for t in tenants))}, "
          f"one KV pool, cross-tenant batched verify)")
    t0 = time.perf_counter()
    fleet.generate(prompts, max_new_tokens=8)
    wall = time.perf_counter() - t0
    for t in tenants:
        st = fleet.tenant(t.name).stats
        print(f"  {t.name:>6}: cut={t.cut_layer} k={t.spec_k} — "
              f"{st.decode_tokens:3d} committed tokens, "
              f"{st.transmitted_bytes / 1e3:5.1f}KB wire, "
              f"sim clock {fleet.tenant(t.name).now():.2f}s")
    agg = fleet.stats
    print(f"  fleet: {agg.decode_tokens} committed tokens in {wall:.2f}s "
          f"wall over {fleet.round_calls} batched round dispatches — each "
          f"turn verifies every due tenant in one paged multi-query call "
          f"per (cut, k) group; benchmarks/fleet_serve.py measures the "
          f"aggregate speedup vs independent engines.  Pool peak "
          f"utilization {agg.pool_utilization_peak:.0%}")


def sampling_demo(params, cut_layer):
    """Temperature>0 serving: the verify step becomes exact rejection
    sampling against the cloud distribution — outputs are distributed
    exactly as non-speculative cloud sampling (tests/test_sampled_spec
    holds the TV-distance gate), the speculative round structure and its
    per-round RTT win are unchanged, and the per-request seed makes the
    stream replay bit-identically across engines and restarts."""
    from repro.serve.engine import SamplingParams
    ch = Channel.from_kbps(500, rtt_ms=50)
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, CFG.vocab, 12).astype(np.int32)
               for _ in range(4)]
    sp = [SamplingParams(temperature=0.9, top_p=0.95, seed=i)
          for i in range(4)]

    def fresh():
        return CollaborativeServingEngine(params, CFG, cut_layer=cut_layer,
                                          channel=ch, max_len=64,
                                          max_batch=4, spec_k=4)
    a = fresh()
    outs = a.generate(prompts, max_new_tokens=8, sampling=sp)
    replay = fresh().generate(prompts, max_new_tokens=8, sampling=sp)
    greedy = fresh().generate(prompts, max_new_tokens=8)
    t0 = fresh().generate(prompts, max_new_tokens=8,
                          sampling=[SamplingParams(temperature=0.0)] * 4)
    print(f"\nsampled decode (T=0.9, top_p=0.95, k=4): draft acceptance "
          f"{a.stats.acceptance_rate():.0%} under stochastic "
          f"accept-with-prob-min(1,p/q) grading")
    print(f"  seeded replay bit-identical across engines: {outs == replay}")
    print(f"  temperature=0 request == greedy fast path: {t0 == greedy} "
          f"(sampled rows never perturb greedy ones)")
    print(f"  first sampled stream: {outs[0]}")


def main(overload: bool = False, mesh_n: int = 1, fleet_n: int = 0,
         sample: bool = False):
    print(f"model: {CFG.name} ({CFG.param_count() / 1e6:.1f}M params)")
    mesh = None
    if mesh_n > 1:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(model=mesh_n)
        print(f"cloud mesh: {dict(mesh.shape)} over "
              f"{len(jax.devices())} host devices (suffix weights + paged "
              f"KV pool shard over 'model'; the edge side replicates)")
    params = init_lm(jax.random.PRNGKey(0), CFG)

    # --- Algorithm 1: choose the cut for this environment ---------------
    graph = make_graph(CFG, batch=1, seq=32)
    channel = Channel.from_kbps(250, rtt_ms=20)
    tuner = AutoTuner(graph, EDGE_TX2_CLASS, CLOUD_TITANXP_CLASS)
    best, perfs = tuner.tune(channel)
    print(f"auto-tuned cut @250KB/s: {best.point} "
          f"(upload {best.transmit_bytes / 1e3:.1f}KB, "
          f"edge download {best.edge_model_bytes / 1e3:.0f}KB, "
          f"storage reduction {best.storage_reduction:.1%})")
    cut_layer = 0
    if best.point.startswith("blk"):
        cut_layer = int(best.point.split("/")[0][3:])

    # --- batched serving (continuous batching: 8 requests, 4 slots) -----
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, CFG.vocab, 16).astype(np.int32)
               for _ in range(8)]

    cloud = ServingEngine(params, CFG, max_batch=4, max_len=64)
    t0 = time.perf_counter()
    ref = cloud.generate(prompts, max_new_tokens=8)
    t_cloud = time.perf_counter() - t0
    print(f"\ncloud-only: {len(prompts)} requests x 8 tokens in "
          f"{t_cloud:.2f}s  ({cloud.stats.prefill_calls} prefills, "
          f"{cloud.stats.decode_steps} decode steps)")

    collab = CollaborativeServingEngine(params, CFG, cut_layer=cut_layer,
                                        channel=channel, max_len=64,
                                        max_batch=4, timed=True, mesh=mesh)
    t0 = time.perf_counter()
    got = collab.generate(prompts, max_new_tokens=8)
    t_collab = time.perf_counter() - t0
    agree = np.mean([a == b for r, g in zip(ref, got)
                     for a, b in zip(r, g)])
    s = collab.stats
    print(f"collaborative (cut after block {cut_layer}): {t_collab:.2f}s "
          f"(prefill {s.prefill_s:.2f}s / decode {s.decode_s:.2f}s / "
          f"simulated wire {s.channel_latency_s:.2f}s)")
    print(f"  wire: {s.prefill_bytes / 1e3:.1f}KB one-time prefill + "
          f"{s.bytes_per_decode_token():.0f} B per generated token "
          f"(constant — the [B,1,D] Eq.(1) delta)")
    print(f"token agreement with cloud-only greedy: {agree:.1%} "
          f"(INT8 edge noise can flip near-ties)")

    # --- speculative draft/verify rounds on a high-RTT link -------------
    rtt_channel = Channel.from_kbps(250, rtt_ms=100)
    from repro.core.autotune import spec_k_for_lm
    tuned = spec_k_for_lm(CFG, cut_layer, batch=4, channel=rtt_channel)[0]
    spec = CollaborativeServingEngine(params, CFG, cut_layer=cut_layer,
                                      channel=rtt_channel, max_len=64,
                                      max_batch=4, spec_k=min(tuned.k, 4))
    spec.generate(prompts[:4], max_new_tokens=8)
    base = CollaborativeServingEngine(params, CFG, cut_layer=cut_layer,
                                      channel=rtt_channel, max_len=64,
                                      max_batch=4)
    base.generate(prompts[:4], max_new_tokens=8)
    print(f"\nspeculative rounds @100ms RTT (auto-tuned k={tuned.k}, "
          f"running k={spec.spec_k}): draft acceptance "
          f"{spec.stats.acceptance_rate():.0%}, simulated channel "
          f"{spec.stats.channel_latency_s:.2f}s vs "
          f"{base.stats.channel_latency_s:.2f}s per-token — the RTT is "
          f"paid per round, not per token")

    # --- contrast with the seed recompute path --------------------------
    rec_prompts, rec_new = prompts[:4], 8
    rec = CollaborativeServingEngine(params, CFG, cut_layer=cut_layer,
                                     channel=channel, max_len=64)
    rec.generate_recompute(rec_prompts, max_new_tokens=rec_new)
    per_tok_rec = rec.stats.transmitted_bytes / (rec_new * len(rec_prompts))
    print(f"\nrecompute-from-scratch baseline would ship "
          f"{per_tok_rec / 1e3:.1f}KB per token (grows with sequence); "
          f"incremental decode ships "
          f"{s.bytes_per_decode_token() / 1e3:.3f}KB — "
          f"{per_tok_rec / s.bytes_per_decode_token():.0f}x less")

    # --- close the tuning loop online: serve through a channel swing ----
    # telemetry -> policy -> engine: the link telemetry estimates
    # bandwidth/RTT from the traffic itself, the policy re-runs the
    # cost-model grid, and the engine swaps spec_k between rounds and
    # the cut layer at admission boundaries (prequantized weight bank)
    # (clamp like the launcher: every candidate cut keeps a cloud block)
    adaptive = CollaborativeServingEngine(
        params, CFG, cut_layer=min(cut_layer, CFG.n_layers - 2),
        channel=channel, max_len=64, max_batch=4, policy="auto")
    for label, ch in [("good link", Channel.from_kbps(2000, rtt_ms=5)),
                      ("congested", Channel.from_kbps(200, rtt_ms=150)),
                      ("recovered", Channel.from_kbps(2000, rtt_ms=5))]:
        adaptive.channel = ch
        adaptive.generate(prompts, max_new_tokens=8)
        tel = adaptive.telemetry
        print(f"{label:>10}: engine now (cut={adaptive.cut}, "
              f"k={adaptive.spec_k}); telemetry est "
              f"{(tel.bandwidth_bytes_per_s or 0) / 1e3:.0f}KB/s "
              f"rtt {(tel.rtt_s or 0) * 1e3:.0f}ms")
    st = adaptive.stats
    print(f"online re-tuning: {st.spec_k_switches} draft-length + "
          f"{st.cut_switches} cut switches while serving "
          f"{st.decode_tokens} tokens (acceptance "
          f"{st.acceptance_rate():.0%}) — see benchmarks/adaptive_serve.py "
          f"for the drifting-channel win over fixed cuts")

    # --- temperature>0 serving (opt-in: --sample) -----------------------
    if sample:
        sampling_demo(params, min(cut_layer, CFG.n_layers - 2))

    # --- overload robustness (opt-in: --overload) -----------------------
    if overload:
        overload_demo(params, min(cut_layer, CFG.n_layers - 2))

    # --- multi-tenant fleet serving (opt-in: --fleet N) -----------------
    if fleet_n > 0:
        fleet_demo(params, min(max(cut_layer, 1), CFG.n_layers - 2),
                   fleet_n)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--overload", action="store_true",
                    help="append the overload-robustness demo: a priority "
                         "burst preempting a best-effort wave on a 2x "
                         "oversubscribed KV page pool")
    ap.add_argument("--mesh", type=int, default=1,
                    help="tensor-parallel degree for the cloud suffix and "
                         "paged KV pool (emulated host devices on CPU)")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="append the multi-tenant demo: N simulated edges "
                         "with heterogeneous links share one cloud engine "
                         "(cross-tenant batched verify, shared weight "
                         "bank + KV page pool)")
    ap.add_argument("--sample", action="store_true",
                    help="append the temperature>0 demo: rejection-sampled "
                         "verify (exact cloud distribution), seeded "
                         "bit-identical replay, greedy fast-path parity")
    args = ap.parse_args()
    main(overload=args.overload, mesh_n=args.mesh, fleet_n=args.fleet,
         sample=args.sample)
