"""End-to-end driver: serve a small LM with batched requests, cloud-only
vs cloud-edge collaborative at the auto-tuned partition point.

This is the paper's deployment story on the LM family: Algorithm 1 picks
the cut from the layer graph + device/channel models, then the
collaborative engine runs the INT8 edge prefix and ships one quantized
boundary blob per forward.

Run:  PYTHONPATH=src python examples/collaborative_serve.py
"""
import time

import jax
import numpy as np

from repro.core.autotune import AutoTuner
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel,
                                  EDGE_TX2_CLASS)
from repro.models.transformer import LMConfig, init_lm, make_graph
from repro.serve.engine import CollaborativeServingEngine, ServingEngine

CFG = LMConfig(name="edge-lm-25m", n_layers=6, d_model=256, n_heads=8,
               n_kv=4, d_ff=1024, vocab=2048, max_seq=128, remat=False)


def main():
    print(f"model: {CFG.name} ({CFG.param_count() / 1e6:.1f}M params)")
    params = init_lm(jax.random.PRNGKey(0), CFG)

    # --- Algorithm 1: choose the cut for this environment ---------------
    graph = make_graph(CFG, batch=1, seq=32)
    channel = Channel.from_kbps(250, rtt_ms=20)
    tuner = AutoTuner(graph, EDGE_TX2_CLASS, CLOUD_TITANXP_CLASS)
    best, perfs = tuner.tune(channel)
    print(f"auto-tuned cut @250KB/s: {best.point} "
          f"(upload {best.transmit_bytes / 1e3:.1f}KB, "
          f"edge download {best.edge_model_bytes / 1e3:.0f}KB, "
          f"storage reduction {best.storage_reduction:.1%})")
    cut_layer = 0
    if best.point.startswith("blk"):
        cut_layer = int(best.point.split("/")[0][3:])

    # --- batched serving -------------------------------------------------
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, CFG.vocab, 16).astype(np.int32)
               for _ in range(8)]

    cloud = ServingEngine(params, CFG, max_batch=4, max_len=64)
    t0 = time.perf_counter()
    ref = cloud.generate(prompts, max_new_tokens=8)
    t_cloud = time.perf_counter() - t0
    print(f"\ncloud-only: {len(prompts)} requests x 8 tokens in "
          f"{t_cloud:.2f}s  ({cloud.stats.decode_steps} decode steps)")

    collab = CollaborativeServingEngine(params, CFG, cut_layer=cut_layer,
                                        channel=channel, max_len=64)
    t0 = time.perf_counter()
    got = collab.generate(prompts, max_new_tokens=8)
    t_collab = time.perf_counter() - t0
    agree = np.mean([a == b for r, g in zip(ref, got)
                     for a, b in zip(r, g)])
    print(f"collaborative (cut after block {cut_layer}): {t_collab:.2f}s, "
          f"transmitted {collab.stats.transmitted_bytes / 1e3:.1f}KB int8 "
          f"(simulated wire time {collab.stats.channel_latency_s:.2f}s)")
    print(f"token agreement with cloud-only greedy: {agree:.1%} "
          f"(INT8 edge noise can flip near-ties)")
    raw_bytes = sum(p.size * 4 for p in prompts) * 8
    print(f"\nwire traffic vs shipping fp32 activations every step: "
          f"{collab.stats.transmitted_bytes / 1e3:.0f}KB int8 — the paper's "
          f"Eq.(1) boundary quantization at work")


if __name__ == "__main__":
    main()
