"""Train an LM with quantization-aware training (QAT), checkpointing and
int8 error-feedback gradient compression — then deploy the edge prefix.

Defaults are CPU-sized (a few minutes). ``--big`` trains a ~100M-param
model for a few hundred steps (the assignment's end-to-end scale) —
expect hours on CPU, minutes on real accelerators.

Run:  PYTHONPATH=src python examples/train_qat.py [--steps N] [--big]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import TokenPipeline
from repro.models.transformer import LMConfig, init_lm, lm_loss
from repro.train.loop import Trainer, TrainerConfig
from repro.train.qat import make_qat_loss

SMALL = LMConfig(name="qat-lm-2m", n_layers=4, d_model=128, n_heads=4,
                 n_kv=2, d_ff=512, vocab=512, max_seq=64, remat=False)
BIG = LMConfig(name="qat-lm-100m", n_layers=12, d_model=768, n_heads=12,
               n_kv=4, d_ff=2048, vocab=32768, max_seq=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt", default="artifacts/qat_ckpt")
    args = ap.parse_args()
    cfg = BIG if args.big else SMALL
    if args.big:
        args.seq, args.batch = 512, 16

    print(f"training {cfg.name} ({cfg.param_count() / 1e6:.1f}M params) "
          f"for {args.steps} steps with QAT + int8 grad compression")
    params = init_lm(jax.random.PRNGKey(0), cfg)

    qat = make_qat_loss(lambda p, b, qctx: lm_loss(p, b, cfg, qctx=qctx))
    tcfg = TrainerConfig(n_steps=args.steps, lr=3e-3, warmup=args.steps // 10,
                         grad_compress=True, ckpt_dir=args.ckpt,
                         ckpt_every=max(args.steps // 3, 1), log_every=10)
    trainer = Trainer(qat, params, tcfg)
    start = trainer.maybe_restore()
    if start:
        print(f"resumed from checkpoint at step {start}")

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    hist = trainer.fit(iter(pipe), start_step=start)
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{len(hist)} steps")

    # deployment check: QAT params evaluated on the INT8 lattice vs fp32
    batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch_at(10_000))
    fp32 = float(lm_loss(trainer.params, batch, cfg))
    int8 = float(qat(trainer.params, batch))
    print(f"eval loss fp32={fp32:.4f} int8-lattice={int8:.4f} "
          f"(gap {abs(fp32 - int8):.4f} — trivial, as the paper reports)")


if __name__ == "__main__":
    main()
