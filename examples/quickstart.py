"""Quickstart: the paper's full pipeline on a small CNN in ~a minute.

  1. build a model + its layer graph
  2. find candidate partition points (§2.2 rules)
  3. auto-tune the cut for several wireless bandwidths (Algorithm 1)
  4. run collaborative inference: INT8 edge → simulated channel → FP32 cloud

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import AutoTuner
from repro.core.collab import CollaborativeEngine
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel,
                                  EDGE_TX2_CLASS)
from repro.core.partition import partition_report
from repro.models import legacy


def main():
    print("== AlexNet (paper Table 3 subject), ImageNet-sized input ==\n")
    graph = legacy.alexnet_graph()
    print(partition_report(graph))

    print("\n== Algorithm 1: best cut per wireless bandwidth ==")
    tuner = AutoTuner(graph, EDGE_TX2_CLASS, CLOUD_TITANXP_CLASS)
    print(f"{'bandwidth':>12} {'best cut':>10} {'total (s)':>10} "
          f"{'upload (KB)':>12} {'edge model (KB)':>16} {'storage red.':>12}")
    for kbps in (50, 100, 250, 500, 1000, 10000):
        ch = Channel.from_kbps(kbps)
        best, _ = tuner.tune(ch)
        print(f"{kbps:>10} KB/s {best.point:>10} {best.total_s:>10.3f} "
              f"{best.transmit_bytes / 1e3:>12.1f} "
              f"{best.edge_model_bytes / 1e3:>16.1f} "
              f"{best.storage_reduction:>11.1%}")
    sp = tuner.speedup_vs_cloud_only(Channel.from_kbps(250))
    print(f"\nspeed-up vs cloud-only @250KB/s: {sp:.2f}x "
          f"(paper reports 1.7x for AlexNet)")

    print("\n== collaborative inference on device (small CNN, real compute) ==")
    from tests.test_collab import tiny_cnn, _input
    model = tiny_cnn()
    x = _input(batch=1)
    truth = model.full_apply(x)
    for cut in ("input", "conv1", "conv2", "head"):
        eng = CollaborativeEngine(model, cut,
                                  channel=Channel.from_kbps(250),
                                  calib_batches=[_input(seed=9)])
        y, rec = eng.infer(x)
        rel = float(jnp.linalg.norm(y - truth) / jnp.linalg.norm(truth))
        print(f"  cut={cut:6s} blob={rec.blob_bytes:6d}B ({rec.precision}) "
              f"sim-latency={rec.simulated_latency_s * 1e3:7.2f}ms "
              f"rel-err vs fp32={rel:.4f}")
    print("\nDone. The INT8 edge keeps the output within quantization noise.")


if __name__ == "__main__":
    main()
