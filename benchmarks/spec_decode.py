"""Speculative collaborative decode benchmark.

Measures the draft/verify-round engine (edge drafts k tokens locally
through the INT8 suffix copy, one [B, k, D] uplink blob, one batched
cloud verify with longest-prefix acceptance) against the per-token
incremental collaborative decode (PR 1's path — exactly the ``spec_k=1``
configuration of the same engine, bit for bit), on an RTT-dominated
channel where the per-token path pays two channel traversals per token.

Reported per *accepted* token, both axes of the win:
  * wall-clock (compute only — the channel is simulated) and *modeled*
    end-to-end time (wall + simulated channel latency, where the k-fold
    RTT amortization shows up);
  * wire bytes (uplink deltas + graded drafts, plus the downlink
    accept-mask + corrected token — `ServeStats` counts both).

Also records the measured draft acceptance rate, feeds it back into
``autotune.tune_spec_k``, and reports the k the auto-tuner would pick
for this channel.  Writes ``BENCH_spec_decode.json`` so future PRs have
a perf trajectory to regress against.

    PYTHONPATH=src python -m benchmarks.spec_decode
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.autotune import spec_k_for_lm
from repro.core.costmodel import Channel
from repro.models.transformer import LMConfig, init_lm
from repro.serve.engine import CollaborativeServingEngine, ServeStats

OUT = Path("BENCH_spec_decode.json")

CFG = LMConfig(name="spec-bench-lm", n_layers=6, d_model=256, n_heads=8,
               n_kv=4, d_ff=1024, vocab=2048, max_seq=256, remat=False)
CUT = 1
BATCH = 4
PLEN = 32
NEW = 16
# RTT-dominated wireless link: 500 KB/s with a 100 ms round trip
# (congested cellular / satellite class) — at one uplink + one downlink
# per round, the per-token path pays 200 ms/token in RTT alone before a
# single byte moves, which is exactly what drafting k tokens amortizes
CHANNEL = Channel.from_kbps(500, rtt_ms=100)


def _engine(params, k, max_len):
    return CollaborativeServingEngine(params, CFG, cut_layer=CUT,
                                      channel=CHANNEL, max_len=max_len,
                                      max_batch=BATCH, spec_k=k, timed=True)


def _measure(eng, prompts, new_tokens):
    eng.generate(prompts, max_new_tokens=2)          # compile all phases
    eng.stats = ServeStats()
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new_tokens=new_tokens)
    wall = time.perf_counter() - t0
    s = eng.stats
    acc = max(s.decode_tokens, 1)
    return outs, {
        "wall_s": wall,
        "accepted_tokens": s.decode_tokens,
        "rounds": s.decode_steps,
        "acceptance_rate": s.acceptance_rate(),
        "wall_us_per_accepted_token": wall / acc * 1e6,
        "e2e_us_per_accepted_token": (wall + s.channel_latency_s) / acc * 1e6,
        "uplink_bytes_per_accepted_token": s.bytes_per_decode_token(),
        "wire_bytes_per_accepted_token": s.wire_bytes_per_accepted_token(),
        "channel_latency_s": s.channel_latency_s,
        "decode_s": s.decode_s,
    }


def run(print_fn=print, quick: bool = False) -> dict:
    ks = (2, 4) if quick else (2, 4, 8)
    new_tokens = 8 if quick else NEW
    max_len = PLEN + NEW + max(ks)       # speculative overshoot headroom
    params = init_lm(jax.random.PRNGKey(0), CFG)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, CFG.vocab, PLEN).astype(np.int32)
               for _ in range(BATCH)]

    # -- per-token baseline: spec_k=1 IS PR 1's incremental path ----------
    base_eng = _engine(params, 1, max_len)
    base_out, base = _measure(base_eng, prompts, new_tokens)

    sweep = {}
    best_k, best_e2e = 1, base["e2e_us_per_accepted_token"]
    for k in ks:
        eng = _engine(params, k, max_len)
        outs, row = _measure(eng, prompts, new_tokens)
        # greedy-token fidelity vs the per-token path (INT8 caches see
        # the verify's batched lattice, so near-ties may flip — the fp
        # configurations are bit-identical, see test_spec_decode)
        agree = sum(a == b for r, g in zip(base_out, outs)
                    for a, b in zip(r, g)) / (BATCH * new_tokens)
        row["token_agreement_vs_k1"] = agree
        row["wall_speedup_vs_k1"] = (base["wall_us_per_accepted_token"]
                                     / row["wall_us_per_accepted_token"])
        row["e2e_speedup_vs_k1"] = (base["e2e_us_per_accepted_token"]
                                    / row["e2e_us_per_accepted_token"])
        row["wire_reduction_vs_k1"] = (base["wire_bytes_per_accepted_token"]
                                       / row["wire_bytes_per_accepted_token"])
        sweep[k] = row
        if row["e2e_us_per_accepted_token"] < best_e2e:
            best_k, best_e2e = k, row["e2e_us_per_accepted_token"]
        print_fn(f"k={k}: acc {row['acceptance_rate']:.2f}  "
                 f"wall {row['wall_us_per_accepted_token']:8.0f} us/tok "
                 f"({row['wall_speedup_vs_k1']:.2f}x)  e2e "
                 f"{row['e2e_us_per_accepted_token']:8.0f} us/tok "
                 f"({row['e2e_speedup_vs_k1']:.2f}x)  wire "
                 f"{row['wire_bytes_per_accepted_token']:.0f} B/tok "
                 f"({row['wire_reduction_vs_k1']:.2f}x)  "
                 f"agree {agree:.0%}")

    # -- auto-tuner: what k does the model pick at the measured acceptance?
    meas_acc = float(np.mean([sweep[k]["acceptance_rate"] for k in ks]))
    tuned, perfs = spec_k_for_lm(CFG, CUT, batch=BATCH, channel=CHANNEL,
                                 acceptance=meas_acc)
    print_fn(f"per-token baseline: wall "
             f"{base['wall_us_per_accepted_token']:.0f} us/tok, e2e "
             f"{base['e2e_us_per_accepted_token']:.0f} us/tok, wire "
             f"{base['wire_bytes_per_accepted_token']:.0f} B/tok")
    print_fn(f"auto-tuner picks k={tuned.k} at measured acceptance "
             f"{meas_acc:.2f} (predicted "
             f"{tuned.s_per_token * 1e3:.1f} ms/token); measured best "
             f"k={best_k}")

    result = {
        "config": {"model": CFG.name, "cut_layer": CUT, "batch": BATCH,
                   "prompt_len": PLEN, "new_tokens": new_tokens,
                   "channel_kbps": 500, "rtt_ms": 100, "quick": quick},
        "per_token_baseline": base,
        "speculative": {str(k): v for k, v in sweep.items()},
        "measured_acceptance": meas_acc,
        "autotuned_k": tuned.k,
        "autotuned_s_per_token": tuned.s_per_token,
        "predicted": {str(p.k): {"s_per_token": p.s_per_token,
                                 "round_s": p.breakdown.total_s,
                                 "expected_tokens": p.breakdown.tokens}
                      for p in perfs},
    }
    OUT.write_text(json.dumps(result, indent=1))
    print_fn(f"-> {OUT}")
    return result


if __name__ == "__main__":
    run()
