"""Quantized-kernel micro-benchmarks.

Two measurements:
  1. wall-clock of the XLA INT8 path vs FP32 matmul on this host (real
     computation — shows the int8 arithmetic works end to end), and
  2. the analytic MXU model for the Pallas kernel (the TPU target):
     int8 394 TOP/s vs bf16 197 TFLOP/s per chip, fused epilogue saving
     3 extra HBM round-trips of the accumulator.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import compute_qparams, quantize
from repro.kernels.ref import int8_matmul_ref


def _time(fn, *args, iters=5) -> float:
    jax.block_until_ready(fn(*args))           # compile + drain the queue
    t0 = time.perf_counter()
    for _ in range(iters):
        # fence every iteration: async dispatch would otherwise overlap
        # device work with the host loop and under-report per-iter time
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(print_fn=print, *, m=512, k=1024, n=512) -> dict:
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.uniform(-2, 2, (m, k)).astype(np.float32))
    w = jnp.asarray(rng.uniform(-1, 1, (k, n)).astype(np.float32))
    qa, qw = compute_qparams(a), compute_qparams(w, axis=1)
    a_q, w_q = quantize(a, qa), quantize(w, qw)

    f32 = jax.jit(lambda x, y: x @ y)
    int8 = jax.jit(lambda x, y: int8_matmul_ref(x, y, qa, qw))

    t_f32 = _time(f32, a, w)
    t_int8 = _time(int8, a_q, w_q)
    err = float(jnp.linalg.norm(int8(a_q, w_q) - a @ w)
                / jnp.linalg.norm(a @ w))

    flops = 2 * m * k * n
    mxu_bf16_s = flops / 197e12
    mxu_int8_s = flops / 394e12
    # unfused epilogue: acc int32 + dequant f32 + requant int8 round-trips
    hbm_extra = m * n * (4 + 4 + 1) / 819e9
    print_fn(f"host XLA  fp32 matmul {m}x{k}x{n}: {t_f32 * 1e6:9.1f} us")
    print_fn(f"host XLA  int8 matmul (+ asym corr): {t_int8 * 1e6:9.1f} us "
             f"(rel err vs fp32 {err:.4f})")
    print_fn(f"MXU model bf16: {mxu_bf16_s * 1e6:7.2f} us   int8: "
             f"{mxu_int8_s * 1e6:7.2f} us (2.0x)")
    print_fn(f"fused epilogue saves {hbm_extra * 1e6:.2f} us of HBM traffic "
             f"per call (acc+dequant+requant round-trips)")
    return {"t_f32_us": t_f32 * 1e6, "t_int8_us": t_int8 * 1e6,
            "rel_err": err, "mxu_speedup": 2.0,
            "epilogue_saving_us": hbm_extra * 1e6}


if __name__ == "__main__":
    run()
