"""Quantized-kernel micro-benchmarks.

Two measurements:
  1. wall-clock of the XLA INT8 path vs FP32 matmul on this host (real
     computation — shows the int8 arithmetic works end to end), and
  2. the analytic MXU model for the Pallas kernel (the TPU target):
     int8 394 TOP/s vs bf16 197 TFLOP/s per chip, fused epilogue saving
     3 extra HBM round-trips of the accumulator.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import compute_qparams, quantize
from repro.kernels.ref import int8_matmul_ref


def _time(fn, *args, iters=5) -> float:
    jax.block_until_ready(fn(*args))           # compile + drain the queue
    t0 = time.perf_counter()
    for _ in range(iters):
        # fence every iteration: async dispatch would otherwise overlap
        # device work with the host loop and under-report per-iter time
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(print_fn=print, *, m=512, k=1024, n=512) -> dict:
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.uniform(-2, 2, (m, k)).astype(np.float32))
    w = jnp.asarray(rng.uniform(-1, 1, (k, n)).astype(np.float32))
    qa, qw = compute_qparams(a), compute_qparams(w, axis=1)
    a_q, w_q = quantize(a, qa), quantize(w, qw)

    f32 = jax.jit(lambda x, y: x @ y)
    int8 = jax.jit(lambda x, y: int8_matmul_ref(x, y, qa, qw))

    t_f32 = _time(f32, a, w)
    t_int8 = _time(int8, a_q, w_q)
    err = float(jnp.linalg.norm(int8(a_q, w_q) - a @ w)
                / jnp.linalg.norm(a @ w))

    flops = 2 * m * k * n
    mxu_bf16_s = flops / 197e12
    mxu_int8_s = flops / 394e12
    # unfused epilogue: acc int32 + dequant f32 + requant int8 round-trips
    hbm_extra = m * n * (4 + 4 + 1) / 819e9
    print_fn(f"host XLA  fp32 matmul {m}x{k}x{n}: {t_f32 * 1e6:9.1f} us")
    print_fn(f"host XLA  int8 matmul (+ asym corr): {t_int8 * 1e6:9.1f} us "
             f"(rel err vs fp32 {err:.4f})")
    print_fn(f"MXU model bf16: {mxu_bf16_s * 1e6:7.2f} us   int8: "
             f"{mxu_int8_s * 1e6:7.2f} us (2.0x)")
    print_fn(f"fused epilogue saves {hbm_extra * 1e6:.2f} us of HBM traffic "
             f"per call (acc+dequant+requant round-trips)")
    return {"t_f32_us": t_f32 * 1e6, "t_int8_us": t_int8 * 1e6,
            "rel_err": err, "mxu_speedup": 2.0,
            "epilogue_saving_us": hbm_extra * 1e6}


def run_paged(print_fn=print, *, batch=4, n_heads=8, n_kv=4, hd=32,
              page_size=16, seq=128) -> dict:
    """Paged-attention microbenchmark: one decode step's attention read.

    The dense baseline is what ``_sdpa`` does each decode step over a
    pre-allocated fp16 cache: stream all ``max_len`` positions, mask the
    tail.  The paged INT8 path streams only the pages a request actually
    allocated (``seq`` long here) at 1 B/elem.  Sweeping ``max_len``
    shows the dense cost growing with the pre-allocation while the paged
    cost stays flat — the same asymptotics the Pallas kernel has on TPU,
    measured here through the XLA reference path (the off-TPU
    production fallback).  The Pallas kernel itself is checked for
    parity against that reference in interpret mode."""
    import math

    from repro.kernels.paged_attention import (paged_attention_ref,
                                               paged_flash_decode)

    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(batch, n_heads, hd).astype(np.float32))
    pages_per = seq // page_size
    n_pages = batch * pages_per + 1
    kp = jnp.asarray(rng.randint(-127, 128,
                                 (n_pages, page_size, n_kv, hd))
                     .astype(np.int8))
    vp = jnp.asarray(rng.randint(-127, 128,
                                 (n_pages, page_size, n_kv, hd))
                     .astype(np.int8))
    bt = jnp.asarray(np.arange(1, n_pages).reshape(batch, pages_per)
                     .astype(np.int32))
    lens = jnp.full((batch,), seq, jnp.int32)
    ks = jnp.full((batch, n_kv), 0.03, jnp.float32)
    vs = jnp.full((batch, n_kv), 0.03, jnp.float32)

    paged = jax.jit(lambda *a: paged_attention_ref(*a))
    t_paged = _time(paged, q, kp, vp, bt, lens, ks, vs)
    err = float(jnp.abs(
        paged_flash_decode(q, kp, vp, bt, lens, ks, vs, interpret=True)
        - paged(q, kp, vp, bt, lens, ks, vs)).max())
    paged_bytes = 2 * batch * pages_per * page_size * n_kv * hd

    def dense_step(qd, k, v, ln):
        g = n_heads // n_kv
        qg = qd.reshape(batch, n_kv, g, hd).astype(jnp.float32) \
            / math.sqrt(hd)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        s = jnp.einsum("bhgd,blhd->bhgl", qg, kf)
        mask = jnp.arange(k.shape[1])[None, None, None, :] \
            < ln[:, None, None, None]
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgl,blhd->bhgd", p, vf)

    rows = []
    for max_len in (256, 1024, 4096):
        kd = jnp.asarray(rng.randn(batch, max_len, n_kv, hd)
                         .astype(np.float16))
        vd = jnp.asarray(rng.randn(batch, max_len, n_kv, hd)
                         .astype(np.float16))
        dense = jax.jit(dense_step)
        t_dense = _time(dense, q, kd, vd, lens)
        dense_bytes = 2 * batch * max_len * n_kv * hd * 2
        rows.append({"max_len": max_len,
                     "t_dense_fp16_us": t_dense * 1e6,
                     "t_paged_int8_us": t_paged * 1e6,
                     "speedup": t_dense / t_paged,
                     "dense_cache_bytes": dense_bytes,
                     "paged_cache_bytes": paged_bytes})
        print_fn(f"max_len {max_len:5d}: dense fp16 {t_dense * 1e6:9.1f} us "
                 f"{dense_bytes / 1024:8.0f} KiB | paged int8 "
                 f"{t_paged * 1e6:9.1f} us {paged_bytes / 1024:6.0f} KiB "
                 f"({t_dense / t_paged:5.1f}x)")
    print_fn(f"pallas kernel (interpret) vs XLA ref max err: {err:.2e}")
    return {"sweep": rows, "kernel_ref_err": err,
            "paged_speedup_at_4096": rows[-1]["speedup"]}


if __name__ == "__main__":
    run()
    run_paged()
