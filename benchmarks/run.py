"""Benchmark orchestrator — one section per paper table/figure.

Prints human tables per benchmark, then a machine-readable
``name,us_per_call,derived`` CSV summary at the end.

    PYTHONPATH=src python -m benchmarks.run [--quick]

``--quick`` is the CI smoke mode: every section's callable is still
resolved (so a renamed/broken benchmark registration fails loudly on
CPU), but only the cheap analytic sections and shrunken speculative-
decode / adaptive-serve runs actually execute.  Quick mode then runs
the **benchmark-drift guard**: fresh quick-mode numbers are compared
against the committed ``BENCH_*.json`` headline metrics, and the
process exits non-zero on a >2x regression of any tracked metric —
CI catches a perf cliff, not just a crash.

A crashing section no longer aborts the run: every section executes,
all section errors AND all drift regressions are reported together at
the end (single non-zero exit), and an absent or unreadable tracked
``BENCH_*.json`` prints a clear skip line instead of tracebacking.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

# tracked headline metrics: BENCH file -> dotted paths into its JSON,
# all "higher is better" ratios (scale-free, so quick-mode runs remain
# comparable with the committed full-mode numbers within the 2x band;
# the k=2 spec column is tracked because its quick/full gap is the
# smallest — the larger-k wins grow with tokens per run)
DRIFT_TRACKED = {
    "BENCH_spec_decode.json": ["speculative.2.e2e_speedup_vs_k1"],
    "BENCH_adaptive_serve.json": ["adaptive_vs_worst_fixed_e2e_speedup"],
    "BENCH_chaos_serve.json": ["outage_availability",
                               "resilient_vs_naive_sim_speedup"],
    "BENCH_overload_serve.json": ["goodput_vs_naive",
                                  "priority_ontime_frac"],
    "BENCH_sharded_serve.json": ["speedup_vs_1dev.4"],
    "BENCH_fleet_serve.json": ["aggregate_speedup_vs_independent",
                               "dispatch_ratio"],
    # sampled speculative decode: stochastic acceptance at T=1 and the
    # e2e win over the serial sampled baseline (row keys are dot-free
    # on purpose — see benchmarks/sampled_spec.py)
    "BENCH_sampled_spec.json": ["acceptance.t10",
                                "e2e_speedup_vs_serial.t10"],
}
DRIFT_RATIO = 2.0


def _load_tracked(print_fn=print) -> dict:
    """Read every tracked ``BENCH_*.json`` that exists; an absent or
    unparseable file gets a clear skip line instead of a traceback (the
    drift guard then treats it as not baselined)."""
    out = {}
    for fname in DRIFT_TRACKED:
        p = Path(fname)
        if not p.exists():
            print_fn(f"skip {fname}: absent (run the full benchmark once "
                     f"to baseline it)")
            continue
        try:
            out[fname] = json.loads(p.read_text())
        except ValueError as e:
            print_fn(f"skip {fname}: unreadable JSON ({e})")
    return out


def _lookup(d, dotted: str):
    for part in dotted.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d if isinstance(d, (int, float)) else None


def check_drift(committed: dict, fresh: dict,
                ratio: float = DRIFT_RATIO) -> list:
    """Compare tracked headline metrics; returns human-readable failure
    strings for every metric that regressed by more than ``ratio``.
    Files/metrics absent from the *committed* side are skipped (nothing
    baselined yet), but a metric that is baselined and then disappears
    from the fresh run is itself a failure — a renamed key must not
    silently disarm the guard."""
    failures = []
    for fname, metrics in DRIFT_TRACKED.items():
        if fname not in committed or fname not in fresh:
            continue
        for m in metrics:
            old = _lookup(committed[fname], m)
            new = _lookup(fresh[fname], m)
            if old is None:
                continue
            if new is None:
                failures.append(
                    f"{fname}:{m} missing from fresh run (metric "
                    f"renamed/dropped? update DRIFT_TRACKED)")
            elif new < old / ratio:
                failures.append(
                    f"{fname}:{m} regressed >{ratio}x: "
                    f"committed {old:.3f} -> fresh {new:.3f}")
    return failures


def step_summary_table(committed: dict, fresh: dict,
                       ratio: float = DRIFT_RATIO) -> str:
    """Markdown drift-guard table (committed vs fresh, ratio, verdict)
    for the GitHub Actions job summary.  Mirrors ``check_drift``'s
    verdicts exactly: missing-fresh is a fail, unbaselined is a skip."""
    lines = ["## Benchmark drift guard", "",
             "| metric | committed | fresh | ratio | status |",
             "|---|---:|---:|---:|---|"]
    for fname, metrics in DRIFT_TRACKED.items():
        for m in metrics:
            name = f"`{fname.removeprefix('BENCH_').removesuffix('.json')}"\
                   f":{m}`"
            old = _lookup(committed.get(fname, {}), m)
            new = _lookup(fresh.get(fname, {}), m)
            if old is None:
                lines.append(f"| {name} | — | — | — | skipped "
                             f"(not baselined) |")
            elif new is None:
                lines.append(f"| {name} | {old:.3f} | missing | — | "
                             f"FAIL |")
            else:
                r = new / old if old else float("inf")
                verdict = "FAIL" if new < old / ratio else "ok"
                lines.append(f"| {name} | {old:.3f} | {new:.3f} | "
                             f"{r:.2f}x | {verdict} |")
    return "\n".join(lines) + "\n"


def main(quick: bool = False) -> None:
    from benchmarks import (adaptive_serve, chaos_serve, collab_decode,
                            fig3_breakdown, fleet_serve, kernel_bench,
                            optimized_decode, overload_serve, paged_decode,
                            roofline, sampled_spec, sharded_serve,
                            spec_decode, table3_partition,
                            table12_transmission)

    # snapshot the committed headline numbers before any section
    # rewrites its BENCH file
    print("=== committed BENCH baselines " + "=" * 38)
    committed = _load_tracked()

    csv_rows = []
    errors = []

    def section(name, fn, derived_fn, *, heavy: bool = False):
        # resolve the callable eagerly even when skipping: registration
        # breakage (renamed module/function) must fail in --quick too
        assert callable(fn), name
        if quick and heavy:
            print(f"\n=== {name} (skipped: --quick) " + "=" * 40)
            csv_rows.append((name, 0.0, "skipped"))
            return None
        print(f"\n=== {name} " + "=" * max(1, 66 - len(name)))
        t0 = time.perf_counter()
        # a crashing section must not abort the run: later sections and
        # the drift guard still execute, and ALL failures are reported
        # together at the end
        try:
            result = fn()
            csv_rows.append((name, (time.perf_counter() - t0) * 1e6,
                             derived_fn(result)))
            return result
        except Exception as e:          # noqa: BLE001 - collected, re-raised
            print(f"ERROR in {name}: {type(e).__name__}: {e}")
            errors.append(f"{name}: {type(e).__name__}: {e}")
            csv_rows.append((name, (time.perf_counter() - t0) * 1e6,
                             "ERROR"))
            return None

    section("table1_2_transmission", table12_transmission.run,
            lambda r: f"inception_rows={len(r['Table1'])};"
                      f"residual_rows={len(r['Table2'])}")
    section("table3_partition", table3_partition.run,
            lambda r: ";".join(f"{k}:{v['best']}@{v['speedup']:.2f}x"
                               for k, v in r.items()))
    section("fig3_breakdown", fig3_breakdown.run,
            lambda r: f"candidates={len(r)};"
                      f"best={[x[0] for x in r if x[5]][0]}")
    section("kernel_int8_matmul", kernel_bench.run,
            lambda r: f"int8_vs_fp32={r['t_int8_us'] / r['t_f32_us']:.2f};"
                      f"rel_err={r['rel_err']:.4f}", heavy=True)
    section("kernel_paged_attention", kernel_bench.run_paged,
            lambda r: f"speedup@4096={r['paged_speedup_at_4096']:.1f}x;"
                      f"kernel_err={r['kernel_ref_err']:.1e}", heavy=True)
    section("roofline_16x16", lambda: roofline.run(mesh="16x16"),
            lambda r: f"cells={len(r)}")
    section("roofline_multipod", lambda: roofline.run(mesh="multipod"),
            lambda r: f"cells={len(r)}")

    section("optimized_decode_serving", optimized_decode.summarize,
            lambda r: f"cells={len(r)}", heavy=True)

    section("collab_decode", collab_decode.run,
            lambda r: f"us_per_token={r['incremental']['us_per_token']:.0f};"
                      f"bytes_per_token="
                      f"{r['incremental']['bytes_per_token']:.0f};"
                      f"speedup={r['speedup_wall']:.1f}x", heavy=True)

    section("paged_decode", paged_decode.run,
            lambda r: ";".join(
                f"{row['max_len']}:{row['speedup']:.1f}x/"
                f"{row['cache_bytes_ratio']:.0f}xB"
                for row in r["sweep"]), heavy=True)

    section("spec_decode", lambda: spec_decode.run(quick=quick),
            lambda r: ";".join(
                f"k={k}:{v['e2e_speedup_vs_k1']:.2f}x/"
                f"{v['wire_reduction_vs_k1']:.2f}xB"
                for k, v in r["speculative"].items())
            + f";autotuned_k={r['autotuned_k']}")

    section("sampled_spec", lambda: sampled_spec.run(quick=quick),
            lambda r: ";".join(
                f"{k}:acc={r['acceptance'][k]:.2f}/"
                f"{r['e2e_speedup_vs_serial'][k]:.2f}x"
                for k in r["acceptance"]))

    section("adaptive_serve", lambda: adaptive_serve.run(quick=quick),
            lambda r: f"vs_worst_fixed="
                      f"{r['adaptive_vs_worst_fixed_e2e_speedup']:.2f}x;"
                      f"fp_bit_identical={r['fp_bit_identical']}")

    section("chaos_serve", lambda: chaos_serve.run(quick=quick),
            lambda r: f"availability={r['outage_availability']:.2f};"
                      f"naive_in_window="
                      f"{r['naive_tokens_per_s_in_window']:.1f}tok/s;"
                      f"lossless_bit_identical={r['lossless_bit_identical']}")

    section("overload_serve", lambda: overload_serve.run(quick=quick),
            lambda r: f"goodput_vs_naive={r['goodput_vs_naive']:.2f}x;"
                      f"priority_ontime={r['priority_ontime_frac']:.2f};"
                      f"p99_wait={r['p99_queue_wait_s']:.2f}s;"
                      f"lossless_bit_identical="
                      f"{r['lossless_preemption_bit_identical']}")

    section("sharded_serve", lambda: sharded_serve.run(quick=quick),
            lambda r: f"speedup@4dev={r['speedup_vs_1dev']['4']:.2f}x;"
                      f"lossless_bit_identical={r['lossless_bit_identical']};"
                      f"kernel_parity={r['kernel_interpret_parity_ok']}")

    section("fleet_serve", lambda: fleet_serve.run(quick=quick),
            lambda r: f"aggregate_speedup="
                      f"{r['aggregate_speedup_vs_independent']:.2f}x;"
                      f"dispatch_ratio={r['dispatch_ratio']:.1f}x;"
                      f"lossless_bit_identical="
                      f"{r['fleet_lossless_bit_identical']}")

    print("\n=== CSV summary " + "=" * 52)
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.0f},{derived}")

    failures = []
    if quick:
        print("\n=== benchmark drift guard " + "=" * 42)
        fresh = _load_tracked()
        failures = check_drift(committed, fresh)
        for f in failures:
            print("FAIL", f)
        summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
        if summary_path:
            with open(summary_path, "a") as fp:
                fp.write(step_summary_table(committed, fresh))
        if not failures:
            compared = sum(
                1 for f, ms in DRIFT_TRACKED.items()
                if f in committed and f in fresh
                for m in ms
                if _lookup(committed[f], m) is not None
                and _lookup(fresh[f], m) is not None)
            print(f"ok: {compared} tracked metrics within {DRIFT_RATIO}x "
                  f"of committed")

    if errors or failures:
        print(f"\n{len(errors)} section error(s), "
              f"{len(failures)} drift regression(s)")
        for e in errors:
            print("ERROR", e)
        raise SystemExit(1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: resolve every registration, run only "
                         "the cheap sections")
    main(quick=ap.parse_args().quick)
