"""Benchmark orchestrator — one section per paper table/figure.

Prints human tables per benchmark, then a machine-readable
``name,us_per_call,derived`` CSV summary at the end.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (fig3_breakdown, kernel_bench, roofline,
                            table3_partition, table12_transmission)

    csv_rows = []

    def section(name, fn, derived_fn):
        print(f"\n=== {name} " + "=" * max(1, 66 - len(name)))
        t0 = time.perf_counter()
        result = fn()
        us = (time.perf_counter() - t0) * 1e6
        csv_rows.append((name, us, derived_fn(result)))
        return result

    section("table1_2_transmission", table12_transmission.run,
            lambda r: f"inception_rows={len(r['Table1'])};"
                      f"residual_rows={len(r['Table2'])}")
    section("table3_partition", table3_partition.run,
            lambda r: ";".join(f"{k}:{v['best']}@{v['speedup']:.2f}x"
                               for k, v in r.items()))
    section("fig3_breakdown", fig3_breakdown.run,
            lambda r: f"candidates={len(r)};"
                      f"best={[x[0] for x in r if x[5]][0]}")
    section("kernel_int8_matmul", kernel_bench.run,
            lambda r: f"int8_vs_fp32={r['t_int8_us'] / r['t_f32_us']:.2f};"
                      f"rel_err={r['rel_err']:.4f}")
    section("kernel_paged_attention", kernel_bench.run_paged,
            lambda r: f"speedup@4096={r['paged_speedup_at_4096']:.1f}x;"
                      f"kernel_err={r['kernel_ref_err']:.1e}")
    section("roofline_16x16", lambda: roofline.run(mesh="16x16"),
            lambda r: f"cells={len(r)}")
    section("roofline_multipod", lambda: roofline.run(mesh="multipod"),
            lambda r: f"cells={len(r)}")

    from benchmarks import optimized_decode
    section("optimized_decode_serving", optimized_decode.summarize,
            lambda r: f"cells={len(r)}")

    from benchmarks import collab_decode
    section("collab_decode", collab_decode.run,
            lambda r: f"us_per_token={r['incremental']['us_per_token']:.0f};"
                      f"bytes_per_token="
                      f"{r['incremental']['bytes_per_token']:.0f};"
                      f"speedup={r['speedup_wall']:.1f}x")

    from benchmarks import paged_decode
    section("paged_decode", paged_decode.run,
            lambda r: ";".join(
                f"{row['max_len']}:{row['speedup']:.1f}x/"
                f"{row['cache_bytes_ratio']:.0f}xB"
                for row in r["sweep"]))

    print("\n=== CSV summary " + "=" * 52)
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
