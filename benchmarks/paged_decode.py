"""Paged INT8-KV decode benchmark.

Measures the serving engines end to end: the dense engine pre-allocates
``[B, max_len]`` fp16 KV per slot and its decode einsum streams the
whole thing every step, while the paged engine allocates INT8 pages on
demand (``PageAllocator`` block tables) and its decode reads only the
pages a request actually owns.  Sweeping ``max_len`` with a fixed
workload shows the dense step cost growing with the pre-allocation while
the paged step's *attention read* stays flat — time and resident cache
bytes both.  (Off-TPU a residual max_len dependence remains in the paged
numbers: the functional cache-scatter copies the page pool every step
because XLA:CPU ignores buffer donation; on TPU donation makes the
update in place.)  Also
checks that the collaborative engine's default (paged INT8 edge cache,
per-slot scales calibrated at prefill) keeps greedy outputs within quant
tolerance of the fp edge configuration.  Writes
``BENCH_paged_decode.json`` so future PRs have a perf trajectory to
regress against.

    PYTHONPATH=src python -m benchmarks.paged_decode
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.models.transformer import LMConfig, init_lm
from repro.serve.engine import (CollaborativeServingEngine, ServeStats,
                                ServingEngine)

OUT = Path("BENCH_paged_decode.json")

CFG = LMConfig(name="paged-bench-lm", n_layers=4, d_model=256, n_heads=8,
               n_kv=4, d_ff=1024, vocab=2048, max_seq=4096, remat=False)
BATCH = 4
PLEN = 32
NEW = 16
PAGE = 16


def _decode_us_per_token(eng, prompts, repeats: int = 3) -> float:
    """Best-of-N decode wall clock per token (N runs tame scheduler
    noise on shared CPU hosts; each run fences every step via timed=True)."""
    eng.generate(prompts, max_new_tokens=2)         # compile all phases
    best = float("inf")
    for _ in range(repeats):
        eng.stats = ServeStats()
        eng.generate(prompts, max_new_tokens=NEW)
        best = min(best,
                   eng.stats.decode_s / max(eng.stats.decode_tokens, 1))
    return best * 1e6


def run(print_fn=print) -> dict:
    params = init_lm(jax.random.PRNGKey(0), CFG)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, CFG.vocab, PLEN).astype(np.int32)
               for _ in range(BATCH)]

    sweep = []
    for max_len in (128, 512, 2048):
        dense = ServingEngine(params, CFG, max_batch=BATCH, max_len=max_len,
                              cache_dtype=jax.numpy.bfloat16, timed=True)
        paged = ServingEngine(params, CFG, max_batch=BATCH, max_len=max_len,
                              paged=True, int8_kv=True, page_size=PAGE,
                              timed=True)
        t_dense = _decode_us_per_token(dense, prompts)
        t_paged = _decode_us_per_token(paged, prompts)
        # footprints: dense = the pre-allocation; paged = pages actually
        # resident for this workload (prompt+generation, page granular)
        dense_bytes = dense.cache_bytes()
        pages_per_req = -(-(PLEN + NEW) // PAGE)
        per_page = PAGE * CFG.n_kv * CFG.hd
        paged_live = 2 * CFG.n_layers * BATCH * pages_per_req * per_page \
            + 2 * CFG.n_layers * BATCH * CFG.n_kv * 4
        row = {"max_len": max_len,
               "dense_fp16_us_per_token": t_dense,
               "paged_int8_us_per_token": t_paged,
               "speedup": t_dense / max(t_paged, 1e-9),
               "dense_cache_bytes": dense_bytes,
               "paged_live_cache_bytes": paged_live,
               "cache_bytes_ratio": dense_bytes / paged_live}
        sweep.append(row)
        print_fn(f"max_len {max_len:5d}: dense fp16 {t_dense:8.1f} us/tok "
                 f"{dense_bytes / 2**20:7.1f} MiB | paged int8 "
                 f"{t_paged:8.1f} us/tok {paged_live / 2**20:5.2f} MiB "
                 f"({row['speedup']:.1f}x time, "
                 f"{row['cache_bytes_ratio']:.0f}x bytes)")

    # greedy fidelity of the collaborative default (paged INT8 edge)
    fp = CollaborativeServingEngine(params, CFG, cut_layer=1, max_len=128,
                                    max_batch=BATCH, edge_paged=False,
                                    edge_int8=False, cloud_paged=False,
                                    cloud_int8=False)
    q8 = CollaborativeServingEngine(params, CFG, cut_layer=1, max_len=128,
                                    max_batch=BATCH, page_size=PAGE)
    ref = fp.generate(prompts, max_new_tokens=NEW)
    got = q8.generate(prompts, max_new_tokens=NEW)
    agree = sum(a == b for r, g in zip(ref, got) for a, b in zip(r, g)) \
        / (BATCH * NEW)
    # first-token agreement isolates per-step quant tolerance from the
    # compounding divergence of greedy sampling on a random-weight model
    first_agree = sum(r[0] == g[0] for r, g in zip(ref, got)) / BATCH
    # resident edge bytes for this workload (pages are returned at
    # retirement, so post-run live is 0; report what the run held)
    n_edge = q8.n_edge
    pages_per_req = -(-(PLEN + NEW) // PAGE)
    q8_resident = 2 * n_edge * BATCH * pages_per_req \
        * (PAGE * CFG.n_kv * CFG.hd) \
        + 2 * n_edge * BATCH * CFG.n_kv * 4
    print_fn(f"collab default (paged INT8 edge) vs fp edge: "
             f"{agree:.0%} greedy tokens agree ({first_agree:.0%} first "
             f"tokens), edge cache {fp.edge_cache_bytes() / 2**20:.1f} MiB "
             f"-> {q8_resident / 2**20:.2f} MiB resident")

    result = {
        "config": {"model": CFG.name, "batch": BATCH, "prompt_len": PLEN,
                   "new_tokens": NEW, "page_size": PAGE},
        "sweep": sweep,
        "collab_quantized_edge": {
            "greedy_agreement_vs_fp_edge": agree,
            "first_token_agreement_vs_fp_edge": first_agree,
            "fp_edge_cache_bytes": fp.edge_cache_bytes(),
            "paged_int8_edge_resident_bytes": q8_resident,
        },
    }
    OUT.write_text(json.dumps(result, indent=1))
    print_fn(f"-> {OUT}")
    return result


if __name__ == "__main__":
    run()
