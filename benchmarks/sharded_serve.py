"""Tensor-parallel cloud verify: serving throughput vs mesh size.

Runs the collaborative engine's full serve loop at TP meshes of 1, 2,
4 and 8 devices and reports verify-loop tokens/s per mesh.  The whole
measurement lives in a SUBPROCESS that forces 8 XLA host-platform
devices before importing jax (the parent process must keep its real
1-device view — same discipline as ``tests/test_multidevice.py``).

Host-platform "devices" are slices of the same CPU, so wall time can
not actually drop with mesh size here; what the benchmark checks is
that the sharded *verify phase* — the TP'd computation — stays near
the 1-device wall (per-shard work drops by the TP degree while the
host serializes the shards: n shards × work/n ≈ constant) and converts
that into the headline

    speedup_vs_1dev[n] = (verify_s_1 / verify_s_n) * n / min(n, cpus)

i.e. ideal-parallel extrapolation of the measured per-shard math, with
the serialization the 1-core container forces divided back out.  The
verify jit is timed directly (a blocking wrapper installed after
warm-up) so the replicated edge/draft phases — which the host must run
once per device here, but a real pod runs once per chip for free in
parallel — don't pollute the cloud-scaling number.  The JSON carries
``"emulated": true`` to keep the caveat attached.  End-to-end walls
per mesh are reported untracked alongside.

Also exercised and reported:

* ``lossless_bit_identical`` — a_bits=None greedy streams at mesh
  1/2/4/8 equal the unsharded engine's, token for token;
* ``kernel_interpret_parity_ok`` — ``paged_flash_mq_sharded`` (the
  shard_map'd pallas kernel) run through the Pallas interpreter against
  the unsharded kernel, exact to the bit (attention is per-kv-head
  independent, so TP introduces no reduction reordering).

    PYTHONPATH=src python -m benchmarks.sharded_serve
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

OUT = Path("BENCH_sharded_serve.json")

MESHES = (1, 2, 4, 8)

_SCRIPT = textwrap.dedent("""
    import os, sys, time, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.models.transformer import LMConfig, init_lm
    from repro.serve.engine import CollaborativeServingEngine
    from repro.core.costmodel import Channel
    from repro.launch.mesh import make_serve_mesh
    from repro.kernels import paged_attention as PA

    quick = bool(int(sys.argv[1]))
    new_tokens = 8 if quick else 24
    reps = 1 if quick else 3
    K = 4
    # n_kv=8 so every mesh size up to 8 actually shards the KV pool;
    # d_model=512 keeps per-shard GEMMs large enough that compute (which
    # TP divides) dominates per-op dispatch overhead (which it doesn't)
    CFG = LMConfig(name="sharded-bench-lm", n_layers=4, d_model=512,
                   n_heads=8, n_kv=8, d_ff=1024, vocab=1024, max_seq=128,
                   remat=False)
    params = init_lm(jax.random.PRNGKey(0), CFG)
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, CFG.vocab, 12).astype(np.int32)
               for _ in range(4)]

    def build(mesh):
        return CollaborativeServingEngine(
            params, CFG, cut_layer=1, spec_k=K, max_batch=4, max_len=128,
            channel=Channel.from_kbps(10_000_000), page_size=16,
            a_bits=None, edge_int8=False, cloud_int8=False, mesh=mesh)

    def serve(eng):
        t0 = time.perf_counter()
        out = eng.generate([p.copy() for p in prompts],
                           max_new_tokens=new_tokens)
        return out, time.perf_counter() - t0

    def tap_verify(eng):
        # wrap the warm verify jit with a blocking timer: measures the
        # TP'd cloud phase alone, not the replicated edge/draft phases
        draft, verify = eng._spec_fns(K)
        acc = [0.0]
        def timed(*a, **kw):
            # dispatch is async: the draft outputs we receive are still
            # in flight, and blocking on verify's output would charge the
            # tail of the (replicated, once-per-device-on-this-host) edge
            # phase to the verify clock.  Drain the inputs first.
            jax.block_until_ready((a, kw))
            t0 = time.perf_counter()
            out = jax.block_until_ready(verify(*a, **kw))
            acc[0] += time.perf_counter() - t0
            return out
        eng._spec_jits[K] = (draft, timed)
        return acc

    ref_stream, _ = serve(build(None))

    walls, verify_s, streams = {}, {}, {}
    for n in (1, 2, 4, 8):
        eng = build(make_serve_mesh(model=n))
        streams[n], _ = serve(eng)             # warm every phase jit
        acc = tap_verify(eng)
        best_w, best_v = None, None
        for _ in range(reps):
            acc[0] = 0.0
            _, w = serve(eng)
            if best_v is None or acc[0] < best_v:
                best_w, best_v = w, acc[0]
        walls[n], verify_s[n] = best_w, best_v

    # shard_map kernel through the Pallas interpreter vs the plain kernel
    B, S, H, NKV, HD, PAGE, NP, PPS = 2, 3, 8, 4, 16, 8, 12, 4
    q = jnp.asarray(rng.randn(B, S, H, HD), jnp.float32)
    kp = jnp.asarray(rng.randint(-127, 127, (NP, PAGE, NKV, HD)), jnp.int8)
    vp = jnp.asarray(rng.randint(-127, 127, (NP, PAGE, NKV, HD)), jnp.int8)
    bt = jnp.asarray(rng.permutation(NP)[:B * PPS].reshape(B, PPS), jnp.int32)
    lens = jnp.asarray([17, 25], jnp.int32)
    ks = jnp.asarray(np.abs(rng.randn(B, NKV)) * 0.02, jnp.float32)
    plain = PA.paged_flash_mq(q, kp, vp, bt, lens, lens - S, ks, ks,
                              interpret=True)
    sharded = PA.paged_flash_mq_sharded(
        q, kp, vp, bt, lens, lens - S, ks, ks,
        mesh=make_serve_mesh(model=4, data=2), interpret=True)
    kerr = float(jnp.abs(sharded - plain).max())

    cpus = os.cpu_count() or 1
    result = {
        "emulated": True,
        "cpu_count": cpus,
        "config": CFG.name,
        "new_tokens": new_tokens,
        "wall_s": {str(n): walls[n] for n in walls},
        "verify_s": {str(n): verify_s[n] for n in verify_s},
        "verify_tokens_per_s": {
            str(n): 4 * new_tokens / verify_s[n] for n in verify_s},
        "speedup_vs_1dev": {
            str(n): (verify_s[1] / verify_s[n]) * n / min(n, cpus)
            for n in verify_s},
        "lossless_bit_identical": all(streams[n] == ref_stream
                                      for n in streams),
        "kernel_interpret_parity_maxerr": kerr,
        "kernel_interpret_parity_ok": kerr == 0.0,
    }
    print("SHARDED_JSON " + json.dumps(result))
""")


def run(print_fn=print, quick: bool = False) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, str(int(quick))],
        capture_output=True, text=True, env=env, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("SHARDED_JSON ")]
    assert line, proc.stdout[-4000:]
    result = json.loads(line[-1][len("SHARDED_JSON "):])

    for n in MESHES:
        print_fn(f"mesh {n}: wall {result['wall_s'][str(n)]*1e3:8.1f} ms  "
                 f"verify {result['verify_s'][str(n)]*1e3:7.1f} ms  "
                 f"{result['verify_tokens_per_s'][str(n)]:7.1f} vtok/s  "
                 f"speedup_vs_1dev(emulated) "
                 f"{result['speedup_vs_1dev'][str(n)]:.2f}x")
    print_fn(f"lossless streams bit-identical across meshes: "
             f"{result['lossless_bit_identical']}")
    print_fn(f"shard_map kernel interpret parity: "
             f"{result['kernel_interpret_parity_ok']} "
             f"(maxerr {result['kernel_interpret_parity_maxerr']:.1e})")

    OUT.write_text(json.dumps(result, indent=2, sort_keys=True) + "\n")
    print_fn(f"wrote {OUT}")
    return result


if __name__ == "__main__":
    run()
