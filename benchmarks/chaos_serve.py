"""Collaborative serving through a cloud outage: chaos benchmark.

Two engines serve the identical request waves over the identical fault
schedule — a hard cloud outage window on the simulated channel clock —
and the benchmark integrates each engine's per-round availability trace
across the window:

* ``naive`` — the plain ``CollaborativeServingEngine`` on the blocking
  channel semantics every pre-reliability engine assumes: a message
  that hits the outage retries on a fixed RTO until the window closes,
  so the whole batch stalls and commits nothing until the cloud is
  back;
* ``resilient`` — ``ResilientCollaborativeEngine`` on a
  ``ReliableTransport``: the retry budget exhausts, the engine declares
  the cloud down, serves edge-only out of the draft suffix (zero wire
  bytes per token), probes, and resyncs the cloud KV on reconnect.

Reported per engine: simulated serving time per committed token (the
clock integrates transfers, deadline waits, probes, and the resync
replay — wall time is reported separately and untracked because CPU
jit compilation dominates it at this scale), tokens/s inside vs
outside the outage window, and the reconnect stall (the largest
inter-round gap in simulated time).  Headlines for the drift guard:

* ``outage_availability`` — the resilient engine's in-window token
  rate over its out-of-window rate (the naive engine's is identically
  zero: no round completes inside the window);
* ``resilient_vs_naive_sim_speedup`` — simulated s/token ratio.

A tiny-model lossless section re-runs an outage + resync with
``a_bits=None`` and checks the stream is bit-identical to a fault-free
engine's (``lossless_bit_identical``) — degradation is
output-transparent when the boundary is lossless.

    PYTHONPATH=src python -m benchmarks.chaos_serve
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.costmodel import Channel
from repro.models.transformer import LMConfig, init_lm
from repro.serve import (CollaborativeServingEngine, FaultyChannel,
                         LinkTelemetry, ReliableTransport,
                         ResilientCollaborativeEngine)

OUT = Path("BENCH_chaos_serve.json")

CFG = LMConfig(name="chaos-bench-lm", n_layers=6, d_model=256, n_heads=8,
               n_kv=4, d_ff=1024, vocab=2048, max_seq=128, remat=False)
CUT = 2
K = 4
BATCH = 4
PLEN = 24
BASE = Channel.from_kbps(50, rtt_ms=20)


def _prompts(n, seed, cfg=CFG, plen=PLEN):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, plen).astype(np.int32)
            for _ in range(n)]


def _surveyed_transport(fch, **kw):
    """A reliable transport whose telemetry starts from a site survey of
    the base link (the same honest samples the offline tuner uses), so
    message deadlines are payload-aware from the first send — a 30 KB
    prefill blob legitimately takes ~0.6 s on this link and must not be
    declared lost by a flat sub-second fallback deadline."""
    tel = LinkTelemetry()
    for n in (64, 1000, 4000, 16000, 32000):
        tel.observe_transfer(n, BASE.transfer_time(n))
    return ReliableTransport(fch, tel, **kw)


class _LoggedEngine(CollaborativeServingEngine):
    """The baseline engine plus the availability trace the resilient
    engine keeps natively — same hook, so the two logs line up."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.round_log = []

    def _after_round(self, n_active, committed):
        self.round_log.append({
            "t_s": float(getattr(self.channel, "clock_s", 0.0)),
            "committed": committed, "cloud_down": False})


def _window_rates(round_log, t0, t1, t_end):
    """Integrate a round log over/outside the outage window.  A round is
    binned by its completion time; the naive engine's window-spanning
    stall round therefore lands (correctly) outside."""
    tok_in = sum(r["committed"] for r in round_log if t0 <= r["t_s"] < t1)
    tok_out = sum(r["committed"] for r in round_log
                  if not t0 <= r["t_s"] < t1)
    out_span = max(t_end - (t1 - t0), 1e-9)
    gaps = np.diff([0.0] + [r["t_s"] for r in round_log]) \
        if round_log else np.zeros(1)
    return {
        "tokens_in_window": int(tok_in),
        "tokens_per_s_in_window": tok_in / max(t1 - t0, 1e-9),
        "tokens_per_s_outside": tok_out / out_span,
        "max_round_gap_s": float(np.max(gaps)),
        "p99_round_gap_s": float(np.percentile(gaps, 99)),
    }


def _serve(eng, fch, n_reqs, new_tokens, window):
    t_wall = time.perf_counter()
    eng.generate(_prompts(n_reqs, seed=11), max_new_tokens=new_tokens)
    wall = time.perf_counter() - t_wall
    s = eng.stats
    accepted = max(s.decode_tokens, 1)
    t_end = float(fch.clock_s)
    r = {
        "wall_s": wall,
        "sim_s": t_end,
        "accepted_tokens": s.decode_tokens,
        "sim_ms_per_token": t_end / accepted * 1e3,
        "channel_s": s.channel_latency_s,
        "faults": dict(fch.faults),
        "retries": s.retries, "timeouts": s.timeouts,
        "edge_only_tokens": s.edge_only_tokens,
        "resyncs": s.resyncs, "outage_s": s.outage_s,
    }
    r.update(_window_rates(eng.round_log, window[0], window[1], t_end))
    return r


def _lossless_bit_identity(print_fn) -> bool:
    """Tiny-model lossless outage + resync vs the fault-free stream."""
    tiny = LMConfig(name="chaos-tiny", n_layers=3, d_model=32, n_heads=4,
                    n_kv=2, d_ff=64, vocab=64, max_seq=64, remat=False)
    params = init_lm(jax.random.PRNGKey(1), tiny)
    fp = dict(a_bits=None, edge_int8=False, cloud_int8=False, page_size=8,
              max_batch=2, max_len=64)
    prompts = _prompts(3, seed=23, cfg=tiny, plen=9)
    ref = CollaborativeServingEngine(
        params, tiny, cut_layer=1, spec_k=1,
        channel=Channel.from_kbps(500, rtt_ms=10), **fp).generate(
        prompts, max_new_tokens=12)
    tiny_ch = Channel.from_kbps(500, rtt_ms=10)
    fch = FaultyChannel(tiny_ch, seed=3, outages=[(0.05, 0.6)])
    eng = ResilientCollaborativeEngine(
        params, tiny, cut_layer=1, spec_k=1, channel=fch,
        transport=ReliableTransport(fch), **fp)
    got = eng.generate(prompts, max_new_tokens=12)
    ok = got == ref and eng.stats.edge_only_tokens > 0 \
        and eng.stats.resyncs >= 1
    print_fn(f"lossless outage+resync bit-identity: {ok} "
             f"(edge_only={eng.stats.edge_only_tokens}, "
             f"resyncs={eng.stats.resyncs})")
    return ok


def run(print_fn=print, quick: bool = False) -> dict:
    n_reqs, new_tokens = (6, 16) if quick else (8, 16)
    window = (0.2, 1.0)
    params = init_lm(jax.random.PRNGKey(0), CFG)
    print_fn(f"outage window {window} on {BASE.name}; "
             f"{n_reqs} reqs x {new_tokens} tokens, cut={CUT}, k={K}")

    results = {}
    fch_naive = FaultyChannel(BASE, seed=0, outages=[window], rto_s=0.25)
    naive = _LoggedEngine(params, CFG, cut_layer=CUT, spec_k=K,
                          channel=fch_naive, max_batch=BATCH, max_len=128)
    results["naive"] = _serve(naive, fch_naive, n_reqs, new_tokens, window)

    fch_res = FaultyChannel(BASE, seed=0, outages=[window], rto_s=0.25)
    resilient = ResilientCollaborativeEngine(
        params, CFG, cut_layer=CUT, spec_k=K, channel=fch_res,
        transport=_surveyed_transport(fch_res, max_retries=1,
                                      deadline_margin=1.5),
        probe_every=1, max_batch=BATCH, max_len=128)
    results["resilient"] = _serve(resilient, fch_res, n_reqs, new_tokens,
                                  window)

    for name, r in results.items():
        print_fn(f"{name:>9}: sim {r['sim_ms_per_token']:6.1f} ms/tok  "
                 f"in-window {r['tokens_per_s_in_window']:6.1f} tok/s  "
                 f"outside {r['tokens_per_s_outside']:6.1f} tok/s  "
                 f"max gap {r['max_round_gap_s']:.2f}s  "
                 f"edge_only={r['edge_only_tokens']} "
                 f"resyncs={r['resyncs']}")

    res, nai = results["resilient"], results["naive"]
    availability = res["tokens_per_s_in_window"] \
        / max(res["tokens_per_s_outside"], 1e-9)
    speedup = nai["sim_ms_per_token"] / max(res["sim_ms_per_token"], 1e-9)
    ok = _lossless_bit_identity(print_fn)
    print_fn(f"outage availability {availability:.2f} "
             f"(naive in-window rate: {nai['tokens_per_s_in_window']:.1f}) "
             f" resilient vs naive: {speedup:.2f}x")

    result = {
        "config": {"model": CFG.name, "cut": CUT, "spec_k": K,
                   "batch": BATCH, "prompt_len": PLEN,
                   "new_tokens": new_tokens, "requests": n_reqs,
                   "channel": BASE.name, "outage_window_s": list(window),
                   "quick": quick},
        "engines": results,
        "outage_availability": availability,
        "naive_tokens_per_s_in_window": nai["tokens_per_s_in_window"],
        "resilient_vs_naive_sim_speedup": speedup,
        "reconnect_stall_p99_s": res["p99_round_gap_s"],
        "lossless_bit_identical": ok,
    }
    OUT.write_text(json.dumps(result, indent=1))
    print_fn(f"-> {OUT}")
    return result


if __name__ == "__main__":
    run()
