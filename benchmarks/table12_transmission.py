"""Paper Tables 1 & 2: blob analysis of inception / residual partitions.

Reproduces the transmission-count analysis that motivates the candidate
rules: brother branches and live shortcuts force multi-blob cuts."""
from __future__ import annotations

from repro.core.graph import LayerGraph
from repro.core.partition import candidate_partition_points


def inception_graph() -> LayerGraph:
    g = LayerGraph("inception")
    g.add("input", "input", [], (1, 3, 32, 32))
    g.add("pre", "conv", ["input"], (1, 64, 32, 32), flops=1e6,
          param_elems=1728)
    g.add("b2a", "conv", ["pre"], (1, 32, 32, 32), flops=1e6, param_elems=2048)
    g.add("b2b", "conv", ["b2a"], (1, 64, 32, 32), flops=2e6,
          param_elems=18432)
    g.add("b1", "conv", ["pre"], (1, 64, 32, 32), flops=1e6, param_elems=4096)
    g.add("b3a", "conv", ["pre"], (1, 16, 32, 32), flops=5e5, param_elems=1024)
    g.add("b3b", "conv", ["b3a"], (1, 32, 32, 32), flops=2e6,
          param_elems=12800)
    g.add("b4p", "maxpool", ["pre"], (1, 64, 32, 32))
    g.add("b4b", "conv", ["b4p"], (1, 32, 32, 32), flops=1e6, param_elems=2048)
    g.add("concat", "concat", ["b1", "b2b", "b3b", "b4b"], (1, 192, 32, 32))
    g.add("post", "conv", ["concat"], (1, 64, 32, 32), flops=3e6,
          param_elems=12288)
    return g


def residual_graph() -> LayerGraph:
    g = LayerGraph("residual")
    g.add("input", "input", [], (1, 64, 16, 16))
    g.add("pre", "conv", ["input"], (1, 64, 16, 16), flops=1e6,
          param_elems=36864)
    g.add("conv_a", "conv", ["pre"], (1, 64, 16, 16), flops=1e6,
          param_elems=36864)
    g.add("conv_b", "conv", ["conv_a"], (1, 64, 16, 16), flops=1e6,
          param_elems=36864)
    g.add("add", "add", ["conv_b", "pre"], (1, 64, 16, 16))
    g.add("post", "conv", ["add"], (1, 64, 16, 16), flops=1e6,
          param_elems=36864)
    return g


def run(print_fn=print) -> dict:
    out = {}
    for builder, paper_tbl in ((inception_graph, "Table1"),
                               (residual_graph, "Table2")):
        g = builder()
        cands = {c.name for c in candidate_partition_points(g)}
        rows = []
        for name in g.topo():
            if g[name].op in ("input",):
                continue
            blobs = g.crossing_blobs(name)
            kinds = "+".join(f"{b.precision}x1" for b in blobs)
            rows.append((name, len(blobs), kinds, name in cands))
            print_fn(f"{paper_tbl} {g.name:10s} point={name:8s} "
                     f"blobs={len(blobs)} [{kinds}] "
                     f"candidate={name in cands}")
        out[paper_tbl] = rows
    return out


if __name__ == "__main__":
    run()
