"""Paper Fig. 3: per-candidate latency breakdown (edge + upload + cloud).

One bar per candidate partition of AlexNet at the paper's 250 KB/s;
marks the best (and fastest) cut like the paper's pentagrams."""
from __future__ import annotations

from repro.core.autotune import AutoTuner
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel,
                                  EDGE_TX2_CLASS)
from repro.models import legacy


def run(print_fn=print, *, kbps: float = 250.0) -> list:
    g = legacy.alexnet_graph()
    tuner = AutoTuner(g, EDGE_TX2_CLASS, CLOUD_TITANXP_CLASS)
    ch = Channel.from_kbps(kbps)
    best, perfs = tuner.tune(ch)
    rows = []
    print_fn(f"AlexNet @ {kbps:g} KB/s  (* = best/fastest cut)")
    print_fn(f"{'cut':>8} {'edge(s)':>8} {'upload(s)':>10} {'cloud(s)':>9} "
             f"{'total(s)':>9}  bar")
    scale = 40.0 / max(p.total_s for p in perfs)
    for p in perfs:
        mark = "*" if p.point == best.point else " "
        e = int(p.edge_time_s * scale)
        u = int(p.upload_time_s * scale)
        c = int(p.cloud_time_s * scale)
        bar = "E" * e + "U" * u + "C" * c
        print_fn(f"{mark}{p.point:>7} {p.edge_time_s:>8.3f} "
                 f"{p.upload_time_s:>10.3f} {p.cloud_time_s:>9.3f} "
                 f"{p.total_s:>9.3f}  {bar}")
        rows.append((p.point, p.edge_time_s, p.upload_time_s, p.cloud_time_s,
                     p.total_s, p.point == best.point))
    return rows


if __name__ == "__main__":
    run()
