"""Paper Table 3: best partition per network per wireless environment.

AlexNet / VGG16 / ResNet-18 / GoogLeNet at the paper's measured
bandwidths (250 / 240 / 70 / 180 KB/s).  Columns mirror the paper:
best cut, end-to-end time, speed-up vs cloud-only, edge model download,
storage reduction.  Our devices are roofline models calibrated to
TX2/TITAN-class hardware (DESIGN.md §3), so cut names are expected to
match in *character* (late-conv / early-fc at low bandwidth), not
necessarily layer-for-layer.
"""
from __future__ import annotations

from repro.core.autotune import AutoTuner
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel,
                                  EDGE_TX2_CLASS)
from repro.models import legacy, resnet

PAPER = {  # network -> (bandwidth KB/s, paper best cut, paper speedup)
    "alexnet": (250, "conv5", "1.7x"),
    "vgg16": (240, "conv1_2", "<1x"),
    "resnet-18": (70, "res4a", "1.13x"),
    "googlenet": (180, "conv2", "<1x"),
}


def _graphs():
    return {
        "alexnet": legacy.alexnet_graph(),
        "vgg16": legacy.vgg16_graph(),
        "resnet-18": resnet.make_graph(
            resnet.ResNetConfig(name="resnet-18", depths=(2, 2, 2, 2),
                                bottleneck=False), batch=1),
        "googlenet": legacy.googlenet_graph(),
    }


def run(print_fn=print) -> dict:
    out = {}
    hdr = (f"{'network':>10} {'KB/s':>5} {'best cut':>12} {'time(s)':>8} "
           f"{'speedup':>8} {'download(KB)':>13} {'storage red':>12} "
           f"{'paper cut':>10} {'paper sp':>8}")
    print_fn(hdr)
    for name, g in _graphs().items():
        kbps, paper_cut, paper_sp = PAPER[name]
        tuner = AutoTuner(g, EDGE_TX2_CLASS, CLOUD_TITANXP_CLASS)
        ch = Channel.from_kbps(kbps)
        best, perfs = tuner.tune(ch)
        sp = tuner.speedup_vs_cloud_only(ch)
        print_fn(f"{name:>10} {kbps:>5} {best.point:>12} "
                 f"{best.total_s:>8.3f} {sp:>7.2f}x "
                 f"{best.edge_model_bytes / 1e3:>13.1f} "
                 f"{best.storage_reduction:>11.1%} "
                 f"{paper_cut:>10} {paper_sp:>8}")
        out[name] = {"best": best.point, "total_s": best.total_s,
                     "speedup": sp,
                     "download_kb": best.edge_model_bytes / 1e3,
                     "storage_reduction": best.storage_reduction,
                     "n_candidates": len(perfs)}
    return out


if __name__ == "__main__":
    run()
