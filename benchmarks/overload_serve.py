"""Overload serving benchmark: goodput under 2x KV pool oversubscription.

Two engines serve identical mixed-priority request waves through a
shared simulated channel clock, with the KV page pool sized at **half**
the worst-case demand of a full batch (4 slots x ~49 positions wants
~20 usable pages; the pool has 10):

* ``naive`` — the plain ``CollaborativeServingEngine``: admission
  reserves worst-case ``prompt + max_new`` pages up front, so the pool
  fits ~1 full-budget request at a time and the best-effort wave
  head-of-line blocks the late-arriving priority requests past their
  deadlines;
* ``robust`` — the same engine with ``demand_paged=True`` (admission
  reserves only the padded prompt, pages grow as positions are actually
  written, and ``PoolExhausted`` preempts the lowest-priority /
  most-remaining victim with replay-based resume) and
  ``admission="deadline"`` (requests predicted to finish past their
  deadline are shed instead of poisoning the pool).

Traffic per offered-load level: a staggered wave of best-effort
requests (no deadline — they are the overload) plus a burst of
priority-1 requests whose deadline is calibrated from a measured
lone-request service time.  **Goodput** counts only tokens of requests
that met their deadline (deadline-free requests always count), per
simulated second.  Headlines for the drift guard:

* ``goodput_vs_naive`` — robust over naive goodput at the heaviest
  load (the ISSUE's acceptance bar is >= 1.5x);
* ``priority_ontime_frac`` — fraction of priority requests the robust
  engine finished on time at the heaviest load.

Also reported per engine/load: p50/p99 queue wait (``admit_s -
arrival_s``), preemptions, sheds, deadline misses, and a lossless
preemption bit-identity check (an ``a_bits=None`` run under a pool
squeeze must match the unpressured stream bit for bit).

    PYTHONPATH=src python -m benchmarks.overload_serve
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.costmodel import Channel
from repro.models.transformer import LMConfig, init_lm
from repro.serve import (CollaborativeServingEngine, FaultyChannel,
                         PressureSchedule, Request)

OUT = Path("BENCH_overload_serve.json")

CFG = LMConfig(name="overload-bench-lm", n_layers=3, d_model=32, n_heads=4,
               n_kv=2, d_ff=64, vocab=64, max_seq=64, remat=False)
CUT = 1
PAGE = 8
# 2x oversubscription: 4 slots x (9 prompt + 40 new) wants ~20 usable
# pages; the pool has 10 (plus the reserved dump page)
POOL = dict(page_size=PAGE, max_batch=4, max_len=64, num_pages=11)
BASE = Channel.from_kbps(500, rtt_ms=10)
PLEN = 9
BE_NEW = 40              # best-effort generation budget
PRI_NEW = 20             # priority generation budget
DEADLINE_MARGIN = 3.0    # deadline = arrival + margin * lone service time


def _mk_prompts(n, seed):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, PLEN).astype(np.int32)
            for _ in range(n)]


def _traffic(n_be, n_pri, gap, deadline_s):
    """A best-effort wave arriving every ``gap`` seconds, then a burst of
    priority requests landing mid-wave with calibrated deadlines."""
    prompts = _mk_prompts(n_be + n_pri, seed=7)
    reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=BE_NEW,
                    priority=0, arrival_s=i * gap) for i in range(n_be)]
    t0 = 2 * gap  # the burst lands while the wave still holds the pool
    reqs += [Request(uid=100 + i, prompt=prompts[n_be + i],
                     max_new_tokens=PRI_NEW, priority=1,
                     arrival_s=t0 + i * gap,
                     deadline_s=t0 + i * gap + deadline_s)
             for i in range(n_pri)]
    return reqs


def _calibrate_deadline(params) -> float:
    """Measure one priority-shaped request served alone on an idle
    engine; deadlines are a fixed multiple of that — tight enough that
    head-of-line blocking misses them, loose enough that preempting
    into service meets them."""
    fch = FaultyChannel(BASE, seed=0)
    eng = CollaborativeServingEngine(params, CFG, cut_layer=CUT,
                                     channel=fch, **POOL)
    eng.generate(_mk_prompts(1, seed=1), max_new_tokens=PRI_NEW)
    return DEADLINE_MARGIN * float(fch.clock_s)


def _serve(eng, fch, reqs):
    t_wall = time.perf_counter()
    eng.generate_requests(reqs)
    wall = time.perf_counter() - t_wall
    sim = float(fch.clock_s)
    ontime = [r for r in reqs if not r.shed
              and (r.deadline_s is None
                   or (r.finish_s is not None
                       and r.finish_s <= r.deadline_s))]
    good = sum(len(r.out_tokens) for r in ontime)
    waits = [r.admit_s - r.arrival_s for r in reqs if r.admit_s is not None]
    pri = [r for r in reqs if r.priority > 0]
    s = eng.stats
    return {
        "wall_s": wall,
        "sim_s": sim,
        "total_tokens": sum(len(r.out_tokens) for r in reqs),
        "goodput_tokens": good,
        "goodput_tok_per_s": good / max(sim, 1e-9),
        "priority_ontime_frac": sum(
            1 for r in pri
            if r.finish_s is not None and r.finish_s <= r.deadline_s)
        / max(len(pri), 1),
        "p50_queue_wait_s": float(np.percentile(waits, 50)) if waits else 0.0,
        "p99_queue_wait_s": float(np.percentile(waits, 99)) if waits else 0.0,
        "preemptions": s.preemptions,
        "shed": s.shed,
        "deadline_misses": s.deadline_misses,
        "queue_wait_s": s.queue_wait_s,
        "stall_wait_s": s.stall_wait_s,
    }


def _lossless_preemption_identity(params, print_fn) -> bool:
    """An ``a_bits=None`` run whose pool is squeezed to zero free pages
    mid-flight must preempt at least once and still emit the exact
    unpressured streams — preemption/resume is invisible in the output."""
    fp = dict(a_bits=None, edge_int8=False, cloud_int8=False,
              page_size=PAGE, max_batch=2, max_len=64)
    prompts = _mk_prompts(3, seed=23)
    ref = CollaborativeServingEngine(
        params, CFG, cut_layer=CUT, channel=FaultyChannel(BASE, seed=0),
        **fp).generate(prompts, max_new_tokens=12)
    eng = CollaborativeServingEngine(
        params, CFG, cut_layer=CUT, channel=FaultyChannel(BASE, seed=0),
        demand_paged=True, pressure=PressureSchedule([(0.02, 0.25, 0)]),
        **fp)
    got = eng.generate(prompts, max_new_tokens=12)
    ok = got == ref and eng.stats.preemptions >= 1
    print_fn(f"lossless preemption bit-identity: {ok} "
             f"(preemptions={eng.stats.preemptions})")
    return ok


def run(print_fn=print, quick: bool = False) -> dict:
    # offered load = arrival rate of the identical wave; the pool
    # geometry (2x oversubscribed) is fixed across the sweep
    loads = [("heavy", 0.05)] if quick else [
        ("light", 0.30), ("medium", 0.15), ("heavy", 0.05)]
    n_be, n_pri = (6, 2) if quick else (8, 3)
    params = init_lm(jax.random.PRNGKey(0), CFG)
    deadline_s = _calibrate_deadline(params)
    print_fn(f"pool {POOL['num_pages']} pages @ {PAGE} "
             f"(~2x oversubscribed), {n_be} best-effort x {BE_NEW} tok + "
             f"{n_pri} priority x {PRI_NEW} tok, "
             f"deadline={deadline_s:.2f}s on {BASE.name}")

    sweep = {}
    for load, gap in loads:
        sweep[load] = {"arrival_gap_s": gap}
        for name, kw in [("naive", {}),
                         ("robust", dict(demand_paged=True,
                                         admission="deadline"))]:
            fch = FaultyChannel(BASE, seed=0)
            eng = CollaborativeServingEngine(
                params, CFG, cut_layer=CUT, channel=fch, **POOL, **kw)
            r = _serve(eng, fch, _traffic(n_be, n_pri, gap, deadline_s))
            sweep[load][name] = r
            print_fn(f"{load:>6}/{name:>6}: goodput "
                     f"{r['goodput_tok_per_s']:6.1f} tok/s "
                     f"({r['goodput_tokens']}/{r['total_tokens']} tok in "
                     f"{r['sim_s']:.2f}s)  p99 wait "
                     f"{r['p99_queue_wait_s']:.2f}s  "
                     f"preempt={r['preemptions']} shed={r['shed']} "
                     f"miss={r['deadline_misses']}")

    heavy = sweep["heavy"]
    goodput_ratio = heavy["robust"]["goodput_tok_per_s"] \
        / max(heavy["naive"]["goodput_tok_per_s"], 1e-9)
    ok = _lossless_preemption_identity(params, print_fn)
    print_fn(f"goodput robust vs naive at heavy load: {goodput_ratio:.2f}x "
             f"(priority on-time: robust "
             f"{heavy['robust']['priority_ontime_frac']:.2f} vs naive "
             f"{heavy['naive']['priority_ontime_frac']:.2f})")

    result = {
        "config": {"model": CFG.name, "cut": CUT, **POOL,
                   "channel": BASE.name, "prompt_len": PLEN,
                   "best_effort": {"n": n_be, "max_new": BE_NEW},
                   "priority": {"n": n_pri, "max_new": PRI_NEW,
                                "deadline_s": deadline_s},
                   "quick": quick},
        "sweep": sweep,
        "goodput_vs_naive": goodput_ratio,
        "priority_ontime_frac": heavy["robust"]["priority_ontime_frac"],
        "naive_priority_ontime_frac": heavy["naive"]["priority_ontime_frac"],
        "p99_queue_wait_s": heavy["robust"]["p99_queue_wait_s"],
        "naive_p99_queue_wait_s": heavy["naive"]["p99_queue_wait_s"],
        "lossless_preemption_bit_identical": ok,
    }
    OUT.write_text(json.dumps(result, indent=1))
    print_fn(f"-> {OUT}")
    return result


if __name__ == "__main__":
    run()
