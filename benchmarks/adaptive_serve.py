"""Online-adaptive collaborative serving under a drifting channel.

The serving control loop (telemetry → policy → engine) against the
scenario it exists for: the wireless link swings 2 MB/s ↔ 200 KB/s
(with the RTT swinging 5 ms ↔ 150 ms — congestion moves both).  Three
engines serve identical request waves through the same drift:

* ``fixed_cut0`` / ``fixed_cut4`` — the two fixed-cut extremes, each
  with its draft length tuned *offline* for the initial fast channel
  (the repo's pre-PR-4 deployment story: tune once, serve forever);
* ``adaptive`` — starts from the same offline tune at the *high* cut,
  but runs the online policy: EWMA link telemetry re-evaluates the
  (cut, spec_k) grid every scheduler turn, switching the draft length
  between rounds and the cut layer at request-admission boundaries out
  of the prequantized weight bank.

Reported per *accepted* token: measured wall + simulated channel
latency (the e2e the policy optimizes), wire bytes, and the control
events.  The headline is ``adaptive_vs_worst_fixed_e2e_speedup`` —
the drift guard in ``benchmarks/run.py --quick`` regresses against it.

A second, tiny-model section re-runs the drift **lossless**
(``a_bits=None``, fp caches) with scripted mid-stream cut/k switches
and checks the greedy streams are bit-identical to fixed-cut runs —
re-partitioning is output-transparent (``fp_bit_identical``).

Compilation is excluded from timing: every (cut, k) configuration an
engine may serve is prewarmed before the clock starts, so the measured
window exercises warm switches only (an online k switch after warm-up
never recompiles; a cut switch re-traces only on first use of that cut).

    PYTHONPATH=src python -m benchmarks.adaptive_serve
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.autotune import spec_k_for_lm
from repro.core.costmodel import Channel
from repro.models.transformer import LMConfig, init_lm
from repro.serve.engine import (AdaptivePolicy, CollaborativeServingEngine,
                                ServeStats)

OUT = Path("BENCH_adaptive_serve.json")

CFG = LMConfig(name="adaptive-bench-lm", n_layers=6, d_model=256, n_heads=8,
               n_kv=4, d_ff=1024, vocab=2048, max_seq=256, remat=False)
CUT_LO, CUT_HI = 0, 4
KS = (1, 8)                    # candidate draft lengths (prewarmed)
BATCH = 4
PLEN = 32
NEW = 12
# the drift: a good wireless link congesting to a tenth of its
# bandwidth with a 30x RTT, then recovering
FAST = Channel.from_kbps(2000, rtt_ms=5)
SLOW = Channel.from_kbps(200, rtt_ms=150)


def _prompts(n, seed, cfg=CFG, plen=PLEN):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, plen).astype(np.int32)
            for _ in range(n)]


def _prewarm(eng, cuts, ks):
    """Compile every (cut, k, admission-group-size) config the engine
    may serve — speculative retirement staggers the slots, so mid-wave
    admissions come in partial groups of every size — with the policy
    held so the warmup schedule is exhaustive and deterministic; restore
    the starting config and reset the measured counters (the link
    telemetry keeps its lock — it is state about the channel, not about
    the measurement window)."""
    saved, eng.policy = eng.policy, None
    start_cut, start_k = eng.cut, eng.spec_k
    for cut in cuts:
        if cut != eng.cut:
            eng._set_cut(cut)
        for k in ks:
            eng.spec_k = k
            for n in range(1, eng.max_batch + 1):
                eng.generate(_prompts(n, seed=3), max_new_tokens=2)
    if eng.cut != start_cut:
        eng._set_cut(start_cut)
    eng.spec_k = start_k
    eng.policy = saved
    if saved is not None:
        saved.history.clear()
    eng.stats = ServeStats()


def _run_waves(eng, phases, reqs_per_wave, new_tokens, seed0=11):
    """Serve one request wave per channel phase; returns per-wave and
    total (wall + simulated channel) metrics."""
    waves = []
    outs = []
    wall_total = 0.0
    for i, ch in enumerate(phases):
        eng.channel = ch
        prompts = _prompts(reqs_per_wave, seed0 + i)
        ch_before = eng.stats.channel_latency_s
        tok_before = eng.stats.decode_tokens
        t0 = time.perf_counter()
        outs.append(eng.generate(prompts, max_new_tokens=new_tokens))
        wall = time.perf_counter() - t0
        wall_total += wall
        waves.append({
            "channel": ch.name, "rtt_ms": ch.rtt_s * 1e3,
            "wall_s": wall,
            "channel_s": eng.stats.channel_latency_s - ch_before,
            "accepted_tokens": eng.stats.decode_tokens - tok_before,
            "spec_k_after": eng.spec_k, "cut_after": eng.cut,
        })
    s = eng.stats
    accepted = max(s.decode_tokens, 1)
    return outs, {
        "waves": waves,
        "wall_s": wall_total,
        "channel_s": s.channel_latency_s,
        "accepted_tokens": s.decode_tokens,
        "acceptance_rate": s.acceptance_rate(),
        "e2e_us_per_accepted_token":
            (wall_total + s.channel_latency_s) / accepted * 1e6,
        "wire_bytes_per_accepted_token": s.wire_bytes_per_accepted_token(),
        "cut_switches": s.cut_switches,
        "spec_k_switches": s.spec_k_switches,
        "final_cut": eng.cut, "final_spec_k": eng.spec_k,
    }


def _fp_bit_identity(print_fn) -> bool:
    """Lossless drift run on a tiny model: scripted mid-stream cut + k
    switches must leave the greedy streams bit-identical to fixed-cut
    engines serving the same waves."""
    tiny = LMConfig(name="fp-tiny", n_layers=3, d_model=32, n_heads=4,
                    n_kv=2, d_ff=64, vocab=64, max_seq=64, remat=False)
    params = init_lm(jax.random.PRNGKey(1), tiny)
    fp = dict(a_bits=None, edge_int8=False, cloud_int8=False, page_size=8,
              max_batch=2, max_len=64)
    adaptive = CollaborativeServingEngine(params, tiny, cut_layer=0,
                                          candidate_cuts=(0, 1), spec_k=8,
                                          **fp)
    fixed = {c: CollaborativeServingEngine(params, tiny, cut_layer=c,
                                           spec_k=1, **fp) for c in (0, 1)}
    script = [(0, 1), (1, 4), (0, 8)]    # (cut, spec_k) per wave
    ok = True
    for i, (cut, k) in enumerate(script):
        if cut != adaptive.cut:
            adaptive._set_cut(cut)       # drained: admission boundary
        adaptive.spec_k = k
        wave = _prompts(4, 97 + i, cfg=tiny, plen=7 + 3 * i)
        got = adaptive.generate(wave, max_new_tokens=6)
        ref = fixed[cut].generate(wave, max_new_tokens=6)
        ok = ok and got == ref
    print_fn(f"fp bit-identity across re-partitions: {ok}")
    return ok


def run(print_fn=print, quick: bool = False) -> dict:
    # the congestion episode spans two waves: one where the policy is
    # still reacting (telemetry convergence + the drain barriers) and
    # one served at the retuned config throughout
    phases = [FAST, SLOW, SLOW] if quick else [FAST, SLOW, SLOW, FAST]
    reqs, new_tokens = (4, 8) if quick else (8, NEW)
    max_len = 64
    params = init_lm(jax.random.PRNGKey(0), CFG)

    # offline tunes at the initial (fast) channel — the static story
    k_lo = spec_k_for_lm(CFG, CUT_LO, batch=BATCH, channel=FAST, ks=KS)[0].k
    k_hi = spec_k_for_lm(CFG, CUT_HI, batch=BATCH, channel=FAST, ks=KS)[0].k
    print_fn(f"offline tune @{FAST.name}: cut {CUT_LO} -> k={k_lo}, "
             f"cut {CUT_HI} -> k={k_hi}")

    engines = {}
    for name, cut, k in (("fixed_cut0", CUT_LO, k_lo),
                         ("fixed_cut4", CUT_HI, k_hi)):
        eng = CollaborativeServingEngine(params, CFG, cut_layer=cut,
                                         channel=FAST, max_len=max_len,
                                         max_batch=BATCH, spec_k=k)
        _prewarm(eng, (cut,), (k,))
        engines[name] = eng

    policy = AdaptivePolicy(CFG, batch=BATCH, cuts=(CUT_LO, CUT_HI), ks=KS,
                            fallback_channel=FAST)
    adaptive = CollaborativeServingEngine(params, CFG, cut_layer=CUT_HI,
                                          channel=FAST, max_len=max_len,
                                          max_batch=BATCH, spec_k=k_hi,
                                          policy=policy)
    _prewarm(adaptive, (CUT_HI, CUT_LO), KS)
    engines["adaptive"] = adaptive

    results = {}
    for name, eng in engines.items():
        _, results[name] = _run_waves(eng, phases, reqs, new_tokens)
        r = results[name]
        print_fn(f"{name:>11}: e2e {r['e2e_us_per_accepted_token'] / 1e3:8.1f}"
                 f" ms/tok  wire {r['wire_bytes_per_accepted_token']:6.0f}"
                 f" B/tok  switches cut={r['cut_switches']}"
                 f" k={r['spec_k_switches']}  final=(cut {r['final_cut']},"
                 f" k {r['final_spec_k']})")

    worst_fixed = max(results["fixed_cut0"]["e2e_us_per_accepted_token"],
                      results["fixed_cut4"]["e2e_us_per_accepted_token"])
    best_fixed = min(results["fixed_cut0"]["e2e_us_per_accepted_token"],
                     results["fixed_cut4"]["e2e_us_per_accepted_token"])
    adapt = results["adaptive"]["e2e_us_per_accepted_token"]
    fp_ok = _fp_bit_identity(print_fn)
    print_fn(f"adaptive vs worst fixed cut: {worst_fixed / adapt:.2f}x, "
             f"vs best fixed cut: {best_fixed / adapt:.2f}x")

    result = {
        "config": {"model": CFG.name, "cuts": [CUT_LO, CUT_HI], "ks": list(KS),
                   "batch": BATCH, "prompt_len": PLEN,
                   "new_tokens": new_tokens, "requests_per_wave": reqs,
                   "phases": [ch.name for ch in phases], "quick": quick},
        "engines": results,
        "adaptive_vs_worst_fixed_e2e_speedup": worst_fixed / adapt,
        "adaptive_vs_best_fixed_e2e_speedup": best_fixed / adapt,
        "control_events": [
            {"cut": d.cut, "spec_k": d.spec_k,
             "predicted_s_per_token": d.s_per_token,
             "bandwidth_bytes_per_s": d.bandwidth_bytes_per_s,
             "rtt_s": d.rtt_s, "acceptance": d.acceptance}
            for d in policy.history],
        "fp_bit_identical": fp_ok,
    }
    OUT.write_text(json.dumps(result, indent=1))
    print_fn(f"-> {OUT}")
    return result


if __name__ == "__main__":
    run()
