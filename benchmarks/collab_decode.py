"""Incremental collaborative decode benchmark.

Measures the split-KV-cache collaborative engine (per-token [B, 1, D]
boundary delta over the wire) against the seed recompute-from-scratch
path (whole split forward re-run per token, whole boundary blob
retransmitted), and records the per-phase split plus the analytic
roofline prediction.  Writes ``BENCH_collab_decode.json`` so future PRs
have a perf trajectory to regress against.

    PYTHONPATH=src python -m benchmarks.collab_decode
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel,
                                  EDGE_TX2_CLASS, collab_decode_step_time)
from repro.models.transformer import LMConfig, init_lm
from repro.serve.engine import CollaborativeServingEngine, ServeStats

OUT = Path("BENCH_collab_decode.json")

CFG = LMConfig(name="collab-bench-lm", n_layers=6, d_model=256, n_heads=8,
               n_kv=4, d_ff=1024, vocab=2048, max_seq=256, remat=False)
CUT = 1
BATCH = 4
PLEN = 32
NEW = 16


def _engine(params, channel):
    return CollaborativeServingEngine(params, CFG, cut_layer=CUT,
                                      channel=channel, max_len=PLEN + NEW,
                                      max_batch=BATCH, timed=True)


def run(print_fn=print) -> dict:
    params = init_lm(jax.random.PRNGKey(0), CFG)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, CFG.vocab, PLEN).astype(np.int32)
               for _ in range(BATCH)]
    channel = Channel.from_kbps(250, rtt_ms=20)

    # -- incremental split-cache path (warm-up compile, then measure) ------
    # keep the warmed instance: the phase jits are bound methods, so a
    # fresh engine would retrace and the measurement would pay compile
    inc = _engine(params, channel)
    inc.generate(prompts, max_new_tokens=2)          # compile all phases
    inc.stats = ServeStats()
    t0 = time.perf_counter()
    inc.generate(prompts, max_new_tokens=NEW)
    t_inc = time.perf_counter() - t0
    inc_stats = inc.stats.report()

    # -- seed recompute path (re-runs the full split forward per token) ----
    # generate_recompute syncs every step (np.asarray of the argmax), so
    # the wall clock needs no extra fence
    rec = _engine(params, channel)
    t0 = time.perf_counter()
    rec.generate_recompute(prompts, max_new_tokens=NEW)
    t_rec = time.perf_counter() - t0
    rec_tokens = NEW * BATCH

    # -- analytic prediction (roofline devices + channel) ------------------
    blk = CFG.block_param_count()
    head = CFG.vocab * CFG.d_model + CFG.d_model
    pred = collab_decode_step_time(
        edge_flops=2 * blk * (CUT + 1) * BATCH,
        cloud_flops=2 * (blk * (CFG.n_layers - CUT - 1) + head) * BATCH,
        blob_bytes=BATCH * (CFG.d_model + 8),
        edge=EDGE_TX2_CLASS, cloud=CLOUD_TITANXP_CLASS, channel=channel,
        return_bytes=4 * BATCH)

    result = {
        "config": {"model": CFG.name, "cut_layer": CUT, "batch": BATCH,
                   "prompt_len": PLEN, "new_tokens": NEW},
        "incremental": {
            "wall_s": t_inc,
            "us_per_token": t_inc / (NEW * BATCH) * 1e6,
            "bytes_per_token": inc_stats["bytes_per_decode_token"],
            "prefill_bytes": inc_stats["prefill_bytes"],
            "prefill_s": inc_stats["prefill_s"],
            "decode_s": inc_stats["decode_s"],
            "channel_latency_s": inc_stats["channel_latency_s"],
        },
        "recompute_baseline": {
            "wall_s": t_rec,
            "us_per_token": t_rec / rec_tokens * 1e6,
            "bytes_per_token": rec.stats.transmitted_bytes / rec_tokens,
            "channel_latency_s": rec.stats.channel_latency_s,
        },
        "speedup_wall": t_rec / max(t_inc, 1e-9),
        "wire_reduction": (rec.stats.transmitted_bytes / rec_tokens)
                          / max(inc_stats["bytes_per_decode_token"], 1e-9),
        "predicted_step": {"decode_s": pred.decode_s,
                           "channel_s": pred.channel_s},
    }
    OUT.write_text(json.dumps(result, indent=1))

    i, r = result["incremental"], result["recompute_baseline"]
    print_fn(f"incremental: {i['us_per_token']:9.1f} us/token  "
             f"{i['bytes_per_token']:7.1f} B/token  "
             f"(prefill {i['prefill_s']:.3f}s / decode {i['decode_s']:.3f}s "
             f"/ wire {i['channel_latency_s']:.3f}s)")
    print_fn(f"recompute:   {r['us_per_token']:9.1f} us/token  "
             f"{r['bytes_per_token']:7.1f} B/token  "
             f"(wire {r['channel_latency_s']:.3f}s)")
    print_fn(f"speedup {result['speedup_wall']:.1f}x wall, "
             f"{result['wire_reduction']:.1f}x less wire traffic per token "
             f"-> {OUT}")
    return result


if __name__ == "__main__":
    run()
