import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
"""Beyond-paper optimized serving sweep: every LM decode cell re-measured
with the §Perf winners (flash-decoding score layout + INT8 KV cache),
recorded next to the paper-faithful baselines.

    PYTHONPATH=src python -m benchmarks.optimized_decode
"""
import json
from pathlib import Path

import jax

from repro.configs import get_arch, list_cells
from repro.launch.dryrun import (HBM_BW, LINK_BW, PEAK_BF16, _module_costs)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

OUT = Path("artifacts/dryrun_optimized")


def run(print_fn=print) -> list:
    mesh = make_production_mesh()
    rows = []
    for arch, shape in list_cells():
        spec = get_arch(arch)
        if spec.shapes[shape].kind != "decode":
            continue
        cfg = spec.full

        def costs(ov, variant):
            c = build_cell(arch, shape, mesh, unroll=True, cfg_override=ov,
                           variant=variant)
            return _module_costs(c.lower().compile())

        rec = {"arch": arch, "shape": shape, "variant": "int8kv_sseq"}
        c1 = costs({"n_layers": 1}, "int8kv_sseq")
        c2 = costs({"n_layers": 2}, "int8kv_sseq")
        tot = {k: c1[k] + (cfg.n_layers - 1) * max(c2[k] - c1[k], 0.0)
               for k in ("flops", "bytes", "coll")}
        co = build_cell(arch, shape, mesh, variant="int8kv_sseq"
                        ).lower().compile()
        rec.update(
            compute_s=tot["flops"] / PEAK_BF16,
            memory_s=tot["bytes"] / HBM_BW,
            collective_s=tot["coll"] / LINK_BW,
            peak_gib=co.memory_analysis().temp_size_in_bytes / 2**30)
        # baseline for comparison
        base_f = Path(f"artifacts/dryrun/{arch}__{shape}__16x16.json")
        if base_f.exists():
            b = json.loads(base_f.read_text())["roofline"]
            rec["baseline"] = {k: b[k] for k in ("compute_s", "memory_s",
                                                 "collective_s")}
        OUT.mkdir(parents=True, exist_ok=True)
        (OUT / f"{arch}__{shape}.json").write_text(json.dumps(rec, indent=1))
        dom = max(("compute", rec["compute_s"]), ("memory", rec["memory_s"]),
                  ("collective", rec["collective_s"]), key=lambda x: x[1])
        base = rec.get("baseline", {})
        base_dom = max(base.values()) if base else float("nan")
        print_fn(f"{arch:22s} {shape:11s} optimized dom={dom[0]}:"
                 f"{dom[1]:.4f}s (baseline dominant {base_dom:.4f}s -> "
                 f"{base_dom / max(dom[1], 1e-9):.1f}x better)")
        rows.append(rec)
    return rows


def summarize(print_fn=print) -> list:
    """Read previously-computed optimized artifacts (no recompilation)."""
    rows = []
    for f in sorted(OUT.glob("*.json")):
        r = json.loads(f.read_text())
        dom = max(("compute", r["compute_s"]), ("memory", r["memory_s"]),
                  ("collective", r["collective_s"]), key=lambda x: x[1])
        base = r.get("baseline", {})
        base_dom = max(base.values()) if base else float("nan")
        print_fn(f"{r['arch']:>22} {r['shape']:>11} {r['variant']:>12} "
                 f"dom={dom[0]}:{dom[1]:.4f}s  baseline {base_dom:.4f}s  "
                 f"({base_dom / max(dom[1], 1e-9):.1f}x)")
        rows.append(r)
    if not rows:
        print_fn("(no optimized artifacts — run "
                 "`python -m benchmarks.optimized_decode` first)")
    return rows


if __name__ == "__main__":
    run()
