"""Sampled (temperature>0) speculative decode benchmark.

Sweeps decode temperature at a fixed draft length and measures what
stochastic acceptance does to the speculative win: each graded draft is
now accepted with probability ``min(1, p/q)`` instead of by exact argmax
match, so rising temperature taxes the acceptance rate — and the sampled
rounds additionally ship the drafter's k-1 f32 q rows uplink for the
rejection test.  Per temperature the sweep reports, against the
non-speculative (spec_k=1) *sampled* cloud baseline of the same
temperature:

  * measured acceptance rate (greedy row at t=0 for reference — the
    bit-identical fast path);
  * modeled end-to-end time per accepted token (wall + simulated
    channel) and the speedup over the serial baseline;
  * wire bytes per accepted token (the q-row surcharge shows up here);
  * the k ``autotune.tune_spec_k`` would pick at the measured stochastic
    acceptance with the q-bytes priced in (``lm_round_args
    (sampled_frac=1)``).

Row keys are dot-free (``t00``/``t05``/``t10``) so ``benchmarks.run``'s
dotted drift-guard paths can address them.  Writes
``BENCH_sampled_spec.json``; the drift guard tracks ``acceptance.t10``
and ``e2e_speedup_vs_serial.t10``.

    PYTHONPATH=src python -m benchmarks.sampled_spec
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.autotune import spec_k_for_lm
from repro.core.costmodel import Channel
from repro.models.transformer import LMConfig, init_lm
from repro.serve.engine import (CollaborativeServingEngine, SamplingParams,
                                ServeStats)

OUT = Path("BENCH_sampled_spec.json")

CFG = LMConfig(name="sampled-bench-lm", n_layers=6, d_model=256, n_heads=8,
               n_kv=4, d_ff=1024, vocab=2048, max_seq=256, remat=False)
CUT = 1
K = 4
BATCH = 4
PLEN = 32
NEW = 16
CHANNEL = Channel.from_kbps(500, rtt_ms=100)


def _engine(params, k, max_len):
    return CollaborativeServingEngine(params, CFG, cut_layer=CUT,
                                      channel=CHANNEL, max_len=max_len,
                                      max_batch=BATCH, spec_k=k, timed=True)


def _sampling(temp):
    if temp <= 0:
        return None                           # the greedy fast path
    return [SamplingParams(temperature=temp, top_p=0.95, seed=i)
            for i in range(BATCH)]


def _measure(eng, prompts, new_tokens, temp):
    eng.generate(prompts, max_new_tokens=2, sampling=_sampling(temp))
    eng.stats = ServeStats()
    t0 = time.perf_counter()
    eng.generate(prompts, max_new_tokens=new_tokens,
                 sampling=_sampling(temp))
    wall = time.perf_counter() - t0
    s = eng.stats
    acc = max(s.decode_tokens, 1)
    return {
        "wall_s": wall,
        "accepted_tokens": s.decode_tokens,
        "rounds": s.decode_steps,
        "acceptance_rate": s.acceptance_rate(),
        "e2e_us_per_accepted_token": (wall + s.channel_latency_s) / acc * 1e6,
        "wire_bytes_per_accepted_token": s.wire_bytes_per_accepted_token(),
        "channel_latency_s": s.channel_latency_s,
    }


def run(print_fn=print, quick: bool = False) -> dict:
    temps = (0.0, 1.0) if quick else (0.0, 0.5, 1.0)
    new_tokens = 8 if quick else NEW
    max_len = PLEN + NEW + K
    params = init_lm(jax.random.PRNGKey(0), CFG)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, CFG.vocab, PLEN).astype(np.int32)
               for _ in range(BATCH)]

    spec_eng = _engine(params, K, max_len)
    serial_eng = _engine(params, 1, max_len)
    rows, acceptance, speedup, tuned_k = {}, {}, {}, {}
    for temp in temps:
        key = f"t{temp:.1f}".replace(".", "")        # t00 / t05 / t10
        serial = _measure(serial_eng, prompts, new_tokens, temp)
        row = _measure(spec_eng, prompts, new_tokens, temp)
        row["e2e_speedup_vs_serial"] = (serial["e2e_us_per_accepted_token"]
                                        / row["e2e_us_per_accepted_token"])
        row["serial"] = serial
        # what the tuner would pick at the measured stochastic
        # acceptance, q-row uplink priced in for sampled traffic
        best, _ = spec_k_for_lm(CFG, CUT, batch=BATCH, channel=CHANNEL,
                                acceptance=row["acceptance_rate"],
                                ks=(1, 2, 4, 8),
                                sampled_frac=0.0 if temp <= 0 else 1.0)
        row["tuned_k_at_measured_acceptance"] = best.k
        rows[key] = row
        acceptance[key] = row["acceptance_rate"]
        speedup[key] = row["e2e_speedup_vs_serial"]
        tuned_k[key] = best.k
        print_fn(f"T={temp:.1f}: acc {row['acceptance_rate']:.2f}  e2e "
                 f"{row['e2e_us_per_accepted_token']:8.0f} us/tok "
                 f"({row['e2e_speedup_vs_serial']:.2f}x vs serial)  wire "
                 f"{row['wire_bytes_per_accepted_token']:.0f} B/tok  "
                 f"tuner k={best.k}")

    result = {
        "config": {"model": CFG.name, "cut_layer": CUT, "spec_k": K,
                   "batch": BATCH, "prompt_len": PLEN,
                   "new_tokens": new_tokens, "channel_kbps": 500,
                   "rtt_ms": 100, "top_p": 0.95, "quick": quick},
        "rows": rows,
        "acceptance": acceptance,
        "e2e_speedup_vs_serial": speedup,
        "tuned_k": tuned_k,
    }
    OUT.write_text(json.dumps(result, indent=1))
    print_fn(f"-> {OUT}")
    return result


if __name__ == "__main__":
    run()
