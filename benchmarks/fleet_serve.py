"""Fleet serving benchmark: N tenant edges on one shared cloud engine.

Four simulated edges — heterogeneous links, identical (cut, spec_k) so
their rounds coalesce — stream staggered request waves at the cloud two
ways:

* ``fleet`` — one ``FleetServingEngine`` (max_batch = 8, one shared
  ``_CutBank`` / KV page pool): every scheduler turn verifies ALL
  tenants' due drafts in ONE batched ``paged_flash_mq`` call;
* ``independent`` — four separate ``CollaborativeServingEngine``s
  (max_batch = 2, a quarter of the page pool each — the same aggregate
  hardware budget), each serving one tenant's stream, run back to back
  on the same host.

Both sides run the identical workload through an untimed warm-up pass
that compiles every phase shape, then ``REPS`` timed replays (fresh
channels/stats each) of which the best (minimum) wall is reported — so
the headline measures dispatch and batching, not XLA compiles or host
scheduler jitter.  **Aggregate throughput** is total committed tokens
over host wall-clock; the fleet's win is issuing ~N-fold fewer phase dispatches
per round (``round_calls`` vs the independents' summed
``decode_steps``).  Per-tenant request latency (p50/p99 of
``finish_s - arrival_s`` on each tenant's own simulated clock) is
reported for both sides — cross-tenant batching must not buy
throughput with tail latency.

Headline for the drift guard: ``aggregate_speedup_vs_independent``
(the ISSUE's acceptance bar is >= 1.5x at N = 4).  A lossless
(``a_bits=None``) fleet-vs-solo bit-identity check rides along.

    PYTHONPATH=src python -m benchmarks.fleet_serve
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.costmodel import Channel
from repro.models.transformer import LMConfig, init_lm
from repro.serve import (CollaborativeServingEngine, FaultyChannel,
                         FleetServingEngine, Request, ServeStats,
                         TenantSpec)

OUT = Path("BENCH_fleet_serve.json")

CFG = LMConfig(name="fleet-bench-lm", n_layers=3, d_model=32, n_heads=4,
               n_kv=2, d_ff=64, vocab=64, max_seq=64, remat=False)
CUT = 1
K = 4                    # shared draft length -> rounds coalesce
PLEN = 9
NEW = 32
PAGE = 8
MAXLEN = 64
REPS = 3                 # timed replays per side; best (min) wall wins
# heterogeneous last hops, one per tenant (kbps, rtt_ms)
LINKS = [(2000, 20), (1000, 40), (500, 60), (250, 80)]


def _channels(seed: int = 0):
    """Fresh per-tenant clocked channels (fault-free ``FaultyChannel``
    wrappers: deterministic, but they own a simulated clock, which the
    plain ``Channel`` does not)."""
    return {f"edge{i}": FaultyChannel(Channel.from_kbps(bw, rtt_ms=rtt),
                                      seed=seed + i)
            for i, (bw, rtt) in enumerate(LINKS)}


def _traffic(n_req: int, gap: float, seed: int):
    rng = np.random.RandomState(seed)
    return [Request(uid=i,
                    prompt=rng.randint(0, CFG.vocab, PLEN).astype(np.int32),
                    max_new_tokens=NEW, arrival_s=i * gap)
            for i in range(n_req)]


def _latency(reqs):
    lats = [r.finish_s - r.arrival_s for r in reqs if r.finish_s is not None]
    return {"p50_latency_s": float(np.percentile(lats, 50)) if lats else 0.0,
            "p99_latency_s": float(np.percentile(lats, 99)) if lats else 0.0}


def _run_fleet(params, n_req: int, gap: float):
    chans = _channels()
    fleet = FleetServingEngine(
        params, CFG,
        [TenantSpec(name, ch, cut_layer=CUT, spec_k=K)
         for name, ch in chans.items()],
        max_batch=2 * len(chans), max_len=MAXLEN, page_size=PAGE)
    # warm-up pass: identical traffic, so the timed passes replay the
    # exact group-size/bucket sequence through already-compiled phases
    fleet.generate_requests({name: _traffic(n_req, gap, seed=10 + i)
                             for i, name in enumerate(chans)})
    best = None
    for _rep in range(REPS):
        for i, (name, t) in enumerate(fleet._tenants.items()):
            t.transport.channel = _channels()[name]
            t.stats = ServeStats()
            fleet.fairness.vservice[name] = 0.0
        fleet.round_calls = 0
        reqs = {name: _traffic(n_req, gap, seed=10 + i)
                for i, name in enumerate(chans)}
        t0 = time.perf_counter()
        fleet.generate_requests(reqs)
        wall = time.perf_counter() - t0
        per_tenant = {}
        for name, rl in reqs.items():
            t = fleet.tenant(name)
            per_tenant[name] = {
                **_latency(rl),
                "tokens": sum(len(r.out_tokens) for r in rl),
                "sim_s": t.now(),
                "wire_bytes": t.stats.transmitted_bytes,
            }
        tokens = sum(p["tokens"] for p in per_tenant.values())
        snap = {"wall_s": wall, "tokens": tokens,
                "tokens_per_s_wall": tokens / max(wall, 1e-9),
                "round_dispatches": fleet.round_calls,
                "pool_utilization_peak": fleet.stats.pool_utilization_peak,
                "per_tenant": per_tenant}
        if best is None or wall < best["wall_s"]:
            best = snap
    return best


def _run_independent(params, n_req: int, gap: float):
    chans = _channels()
    engines = {}
    for name, ch in chans.items():
        engines[name] = CollaborativeServingEngine(
            params, CFG, cut_layer=CUT, channel=ch, spec_k=K,
            max_batch=2, max_len=MAXLEN, page_size=PAGE)
    # warm-up pass per engine (each owns its own jitted phases)
    for i, (name, eng) in enumerate(engines.items()):
        eng.generate_requests(_traffic(n_req, gap, seed=10 + i))
    best = None
    for _rep in range(REPS):
        per_tenant = {}
        wall = 0.0
        dispatches = 0
        for i, (name, eng) in enumerate(engines.items()):
            eng.transport.channel = _channels()[name]
            eng.stats = ServeStats()
            reqs = _traffic(n_req, gap, seed=10 + i)
            t0 = time.perf_counter()
            eng.generate_requests(reqs)
            wall += time.perf_counter() - t0
            dispatches += eng.stats.decode_steps
            per_tenant[name] = {
                **_latency(reqs),
                "tokens": sum(len(r.out_tokens) for r in reqs),
                "sim_s": float(eng.channel.clock_s),
                "wire_bytes": eng.stats.transmitted_bytes,
            }
        tokens = sum(p["tokens"] for p in per_tenant.values())
        snap = {"wall_s": wall, "tokens": tokens,
                "tokens_per_s_wall": tokens / max(wall, 1e-9),
                "round_dispatches": dispatches,
                "per_tenant": per_tenant}
        if best is None or wall < best["wall_s"]:
            best = snap
    return best


def _lossless_identity(params, print_fn) -> bool:
    """Two tenants at *different* cuts over one shared bank, lossless:
    each tenant's fleet stream must be bit-identical to the same tenant
    served alone on a solo engine."""
    fp = dict(a_bits=None, edge_int8=False, cloud_int8=False,
              max_len=MAXLEN, page_size=PAGE)
    rng = np.random.RandomState(3)
    prompts = {n: [rng.randint(0, CFG.vocab, PLEN).astype(np.int32)
                   for _ in range(3)] for n in ("a", "b")}
    fleet = FleetServingEngine(
        params, CFG,
        [TenantSpec("a", Channel.from_kbps(2000, rtt_ms=20), cut_layer=0,
                    spec_k=1),
         TenantSpec("b", Channel.from_kbps(500, rtt_ms=50), cut_layer=1,
                    spec_k=K)],
        max_batch=4, **fp)
    got = fleet.generate(prompts, max_new_tokens=12)
    ok = True
    for name, cut, k, kbps, rtt in [("a", 0, 1, 2000, 20),
                                    ("b", 1, K, 500, 50)]:
        solo = CollaborativeServingEngine(
            params, CFG, cut_layer=cut, spec_k=k,
            channel=Channel.from_kbps(kbps, rtt_ms=rtt), max_batch=4, **fp)
        ok = ok and got[name] == solo.generate(prompts[name],
                                               max_new_tokens=12)
    print_fn(f"lossless fleet-vs-solo bit-identity: {ok}")
    return ok


def run(print_fn=print, quick: bool = False) -> dict:
    n_req = 3 if quick else 6
    gap = 0.2
    params = init_lm(jax.random.PRNGKey(0), CFG)
    print_fn(f"{len(LINKS)} tenants x {n_req} req x {NEW} tok "
             f"(cut={CUT}, k={K}), links "
             + ", ".join(f"{bw}kbps/{rtt}ms" for bw, rtt in LINKS))

    fleet = _run_fleet(params, n_req, gap)
    indep = _run_independent(params, n_req, gap)
    speedup = fleet["tokens_per_s_wall"] / max(indep["tokens_per_s_wall"],
                                               1e-9)
    for name, r in [("fleet", fleet), ("independent", indep)]:
        p99 = max(p["p99_latency_s"] for p in r["per_tenant"].values())
        print_fn(f"{name:>12}: {r['tokens']} tok in {r['wall_s']:.2f}s wall "
                 f"({r['tokens_per_s_wall']:7.1f} tok/s), "
                 f"{r['round_dispatches']} round dispatches, "
                 f"worst p99 latency {p99:.2f}s")
    print_fn(f"aggregate speedup vs independent: {speedup:.2f}x "
             f"(dispatch ratio "
             f"{indep['round_dispatches'] / max(fleet['round_dispatches'], 1):.1f}x)")
    ok = _lossless_identity(params, print_fn)

    result = {
        "config": {"model": CFG.name, "cut": CUT, "spec_k": K,
                   "tenants": len(LINKS),
                   "links_kbps_rtt_ms": LINKS, "prompt_len": PLEN,
                   "max_new": NEW, "n_req_per_tenant": n_req,
                   "arrival_gap_s": gap, "page_size": PAGE,
                   "max_len": MAXLEN, "quick": quick},
        "fleet": fleet,
        "independent": indep,
        "aggregate_speedup_vs_independent": speedup,
        "dispatch_ratio": indep["round_dispatches"]
        / max(fleet["round_dispatches"], 1),
        "fleet_lossless_bit_identical": ok,
    }
    OUT.write_text(json.dumps(result, indent=1))
    print_fn(f"-> {OUT}")
    return result


if __name__ == "__main__":
    run()
