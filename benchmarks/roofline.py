"""§Roofline: render the per-(arch × shape × mesh) roofline table from the
dry-run artifacts (artifacts/dryrun/*.json).

Terms (per device, seconds):
  compute    = HLO_FLOPs / 197e12          (bf16 peak per v5e chip)
  memory     = HLO_bytes / 819e9           (HBM)
  collective = wire_bytes / 50e9           (ICI per link)
plus MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and the dominant term.
"""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path("artifacts/dryrun")


def load(mesh: str = "16x16") -> list[dict]:
    recs = []
    for f in sorted(ARTIFACTS.glob(f"*__{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def run(print_fn=print, *, mesh: str = "16x16") -> list[dict]:
    recs = load(mesh)
    if not recs:
        print_fn(f"(no dry-run artifacts for mesh {mesh} — run "
                 f"`python -m repro.launch.dryrun` first)")
        return []
    print_fn(f"{'arch':>20} {'shape':>12} {'kind':>8} {'compute_s':>10} "
             f"{'memory_s':>10} {'collect_s':>10} {'dominant':>10} "
             f"{'useful':>7} {'peak GiB':>9}")
    for r in recs:
        rl = r["roofline"]
        print_fn(f"{r['arch']:>20} {r['shape']:>12} {r['kind']:>8} "
                 f"{rl['compute_s']:>10.4f} {rl['memory_s']:>10.4f} "
                 f"{rl['collective_s']:>10.4f} {rl['dominant']:>10} "
                 f"{min(r['useful_flop_ratio'], 9.99):>7.2f} "
                 f"{r['memory_analysis']['temp_bytes'] / 2**30:>9.2f}")
    return recs


if __name__ == "__main__":
    run()
