"""Hypothesis property tests on the partition system's invariants, over
randomly generated DAGs (random branches and shortcuts)."""
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; skip, don't "
    "kill collection of the whole tier-1 suite")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core.graph import LayerGraph
from repro.core.partition import (candidate_partition_points,
                                  merge_non_parametric)


@st.composite
def random_graph(draw):
    """Random topo-ordered DAG: chain + random extra (skip) edges +
    random non-parametric nodes."""
    n = draw(st.integers(3, 14))
    g = LayerGraph("rand")
    g.add("input", "input", [], (1, 8))
    names = ["input"]
    for i in range(n):
        op = draw(st.sampled_from(["conv", "dense", "relu", "pool", "add"]))
        # always connect to the previous node (keeps it a single chain
        # backbone); maybe add a skip edge from an earlier node
        inputs = [names[-1]]
        if len(names) > 2 and draw(st.booleans()):
            extra = draw(st.sampled_from(names[:-1]))
            if extra not in inputs:
                inputs.append(extra)
        if op in ("relu", "pool"):
            inputs = [names[-1]]
        parametric = op in ("conv", "dense")
        g.add(f"n{i}", op, inputs, (1, 8),
              flops=float(draw(st.integers(1, 100))) * 1e3,
              param_elems=draw(st.integers(0, 1000)) if parametric else 0)
        names.append(f"n{i}")
    return g


@settings(max_examples=60, deadline=None)
@given(random_graph())
def test_candidates_are_single_blob_own_output(g):
    merged = merge_non_parametric(g)
    cands = candidate_partition_points(g, include_input=False,
                                       include_last=False)
    last = merged.topo()[-1]
    for c in cands:
        blobs = merged.crossing_blobs(c.name)
        assert len(blobs) <= 1
        if c.name != last and blobs:
            assert blobs[0].source == c.name
            assert blobs[0].precision == "int8"


@settings(max_examples=60, deadline=None)
@given(random_graph())
def test_merge_preserves_flops_and_params(g):
    merged = merge_non_parametric(g)
    assert abs(merged.total_flops() - g.total_flops()) < 1e-6
    assert merged.total_param_elems() == g.total_param_elems()
    # merged graph contains no mergeable non-parametric nodes
    for n in merged.topo():
        nd = merged[n]
        assert nd.parametric or nd.op == "input" or not nd.inputs


@settings(max_examples=60, deadline=None)
@given(random_graph())
def test_edge_flops_monotone_and_transmit_positive(g):
    cands = candidate_partition_points(g)
    flops = [c.edge_flops for c in cands]
    assert flops == sorted(flops)
    assert all(c.transmit_bytes > 0 for c in cands)


@settings(max_examples=40, deadline=None)
@given(random_graph(), st.floats(1e3, 1e9))
def test_autotune_best_never_worse_than_endpoints(g, bw):
    """Algorithm 1's pick must beat (or tie) both cloud-only and
    edge-only — it optimizes over a superset."""
    from repro.core.autotune import AutoTuner
    from repro.core.costmodel import (CLOUD_TITANXP_CLASS, Channel,
                                      EDGE_TX2_CLASS)
    tuner = AutoTuner(g, EDGE_TX2_CLASS, CLOUD_TITANXP_CLASS)
    ch = Channel(bandwidth_bytes_per_s=bw)
    best, perfs = tuner.tune(ch)
    assert best.total_s <= min(p.total_s for p in perfs) + 1e-12
    assert best.total_s <= tuner.cloud_only(ch).total_s + 1e-12
