"""Multi-device parity tests — run in a SUBPROCESS with 8 forced host
devices (the main test process must keep the real 1-device view)."""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, dataclasses
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch.mesh import _mk_mesh, mesh_context

    mesh = _mk_mesh((4, 2), ("data", "model"))

    # ---- 1. sharded MoE == unsharded MoE (same routing, same math) ----
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    d, f, E, K = 16, 32, 4, 2
    p = L.moe_init(key, d, f, E)
    x = jnp.asarray(np.random.RandomState(1).randn(8, 6, d), np.float32)
    y_ref, aux_ref = L.moe(p, x, top_k=K, capacity_factor=4.0)

    with mesh, mesh_context(mesh):
        y_sh, aux_sh = jax.jit(lambda p, x: L.moe_sharded(
            p, x, top_k=K, batch_spec="data", model_axis="model"))(p, x)
    # sharded path routes per data-shard (2 tokens fewer per capacity
    # group); with generous capacity results must match closely
    err = float(jnp.linalg.norm(y_sh - y_ref) / jnp.linalg.norm(y_ref))
    assert err < 2e-2, f"moe_sharded mismatch: {err}"
    assert abs(float(aux_sh) - float(aux_ref)) < 0.5

    # ---- 2. LM train step under production-style shardings ------------
    from repro.launch.steps import build_cell
    from repro.launch.mesh import batch_axes
    cell = build_cell("qwen3-moe-30b-a3b", "train_4k", mesh, smoke=True)
    compiled = cell.lower().compile()

    rng = np.random.RandomState(0)
    def conc(x):
        if isinstance(x, jax.ShapeDtypeStruct):
            if jnp.issubdtype(x.dtype, jnp.integer):
                return jnp.asarray(rng.randint(0, 9, x.shape).astype(x.dtype))
            return jnp.asarray(np.abs(rng.randn(*x.shape)).astype(x.dtype)
                               * 0.02)
        return x
    with mesh, mesh_context(mesh):
        args = jax.tree_util.tree_map(
            conc, cell.args,
            is_leaf=lambda v: isinstance(v, jax.ShapeDtypeStruct))
        params, opt, metrics = compiled(*args)
    assert bool(jnp.isfinite(metrics["loss"])), metrics
    print("MULTIDEVICE_OK")
""")


@pytest.mark.slow
def test_multidevice_parity_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=420, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "JAX_PLATFORMS": "cpu",
                          "HOME": "/root"})
    assert "MULTIDEVICE_OK" in proc.stdout, (
        proc.stdout[-2000:], proc.stderr[-3000:])
