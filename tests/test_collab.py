"""Collaborative-inference runtime tests: edge INT8 + cloud FP32 must match
the monolithic FP32 model up to quantization noise, at every candidate cut."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.collab import CollaborativeEngine, Segment, SegmentedModel
from repro.core.costmodel import Channel
from repro.core.graph import LayerGraph
from repro.models import layers as L

jax.config.update("jax_platform_name", "cpu")


def tiny_cnn(key=None, c=8, d=16, n_cls=10, img=16):
    """conv → conv → gap+dense, segmented at each conv boundary."""
    key = key or jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    p1 = L.conv2d_init(k1, 3, 3, c)
    p2 = L.conv2d_init(k2, 3, c, d)
    p3 = L.dense_init(k3, d, n_cls)

    def s1(p, x, *, qctx=None):
        return L.conv2d(p, x, qctx=qctx, name="conv1", act="relu")

    def s2(p, x, *, qctx=None):
        return L.conv2d(p, x, stride=2, qctx=qctx, name="conv2", act="relu")

    def s3(p, x, *, qctx=None):
        x = jnp.mean(x, axis=(1, 2))
        return L.dense(p, x, qctx=qctx, name="head")

    g = LayerGraph("tiny-cnn")
    g.add("input", "input", [], (1, img, img, 3))
    g.add("conv1", "conv", ["input"], (1, img, img, c),
          flops=2 * 9 * 3 * c * img * img, param_elems=9 * 3 * c + c)
    g.add("conv2", "conv", ["conv1"], (1, img // 2, img // 2, d),
          flops=2 * 9 * c * d * (img // 2) ** 2, param_elems=9 * c * d + d)
    g.add("head", "dense", ["conv2"], (1, n_cls), flops=2 * d * n_cls,
          param_elems=d * n_cls + n_cls)
    return SegmentedModel(
        name="tiny-cnn", graph=g,
        segments=[Segment("conv1", s1, p1), Segment("conv2", s2, p2),
                  Segment("head", s3, p3)])


def _input(batch=2, img=16, seed=0):
    return jnp.asarray(
        np.random.RandomState(seed).rand(batch, img, img, 3).astype(np.float32))


def test_segments_align_with_candidates():
    m = tiny_cnn()
    m.verify_alignment()


@pytest.mark.parametrize("cut", ["input", "conv1", "conv2", "head"])
def test_collab_matches_fp32_within_quant_noise(cut):
    m = tiny_cnn()
    x = _input()
    truth = m.full_apply(x)
    eng = CollaborativeEngine(m, cut, calib_batches=[_input(seed=7)])
    got, rec = eng.infer(x)
    rel = float(jnp.linalg.norm(got - truth) / jnp.linalg.norm(truth))
    if cut == "input":
        assert rel < 1e-5                   # cloud-only: fp32 exact up to jit

        assert rec.precision == "fp32"
    else:
        assert rel < 0.12, (cut, rel)       # int8 edge: small error
        assert rec.precision == "int8"


def test_boundary_blob_is_int8_sized():
    m = tiny_cnn()
    x = _input(batch=1)
    eng = CollaborativeEngine(m, "conv2")
    _, rec = eng.infer(x)
    # conv2 output at batch=1: 8*8*16 elems → int8 bytes + 8B scale/zp
    assert rec.blob_bytes == 8 * 8 * 16 + 8


def test_edge_download_is_quarter_of_fp32():
    m = tiny_cnn()
    eng = CollaborativeEngine(m, "conv2")
    assert eng.edge_download_bytes < eng.edge_fp32_bytes / 3.5
    assert 0.0 < eng.storage_reduction < 1.0


def test_channel_latency_scales_with_bytes():
    m = tiny_cnn()
    x = _input(batch=1)
    slow = CollaborativeEngine(m, "conv1", channel=Channel.from_kbps(100))
    fast = CollaborativeEngine(m, "conv1", channel=Channel.from_kbps(10000))
    _, r_slow = slow.infer(x)
    _, r_fast = fast.infer(x)
    assert r_slow.simulated_latency_s == pytest.approx(
        100 * r_fast.simulated_latency_s)
    assert r_slow.simulated_latency_s == pytest.approx(
        r_slow.blob_bytes / 100e3)


def test_static_calibration_close_to_dynamic():
    m = tiny_cnn()
    x = _input()
    calibrated = CollaborativeEngine(
        m, "conv2", calib_batches=[_input(seed=i) for i in range(4)])
    dynamic = CollaborativeEngine(m, "conv2")
    y_c, _ = calibrated.infer(x)
    y_d, _ = dynamic.infer(x)
    rel = float(jnp.linalg.norm(y_c - y_d) / jnp.linalg.norm(y_d))
    assert rel < 0.1


def test_edge_only_cut_runs_everything_on_edge():
    m = tiny_cnn()
    x = _input()
    eng = CollaborativeEngine(m, "head")
    y, rec = eng.infer(x)
    assert rec.cloud_wall_s >= 0 and not eng.cloud_segments
    truth = m.full_apply(x)
    rel = float(jnp.linalg.norm(y - truth) / jnp.linalg.norm(truth))
    assert rel < 0.15
