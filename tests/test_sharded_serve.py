"""Tensor-parallel cloud verify: sharded-engine stream parity.

The engine half runs in a SUBPROCESS with 8 forced host devices (the
main test process must keep its real 1-device view — same discipline
as ``tests/test_multidevice.py``).  In the subprocess, a lossless
(``a_bits=None``) demand-paged engine is built at TP meshes 1/2/4/8
and driven through a seeded chaos run — ``FaultyChannel`` drops/stalls
plus a ``PressureSchedule`` page-pool squeeze that forces preemption —
for several seeds; every mesh's committed greedy stream must equal the
unsharded oracle's token for token (the TP placement may move the
suffix math across devices but must never change it).  The shard_map'd
paged-attention kernel is exercised through the Pallas interpreter
against the unsharded kernel and must match to the bit.
"""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.costmodel import Channel
    from repro.launch.mesh import make_serve_mesh
    from repro.models.transformer import LMConfig, init_lm
    from repro.serve import (CollaborativeServingEngine, FaultyChannel,
                             PressureSchedule)
    from repro.kernels import paged_attention as PA

    CFG = LMConfig(name="shard-tiny", n_layers=4, d_model=32, n_heads=4,
                   n_kv=2, d_ff=64, vocab=64, max_seq=64, remat=False)
    LOSSLESS_FP = dict(a_bits=None, edge_int8=False, cloud_int8=False,
                       page_size=8, max_batch=2, max_len=64)
    BASE_CH = Channel.from_kbps(500, rtt_ms=10)
    WINDOWS = [(0.0, 1.5, 1)]      # squeeze the pool early -> preemption
    params = init_lm(jax.random.PRNGKey(0), CFG)

    def prompts(seed):
        rng = np.random.RandomState(seed)
        return [rng.randint(0, CFG.vocab, l).astype(np.int32)
                for l in (7, 13)]

    def build(mesh):
        return CollaborativeServingEngine(params, CFG, cut_layer=1,
                                          spec_k=4, demand_paged=True,
                                          mesh=mesh, **LOSSLESS_FP)

    def chaos_run(eng, seed):
        eng.channel = FaultyChannel(BASE_CH, seed=seed, drop_p=0.15,
                                    stall_p=0.15)
        eng.pressure = PressureSchedule(WINDOWS)
        try:
            return eng.generate(prompts(seed), max_new_tokens=6)
        finally:
            eng.pressure.apply(eng._pool.allocator, float("inf"))
            eng.pressure = None

    SEEDS = (0, 1)
    oracle_eng = build(None)
    oracle = {s: chaos_run(oracle_eng, s) for s in SEEDS}
    # the chaos actually fired: link faults and a pool squeeze both hit
    assert sum(oracle_eng.channel.faults.values()) >= 1, \
        oracle_eng.channel.faults

    for n in (1, 2, 4, 8):
        eng = build(make_serve_mesh(model=n))
        # the placement really sharded something: some cloud-suffix leaf
        # is partitioned over the model axis (d_ff=64 divides all n)
        specs = [l.sharding.spec for l in jax.tree.leaves(eng.cloud_blocks)]
        assert any("model" in jax.tree.leaves(tuple(s)) for s in specs), \
            (n, specs)
        for s in SEEDS:
            got = chaos_run(eng, s)
            assert got == oracle[s], (n, s, got, oracle[s])

    # shard_map kernel through the Pallas interpreter: bit-exact vs the
    # unsharded kernel (attention is per-kv-head independent under TP)
    rng = np.random.RandomState(7)
    B, S, H, NKV, HD, PAGE, NP, PPS = 2, 3, 8, 4, 16, 8, 12, 4
    q = jnp.asarray(rng.randn(B, S, H, HD), jnp.float32)
    kp = jnp.asarray(rng.randint(-127, 127, (NP, PAGE, NKV, HD)), jnp.int8)
    vp = jnp.asarray(rng.randint(-127, 127, (NP, PAGE, NKV, HD)), jnp.int8)
    bt = jnp.asarray(rng.permutation(NP)[:B * PPS].reshape(B, PPS),
                     jnp.int32)
    lens = jnp.asarray([17, 25], jnp.int32)
    ks = jnp.asarray(np.abs(rng.randn(B, NKV)) * 0.02, jnp.float32)
    plain = PA.paged_flash_mq(q, kp, vp, bt, lens, lens - S, ks, ks,
                              interpret=True)
    sharded = PA.paged_flash_mq_sharded(
        q, kp, vp, bt, lens, lens - S, ks, ks,
        mesh=make_serve_mesh(model=4, data=2), interpret=True)
    assert bool(jnp.all(plain == sharded)), \
        float(jnp.abs(plain - sharded).max())
    dec_plain = PA.paged_flash_decode(q[:, -1], kp, vp, bt, lens, ks, ks,
                                      interpret=True)
    dec_sharded = PA.paged_flash_decode_sharded(
        q[:, -1], kp, vp, bt, lens, ks, ks,
        mesh=make_serve_mesh(model=4, data=2), interpret=True)
    assert bool(jnp.all(dec_plain == dec_sharded)), \
        float(jnp.abs(dec_plain - dec_sharded).max())

    print("SHARDED_OK")
""")


@pytest.mark.slow
def test_sharded_engine_chaos_parity_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "JAX_PLATFORMS": "cpu", "HOME": "/root"})
    assert "SHARDED_OK" in proc.stdout, (
        proc.stdout[-2000:], proc.stderr[-3000:])
