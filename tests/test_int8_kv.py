"""INT8 KV-cache decode: outputs must track the bf16-cache path (the
paper's Eq.1/2 applied to serving state — §Perf hillclimb #1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (LMConfig, decode_step, forward,
                                      init_cache, init_lm, prefill)

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="kv-tiny", n_layers=2, d_model=32, n_heads=4, n_kv=2,
               d_ff=64, vocab=64, max_seq=64, remat=False)


def test_int8_cache_decode_tracks_fp32():
    params = init_lm(jax.random.PRNGKey(0), CFG)
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, CFG.vocab, (2, 9)), jnp.int32)

    # reference: fp32 cache
    cache = init_cache(CFG, 2, max_len=16)
    _, cache = prefill(params, toks[:, :8], CFG, cache=cache)
    ref, _ = decode_step(params, toks[:, 8], cache, jnp.int32(8), CFG)

    # int8 cache: decode all 9 positions step by step
    qcache = init_cache(CFG, 2, max_len=16, quantized=True)
    # calibrate scales from actual k/v magnitudes (generous range)
    qcache["k_scale"] = jnp.full_like(qcache["k_scale"], 0.02)
    qcache["v_scale"] = jnp.full_like(qcache["v_scale"], 0.02)
    logits = None
    for i in range(9):
        logits, qcache = decode_step(params, toks[:, i], qcache,
                                     jnp.int32(i), CFG)
    assert qcache["k"].dtype == jnp.int8
    rel = float(jnp.linalg.norm(logits - ref) / jnp.linalg.norm(ref))
    assert rel < 0.25, rel
    # ranking mostly preserved
    agree = float(jnp.mean(jnp.argmax(logits, -1) == jnp.argmax(ref, -1)))
    assert agree >= 0.5


def test_int8_cache_is_half_the_bytes():
    c16 = init_cache(CFG, 2, max_len=16)
    c8 = init_cache(CFG, 2, max_len=16, quantized=True)

    def nbytes(t):
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(t))

    # fp32-config cache is 4 B/elem; int8 is 1 B + tiny scales
    assert nbytes(c8) < nbytes(c16) / 3.5
