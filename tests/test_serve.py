"""Serving-engine tests: batched KV-cache generation + collaborative mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costmodel import Channel
from repro.models.transformer import LMConfig, forward, init_lm
from repro.serve.engine import CollaborativeServingEngine, ServingEngine

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="serve-tiny", n_layers=3, d_model=32, n_heads=4, n_kv=2,
               d_ff=64, vocab=64, max_seq=64, remat=False)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(n, plen=6, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, plen).astype(np.int32)
            for _ in range(n)]


def test_engine_matches_unbatched_greedy(params):
    """Batched cached decode == naive argmax over the full forward."""
    eng = ServingEngine(params, CFG, max_batch=2, max_len=32)
    prompts = _prompts(2)
    outs = eng.generate(prompts, max_new_tokens=5)

    for p, got in zip(prompts, outs):
        toks = list(p)
        for _ in range(5):
            logits, _ = forward(params,
                                jnp.asarray([toks], jnp.int32), CFG)
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert toks[len(p):] == got


def test_engine_batches_multiple_calls(params):
    eng = ServingEngine(params, CFG, max_batch=2, max_len=32)
    outs = eng.generate(_prompts(5), max_new_tokens=3)
    assert len(outs) == 5 and all(len(o) == 3 for o in outs)
    assert eng.stats.prefill_calls == 3          # ceil(5/2)
    # each wave needs max_new-1 decode steps: the first output token
    # comes from its prefill, and slots retire before the wasted step
    assert eng.stats.decode_steps == 6


def test_collaborative_engine_close_to_cloud_only(params):
    prompts = _prompts(3, seed=7)
    cloud = ServingEngine(params, CFG, max_batch=4, max_len=32)
    ref = cloud.generate(prompts, max_new_tokens=4)
    collab = CollaborativeServingEngine(params, CFG, cut_layer=1,
                                        channel=Channel.from_kbps(100))
    got = collab.generate(prompts, max_new_tokens=4)
    # int8 edge may flip occasional argmax ties; most tokens agree
    agree = sum(a == b for r, g in zip(ref, got)
                for a, b in zip(r, g))
    total = sum(len(r) for r in ref)
    assert agree / total >= 0.75, (ref, got)
    assert collab.stats.transmitted_bytes > 0
    assert collab.stats.channel_latency_s > 0


def test_collaborative_transmits_int8_blob_size(params):
    from repro.serve.engine import _MSG_BYTES

    collab = CollaborativeServingEngine(params, CFG, cut_layer=0)
    toks = np.stack(_prompts(2, plen=8, seed=3))
    collab.forward(toks)
    # boundary blob: [2, 8, 32] int8 + 8B scale/zp + one message header
    assert collab.stats.transmitted_bytes == 2 * 8 * 32 + 8 + _MSG_BYTES


def test_collaborative_logits_close_to_monolithic(params):
    collab = CollaborativeServingEngine(params, CFG, cut_layer=1)
    toks = np.stack(_prompts(2, plen=8, seed=5))
    got = collab.forward(toks)
    ref, _ = forward(params, jnp.asarray(toks), CFG)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel < 0.15, rel
