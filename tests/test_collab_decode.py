"""Incremental collaborative decode: split-KV-cache equivalence against
the seed recompute-from-scratch path, and O(1) per-token wire traffic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costmodel import Channel
from repro.models import layers as ML
from repro.models import transformer as TF
from repro.models.transformer import LMConfig, forward, init_lm
from repro.serve.engine import CollaborativeServingEngine, ServingEngine

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="collab-tiny", n_layers=3, d_model=32, n_heads=4, n_kv=2,
               d_ff=64, vocab=64, max_seq=64, remat=False)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(n, plen=6, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, plen).astype(np.int32)
            for _ in range(n)]


@pytest.mark.parametrize("cut", [0, 1, 2])
def test_split_cache_logits_match_monolithic(params, cut):
    """Cut-aware prefill + decode over *two* caches (edge prefix, cloud
    suffix sub-ranges) must reproduce the monolithic forward's logits —
    no quantization, pure cache math."""
    b, s = 2, 8
    toks = jnp.asarray(np.stack(_prompts(b, plen=s + 1, seed=4)))
    ref, _ = forward(params, toks, CFG)

    edge, cloud = TF.split_blocks(params, CFG, cut)
    n_edge = cut + 1
    ce = TF.init_cache(CFG, b, max_len=16, layers=n_edge)
    cc = TF.init_cache(CFG, b, max_len=16, layers=CFG.n_layers - n_edge)
    rope = ML.rope_table(16, CFG.hd, base=CFG.rope_base, dtype=CFG.dtype)

    x = ML.embed(params["embed"], toks[:, :s]).astype(CFG.dtype)
    h, ce = TF.run_blocks(edge, x, CFG, rope=rope, cache=ce,
                          cache_index=jnp.int32(0))
    h, cc = TF.run_blocks(cloud, h, CFG, rope=rope, cache=cc,
                          cache_index=jnp.int32(0))
    pre = TF.lm_head(params, h[:, -1:])[:, 0]
    np.testing.assert_allclose(np.asarray(pre), np.asarray(ref[:, s - 1]),
                               rtol=2e-4, atol=2e-4)

    # one incremental step with per-slot position vector
    pos = jnp.full((b,), s, jnp.int32)
    x = ML.embed(params["embed"], toks[:, s:s + 1]).astype(CFG.dtype)
    h, ce = TF.run_blocks(edge, x, CFG, rope=rope, cache=ce, cache_index=pos)
    h, cc = TF.run_blocks(cloud, h, CFG, rope=rope, cache=cc,
                          cache_index=pos)
    step = TF.lm_head(params, h)[:, 0]
    np.testing.assert_allclose(np.asarray(step), np.asarray(ref[:, s]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("cut", [0, 1, 2])
def test_incremental_decode_matches_recompute(params, cut):
    """With quantization noise out of the way (16-bit lattice, fp dense
    edge cache — the INT8 paged default is covered with quant tolerance
    in test_paged_attention), the incremental split-cache decode must
    emit exactly the seed recompute path's greedy tokens — the cache
    refactor is lossless."""
    prompts = _prompts(3)
    inc = CollaborativeServingEngine(params, CFG, cut_layer=cut,
                                     max_batch=3, max_len=32, a_bits=16,
                                     edge_paged=False, edge_int8=False,
                                     cloud_paged=False, cloud_int8=False)
    got = inc.generate(prompts, max_new_tokens=8)
    rec = CollaborativeServingEngine(params, CFG, cut_layer=cut,
                                     max_batch=3, max_len=32, a_bits=16)
    ref = rec.generate_recompute(prompts, max_new_tokens=8)
    assert got == ref


def test_incremental_int8_tracks_recompute(params):
    """At INT8 the two paths see different dynamic-quant granularities
    (per-token delta vs whole-sequence blob), so we require the prefill
    tokens to agree exactly and the streams to mostly agree after."""
    prompts = _prompts(3, seed=2)
    inc = CollaborativeServingEngine(params, CFG, cut_layer=1,
                                     max_batch=3, max_len=32)
    got = inc.generate(prompts, max_new_tokens=6)
    rec = CollaborativeServingEngine(params, CFG, cut_layer=1,
                                     max_batch=3, max_len=32)
    ref = rec.generate_recompute(prompts, max_new_tokens=6)
    assert [g[0] for g in got] == [r[0] for r in ref]
    agree = sum(a == b for r, g in zip(ref, got) for a, b in zip(r, g))
    assert agree / sum(len(r) for r in ref) >= 0.5


@pytest.mark.parametrize("plen", [6, 12])
def test_decode_bytes_per_token_are_O1(params, plen):
    """Every decode step ships the same per-request [1, D] delta (plus
    its Eq.(1) scale/zero-point and one message header) — transmitted
    bytes per generated token do not grow with sequence length, while
    the one-time prefill blob is O(S)."""
    from repro.serve.engine import _MSG_BYTES

    b = 3
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=b,
                                     max_len=32,
                                     channel=Channel.from_kbps(100))
    eng.generate(_prompts(b, plen=plen), max_new_tokens=8)
    per_step = b * (CFG.d_model + 8) + _MSG_BYTES
    # 8 tokens = 1 from prefill + 7 decode steps, each the same delta
    assert eng.stats.decode_bytes_log == [per_step] * 7
    assert eng.stats.prefill_bytes == b * (plen * CFG.d_model + 8) \
        + _MSG_BYTES
    assert eng.stats.bytes_per_decode_token() == \
        pytest.approx(per_step / b)
    # and the recompute path really is O(S) per token, for contrast
    rec = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=b,
                                     max_len=32)
    rec.generate_recompute(_prompts(b, plen=plen), max_new_tokens=8)
    assert rec.stats.transmitted_bytes > eng.stats.transmitted_bytes


def test_continuous_batching_mixed_lengths(params):
    """Slot scheduler: different prompt lengths join mid-flight as slots
    free up; every request still matches the naive uncached greedy."""
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, CFG.vocab, l).astype(np.int32)
               for l in (5, 8, 5, 11, 8)]
    eng = ServingEngine(params, CFG, max_batch=2, max_len=32)
    outs = eng.generate(prompts, max_new_tokens=4)
    for p, got in zip(prompts, outs):
        toks = list(p)
        for _ in range(4):
            logits, _ = forward(params, jnp.asarray([toks], jnp.int32), CFG)
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert toks[len(p):] == got


def test_collab_continuous_batching_frees_slots(params):
    """The collaborative engine rides the same scheduler: more requests
    than slots drain through with split caches intact."""
    prompts = _prompts(5, seed=6)
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=2,
                                     max_len=32, a_bits=16,
                                     edge_paged=False, edge_int8=False,
                                     cloud_paged=False, cloud_int8=False)
    outs = eng.generate(prompts, max_new_tokens=3)
    rec = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=5,
                                     max_len=32, a_bits=16)
    ref = rec.generate_recompute(prompts, max_new_tokens=3)
    assert len(outs) == 5 and all(len(o) == 3 for o in outs)
    assert eng.stats.prefill_calls == 3          # 2 + 2 + 1 admissions
    assert outs == ref
    # idle slots are never charged to the wire: the last request decodes
    # alone, and its rounds' uplinks carry exactly one per-request delta
    # (int16 lattice at a_bits=16) + the message header
    from repro.serve.engine import _MSG_BYTES
    assert eng.stats.decode_bytes_log[-1] == (2 * CFG.d_model + 8) \
        + _MSG_BYTES


def test_timed_mode_populates_phase_latency(params):
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=2,
                                     max_len=32, timed=True)
    eng.generate(_prompts(2), max_new_tokens=3)
    assert eng.stats.prefill_s > 0.0
    assert eng.stats.decode_s > 0.0
    # 2 requests x (3 tokens = 1 prefill + 2 decode steps)
    assert eng.stats.prefill_tokens == 12 and eng.stats.decode_tokens == 4
