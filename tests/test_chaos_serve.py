"""The reliability layer: fault injection, reliable transport, and
edge-only graceful degradation with cloud resync.

Covers: the ``FaultyChannel`` fault model (scripted + seeded modes,
outage windows, the naive blocking baseline semantics), the message
checksum, ``ReliableTransport`` deadlines/retries/backoff and the
``CloudUnreachable`` escalation, the loss-rate EWMA feeding the
costmodel's expected-retransmit pricing, the telemetry input guards
(zero-duration samples, bandwidth ceiling), ``AdaptivePolicy``
flap-damping (``min_dwell``), and the ``ResilientCollaborativeEngine``
end to end: edge-only streaming through a cloud outage, both resync
flavors (mid-stream replay and outage-admitted calibrating prefill),
keep-the-result downlink-loss semantics, and the headline property —
in the lossless ``a_bits=None`` mode the greedy stream under any
seeded fault schedule is bit-identical to the fault-free stream."""
import jax
import numpy as np
import pytest

from repro.core.costmodel import (CLOUD_TITANXP_CLASS, EDGE_TX2_CLASS,
                                  Channel, collab_decode_step_time)
from repro.models.transformer import LMConfig, init_lm
from repro.serve import (CollaborativeServingEngine, CloudUnreachable,
                         FaultOutcome, FaultyChannel, LinkTelemetry,
                         ReliableTransport, ResilientCollaborativeEngine)
from repro.serve.policy import AdaptivePolicy
from repro.serve.transport import (_MSG_BYTES, DriftingChannel, ServeStats,
                                   checksum)

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="chaos-tiny", n_layers=3, d_model=32, n_heads=4, n_kv=2,
               d_ff=64, vocab=64, max_seq=64, remat=False)
PAGE = 8
LOSSLESS_FP = dict(a_bits=None, edge_int8=False, cloud_int8=False,
                   page_size=PAGE, max_batch=2, max_len=64)
BASE_CH = Channel.from_kbps(500, rtt_ms=10)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, l).astype(np.int32) for l in lens]


# ---------------------------------------------------------------------------
# FaultyChannel: the fault model itself
# ---------------------------------------------------------------------------


def test_faulty_channel_scripted_events():
    ch = FaultyChannel(BASE_CH, script=["drop", "corrupt", "stall", "ok"],
                       stall_s=0.5)
    drop = ch.attempt(1000)
    assert drop == FaultOutcome(False, False, 0.0, "drop")
    assert ch.clock_s == 0.0                 # a silent drop costs nothing
    corrupt = ch.attempt(1000)
    assert corrupt.delivered and corrupt.corrupt and corrupt.kind == "corrupt"
    base_t = BASE_CH.transfer_time(1000)
    assert corrupt.seconds == pytest.approx(base_t)
    stall = ch.attempt(1000)
    assert stall.delivered and not stall.corrupt
    assert stall.seconds == pytest.approx(base_t + 0.5)
    ok = ch.attempt(1000)
    assert ok == FaultOutcome(True, False, ok.seconds, "ok")
    assert ch.clock_s == pytest.approx(3 * base_t + 0.5)
    assert ch.attempts == 4
    assert ch.faults == {"drop": 1, "corrupt": 1, "stall": 1, "outage": 0}


def test_faulty_channel_seeded_is_deterministic():
    kw = dict(seed=7, drop_p=0.3, corrupt_p=0.2, stall_p=0.2)
    a = FaultyChannel(BASE_CH, **kw)
    b = FaultyChannel(BASE_CH, **kw)
    kinds_a = [a.attempt(100).kind for _ in range(50)]
    kinds_b = [b.attempt(100).kind for _ in range(50)]
    assert kinds_a == kinds_b
    assert {"drop", "corrupt", "stall"} <= set(kinds_a)


def test_faulty_channel_outage_window():
    ch = FaultyChannel(BASE_CH, seed=0, outages=[(0.1, 0.4)])
    assert not ch.in_outage()
    ok = ch.attempt(50_000)                  # advances the clock into the
    assert ok.delivered and 0.1 < ch.clock_s < 0.4      # window
    assert ch.in_outage() and ch.outage_end() == 0.4
    out = ch.attempt(100)
    assert out.kind == "outage" and not out.delivered and out.seconds == 0.0
    ch.wait(0.4 - ch.clock_s)
    assert not ch.in_outage() and ch.outage_end() is None
    assert ch.attempt(100).delivered
    assert ch.faults["outage"] == 1


def test_faulty_channel_naive_transfer_blocks_through_outage():
    """The baseline semantics: ``transfer_time`` retries until delivery,
    so an outage stalls the caller for the rest of the window."""
    ch = FaultyChannel(BASE_CH, seed=0, outages=[(0.0, 2.0)], rto_s=0.25)
    t = ch.transfer_time(1000)
    assert t >= 2.0                          # paid the whole window
    assert ch.clock_s >= 2.0 and not ch.in_outage()
    # and with no faults it is exactly the base channel
    clean = FaultyChannel(BASE_CH, seed=0)
    assert clean.transfer_time(1000) == pytest.approx(
        BASE_CH.transfer_time(1000))


def test_faulty_channel_syncs_drifting_base_clock():
    fast = Channel.from_kbps(1000, rtt_ms=1)
    slow = Channel.from_kbps(10, rtt_ms=100)
    ch = FaultyChannel(DriftingChannel([(0.0, fast), (0.5, slow)]), seed=0)
    assert ch.attempt(1000).seconds == pytest.approx(fast.transfer_time(1000))
    ch.wait(1.0)                             # wrapper clock drives the drift
    assert ch.attempt(1000).seconds == pytest.approx(slow.transfer_time(1000))
    assert "faulty[" in ch.name


def test_checksum_detects_corruption():
    blob = np.arange(256, dtype=np.int8)
    c = checksum(blob)
    assert c == checksum(np.arange(256, dtype=np.int8))
    flipped = blob.copy()
    flipped[17] ^= 1
    assert checksum(flipped) != c
    assert checksum(blob.tobytes()) == c


# ---------------------------------------------------------------------------
# ReliableTransport: deadlines, retries, escalation
# ---------------------------------------------------------------------------


def test_reliable_transport_retries_through_drops():
    ch = FaultyChannel(BASE_CH, script=["drop", "drop", "ok"])
    tr = ReliableTransport(ch, max_retries=3, fallback_deadline_s=0.2)
    stats = ServeStats()
    tr.charge(stats, 1000, phase="decode", log=False)
    assert stats.retries == 2 and stats.timeouts == 2
    assert stats.corrupt_msgs == 0
    assert stats.transmitted_bytes == 1000
    # two deadline waits + two backoffs + the delivery all cost time
    assert stats.channel_latency_s > 2 * 0.2
    assert tr.telemetry.loss_rate > 0.0
    assert tr.seq == 1                       # retransmits reuse the seq


def test_reliable_transport_corrupt_resends_immediately():
    ch = FaultyChannel(BASE_CH, script=["corrupt", "ok"])
    tr = ReliableTransport(ch, fallback_deadline_s=0.5)
    stats = ServeStats()
    tr.charge(stats, 1000, phase="decode", log=False)
    assert stats.corrupt_msgs == 1 and stats.timeouts == 0
    assert stats.retries == 1
    # no deadline wait: just two transfers plus one backoff
    assert stats.channel_latency_s < 2 * BASE_CH.transfer_time(1000) + 0.1


def test_reliable_transport_raises_cloud_unreachable():
    ch = FaultyChannel(BASE_CH, seed=0, outages=[(0.0, 100.0)])
    tr = ReliableTransport(ch, max_retries=2, fallback_deadline_s=0.1)
    stats = ServeStats()
    with pytest.raises(CloudUnreachable):
        tr.charge(stats, 1000, phase="decode", log=False)
    assert stats.timeouts == 3 and stats.retries == 2
    assert stats.channel_latency_s > 3 * 0.1   # the waiting is still charged
    assert ch.clock_s > 0.3


def test_reliable_transport_deadline_tracks_telemetry():
    tr = ReliableTransport(FaultyChannel(BASE_CH, seed=0),
                           deadline_margin=3.0, fallback_deadline_s=0.5)
    assert tr.deadline_for(10_000) == 0.5    # fallback until the fit locks
    for n in (100, 5000, 300, 20000, 64, 1000):
        tr.telemetry.observe_transfer(n, BASE_CH.transfer_time(n))
    want = 3.0 * (10_000 / tr.telemetry.bandwidth_bytes_per_s
                  + tr.telemetry.rtt_s)
    assert tr.deadline_for(10_000) == pytest.approx(want, rel=0.01)
    assert tr.deadline_for(0) >= tr.min_deadline_s


def test_reliable_transport_degenerates_on_plain_channel():
    """No ``attempt`` method → the base transport, bit for bit."""
    tr = ReliableTransport(BASE_CH)
    stats = ServeStats()
    tr.charge(stats, 1000, phase="decode", log=False)
    assert stats.retries == stats.timeouts == 0
    assert stats.channel_latency_s == pytest.approx(
        BASE_CH.transfer_time(1000))
    ok, spent = tr.probe(stats)
    assert ok and spent == 0.0


def test_reliable_transport_probe():
    ch = FaultyChannel(BASE_CH, seed=0, outages=[(0.0, 0.3)])
    tr = ReliableTransport(ch, fallback_deadline_s=0.2)
    stats = ServeStats()
    ok, spent = tr.probe(stats)
    assert not ok and spent == pytest.approx(0.2)   # waited one deadline
    assert stats.timeouts == 1
    assert ch.clock_s == pytest.approx(0.2)
    ok, _ = tr.probe(stats)                  # still inside the window
    assert not ok and ch.clock_s == pytest.approx(0.4)
    ok, spent = tr.probe(stats)              # window closed: heartbeat lands
    assert ok and spent == pytest.approx(BASE_CH.transfer_time(_MSG_BYTES))


# ---------------------------------------------------------------------------
# Telemetry guards + loss-rate pricing (satellites)
# ---------------------------------------------------------------------------


def test_telemetry_rejects_zero_duration_samples():
    tel = LinkTelemetry()
    ch = Channel.from_kbps(250, rtt_ms=40)
    for n in (100, 5000, 300, 20000):
        tel.observe_transfer(n, ch.transfer_time(n))
    bw = tel.bandwidth_bytes_per_s
    assert bw == pytest.approx(250e3, rel=0.05)
    for _ in range(50):                      # an infinite-bandwidth burst
        tel.observe_transfer(4096, 0.0)      # must not poison the fit
        tel.observe_transfer(0, 0.01)
    assert tel.bandwidth_bytes_per_s == bw


def test_telemetry_clamps_bandwidth_ceiling():
    tel = LinkTelemetry()
    for n in (100, 5000, 300, 20000, 64, 1000):
        tel.observe_transfer(n, n * 1e-16 + 0.01)    # ~10 PB/s slope
    assert tel.bandwidth_bytes_per_s == tel.BW_CEILING_BYTES_PER_S


def test_loss_rate_ewma_and_expected_retx_pricing():
    tel = LinkTelemetry()
    assert tel.loss_rate == 0.0
    for _ in range(40):
        tel.observe_delivery(True)
        tel.observe_delivery(False)
    assert tel.loss_rate == pytest.approx(0.5, abs=0.15)
    # the estimated channel carries the loss even before the bw fit locks
    est = tel.channel(BASE_CH)
    assert est.bandwidth_bytes_per_s == BASE_CH.bandwidth_bytes_per_s
    assert est.loss_rate == tel.loss_rate
    # and the costmodel prices it as expected retransmissions
    assert Channel(bandwidth_bytes_per_s=1e6,
                   loss_rate=0.5).expected_retx() == pytest.approx(2.0)
    assert Channel(bandwidth_bytes_per_s=1e6,
                   loss_rate=0.999).expected_retx() == pytest.approx(20.0)
    kw = dict(edge_flops=1e7, cloud_flops=5e7, blob_bytes=1000.0,
              return_bytes=16.0, edge=EDGE_TX2_CLASS,
              cloud=CLOUD_TITANXP_CLASS)
    clean = collab_decode_step_time(channel=Channel(
        bandwidth_bytes_per_s=1e6, rtt_s=0.01), **kw)
    lossy = collab_decode_step_time(channel=Channel(
        bandwidth_bytes_per_s=1e6, rtt_s=0.01, loss_rate=0.5), **kw)
    assert lossy.channel_s == pytest.approx(2.0 * clean.channel_s)


def test_policy_min_dwell_damps_flapping():
    """After recommending a switch the policy must hold the new config
    for ``min_dwell`` ticks even if the engine has not adopted it."""
    slow = Channel.from_kbps(100, rtt_ms=80)     # optimum is k > 1
    pol = AdaptivePolicy(CFG, batch=4, cuts=None, fallback_channel=slow,
                         min_dwell=2)
    tel = LinkTelemetry()
    d = pol.decide(tel, cut=1, spec_k=1)
    assert d.spec_k > 1                      # the switch that starts the hold
    for _ in range(2):                       # inside the dwell window
        d = pol.decide(tel, cut=1, spec_k=1)
        assert d.spec_k == 1
    d = pol.decide(tel, cut=1, spec_k=1)     # window over: recommended again
    assert d.spec_k > 1
    # with min_dwell=0 (default) the recommendation repeats every tick
    free = AdaptivePolicy(CFG, batch=4, cuts=None, fallback_channel=slow)
    assert free.decide(tel, cut=1, spec_k=1).spec_k > 1
    assert free.decide(tel, cut=1, spec_k=1).spec_k > 1


# ---------------------------------------------------------------------------
# ResilientCollaborativeEngine: degradation + resync, end to end
# ---------------------------------------------------------------------------


def _resilient(params, fch, *, spec_k=1, tight=False, **over):
    kw = dict(LOSSLESS_FP)
    kw.update(over)
    tr = ReliableTransport(fch, max_retries=1, fallback_deadline_s=0.1) \
        if tight else ReliableTransport(fch)
    return ResilientCollaborativeEngine(params, CFG, cut_layer=1,
                                        spec_k=spec_k, channel=fch,
                                        transport=tr, **kw)


@pytest.fixture(scope="module")
def oracle_stream(params):
    """The fault-free lossless greedy stream every chaos run must match."""
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1, spec_k=1,
                                     channel=BASE_CH, **LOSSLESS_FP)
    def run(lens, seed, max_new):
        return eng.generate(_prompts(lens, seed), max_new_tokens=max_new)
    return run


def test_edge_only_stream_through_outage_is_bit_identical(
        params, oracle_stream):
    """Mid-stream outage: the engine degrades to the draft suffix, keeps
    committing, resyncs on reconnect — and in lossless mode the stream
    is the fault-free stream, bit for bit."""
    fch = FaultyChannel(BASE_CH, seed=3, outages=[(0.05, 0.6)])
    eng = _resilient(params, fch)
    got = eng.generate(_prompts((9, 7, 11), seed=2), max_new_tokens=12)
    assert got == oracle_stream((9, 7, 11), 2, 12)
    s = eng.stats
    assert s.edge_only_tokens > 0 and s.resyncs == 1
    assert s.outage_s > 0.0 and not eng.cloud_down
    assert eng.trace_counts["edge_only"] >= 1
    assert eng.trace_counts["resync"] >= 1
    # the availability trace shows committed tokens while down
    down_rounds = [r for r in eng.round_log if r["cloud_down"]]
    assert down_rounds and all(r["committed"] > 0 for r in down_rounds)


def test_outage_admission_uses_calibrating_resync(params, oracle_stream):
    """Requests admitted *during* the outage never met the cloud; the
    resync must rebuild their cloud KV from position 0 (the calibrating
    prefill flavor) and the stream still matches the oracle."""
    fch = FaultyChannel(BASE_CH, seed=5, outages=[(0.0, 1.2)])
    eng = _resilient(params, fch, spec_k=2, tight=True)
    got = eng.generate(_prompts((9, 9, 9, 9), seed=0), max_new_tokens=12)
    assert got == oracle_stream((9, 9, 9, 9), 0, 12)
    s = eng.stats
    assert s.edge_only_tokens > 0 and s.resyncs >= 1
    assert eng.trace_counts["resync"] >= 1 and not eng.cloud_down
    # the cloud came back mid-run: spec rounds resumed after the resync
    assert s.spec_rounds > 0


def test_spec_rounds_survive_heavy_drops(params, oracle_stream):
    fch = FaultyChannel(BASE_CH, seed=11, drop_p=0.15)
    eng = _resilient(params, fch, spec_k=4)
    got = eng.generate(_prompts((9, 7), seed=4), max_new_tokens=10)
    assert got == oracle_stream((9, 7), 4, 10)
    s = eng.stats
    assert s.retries > 0 and s.timeouts > 0
    assert s.resyncs == 0                    # retries absorbed every drop
    assert eng.telemetry.loss_rate > 0.0


def test_post_recovery_wave_runs_normal_protocol(params, oracle_stream):
    fch = FaultyChannel(BASE_CH, seed=5, outages=[(0.0, 0.5)])
    eng = _resilient(params, fch, spec_k=2, tight=True)
    eng.generate(_prompts((9, 9), seed=6), max_new_tokens=12)
    assert not eng.cloud_down
    before_spec = eng.stats.spec_rounds
    before_edge = eng.stats.edge_only_tokens
    got = eng.generate(_prompts((7, 7), seed=7), max_new_tokens=6)
    assert got == oracle_stream((7, 7), 7, 6)
    assert eng.stats.spec_rounds > before_spec   # clean wave: verify rounds
    assert eng.stats.edge_only_tokens == before_edge  # nothing degraded


def test_int8_mode_survives_corruption_and_outage(params):
    """The default INT8 deployment has no bitwise oracle, but the chaos
    run must complete, count its faults, and come back up."""
    fch = FaultyChannel(BASE_CH, seed=9, corrupt_p=0.3,
                        outages=[(0.05, 0.35)])
    eng = ResilientCollaborativeEngine(
        params, CFG, cut_layer=1, spec_k=2, channel=fch,
        transport=ReliableTransport(fch, max_retries=1,
                                    fallback_deadline_s=0.1),
        page_size=PAGE, max_batch=2, max_len=64)
    out = eng.generate(_prompts((9, 7, 8), seed=8), max_new_tokens=16)
    assert all(len(o) == 16 for o in out)
    s = eng.stats
    assert s.corrupt_msgs > 0
    assert s.edge_only_tokens > 0 and s.resyncs >= 1 and not eng.cloud_down
    assert s.report()["edge_only_tokens"] == s.edge_only_tokens


def test_naive_engine_stalls_through_outage(params):
    """The baseline the chaos benchmark measures against: the plain
    engine's blocking channel pays the whole outage as latency."""
    fch = FaultyChannel(BASE_CH, seed=0, outages=[(0.05, 1.5)], rto_s=0.2)
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1, spec_k=1,
                                     channel=fch, **LOSSLESS_FP)
    eng.generate(_prompts((9, 7), seed=2), max_new_tokens=8)
    assert eng.stats.channel_latency_s >= 1.4    # ate the window
    assert fch.faults["outage"] > 0


# the headline property, guarded like the rest of the tier-1 suite
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @pytest.fixture(scope="module")
    def chaos_engine(params):
        """One reusable resilient engine; each example swaps in a fresh
        fault schedule (keeps the jit cache warm across examples)."""
        return _resilient(params, FaultyChannel(BASE_CH, seed=0), spec_k=2,
                          tight=True)

    @settings(max_examples=10, deadline=None)
    @given(drop_p=st.floats(min_value=0.0, max_value=0.3),
           out_start=st.floats(min_value=0.0, max_value=0.5),
           out_len=st.floats(min_value=0.3, max_value=2.0),
           plens=st.lists(st.integers(min_value=5, max_value=18),
                          min_size=1, max_size=4),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_lossless_stream_identical_under_any_fault_schedule(
            params, chaos_engine, oracle_stream, drop_p, out_start, out_len,
            plens, seed):
        """Any seeded drop rate, any single outage window, reconnect or
        not: the lossless greedy stream is the fault-free stream."""
        eng = chaos_engine
        eng.channel = FaultyChannel(
            BASE_CH, seed=seed, drop_p=drop_p,
            outages=[(out_start, out_start + out_len)])
        eng.stats = ServeStats()
        eng.round_log.clear()
        eng.cloud_down, eng._down_since = False, None
        eng._rounds_down, eng._replay = 0, {}
        got = eng.generate(_prompts(plens, seed % 97), max_new_tokens=8)
        assert got == oracle_stream(tuple(plens), seed % 97, 8)
        assert all(len(g) == 8 for g in got)
else:
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_lossless_stream_identical_under_any_fault_schedule():
        pass
