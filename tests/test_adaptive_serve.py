"""The serve/ package decomposition and the online auto-tuning loop.

Covers: the re-export shims of the split package, per-message framing
folded into the costmodel (predictions == engine accounting), the EWMA
link telemetry (recovers bandwidth/RTT from traffic, tracks drift,
holds through degenerate traffic), the AdaptivePolicy decision rules
(channel-dependent k, hysteresis on both switches), the prequantized
multi-cut weight bank, spec_k="auto" self-correcting from measured
acceptance between requests, mid-stream re-partitions via the drain
barrier, and the benchmark-drift guard.  A hypothesis property test
sweeps (switch round x cut x draft lengths x page-straddling prompt
lengths) and requires the lossless-fp greedy streams to be bit-exactly
the fixed-cut ones."""
import jax
import numpy as np
import pytest

from repro.core.autotune import (lm_round_args, spec_k_for_lm, tune_cut_and_k,
                                 tune_spec_k)
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, EDGE_TX2_CLASS,
                                  Channel, MSG_BYTES, collab_decode_step_time,
                                  speculative_round_time)
from repro.models.transformer import LMConfig, init_lm
from repro.serve.engine import (AdaptivePolicy, CollaborativeServingEngine,
                                Decision, DriftingChannel, LinkTelemetry,
                                SamplingParams, _MSG_BYTES, _QP_BYTES,
                                _TOK_BYTES)
from repro.serve.policy import _CutBank

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="adapt-tiny", n_layers=3, d_model=32, n_heads=4, n_kv=2,
               d_ff=64, vocab=64, max_seq=64, remat=False)
PAGE = 8
# lossless boundary + fp caches: the greedy stream is bitwise
# independent of the cut, so re-partitions must be output-transparent
LOSSLESS_FP = dict(a_bits=None, edge_int8=False, cloud_int8=False,
                   page_size=PAGE)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, l).astype(np.int32) for l in lens]


# ---------------------------------------------------------------------------
# Package decomposition: shims + module budget
# ---------------------------------------------------------------------------


def test_engine_module_reexports_whole_surface():
    """Historical ``from repro.serve.engine import X`` paths must keep
    working after the package split, and ``repro.serve`` exposes the
    same public names."""
    import repro.serve as pkg
    import repro.serve.engine as eng
    for name in ("ServingEngine", "CollaborativeServingEngine",
                 "PageAllocator", "ServeStats", "Request", "Transport",
                 "LinkTelemetry", "DriftingChannel", "AdaptivePolicy",
                 "Decision"):
        assert getattr(eng, name) is getattr(pkg, name)
    assert eng._MSG_BYTES == int(MSG_BYTES)
    # internals tests/benchmarks reach into keep resolving too
    from repro.serve.engine import (_PagedPool, _SlotEngine,  # noqa: F401
                                    _bucket_len, _jit_phase)


def test_serve_modules_stay_small():
    """The decomposition contract: no serve/ module above ~500 lines."""
    from pathlib import Path
    import repro.serve
    pkg_dir = Path(repro.serve.__file__).parent
    for f in pkg_dir.glob("*.py"):
        n = len(f.read_text().splitlines())
        assert n <= 560, f"{f.name} has {n} lines (budget ~500)"


# ---------------------------------------------------------------------------
# Framing folded into the costmodel (open ROADMAP item)
# ---------------------------------------------------------------------------


def test_step_model_charges_message_framing():
    ch = Channel.from_kbps(100, rtt_ms=10)
    kw = dict(edge_flops=1e7, cloud_flops=5e7, blob_bytes=1000.0,
              edge=EDGE_TX2_CLASS, cloud=CLOUD_TITANXP_CLASS, channel=ch,
              return_bytes=16.0)
    step = collab_decode_step_time(**kw)
    assert step.channel_s == pytest.approx(
        ch.transfer_time(1000.0 + MSG_BYTES)
        + ch.transfer_time(16.0 + MSG_BYTES))
    # k=1 speculative round still recovers the step model exactly
    rnd = speculative_round_time(k=1, acceptance=0.5, rows=4, **kw)
    assert rnd.channel_s == step.channel_s
    assert rnd.decode_s == step.decode_s


def test_round_prediction_matches_engine_wire_accounting(params):
    """The costmodel's per-round uplink/downlink byte totals must equal
    what ``ServeStats`` measures for the same (batch, k) — the framing
    satellite's whole point."""
    k, b = 4, 2
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=b,
                                     max_len=64, page_size=PAGE, spec_k=k,
                                     channel=Channel.from_kbps(100))
    eng.generate(_prompts((9, 9), seed=1), max_new_tokens=6)
    s = eng.stats
    args = lm_round_args(CFG, 1, batch=b)
    model_uplink = k * args["blob_bytes"] + (k - 1) * _TOK_BYTES * b \
        + MSG_BYTES
    assert s.decode_bytes == s.decode_steps * model_uplink
    # downlink: corrected token + byte-packed mask + header per round
    per_down = b * (_TOK_BYTES + 1) + _MSG_BYTES
    assert s.decode_downlink_bytes == s.decode_steps * per_down
    assert args["blob_bytes"] == b * (CFG.d_model + _QP_BYTES)


# ---------------------------------------------------------------------------
# Link telemetry
# ---------------------------------------------------------------------------


def _feed(tel, ch, sizes, repeats=1):
    for _ in range(repeats):
        for n in sizes:
            tel.observe_transfer(n, ch.transfer_time(n))


def test_telemetry_recovers_bandwidth_and_rtt():
    tel = LinkTelemetry()
    ch = Channel.from_kbps(250, rtt_ms=40)
    _feed(tel, ch, (100, 5000, 300, 20000, 64, 1000), repeats=3)
    assert tel.bandwidth_bytes_per_s == pytest.approx(250e3, rel=0.05)
    assert tel.rtt_s == pytest.approx(0.04, rel=0.05)
    est = tel.channel(Channel(bandwidth_bytes_per_s=1.0))
    assert est.bandwidth_bytes_per_s == pytest.approx(250e3, rel=0.05)


def test_telemetry_tracks_drift():
    tel = LinkTelemetry()
    _feed(tel, Channel.from_kbps(2000, rtt_ms=5),
          (100, 5000, 300, 20000), repeats=3)
    assert tel.rtt_s < 0.01
    _feed(tel, Channel.from_kbps(200, rtt_ms=150),
          (100, 5000, 300, 20000), repeats=6)
    assert tel.rtt_s == pytest.approx(0.15, rel=0.25)
    assert tel.bandwidth_bytes_per_s < 400e3


def test_telemetry_holds_estimate_on_degenerate_traffic():
    tel = LinkTelemetry()
    ch = Channel.from_kbps(500, rtt_ms=20)
    _feed(tel, ch, (64, 4000, 900, 12000), repeats=3)
    bw = tel.bandwidth_bytes_per_s
    _feed(tel, ch, (500,), repeats=50)     # one message size: no slope
    assert tel.bandwidth_bytes_per_s == pytest.approx(bw, rel=0.2)


def test_telemetry_acceptance_ewma():
    tel = LinkTelemetry()
    assert tel.acceptance(0.7) == 0.7      # prior until a round reports
    tel.observe_round(10, 9)
    assert tel.acceptance() == pytest.approx(0.9)
    for _ in range(30):
        tel.observe_round(10, 3)
    assert tel.acceptance() == pytest.approx(0.3, abs=0.05)


def test_drifting_channel_follows_schedule():
    fast = Channel.from_kbps(1000, rtt_ms=1)
    slow = Channel.from_kbps(10, rtt_ms=100)
    ch = DriftingChannel([(0.0, fast), (0.5, slow)])
    t0 = ch.transfer_time(1000)
    assert t0 == pytest.approx(fast.transfer_time(1000))
    while ch.clock_s < 0.5:
        ch.transfer_time(100_000)
    assert ch.transfer_time(1000) == pytest.approx(slow.transfer_time(1000))
    assert "10KB/s" in ch.name


# ---------------------------------------------------------------------------
# Policy decisions
# ---------------------------------------------------------------------------


def test_policy_picks_k_by_channel():
    fast = AdaptivePolicy(CFG, batch=4, cuts=(0, 1),
                          fallback_channel=Channel.from_kbps(100000))
    d = fast.decide(LinkTelemetry(), cut=0, spec_k=1)
    assert d.spec_k == 1 and d.cut == 0
    slow = AdaptivePolicy(CFG, batch=4, cuts=(0, 1),
                          fallback_channel=Channel.from_kbps(100, rtt_ms=80))
    d = slow.decide(LinkTelemetry(), cut=0, spec_k=1)
    assert d.spec_k > 1


def test_policy_hysteresis_keeps_running_config():
    """A cut whose predicted win is marginal must not trigger the drain
    barrier; an equal-k config never flaps."""
    ch = Channel.from_kbps(100, rtt_ms=80)
    pol = AdaptivePolicy(CFG, batch=4, cuts=(0, 1), fallback_channel=ch)
    d1 = pol.decide(LinkTelemetry(), cut=0, spec_k=1)
    # adopt the decision, then re-decide: nothing should change
    d2 = pol.decide(LinkTelemetry(), cut=d1.cut, spec_k=d1.spec_k)
    assert (d2.cut, d2.spec_k) == (d1.cut, d1.spec_k)
    assert len(pol.history) == 1           # only the first change logged
    # the model's cut preference at high k is a hair's width — far
    # below cut_hysteresis — so the policy must stay on either cut
    best, grid = tune_cut_and_k(CFG, batch=4, channel=ch, cuts=(0, 1),
                                ks=pol.ks)
    for cut in (0, 1):
        d = pol.decide(LinkTelemetry(), cut=cut, spec_k=best.k)
        assert d.cut == cut


def test_policy_k_only_mode_ignores_cut():
    pol = AdaptivePolicy(CFG, batch=2, cuts=None,
                         fallback_channel=Channel.from_kbps(50, rtt_ms=100))
    d = pol.decide(LinkTelemetry(), cut=1, spec_k=1)
    assert d.cut == 1 and d.spec_k > 1


# ---------------------------------------------------------------------------
# Prequantized multi-cut weight bank
# ---------------------------------------------------------------------------


def test_cut_bank_prequantizes_once_and_shares_lattice(params):
    from repro.models.layers import QuantCtx
    ctx = QuantCtx(mode="dynamic", a_bits=8)
    bank = _CutBank(params, CFG, cuts=(0, 1), deploy_qctx=ctx)
    e0, c0, d0 = bank.get(0)
    e1, c1, d1 = bank.get(1)
    raw = params["blocks"]["attn"]["wq"]["w"]
    # edge weights sit on the per-layer deployment lattice (exactly the
    # thresholds the runtime scan would have computed); cloud stays fp
    np.testing.assert_array_equal(np.asarray(e0["attn"]["wq"]["w"][0]),
                                  np.asarray(ctx.weight("w", raw[0])))
    np.testing.assert_array_equal(np.asarray(c0["attn"]["wq"]["w"][0]),
                                  np.asarray(raw[1]))
    # every cut serves the identical quantized block values (layer 1
    # appears in cut-1's prefix and in cut-0's draft suffix)
    np.testing.assert_array_equal(np.asarray(e1["attn"]["wq"]["w"][1]),
                                  np.asarray(d0["attn"]["wq"]["w"][0]))
    with pytest.raises(KeyError):
        bank.get(2)


def test_cut_bank_lossless_mode_keeps_fp_weights(params):
    bank = _CutBank(params, CFG, cuts=(0,), deploy_qctx=None)
    e0, _, _ = bank.get(0)
    np.testing.assert_array_equal(
        np.asarray(e0["attn"]["wq"]["w"][0]),
        np.asarray(params["blocks"]["attn"]["wq"]["w"][0]))


# ---------------------------------------------------------------------------
# spec_k="auto" self-corrects from measured acceptance between requests
# ---------------------------------------------------------------------------


def test_spec_k_auto_self_corrects_between_requests(params):
    ch = Channel.from_kbps(100, rtt_ms=50)
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=2,
                                     max_len=64, page_size=PAGE,
                                     spec_k="auto", channel=ch)
    k0 = eng.spec_k
    assert k0 > 1                          # offline tune at the prior
    assert eng.policy is not None and eng.policy.k_between_requests_only
    # the measured draft quality collapses: between requests the tuner
    # re-runs at the tracked acceptance and k falls back to 1
    eng.telemetry.observe_round(1000, 0)
    assert eng._policy_tick(2) is False    # live requests: deferred
    assert eng.spec_k == k0
    eng._policy_tick(0)                    # drained: between requests
    want = spec_k_for_lm(CFG, 1, batch=2, channel=ch, acceptance=0.0,
                         ks=eng.policy.ks)[0].k
    assert eng.spec_k == want == 1
    assert eng.stats.spec_k_switches == 1
    # and recovers when the drafts grade well again
    for _ in range(60):
        eng.telemetry.observe_round(10, 10)
    eng._policy_tick(0)
    assert eng.spec_k == spec_k_for_lm(
        CFG, 1, batch=2, channel=ch,
        acceptance=eng.telemetry.acceptance(), ks=eng.policy.ks)[0].k > 1


def test_stochastic_acceptance_drives_k_retune_without_recompile(params):
    """Sampled (temperature>0) traffic grades drafts by rejection
    sampling, so the telemetry's acceptance EWMA measures the
    *stochastic* accept rate.  When it collapses, the between-requests
    re-tune steps spec_k down to exactly what ``tune_spec_k`` prices at
    the measured rate; when it recovers, switching back to an
    already-exercised k re-uses every compiled phase — zero new
    traces."""
    ch = Channel.from_kbps(100, rtt_ms=50)
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1, max_batch=2,
                                     max_len=64, page_size=PAGE,
                                     spec_k="auto", channel=ch)
    k0 = eng.spec_k
    assert k0 > 1
    sp = SamplingParams(temperature=1.5, seed=3)
    eng.generate(_prompts((6, 7)), max_new_tokens=10, sampling=sp)
    # rejection grading feeds the same EWMA the greedy verify does (on
    # this tiny model the int8 drafter tracks the fp suffix so closely
    # that the measured stochastic rate stays ~1 — the collapse below is
    # injected, modelling a drafter that diverges on real traffic)
    assert eng.telemetry.n_rounds > 0       # stochastic grading observed
    # all-rejected rounds are first-class samples (see
    # transport.observe_round): a run of them drives the EWMA to 0 and
    # the drained tick re-tunes to the measured rate
    for _ in range(80):
        eng.telemetry.observe_round(10, 0)
    assert eng.telemetry.acceptance() < 0.01
    eng._policy_tick(0)
    want = spec_k_for_lm(CFG, 1, batch=2, channel=ch,
                         acceptance=eng.telemetry.acceptance(),
                         ks=eng.policy.ks)[0].k
    assert eng.spec_k == want == 1
    assert eng.stats.spec_k_switches == 1
    eng.generate(_prompts((6, 7), seed=1), max_new_tokens=6, sampling=sp)
    # recovery: retune lands on some k > 1; warm it once, then a repeat
    # workload at the same (k, shapes) must not trace anything new
    for _ in range(80):
        eng.telemetry.observe_round(10, 10)
    eng._policy_tick(0)
    assert eng.spec_k > 1
    eng.generate(_prompts((6, 7), seed=2), max_new_tokens=10, sampling=sp)
    snap = dict(eng.trace_counts)
    eng.generate(_prompts((6, 7), seed=4), max_new_tokens=10, sampling=sp)
    assert eng.trace_counts == snap


def test_tune_spec_k_uplink_includes_framing():
    best, perfs = tune_spec_k(
        edge_flops=1e7, cloud_flops=5e7, draft_flops=5e7, blob_bytes=1000.0,
        edge=EDGE_TX2_CLASS, cloud=CLOUD_TITANXP_CLASS,
        channel=Channel.from_kbps(250, rtt_ms=20), acceptance=1.0,
        ks=(1, 2), rows=1)
    k1 = [p for p in perfs if p.k == 1][0]
    assert k1.uplink_bytes_per_token == pytest.approx(1000.0 + MSG_BYTES)


# ---------------------------------------------------------------------------
# Mid-stream re-partition: drain barrier + bit-exactness
# ---------------------------------------------------------------------------


class ScriptedPolicy:
    """Deterministic stand-in for AdaptivePolicy: returns the current
    config for the first ``after`` decide calls, then the target."""
    k_between_requests_only = False
    cuts = (0, 1)
    ks = (1, 2, 4, 8)

    def __init__(self, after, cut, spec_k):
        self.after = after
        self.target = (cut, spec_k)
        self.calls = 0
        self.history = []

    def decide(self, telemetry, *, cut, spec_k):
        self.calls += 1
        tc, tk = self.target if self.calls > self.after else (cut, spec_k)
        return Decision(cut=tc, spec_k=tk, s_per_token=0.0,
                        current_s_per_token=0.0, bandwidth_bytes_per_s=0.0,
                        rtt_s=0.0, acceptance=1.0)


def _adaptive_engine(params, policy, cut=0, spec_k=1):
    return CollaborativeServingEngine(params, CFG, cut_layer=cut,
                                      max_batch=2, max_len=64,
                                      spec_k=spec_k, policy=policy,
                                      **LOSSLESS_FP)


@pytest.fixture(scope="module")
def fixed_fp_engines(params):
    """Fixed-cut lossless oracles, one per candidate cut."""
    return {c: CollaborativeServingEngine(params, CFG, cut_layer=c,
                                          max_batch=2, max_len=64, spec_k=1,
                                          **LOSSLESS_FP) for c in (0, 1)}


@pytest.fixture(scope="module")
def adaptive_fp_engine(params):
    """One reusable engine whose scripted policy is swapped per test —
    keeps the jit cache warm across examples."""
    eng = _adaptive_engine(params, ScriptedPolicy(10 ** 9, 0, 1))
    return eng


def _reset(eng, policy, cut=0, spec_k=1):
    eng.policy = None
    if eng.cut != cut:
        eng._set_cut(cut, count=False)
    eng.spec_k = spec_k
    eng.policy = policy


def test_mid_stream_cut_switch_drains_then_repartitions(
        params, adaptive_fp_engine, fixed_fp_engines):
    """More requests than slots: the policy flips (cut, k) after a few
    rounds, the scheduler drains the live slots, re-partitions at the
    admission boundary, and the stream is still bit-exact greedy."""
    eng = adaptive_fp_engine
    _reset(eng, ScriptedPolicy(3, 1, 4), cut=0, spec_k=1)
    prompts = _prompts((7, 9, 8, 15, 6), seed=5)
    got = eng.generate(prompts, max_new_tokens=6)
    assert eng.stats.cut_switches >= 1
    assert eng.stats.spec_k_switches >= 1
    assert eng.cut == 1 and eng.spec_k == 4
    ref = fixed_fp_engines[0].generate(prompts, max_new_tokens=6)
    assert got == ref


def test_warm_k_raise_rebuilds_drafts_without_draining(
        params, adaptive_fp_engine, fixed_fp_engines):
    """Raising k out of k=1 with live slots must NOT drain: the draft
    caches — stale after serial k=1 rounds — are rebuilt in place from
    committed prefix state, the stream stays bit-exact greedy, and the
    scheduler never holds admission on a re-partition barrier (the cut
    is unchanged)."""
    eng = adaptive_fp_engine
    _reset(eng, ScriptedPolicy(2, 0, 4), cut=0, spec_k=1)
    base = {f: getattr(eng.stats, f) for f in
            ("spec_k_switches", "draft_rebuilds", "policy_holds",
             "cut_switches")}                # module-scoped engine: deltas
    prompts = _prompts((7, 9, 8, 15), seed=11)
    got = eng.generate(prompts, max_new_tokens=6)
    assert eng.stats.spec_k_switches > base["spec_k_switches"]
    assert eng.spec_k == 4
    assert eng.stats.draft_rebuilds == base["draft_rebuilds"] + 1
    assert eng.stats.policy_holds == base["policy_holds"]  # zero drains paid
    assert eng.stats.cut_switches == base["cut_switches"]
    ref = fixed_fp_engines[0].generate(prompts, max_new_tokens=6)
    assert got == ref


def test_policy_engine_draftless_k1_wire_is_unchanged(params):
    """A policy engine idling at k=1 must charge exactly the serial
    step's bytes (the draft machinery is provisioned but idle)."""
    pol = ScriptedPolicy(10 ** 9, 0, 1)
    eng = _adaptive_engine(params, pol, cut=0, spec_k=1)
    eng.generate(_prompts((6, 6), seed=6), max_new_tokens=4)
    per_step = 2 * (CFG.d_model * 4 + _QP_BYTES) + _MSG_BYTES  # fp blob
    assert eng.stats.decode_bytes_log == [per_step] * 3


# guarded like the rest of the tier-1 property tests
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @settings(max_examples=10, deadline=None)
    @given(switch_after=st.integers(min_value=0, max_value=3),
           new_cut=st.sampled_from([0, 1]),
           k1=st.sampled_from([1, 2, 4]),
           k2=st.sampled_from([1, 4, 8]),
           plens=st.lists(st.integers(min_value=5, max_value=18),
                          min_size=1, max_size=4),
           max_new=st.integers(min_value=2, max_value=7),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_mid_stream_switch_bit_identical_property(
            params, adaptive_fp_engine, fixed_fp_engines, switch_after,
            new_cut, k1, k2, plens, max_new, seed):
        """For any switch round, any target (cut, k), any prompt lengths
        straddling the page boundary, a mid-stream cut-layer + spec_k
        switch commits exactly the fixed-cut greedy stream."""
        eng = adaptive_fp_engine
        _reset(eng, ScriptedPolicy(switch_after, new_cut, k2),
               cut=0, spec_k=k1)
        prompts = _prompts(plens, seed=seed)
        got = eng.generate(prompts, max_new_tokens=max_new)
        ref = fixed_fp_engines[0].generate(prompts, max_new_tokens=max_new)
        assert got == ref
        assert all(len(g) == max_new for g in got)
else:
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_mid_stream_switch_bit_identical_property():
        pass


# ---------------------------------------------------------------------------
# Benchmark-drift guard
# ---------------------------------------------------------------------------


def test_drift_guard_flags_regressions():
    from benchmarks.run import check_drift
    committed = {
        "BENCH_spec_decode.json":
            {"speculative": {"2": {"e2e_speedup_vs_k1": 1.4}}},
        "BENCH_adaptive_serve.json":
            {"adaptive_vs_worst_fixed_e2e_speedup": 1.5},
    }
    ok = {
        "BENCH_spec_decode.json":
            {"speculative": {"2": {"e2e_speedup_vs_k1": 1.0}}},
        "BENCH_adaptive_serve.json":
            {"adaptive_vs_worst_fixed_e2e_speedup": 1.3},
    }
    assert check_drift(committed, ok) == []
    bad = {
        "BENCH_spec_decode.json":
            {"speculative": {"2": {"e2e_speedup_vs_k1": 0.6}}},
        "BENCH_adaptive_serve.json":
            {"adaptive_vs_worst_fixed_e2e_speedup": 1.3},
    }
    fails = check_drift(committed, bad)
    assert len(fails) == 1 and "spec_decode" in fails[0]
    # a file that did not run cannot regress, and an unbaselined metric
    # is skipped — but a *baselined* metric vanishing from a fresh run
    # must fail loudly (renamed keys must not disarm the guard)
    assert check_drift(committed, {}) == []
    assert check_drift({}, bad) == []
    renamed = {
        "BENCH_spec_decode.json": {"speculative": {"2": {}}},
        "BENCH_adaptive_serve.json":
            {"adaptive_vs_worst_fixed_e2e_speedup": 1.5},
    }
    fails = check_drift(committed, renamed)
    assert len(fails) == 1 and "missing from fresh run" in fails[0]
