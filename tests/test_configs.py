"""Config registry + per-arch smoke tests: every assigned architecture
instantiates (reduced config) and runs one real forward/train step on CPU
with finite outputs — the FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (REGISTRY, get_arch, input_specs, list_archs,
                           list_cells)
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.launch.steps import build_cell

jax.config.update("jax_platform_name", "cpu")

ASSIGNED = ["phi3-medium-14b", "deepseek-7b", "qwen3-moe-30b-a3b",
            "grok-1-314b", "flux-dev", "unet-sd15", "deit-b", "vit-s16",
            "vit-h14", "resnet-152"]


def test_registry_has_all_assigned_plus_baselines():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs, a
    for b in ("alexnet", "vgg16", "resnet-18", "googlenet"):
        assert b in archs, b
    assert set(list_archs(assigned_only=True)) == set(ASSIGNED)


def test_exactly_40_cells():
    cells = list_cells()
    assert len(cells) == 40
    per_arch = {}
    for a, s in cells:
        per_arch.setdefault(a, []).append(s)
    assert all(len(v) == 4 for v in per_arch.items().__iter__().__next__()[1:2])
    for a, shapes in per_arch.items():
        assert len(shapes) == 4, (a, shapes)


def test_full_configs_match_assignment_numbers():
    phi3 = get_arch("phi3-medium-14b").full
    assert (phi3.n_layers, phi3.d_model, phi3.n_heads, phi3.n_kv,
            phi3.d_ff, phi3.vocab) == (40, 5120, 40, 10, 17920, 100352)
    qwen = get_arch("qwen3-moe-30b-a3b").full
    assert (qwen.moe.n_experts, qwen.moe.top_k, qwen.d_ff,
            qwen.vocab) == (128, 8, 768, 151936)
    grok = get_arch("grok-1-314b").full
    assert (grok.n_layers, grok.d_model, grok.moe.n_experts,
            grok.moe.top_k) == (64, 6144, 8, 2)
    flux = get_arch("flux-dev").full
    assert (flux.n_double, flux.n_single, flux.d_model,
            flux.n_heads) == (19, 38, 3072, 24)
    r152 = get_arch("resnet-152").full
    assert r152.depths == (3, 8, 36, 3)
    vith = get_arch("vit-h14").full
    assert (vith.n_layers, vith.d_model, vith.patch) == (32, 1280, 14)


def test_input_specs_cover_all_cells():
    for arch, shape in list_cells():
        specs = input_specs(arch, shape)
        assert specs, (arch, shape)
        for k, v in specs.items():
            assert isinstance(v, jax.ShapeDtypeStruct), (arch, shape, k)


@pytest.fixture(scope="module")
def host_mesh():
    return make_host_mesh()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step_runs_and_is_finite(arch, host_mesh):
    """One REAL reduced-config train/serve step per arch on CPU."""
    spec = get_arch(arch)
    shape = next(iter(spec.shapes))           # the family's train shape
    cell = build_cell(arch, shape, host_mesh, smoke=True)
    compiled = cell.lower().compile()

    # materialize concrete inputs from the abstract args
    rng = np.random.RandomState(0)

    def concretize(x):
        if isinstance(x, jax.ShapeDtypeStruct):
            if jnp.issubdtype(x.dtype, jnp.integer):
                # < smallest smoke n_classes/vocab (OOB labels would
                # NaN-fill through take_along_axis)
                return jnp.asarray(
                    rng.randint(0, 8, x.shape).astype(x.dtype))
            # non-negative so optimizer second moments stay valid
            return jnp.asarray(
                np.abs(rng.randn(*x.shape)).astype(x.dtype) * 0.02)
        return x

    def init_like(tree):
        return jax.tree_util.tree_map(concretize, tree)

    with cell.mesh, mesh_context(cell.mesh):
        concrete = jax.tree_util.tree_map(concretize, cell.args,
                                          is_leaf=lambda x: isinstance(
                                              x, jax.ShapeDtypeStruct))
        out = compiled(*concrete)
    flat = jax.tree_util.tree_leaves(out)
    for leaf in flat:
        assert bool(jnp.all(jnp.isfinite(
            leaf.astype(jnp.float32)))), (arch, shape)


def test_sources_are_recorded():
    for a in list_archs():
        assert get_arch(a).source, a
