"""Overload robustness: demand-paged KV growth, preemption with
replay-based resume, deadline-aware admission/shedding, and the
``PressureSchedule`` resource-fault injector.

Covers: the typed ``PoolExhausted`` / hardened ``PageAllocator.free``
and the build-time geometry floor, allocator interleaving invariants
(hypothesis), demand-paged streams matching worst-case-reservation
streams bit for bit with full page reclamation, the headline property —
lossless ``a_bits=None`` streams under any seeded preemption schedule
are bit-identical to the unpreempted streams — priority traffic
surviving 2x pool oversubscription that head-of-line blocks the naive
baseline, deadline-aware shedding, and the ``ServeStats`` accounting
invariants (the simulated clock decomposes exactly into channel latency
plus charged stall waits; every preemption/shed charges once)."""
import jax
import numpy as np
import pytest

from repro.core.costmodel import Channel, PhaseBreakdown, predict_finish_time
from repro.models.transformer import LMConfig, init_lm
from repro.serve import (CollaborativeServingEngine, FaultyChannel,
                         PageAllocator, PoolExhausted, PressureSchedule,
                         Request, ResilientCollaborativeEngine)
from repro.serve.kvcache import _PagedPool

jax.config.update("jax_platform_name", "cpu")

CFG = LMConfig(name="overload-tiny", n_layers=3, d_model=32, n_heads=4,
               n_kv=2, d_ff=64, vocab=64, max_seq=64, remat=False)
PAGE = 8
LOSSLESS = dict(a_bits=None, edge_int8=False, cloud_int8=False,
                page_size=PAGE, max_batch=2, max_len=64)
# 2x oversubscription: 4 slots x 9+40-token worst case wants ~20 usable
# pages; the pool has 10 (plus the reserved dump page)
OVERSUB = dict(a_bits=None, edge_int8=False, cloud_int8=False,
               page_size=PAGE, max_batch=4, max_len=64, num_pages=11)
BASE_CH = Channel.from_kbps(500, rtt_ms=10)
PLENS = (6, 7, 9)
MAX_NEW = 10


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(lens, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab, l).astype(np.int32) for l in lens]


@pytest.fixture(scope="module")
def lossless_pair(params):
    """(worst-case-reservation oracle, demand-paged engine) — reused
    across tests; callers install a fresh channel/schedule per run."""
    ref = CollaborativeServingEngine(params, CFG, cut_layer=1,
                                     channel=FaultyChannel(BASE_CH, seed=0),
                                     **LOSSLESS)
    dut = CollaborativeServingEngine(params, CFG, cut_layer=1,
                                     channel=FaultyChannel(BASE_CH, seed=0),
                                     demand_paged=True, **LOSSLESS)
    return ref, dut


@pytest.fixture(scope="module")
def oracle(lossless_pair):
    ref, _ = lossless_pair
    return ref.generate(_prompts(PLENS), max_new_tokens=MAX_NEW)


def _pressured_run(dut, windows, prompts=None, max_new=MAX_NEW):
    """One seeded run of the demand-paged engine under a pressure
    schedule, leaving the engine reusable (clock reset via a fresh
    channel; any still-held pages released)."""
    dut.channel = FaultyChannel(BASE_CH, seed=0)
    dut.pressure = PressureSchedule(windows)
    try:
        return dut.generate(prompts or _prompts(PLENS),
                            max_new_tokens=max_new)
    finally:
        dut.pressure.apply(dut._pool.allocator, float("inf"))
        dut.pressure = None


# ---------------------------------------------------------------------------
# Hardened allocator + pool geometry
# ---------------------------------------------------------------------------


def test_pool_exhausted_is_typed_and_state_preserving():
    alloc = PageAllocator(4)
    pages = alloc.alloc(2)
    with pytest.raises(PoolExhausted):
        alloc.alloc(2)
    assert isinstance(PoolExhausted("x"), RuntimeError)  # back-compat
    # the failed alloc mutated nothing
    assert alloc.num_free == 1 and set(alloc.live) == set(pages)
    # free of a page the allocator never handed out
    with pytest.raises(ValueError, match="not live"):
        alloc.free([0])
    alloc.free(pages)
    with pytest.raises(ValueError, match="not live"):
        alloc.free([pages[0]])                           # double free
    assert alloc.num_free == 3


def test_pool_build_floor_rejects_impossible_geometry():
    # max_len 64 @ page 8 needs 8 pages/slot + the dump page
    with pytest.raises(ValueError, match="can never admit"):
        _PagedPool.build(2, 64, PAGE, num_pages=8)
    pool = _PagedPool.build(2, 64, PAGE, num_pages=9)    # exactly the floor
    assert pool.allocator.num_free == 8


def test_demand_growth_and_ensure_contract():
    pool = _PagedPool.build(2, 64, PAGE, num_pages=9)
    pool.admit([0], np.asarray([6]), np.asarray([1]), 8)
    assert pool.pages_held(0) == 1
    assert pool.ensure(0, 17) is True                    # 3 pages now
    assert pool.pages_held(0) == 3
    assert pool.ensure(0, 17) is False                   # idempotent
    held = pool.pages_held(0)
    with pytest.raises(PoolExhausted):
        pool.ensure(0, 64 * 2)                           # past the pool
    assert pool.pages_held(0) == held                    # claim untouched
    pool.retire(0)
    assert pool.allocator.num_free == 8


# ---------------------------------------------------------------------------
# PressureSchedule mechanics
# ---------------------------------------------------------------------------


def test_pressure_schedule_squeezes_and_restores():
    alloc = PageAllocator(9)                             # 8 usable
    pr = PressureSchedule([(1.0, 2.0, 3), (1.5, 1.8, 1)])
    assert pr.target_free(0.5) is None
    assert pr.target_free(1.2) == 3
    assert pr.target_free(1.7) == 1                      # tightest wins
    assert pr.next_change(0.0) == 1.0
    assert pr.next_change(1.6) == 1.8
    assert pr.next_change(3.0) is None
    pr.apply(alloc, 0.5)
    assert pr.held_pages == 0 and alloc.num_free == 8
    pr.apply(alloc, 1.2)
    assert pr.held_pages == 5 and alloc.num_free == 3
    pr.apply(alloc, 1.7)
    assert pr.held_pages == 7 and alloc.num_free == 1
    pr.apply(alloc, 1.9)                                 # ceiling rose to 3
    assert pr.held_pages == 5 and alloc.num_free == 3
    pr.apply(alloc, 3.0)                                 # all windows past
    assert pr.held_pages == 0 and alloc.num_free == 8
    # the squeeze can only take what is free: live pages are untouched
    live = alloc.alloc(6)
    pr.apply(alloc, 1.7)
    assert alloc.num_free == 1 and set(live) <= set(alloc.live)
    pr.apply(alloc, 3.0)
    alloc.free(live)
    assert alloc.num_free == 8


# ---------------------------------------------------------------------------
# Demand paging: same streams, fewer resident pages
# ---------------------------------------------------------------------------


def test_demand_paged_stream_matches_worst_case(lossless_pair, oracle):
    _, dut = lossless_pair
    dut.channel = FaultyChannel(BASE_CH, seed=0)
    got = dut.generate(_prompts(PLENS), max_new_tokens=MAX_NEW)
    assert got == oracle
    assert all(len(g) == MAX_NEW for g in got)
    # every page returned to the free list
    a = dut._pool.allocator
    assert a.num_free == a.num_pages - 1 and not a.live


def test_admission_reserves_prompt_not_budget(params):
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1,
                                     demand_paged=True, **LOSSLESS)
    reqs = [Request(uid=0, prompt=_prompts([6])[0], max_new_tokens=30)]
    # drive one admission by hand: after _admit the claim covers the
    # padded prompt (1 page), not the 30-token budget (5 pages)
    import jax.numpy as jnp
    cur = jnp.zeros((2,), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    toks = np.zeros((1, 8), np.int32)
    toks[0, :6] = reqs[0].prompt
    eng._admit(jnp.asarray(toks), np.asarray([6]), np.asarray([30]),
               np.asarray([0]), cur, pos)
    assert eng._pool.pages_held(0) == 1
    eng._retire(0)


# ---------------------------------------------------------------------------
# Preemption: bit-identical resume via cached replay
# ---------------------------------------------------------------------------


def test_preemption_bit_identity_seeded(lossless_pair, oracle):
    _, dut = lossless_pair
    got = _pressured_run(dut, [(0.02, 0.25, 0)])
    assert dut.stats.preemptions >= 1                    # it actually fired
    assert got == oracle                                 # and left no trace


def test_preemption_bit_identity_speculative(params):
    ref = CollaborativeServingEngine(params, CFG, cut_layer=1, spec_k=4,
                                     channel=FaultyChannel(BASE_CH, seed=0),
                                     **LOSSLESS)
    want = ref.generate(_prompts(PLENS), max_new_tokens=MAX_NEW)
    dut = CollaborativeServingEngine(params, CFG, cut_layer=1, spec_k=4,
                                     channel=FaultyChannel(BASE_CH, seed=0),
                                     demand_paged=True,
                                     pressure=PressureSchedule(
                                         [(0.02, 0.3, 1)]),
                                     **LOSSLESS)
    got = dut.generate(_prompts(PLENS), max_new_tokens=MAX_NEW)
    assert dut.stats.preemptions >= 1
    assert got == want


def test_preemption_under_outage_resilient(params):
    """Pressure and a cloud outage together: preemption, edge-only
    degradation, and resume compose without forking the stream."""
    ref = CollaborativeServingEngine(params, CFG, cut_layer=1, spec_k=2,
                                     channel=FaultyChannel(BASE_CH, seed=0),
                                     **LOSSLESS)
    want = ref.generate(_prompts(PLENS), max_new_tokens=MAX_NEW)
    fch = FaultyChannel(BASE_CH, seed=0, outages=[(0.05, 0.2)])
    dut = ResilientCollaborativeEngine(
        params, CFG, cut_layer=1, spec_k=2, channel=fch, demand_paged=True,
        pressure=PressureSchedule([(0.02, 0.3, 0)]), **LOSSLESS)
    got = dut.generate(_prompts(PLENS), max_new_tokens=MAX_NEW)
    assert dut.stats.preemptions >= 1
    assert got == want


# ---------------------------------------------------------------------------
# 2x oversubscription: priority survives, the naive baseline blocks
# ---------------------------------------------------------------------------


def _overload_reqs():
    rng = np.random.RandomState(7)
    mk = lambda: rng.randint(0, CFG.vocab, 9).astype(np.int32)   # noqa: E731
    rs = [Request(uid=i, prompt=mk(), max_new_tokens=40, priority=0)
          for i in range(6)]
    rs += [Request(uid=10 + i, prompt=mk(), max_new_tokens=20, priority=1,
                   arrival_s=0.3, deadline_s=0.3 + 0.9) for i in range(2)]
    return rs


def test_priority_survives_oversubscription(params):
    """The ISSUE's acceptance scenario: at 2x pool oversubscription with
    mixed-priority traffic, the robust engine preempts best-effort work
    and commits every priority-class token before its deadline, while
    the naive worst-case-reservation baseline head-of-line blocks the
    late-arriving priority requests past their deadlines."""
    results = {}
    for name, kw in [("naive", {}),
                     ("robust", dict(demand_paged=True,
                                     admission="deadline"))]:
        eng = CollaborativeServingEngine(
            params, CFG, cut_layer=1,
            channel=FaultyChannel(BASE_CH, seed=0), **OVERSUB, **kw)
        reqs = _overload_reqs()
        eng.generate_requests(reqs)
        results[name] = (eng, reqs)

    naive, nreqs = results["naive"]
    robust, rreqs = results["robust"]
    npri = [r for r in nreqs if r.priority > 0]
    rpri = [r for r in rreqs if r.priority > 0]
    # robust: all priority tokens committed, on time, via preemption
    assert all(len(r.out_tokens) == r.max_new_tokens for r in rpri)
    assert all(r.finish_s <= r.deadline_s for r in rpri)
    assert robust.stats.preemptions >= 1
    assert robust.stats.deadline_misses == 0
    # naive: no preemption machinery, the full-budget reservations of
    # the best-effort wave head-of-line block the priority class
    assert naive.stats.preemptions == 0
    assert all(r.finish_s > r.deadline_s for r in npri)
    assert naive.stats.deadline_misses == len(npri)
    assert max(r.admit_s for r in rpri) < min(r.admit_s for r in npri)
    # and preemption starved nobody: best-effort still completes fully
    for _, reqs in results.values():
        assert all(len(r.out_tokens) == r.max_new_tokens
                   for r in reqs if r.priority == 0)
    # identical traffic, identical streams — robustness is scheduling,
    # not output drift (lossless mode)
    assert [r.out_tokens for r in rreqs] == [r.out_tokens for r in nreqs]


def test_deadline_shedding(params):
    eng = CollaborativeServingEngine(params, CFG, cut_layer=1,
                                     channel=FaultyChannel(BASE_CH, seed=0),
                                     demand_paged=True, admission="deadline",
                                     **LOSSLESS)
    ps = _prompts((6, 7, 6))
    reqs = [Request(uid=0, prompt=ps[0], max_new_tokens=8, deadline_s=1e9),
            Request(uid=1, prompt=ps[1], max_new_tokens=8, deadline_s=1e-6),
            Request(uid=2, prompt=ps[2], max_new_tokens=8)]  # no deadline
    outs = eng.generate_requests(reqs)
    assert reqs[1].shed and reqs[1].done and outs[1] == []
    assert reqs[1].admit_s is None and reqs[1].finish_s is None
    assert not reqs[0].shed and len(outs[0]) == 8
    assert not reqs[2].shed and len(outs[2]) == 8        # never shed
    assert eng.stats.shed == 1
    # a shed request is not a deadline miss — it never entered service
    assert eng.stats.deadline_misses == 0


def test_predict_finish_time_shape():
    rd = PhaseBreakdown(prefill_s=0.0, decode_s=0.1, channel_s=0.05,
                        tokens=2.0)
    t0 = predict_finish_time(rd, now=1.0, max_new=8)     # 4 rounds
    assert t0 == pytest.approx(1.0 + 4 * rd.total_s)
    # queued work drains across slots ahead of this request
    t1 = predict_finish_time(rd, now=1.0, max_new=8, queue_tokens=16.0,
                             slots=2)
    assert t1 == pytest.approx(t0 + 4 * rd.total_s)
    # prefill shifts the whole schedule
    t2 = predict_finish_time(rd, now=1.0, max_new=8, prefill_s=0.5)
    assert t2 == pytest.approx(t0 + 0.5)


# ---------------------------------------------------------------------------
# ServeStats accounting invariants
# ---------------------------------------------------------------------------


def test_stats_clock_decomposition_and_counters(params):
    """In a fault-free clocked run the simulated clock advances only
    through transfers and charged waits: ``clock_s`` must equal
    ``channel_latency_s + stall_wait_s`` exactly, and per-request
    preemption counts must sum to the engine counter."""
    fch = FaultyChannel(BASE_CH, seed=0)
    eng = CollaborativeServingEngine(
        params, CFG, cut_layer=1, channel=fch, demand_paged=True,
        pressure=PressureSchedule([(0.02, 0.25, 0)]), **LOSSLESS)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=MAX_NEW,
                    arrival_s=0.05 * i)
            for i, p in enumerate(_prompts(PLENS))]
    eng.generate_requests(reqs)
    st = eng.stats
    assert st.preemptions >= 1
    assert st.preemptions == sum(r.preemptions for r in reqs)
    assert fch.clock_s == pytest.approx(
        st.channel_latency_s + st.stall_wait_s, rel=1e-9)
    assert st.stall_wait_s > 0                           # waits were charged
    assert st.queue_wait_s > 0                           # preempts re-queued
    assert st.shed == 0 and st.deadline_misses == 0
    for r in reqs:
        assert r.finish_s >= r.admit_s >= r.arrival_s
    rep = st.report()
    for key in ("preemptions", "shed", "deadline_misses", "queue_wait_s",
                "stall_wait_s"):
        assert key in rep


# ---------------------------------------------------------------------------
# Property tests (guarded like the rest of tier 1)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.booleans(), st.integers(0, 5)),
                    max_size=60))
    def test_allocator_interleaving_property(ops):
        """Any alloc/free interleaving keeps the free list and the live
        set exact complements: no page leaks, none is handed out twice,
        and a failed alloc mutates nothing."""
        alloc = PageAllocator(17)
        held = []
        for is_alloc, n in ops:
            if is_alloc:
                if n > alloc.num_free:
                    before = (alloc.num_free, set(alloc.live))
                    with pytest.raises(PoolExhausted):
                        alloc.alloc(n)
                    assert (alloc.num_free, set(alloc.live)) == before
                else:
                    held.extend(alloc.alloc(n))
            elif held:
                alloc.free([held.pop() for _ in range(min(n, len(held)))])
            assert len(held) == len(set(held))
            assert set(held) == set(alloc.live)
            assert alloc.num_free == 16 - len(held)
            assert all(1 <= p < 17 for p in held)
        if held:
            p = held[0]
            alloc.free([p])
            before = (alloc.num_free, set(alloc.live))
            with pytest.raises(ValueError):
                alloc.free([p])                          # double free
            assert (alloc.num_free, set(alloc.live)) == before

    @settings(max_examples=8, deadline=None)
    @given(windows=st.lists(
        st.tuples(st.floats(0.0, 0.4), st.floats(0.05, 0.5),
                  st.integers(0, 2)),
        min_size=1, max_size=2))
    def test_preemption_schedule_bit_identity_property(
            windows, lossless_pair, oracle):
        """The headline property: under ANY pressure schedule the
        lossless greedy streams are bit-identical to the unpreempted
        oracle — preemption/resume is invisible in the output."""
        _, dut = lossless_pair
        got = _pressured_run(dut, [(t0, t0 + dur, n)
                                   for t0, dur, n in windows])
        assert got == oracle
else:
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_allocator_interleaving_property():
        pass

    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_preemption_schedule_bit_identity_property():
        pass
