"""The divisibility-guarded sharding rules and the TP'd cost model.

``launch.shardings`` promises every rule is guarded: a dim that does
not divide its mesh axes stays unsharded rather than letting GSPMD pad.
The property tests sweep (mesh shape x tensor shape x param role) with
a duck-typed FakeMesh — ``jax.make_mesh`` cannot build arbitrary shapes
on one device, and the rules only ever read ``shape``/``axis_names`` —
and check, for every sharded dim of every produced spec, exact
divisibility by the product of the axes it is split over.

The cost-model half pins the tentpole's policy behavior: the per-layer
TP all-reduce term is zero for a 1-chip cloud, scales with mesh size
and activation bytes, and on a crafted grid the jointly tuned cut
moves edge-ward as the cloud mesh grows — more cloud parallelism makes
cloud layers cheap relative to the (now mesh-taxed) channel, so the
tuner hands the cloud more of the network.
"""
import dataclasses

import pytest
from jax.sharding import PartitionSpec as P

from repro.core.autotune import tune_cut_and_k
from repro.core.costmodel import (CLOUD_TITANXP_CLASS, EDGE_TX2_CLASS,
                                  Channel, DeviceModel, _tp_allreduce_s,
                                  speculative_round_time)
from repro.launch.shardings import (cache_spec, paged_pool_spec,
                                    paged_scale_spec, spec_for_param)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None


class FakeMesh:
    """Duck-typed stand-in for ``jax.sharding.Mesh``: the sharding rules
    only read ``.shape`` (a name->size mapping) and ``.axis_names``."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def _axis_sizes(mesh, entry):
    axes = entry if isinstance(entry, tuple) else (entry,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _assert_divisible(spec, shape, mesh, where=""):
    assert len(spec) <= len(shape), (spec, shape)
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        assert dim % _axis_sizes(mesh, entry) == 0, \
            f"{where}: dim {dim} split over {entry} of {mesh.shape}"


if st is not None:
    MESHES = st.builds(
        lambda d, m: FakeMesh(data=d, model=m),
        st.sampled_from([1, 2, 3, 4, 8]), st.sampled_from([1, 2, 3, 4, 8]))
    DIMS = st.sampled_from([1, 2, 3, 4, 6, 8, 16, 24, 64])

    @settings(max_examples=200, deadline=None)
    @given(mesh=MESHES, d_in=DIMS, d_out=DIMS,
           path=st.sampled_from(["blocks/attn/wq", "blocks/attn/wo",
                                 "blocks/mlp/wi", "emb", "lm_head/out",
                                 "final_norm/scale"]),
           stacked=st.booleans(), zero1=st.booleans())
    def test_param_specs_always_divide(mesh, d_in, d_out, path, stacked,
                                       zero1):
        shape = (3, d_in, d_out) if stacked and path.startswith("blocks") \
            else (d_in, d_out)
        spec = spec_for_param(path, shape, mesh, zero1=zero1)
        if stacked and path.startswith("blocks"):
            assert spec[0] is None          # scan layer axis never sharded
        _assert_divisible(spec, shape, mesh, path)

    @settings(max_examples=200, deadline=None)
    @given(mesh=MESHES, batch=DIMS, seq=DIMS, n_kv=DIMS,
           head_dim=st.sampled_from([4, 8, 64, 128]))
    def test_cache_and_pool_specs_always_divide(mesh, batch, seq, n_kv,
                                                head_dim):
        dense = cache_spec(mesh, batch=batch, seq=seq, n_kv=n_kv,
                           head_dim=head_dim)
        _assert_divisible(dense, (3, batch, seq, n_kv, head_dim), mesh,
                          "dense cache")
        n_pages, page = seq, 8
        pool = paged_pool_spec(mesh, n_pages=n_pages, n_kv=n_kv,
                               head_dim=head_dim)
        _assert_divisible(pool, (3, n_pages, page, n_kv, head_dim), mesh,
                          "paged pool")
        # the pool's guarded dims are exactly kv-heads (TP) and pages
        # (data); the page payload [page_size, head_dim] is the DMA unit
        assert pool[0] is None and pool[2] is None and pool[4] is None
        scale = paged_scale_spec(mesh, batch=batch, n_kv=n_kv)
        _assert_divisible(scale, (3, batch, n_kv), mesh, "pool scales")
else:
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_param_specs_always_divide():
        pass

    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_cache_and_pool_specs_always_divide():
        pass


def test_pool_replicates_heads_when_tp_does_not_divide():
    mesh = FakeMesh(data=2, model=4)
    spec = paged_pool_spec(mesh, n_pages=33, n_kv=2, head_dim=64)
    # 2 kv heads cannot split 4 ways; head_dim must NOT pick up the
    # slack (splitting it tears the per-head gather apart — see the
    # rule's docstring), and 33 pages don't divide data=2 either: the
    # whole pool replicates
    assert spec == P(None, None, None, None, None)
    assert paged_pool_spec(mesh, n_pages=32, n_kv=8, head_dim=64) == \
        P(None, "data", None, "model", None)


# ---------------------------------------------------------------------------
# Cost model: the TP all-reduce term and the mesh-driven cut shift
# ---------------------------------------------------------------------------


def test_tp_allreduce_term_zero_without_a_mesh():
    assert _tp_allreduce_s(CLOUD_TITANXP_CLASS, 4, 1e6) == 0.0   # 1 chip
    meshed = dataclasses.replace(CLOUD_TITANXP_CLASS, n_chips=4)
    assert _tp_allreduce_s(meshed, 4, 1e6) == 0.0                # no link
    linked = dataclasses.replace(meshed, link_bw=1e9)
    assert _tp_allreduce_s(linked, 0, 1e6) == 0.0                # no layers
    # ring all-reduce: 2 ARs/block x 2(n-1)/n x bytes/link
    t = _tp_allreduce_s(linked, 3, 1e6)
    assert t == pytest.approx(2 * 3 * (2 * 3 / 4) * 1e6 / 1e9)
    # grows with the mesh (toward the 2x asymptote) and with the bytes
    assert _tp_allreduce_s(dataclasses.replace(linked, n_chips=8),
                           3, 1e6) > t
    assert _tp_allreduce_s(linked, 3, 2e6) == pytest.approx(2 * t)


def test_verify_round_pays_k_times_the_allreduce_bytes():
    linked = dataclasses.replace(CLOUD_TITANXP_CLASS, n_chips=4,
                                 link_bw=1e9)
    kw = dict(edge_flops=1e7, cloud_flops=4e7, draft_flops=4e7,
              blob_bytes=128.0, edge=EDGE_TX2_CLASS,
              channel=Channel.from_kbps(10_000), acceptance=1.0,
              cloud_layers=3, cloud_act_bytes=4096.0)
    k4 = speculative_round_time(k=4, cloud=linked, **kw)
    k4_flat = speculative_round_time(k=4, cloud=linked,
                                     **dict(kw, cloud_act_bytes=0.0))
    # the verify acts are [B, k, D]: k=4 moves 4x the k=1 AR bytes
    assert k4.decode_s - k4_flat.decode_s == pytest.approx(
        _tp_allreduce_s(linked, 3, 4 * 4096.0))


def test_bigger_cloud_mesh_shifts_best_cut_edgeward():
    """The tentpole's policy consequence, discovered from the joint
    grid: with a deliberately weak single cloud chip behind a fast
    link, small meshes keep the cut deep (tiny edge prefix, the cloud
    carries little); scaling the cloud mesh makes cloud FLOPs cheap
    while the per-layer all-reduce taxes each cloud block only mildly,
    so the tuner hands the cloud the whole network — cut 0."""
    from repro.models.transformer import LMConfig

    cfg = LMConfig(name="cutshift", n_layers=8, d_model=32, n_heads=4,
                   n_kv=2, d_ff=64, vocab=64, max_seq=64, remat=False)
    edge = dataclasses.replace(EDGE_TX2_CLASS, peak_ops_int8=1e9,
                               launch_overhead_s=0.0)
    cloud1 = DeviceModel(name="tpu-sim", peak_flops_fp32=0.5e9,
                         peak_ops_int8=0.5e9, dram_bw=1e12,
                         launch_overhead_s=0.0, n_chips=1, link_bw=1e8)
    ch = Channel.from_kbps(100_000)
    best = {}
    for n in (1, 2, 4, 8):
        cut, _ = tune_cut_and_k(cfg, batch=1, channel=ch,
                                cuts=range(cfg.n_layers - 1),
                                acceptance=0.9, edge=edge,
                                cloud=cloud1.scaled(n), ks=(1, 2, 4, 8))
        best[n] = cut.cut
    assert best[1] == best[2] == 6, best
    assert best[4] == best[8] == 0, best
    assert best[4] < best[1], best
